"""Regenerate the checked-in fused-kernel autotune cache for the DS-CIM
serving decode shapes (src/repro/kernels/autotune_cache.json).

Covers the skinny-M GEMV tiles the serving hot path hits — the per-token
decode matmuls of the reduced serve configs (M=1, request batch riding the
batch grid axis: MLP gate/up/down, LM head, and the '+attn' projections)
plus the decode-shape microbench GEMVs (M in {1, 8, 16}) — for the two
calibrated macro variants the serve/bench paths use (DS-CIM1/L256,
DS-CIM2/L64) — and, since ISSUE 5, the **paged-attention decode cells**
(kernels/paged_attention.py ``(gh, qp)`` winners: GQA head grouping x
padded q rows) for the serving KV geometry at page_size in {4, 8, 16}.
With the cache checked in, cold-start serving with ``--tune`` (or
``REPRO_DSCIM_TUNE=1``) is a dictionary lookup, never a re-tune; unlisted
shapes still sweep once and land in the ``REPRO_AUTOTUNE_CACHE``-pointed
file if set.

Run from the repo root:  PYTHONPATH=src python -m benchmarks.autotune_serving
Only re-time the paged-attention keys (fused winners kept):
                 PYTHONPATH=src python -m benchmarks.autotune_serving --paged-only
"""
from __future__ import annotations

import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# write winners straight into the packaged cache
os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
    REPO, "src", "repro", "kernels", "autotune_cache.json")

# (K, N) of the per-token serving matmuls for the reduced serve configs
# (d_model=64, d_ff=96, vocab_padded=128, 4x16 q heads / 2x16 kv heads):
# MLP gate/up + down, LM head, attention q/o and k/v projections.
SERVE_KN = ((64, 96), (96, 64), (64, 128), (64, 64), (64, 32))
# mesh serving: inside dscim_fused_mvm_sharded's shard_map each device
# tunes for its *local* N = N/nshard — cover the --mesh model={4,8} sizes
# the tests/CI use, so mesh cold starts are lookups too
MESH_NSHARD = (4, 8)
SERVE_BATCHES = (1, 4, 8)          # request batch = the batch grid axis, M=1
BENCH_SHAPES = ((1, 1, 512, 128), (1, 8, 512, 128), (1, 16, 512, 128))
GROUP_K = 128                      # DSCIMLinear serving default granularity

# paged-attention decode cells: (B, KV, n_rep, HD) of the reduced serve
# config (qwen3-0.6b: 2 kv heads x 2-way GQA x hd 16) at the request
# batches serving/CI hit (incl. the DP-sharded locals B/dp) x the
# supported page sizes
PAGED_QSHAPES = tuple((b, 2, 2, 16) for b in (1, 2, 3, 4, 8))
PAGED_PAGE_SIZES = (4, 8, 16)


def serve_kn() -> list:
    """Full-N pairs plus their model-sharded local-N variants (deduped)."""
    kn = set(SERVE_KN)
    for (k, n) in SERVE_KN:
        for s in MESH_NSHARD:
            if n % s == 0:
                kn.add((k, n // s))
    return sorted(kn)


def tune_paged(autotune) -> int:
    """Time the paged-attention cell candidates for the serving shapes."""
    n = 0
    for (B, KV, R, HD) in PAGED_QSHAPES:
        for ps in PAGED_PAGE_SIZES:
            t0 = time.time()
            win = autotune.paged_attn_tiles((B, KV, R, HD), ps,
                                            interpret=True)
            print(f"paged_attn B{B} kv{KV}r{R}hd{HD} ps{ps} -> gh,qp={win} "
                  f"({time.time() - t0:.1f}s)", flush=True)
            n += 1
    return n


def _drop_paged_keys(path: str) -> None:
    """--paged-only re-times the paged winners without touching the fused
    ones: strip just the paged_attn/* keys so ``best`` re-sweeps them
    (DEFAULT_CACHE is the very file being written)."""
    import json
    if not os.path.exists(path):
        return
    with open(path) as f:
        data = json.load(f)
    data = {k: v for k, v in data.items() if not k.startswith("paged_attn/")}
    with open(path, "w") as f:
        json.dump(data, f, indent=0, sort_keys=True)


def main(argv=None):
    from repro.core.seed_search import calibrated_config
    from repro.kernels import autotune

    argv = sys.argv[1:] if argv is None else argv
    if "--paged-only" in argv:
        _drop_paged_keys(autotune.DEFAULT_CACHE)
        autotune.clear()
        n = tune_paged(autotune)
        print(f"# {n} paged keys -> {os.environ['REPRO_AUTOTUNE_CACHE']}")
        return 0

    # a *re*generation must re-time: drop the existing packaged winners
    # first, or autotune.best would read them back (DEFAULT_CACHE is the
    # very file being written) and never sweep the current candidate sets
    if os.path.exists(autotune.DEFAULT_CACHE):
        os.remove(autotune.DEFAULT_CACHE)
    autotune.clear()

    shapes = [(b, 1, k, n) for b in SERVE_BATCHES for (k, n) in serve_kn()]
    shapes += list(BENCH_SHAPES)
    rows = []
    for variant, length in (("dscim1", 256), ("dscim2", 64)):
        cfg = calibrated_config(variant, length, "paper")
        for (B, M, K, N) in shapes:
            # g of the prepared serve weight: prepare_linear_weight pads K
            # up to a whole number of group_k windows, so g is always 128
            t0 = time.time()
            win = autotune.fused_tiles((B, M, K, N), cfg, GROUP_K,
                                       interpret=True, bits="float32")
            rows.append((variant, length, B, M, K, N, win,
                         time.time() - t0))
            print(f"{variant}/L{length} B{B} {M}x{K}x{N} -> bm,bn,bk={win} "
                  f"({rows[-1][-1]:.1f}s)", flush=True)
    nrows = len(rows) + tune_paged(autotune)
    print(f"# {nrows} keys -> {os.environ['REPRO_AUTOTUNE_CACHE']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
