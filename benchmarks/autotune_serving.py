"""Regenerate the checked-in fused-kernel autotune cache for the DS-CIM
serving decode shapes (src/repro/kernels/autotune_cache.json).

Covers the skinny-M GEMV tiles the serving hot path hits — the per-token
decode matmuls of the reduced serve configs (M=1, request batch riding the
batch grid axis: MLP gate/up/down, LM head, and the '+attn' projections)
plus the decode-shape microbench GEMVs (M in {1, 8, 16}) — for the two
calibrated macro variants the serve/bench paths use (DS-CIM1/L256,
DS-CIM2/L64).  With the cache checked in, cold-start serving with
``--tune`` (or ``REPRO_DSCIM_TUNE=1``) is a dictionary lookup, never a
re-tune; unlisted shapes still sweep once and land in the
``REPRO_AUTOTUNE_CACHE``-pointed file if set.

Run from the repo root:  PYTHONPATH=src python -m benchmarks.autotune_serving
"""
from __future__ import annotations

import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# write winners straight into the packaged cache
os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
    REPO, "src", "repro", "kernels", "autotune_cache.json")

# (K, N) of the per-token serving matmuls for the reduced serve configs
# (d_model=64, d_ff=96, vocab_padded=128, 4x16 q heads / 2x16 kv heads):
# MLP gate/up + down, LM head, attention q/o and k/v projections.
SERVE_KN = ((64, 96), (96, 64), (64, 128), (64, 64), (64, 32))
# mesh serving: inside dscim_fused_mvm_sharded's shard_map each device
# tunes for its *local* N = N/nshard — cover the --mesh model={4,8} sizes
# the tests/CI use, so mesh cold starts are lookups too
MESH_NSHARD = (4, 8)
SERVE_BATCHES = (1, 4, 8)          # request batch = the batch grid axis, M=1
BENCH_SHAPES = ((1, 1, 512, 128), (1, 8, 512, 128), (1, 16, 512, 128))
GROUP_K = 128                      # DSCIMLinear serving default granularity


def serve_kn() -> list:
    """Full-N pairs plus their model-sharded local-N variants (deduped)."""
    kn = set(SERVE_KN)
    for (k, n) in SERVE_KN:
        for s in MESH_NSHARD:
            if n % s == 0:
                kn.add((k, n // s))
    return sorted(kn)


def main():
    from repro.core.seed_search import calibrated_config
    from repro.kernels import autotune

    # a *re*generation must re-time: drop the existing packaged winners
    # first, or autotune.best would read them back (DEFAULT_CACHE is the
    # very file being written) and never sweep the current candidate sets
    if os.path.exists(autotune.DEFAULT_CACHE):
        os.remove(autotune.DEFAULT_CACHE)
    autotune.clear()

    shapes = [(b, 1, k, n) for b in SERVE_BATCHES for (k, n) in serve_kn()]
    shapes += list(BENCH_SHAPES)
    rows = []
    for variant, length in (("dscim1", 256), ("dscim2", 64)):
        cfg = calibrated_config(variant, length, "paper")
        for (B, M, K, N) in shapes:
            # g of the prepared serve weight: prepare_linear_weight pads K
            # up to a whole number of group_k windows, so g is always 128
            t0 = time.time()
            win = autotune.fused_tiles((B, M, K, N), cfg, GROUP_K,
                                       interpret=True, bits="float32")
            rows.append((variant, length, B, M, K, N, win,
                         time.time() - t0))
            print(f"{variant}/L{length} B{B} {M}x{K}x{N} -> bm,bn,bk={win} "
                  f"({rows[-1][-1]:.1f}s)", flush=True)
    print(f"# {len(rows)} keys -> {os.environ['REPRO_AUTOTUNE_CACHE']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
