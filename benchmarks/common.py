"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax


def timed(fn, *args, n: int = 3, warmup: int = 1):
    """Median wall time (us) of a jax callable (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
