"""Fig. 6(c): OR-accumulation error vs product sparsity.

Conventional S-CIM (independent PRNGs per row, [27]) saturates as sparsity
drops; DS-CIM's remapped OR is collision-free at every sparsity, with a
uniform error floor — the paper's core qualitative claim.
"""
from __future__ import annotations

import numpy as np

from repro.core.macro import DSCIMMacro
from repro.core.ormac import naive_or_count
from repro.core.seed_search import calibrated_config


def run(H: int = 128, L: int = 256, n_trials: int = 8):
    """Sweep input magnitude (=> product sparsity) and measure relative
    error of OR-accumulated vs exact sums, both circuits."""
    rng = np.random.default_rng(0)
    mac = DSCIMMacro(calibrated_config("dscim1", L, "paper"))
    rows = []
    for level in (16, 48, 96, 160, 224, 255):   # activation magnitude cap
        err_naive, err_ds = [], []
        for t in range(n_trials):
            a = rng.integers(0, level + 1, H)
            w = rng.integers(0, level + 1, H)
            # conventional: unsigned OR-MAC16, independent streams
            or_c, _ = naive_or_count(a, w, L=L, group=16, seed=t)
            exact_p = float((a * w).sum()) / 65536 * L   # expected sum of 1s
            err_naive.append(abs(or_c - exact_p) / max(L, 1))
            # DS-CIM: estimate of the same unsigned sum via remapped OR
            x = (a.astype(np.int64) - 128)[None, :]
            wm = (w.astype(np.int64) - 128)[:, None]
            est = float(np.asarray(mac.mvm(x, wm))[0, 0])
            exact = float((x * wm.T).sum())
            err_ds.append(abs(est - exact) / (H * 255 * 255))
        sparsity = 1.0 - (level / 255.0 / 2) ** 2
        rows.append({
            "name": f"fig6c/level{level}",
            "product_sparsity": round(sparsity, 3),
            "naive_or_err": float(np.mean(err_naive)),
            "dscim_err_pct": 100 * float(np.mean(err_ds)),
        })
    return rows


def main():
    rows = run()
    for r in rows:
        print(f"{r['name']},0,sparsity={r['product_sparsity']};"
              f"naive={r['naive_or_err']:.4f};dscim={r['dscim_err_pct']:.3f}%")
    # headline check: naive error grows >3x from sparse to dense; DS-CIM ~flat
    lo, hi = rows[0], rows[-1]
    print(f"fig6c/summary,0,naive_growth={hi['naive_or_err']/max(lo['naive_or_err'],1e-9):.1f}x;"
          f"dscim_growth={hi['dscim_err_pct']/max(lo['dscim_err_pct'],1e-9):.1f}x")


if __name__ == "__main__":
    main()
