"""Kernel microbenches: DS-CIM bitstream-matmul kernel vs exact int8 matmul
(interpret mode on CPU — correctness-grade timing; TPU roofline terms are
derived analytically from the kernel's tile structure and reported as
`derived`)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.core.seed_search import calibrated_config
from repro.kernels import ops

# v5e constants
PEAK = 197e12
HBM = 819e9


def kernel_roofline(M, K, N, L, k):
    """Analytic TPU roofline for the dscim_mvm kernel: HBM traffic is
    int8 operands + f32 out; MXU work is the L-expanded bitstream matmul."""
    flops = 2.0 * M * N * K * L
    byts = M * K + K * N + 4 * M * N
    t_c = flops / PEAK
    t_m = byts / HBM
    return t_c, t_m, ("compute" if t_c > t_m else "memory"), flops / byts


def run():
    from repro.kernels.dscim_mvm_blocked import (block_point_tables,
                                                 dscim_counts_blocked)
    rows = []
    rng = np.random.default_rng(0)
    for (M, K, N) in [(128, 256, 128)]:
        x = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int8)
        w = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int8)
        us_exact = timed(lambda: ops.int8_matmul(x, w), n=3)
        rows.append({
            "name": f"kernel/int8_matmul/{M}x{K}x{N}", "us": us_exact,
            "derived": "interpret-mode;tpu_t_comp=%.2e" % (
                2.0 * M * N * K / PEAK)})
        for variant, L in (("dscim1", 256), ("dscim2", 64)):
            cfg = calibrated_config(variant, L, "paper")
            us = timed(lambda: ops.dscim_mvm(x, w, cfg), n=2)
            t_c, t_m, dom, ai = kernel_roofline(M, K, N, L, cfg.k)
            rows.append({
                "name": f"kernel/dscim_mvm/{variant}/L{L}/{M}x{K}x{N}",
                "us": us,
                "derived": (f"tpu_t_comp={t_c:.2e}s;tpu_t_mem={t_m:.2e}s;"
                            f"dom={dom};AI={ai:.0f}flops/B")})
            # beyond-paper blocked-points kernel (§Perf cell C)
            _, _, pmax = block_point_tables(cfg)
            us_b = timed(lambda: dscim_counts_blocked(x, w, cfg, bk=16), n=2)
            t_cb, t_mb, domb, aib = kernel_roofline(M, K, N, pmax, cfg.k)
            rows.append({
                "name": f"kernel/dscim_blocked/{variant}/L{L}/{M}x{K}x{N}",
                "us": us_b,
                "derived": (f"pmax={pmax};mxu_reduction={L/pmax:.1f}x;"
                            f"tpu_t_comp={t_cb:.2e}s;"
                            f"overhead_vs_exact={pmax:.0f}x")})
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us']:.0f},{r['derived']}")


if __name__ == "__main__":
    main()
