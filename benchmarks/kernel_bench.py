"""Kernel microbenches: DS-CIM bitstream-matmul kernels vs exact int8 matmul
(interpret mode on CPU — correctness-grade timing; TPU roofline terms are
derived analytically from the kernel's tile structure and reported as
`derived`).

Headline A/B rows (ISSUE 1 acceptance):
  * fused single-launch kernel vs the staged per-window vmap path it
    replaced, with the removed HBM traffic (the (M, nw, N) psum round-trip)
    reported in the derived roofline fields;
  * bf16 vs f32 bit-expansion operands inside the fused kernel.

Prepare-once rows (ISSUE 2): fused MVM with prepared (resident int8)
weights vs per-call weight quantization at serve decode shapes (M=1..16) —
the derived fields record the float-weight HBM reads and quantization work
the prepared path removes from every decode step.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core.seed_search import calibrated_config
from repro.kernels import ops

# v5e constants
PEAK = 197e12
HBM = 819e9


def kernel_roofline(M, K, N, L, k):
    """Analytic TPU roofline for the dscim_mvm kernel: HBM traffic is
    int8 operands + f32 out; MXU work is the L-expanded bitstream matmul."""
    flops = 2.0 * M * N * K * L
    byts = M * K + K * N + 4 * M * N
    t_c = flops / PEAK
    t_m = byts / HBM
    return t_c, t_m, ("compute" if t_c > t_m else "memory"), flops / byts


def fused_hbm_terms(M, K, N, nw):
    """HBM bytes of the fused single-launch path vs the staged vmap path.

    Fused: int8 operands + per-window scale vectors + one f32 output.
    Staged: same operands, plus the (M, nw, N) f32 psum written by the
    per-window kernel launches and re-read (twice: corrections pass and
    dequant einsum) — the round-trip the fusion removes.
    """
    operands = M * K + K * N + 4 * (M * nw + nw * N)
    fused = operands + 4 * M * N
    psum_roundtrip = 3 * 4 * M * nw * N          # write + 2 reads
    staged = operands + 4 * M * N + psum_roundtrip
    return fused, staged, psum_roundtrip


def run(smoke: bool = False):
    from repro.kernels.dscim_fused import (dscim_fused_mvm,
                                           dscim_windowed_vmap_mvm)
    from repro.kernels.dscim_mvm_blocked import (block_point_tables,
                                                 dscim_counts_blocked)
    rows = []
    rng = np.random.default_rng(0)
    shapes = [(32, 128, 32)] if smoke else [(128, 256, 128)]
    reps = 1 if smoke else 2
    for (M, K, N) in shapes:
        x = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int8)
        w = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int8)
        us_exact = timed(lambda: ops.int8_matmul(x, w), n=reps)
        rows.append({
            "name": f"kernel/int8_matmul/{M}x{K}x{N}", "us": us_exact,
            "derived": "interpret-mode;tpu_t_comp=%.2e" % (
                2.0 * M * N * K / PEAK)})
        for variant, L in (("dscim1", 256), ("dscim2", 64)):
            cfg = calibrated_config(variant, L, "paper")
            us = timed(lambda: ops.dscim_mvm(x, w, cfg), n=reps)
            t_c, t_m, dom, ai = kernel_roofline(M, K, N, L, cfg.k)
            rows.append({
                "name": f"kernel/dscim_mvm/{variant}/L{L}/{M}x{K}x{N}",
                "us": us,
                "derived": (f"tpu_t_comp={t_c:.2e}s;tpu_t_mem={t_m:.2e}s;"
                            f"dom={dom};AI={ai:.0f}flops/B")})
            # beyond-paper blocked-points kernel (§Perf cell C)
            _, _, pmax = block_point_tables(cfg)
            us_b = timed(lambda: dscim_counts_blocked(
                x, w, cfg, bm=min(128, M), bn=min(128, N), bk=16), n=reps)
            t_cb, t_mb, domb, aib = kernel_roofline(M, K, N, pmax, cfg.k)
            rows.append({
                "name": f"kernel/dscim_blocked/{variant}/L{L}/{M}x{K}x{N}",
                "us": us_b,
                "derived": (f"pmax={pmax};mxu_reduction={L/pmax:.1f}x;"
                            f"tpu_t_comp={t_cb:.2e}s;"
                            f"overhead_vs_exact={pmax:.0f}x")})

    # --- fused single-launch vs staged per-window vmap (ISSUE 1) ----------
    M, K, N = (32, 128, 32) if smoke else (128, 512, 128)
    group_k = 128
    nw = -(-K // group_k)
    cfg = calibrated_config("dscim1", 256, "paper")
    xf = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)
    wf = jnp.asarray(rng.normal(0, 1, (K, N)), jnp.float32)
    us_staged = timed(lambda: dscim_windowed_vmap_mvm(
        xf, wf, cfg, group_k=group_k), n=reps)
    us_fused = timed(lambda: dscim_fused_mvm(
        xf, wf, cfg, group_k=group_k), n=reps)
    hbm_fused, hbm_staged, psum_rt = fused_hbm_terms(M, K, N, nw)
    shared = (f"g{group_k};nw={nw};hbm_fused={hbm_fused}B;"
              f"hbm_staged={hbm_staged}B;psum_roundtrip_removed={psum_rt}B;"
              f"tpu_t_mem_fused={hbm_fused / HBM:.2e}s;"
              f"tpu_t_mem_staged={hbm_staged / HBM:.2e}s")
    rows.append({
        "name": f"kernel/dscim_staged_vmap/dscim1/L256/{M}x{K}x{N}",
        "us": us_staged,
        "derived": f"launches={nw};{shared}"})
    rows.append({
        "name": f"kernel/dscim_fused/dscim1/L256/{M}x{K}x{N}",
        "us": us_fused,
        "derived": (f"launches=1;speedup_vs_staged={us_staged / us_fused:.2f}x;"
                    f"{shared}")})

    # --- prepared (quantize-once) weights vs per-call quantization --------
    # serve decode shapes: tiny M, weight-dominated — exactly where per-call
    # weight requantization burns the most relative time/traffic
    from repro.core.qweights import prepare_linear_weight
    from repro.kernels.dscim_fused import dscim_fused_mvm_prepared
    Kd, Nd = (128, 64) if smoke else (512, 128)
    for Md in ([1] if smoke else [1, 8, 16]):
        xd = jnp.asarray(rng.normal(0, 1, (Md, Kd)), jnp.float32)
        wd = jnp.asarray(rng.normal(0, 1, (Kd, Nd)), jnp.float32)
        qd = prepare_linear_weight(wd, group_k)
        # time the jitted step — the serving regime, where per-call weight
        # quantization lives inside the traced graph and prepare-once does not
        f_percall = jax.jit(
            lambda a, b: dscim_fused_mvm(a, b, cfg, group_k=group_k))
        f_prep = jax.jit(lambda a, q: dscim_fused_mvm_prepared(a, q, cfg))
        us_percall = timed(lambda: f_percall(xd, wd), n=reps)
        us_prep = timed(lambda: f_prep(xd, qd), n=reps)
        nwd = -(-Kd // group_k)
        # per decode step the prepared path drops: the f32 weight read, the
        # K*N quantize (abs/max/div/round) and the int8 plane write-back
        wq_bytes = 4 * Kd * Nd + Kd * Nd
        shared_d = (f"g{group_k};wquant_removed_bytes={wq_bytes}B;"
                    f"wquant_removed_ops={Kd * Nd};"
                    f"tpu_t_wquant_mem={wq_bytes / HBM:.2e}s")
        rows.append({
            "name": f"kernel/dscim_wquant_percall/decode/{Md}x{Kd}x{Nd}",
            "us": us_percall, "derived": f"nw={nwd};{shared_d}"})
        rows.append({
            "name": f"kernel/dscim_prepared/decode/{Md}x{Kd}x{Nd}",
            "us": us_prep,
            "derived": (f"speedup_vs_percall={us_percall / us_prep:.2f}x;"
                        f"{shared_d}")})

    # --- bf16 vs f32 bit-expansion operands in the fused kernel -----------
    us_bf16 = timed(lambda: dscim_fused_mvm(
        xf, wf, cfg, group_k=group_k, bits="bfloat16"), n=reps)
    us_f32 = timed(lambda: dscim_fused_mvm(
        xf, wf, cfg, group_k=group_k, bits="float32"), n=reps)
    rows.append({
        "name": f"kernel/dscim_fused_bits/bf16/{M}x{K}x{N}", "us": us_bf16,
        "derived": ("vmem_bit_tiles=0.5x_f32;mxu_rate=2x_f32;"
                    f"f32_us={us_f32:.0f};interp_bf16_emulation_ratio="
                    f"{us_bf16 / us_f32:.2f}x")})
    rows.append({
        "name": f"kernel/dscim_fused_bits/f32/{M}x{K}x{N}", "us": us_f32,
        "derived": "baseline_bits=float32"})
    return rows


def main():
    """Prints CSV rows and returns them (benchmarks.run appends the
    kernel rows to the BENCH_kernels.json trajectory)."""
    smoke = "--smoke" in sys.argv[1:]
    rows = run(smoke=smoke)
    for r in rows:
        emit(r["name"], r["us"], r["derived"])
    return rows


if __name__ == "__main__":
    main()
