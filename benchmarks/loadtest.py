"""Router load test: heavy-tailed synthetic traffic against the asyncio
serving frontend (runtime/router.py), with and without injected faults.

The trace is the point: mixed prompt lengths (bucketed one-shot lengths
plus an odd-length tail that exercises chunked prefill), heavy-tailed
generation budgets, bursty arrivals (compressed Poisson with geometric
burst sizes), a sprinkle of deadlines and mid-stream client disconnects.
Every request must end in a definite terminal status and the page pool
must drain to zero live pages — with the fault schedule armed
(``FailureInjector.sampled(chaos_seed)``: device losses + page-pool bit
flips, replayed through snapshot/restore) as well as without.

Correctness, not just liveness: requests that finish ``ok`` in both legs
must produce bitwise-identical tokens (greedy serving is schedule- and
fault-replay-independent), and the plain leg's bucket-length ``ok``
subset is additionally replayed through ``serve_continuous`` directly and
compared bitwise (chunked-prefill requests are sequential-decode
equivalent, not bitwise against the batched prefill — covered by
tests/test_router.py instead).

Emits ``serve/router_plain`` / ``serve/router_chaos`` BENCH rows
(p50/p99 end-to-end latency, useful tok/s, refusal rate, slot occupancy,
page-pool counters) into BENCH_kernels.json via
``benchmarks.run.append_trajectory``; tools/check_artifacts.py schema-
gates them and tools/bench_regression.py bounds the p99/p50 ratio and
the refusal rate.  ``--smoke`` is the CI preset (scripts/ci_smoke.py
``router``): a mini trace, faults armed, same invariants.
"""
from __future__ import annotations

import argparse
import asyncio
import time

import numpy as np


def make_trace(seed: int, n: int, *, buckets=(4, 8), max_prompt: int = 12,
               max_new_cap: int = 8, mean_gap_s: float = 0.002,
               shared_prefix=None, shared_frac: float = 0.0):
    """``n`` request descriptors with arrival offsets.  ~80% of prompts
    hit a one-shot bucket length, the rest land on odd lengths (chunked
    prefill); budgets are geometric (heavy tail, clipped to the cap);
    arrivals are bursty — geometric burst sizes at exponential gaps.  A
    few requests carry deadlines; a few are marked for mid-stream client
    disconnect.  ``shared_prefix``/``shared_frac`` (ISSUE 10): overwrite
    the leading tokens of that fraction of prompts with a common system
    prompt — the prefix-cache leg's hit source (only whole pages below
    the sharing cap actually dedup, so short prompts stay misses)."""
    rng = np.random.default_rng(seed)
    buckets = tuple(buckets)
    odd = [s for s in range(2, max_prompt + 1) if s not in buckets]
    trace = []
    t = 0.0
    i = 0
    while i < n:
        burst = min(1 + rng.geometric(0.45), n - i)
        t += rng.exponential(mean_gap_s) * burst
        for _ in range(burst):
            if rng.random() < 0.8 or not odd:
                S = int(rng.choice(buckets))
            else:
                S = int(rng.choice(odd))
            budget = int(np.clip(rng.geometric(0.35), 1, max_new_cap))
            prompt = rng.integers(1, 1000, S, dtype=np.int32)
            if shared_prefix is not None and rng.random() < shared_frac:
                m = min(len(shared_prefix), S)
                prompt[:m] = shared_prefix[:m]
            req = {"t": t, "prompt": prompt,
                   "max_new": budget, "priority": int(rng.random() < 0.1),
                   "deadline_s": None, "deadline_steps": None,
                   "disconnect_after": None}
            u = rng.random()
            if u < 0.05:
                req["deadline_steps"] = int(rng.integers(1, 6))
            elif u < 0.07:
                req["deadline_s"] = float(rng.uniform(0.2, 2.0))
            if rng.random() < 0.02 and budget > 2:
                req["disconnect_after"] = int(rng.integers(1, budget))
            trace.append(req)
            i += 1
    return trace


async def _client(router, spec, t0, rec):
    from repro.runtime.router import Refused
    await asyncio.sleep(max(0.0, t0 + spec["t"] - time.perf_counter()))
    rec["t_submit"] = time.perf_counter()
    try:
        handle = router.submit(spec["prompt"], spec["max_new"],
                               deadline_s=spec["deadline_s"],
                               deadline_steps=spec["deadline_steps"],
                               priority=spec["priority"])
    except Refused as e:
        rec["status"] = "refused"
        rec["refused_reason"] = e.reason
        rec["t_end"] = time.perf_counter()
        return
    tokens: list = []
    cut = spec["disconnect_after"]
    async for kind, val in handle.events():
        if kind == "token":
            tokens.append(int(val))
            if cut is not None and len(tokens) >= cut:
                handle.cancel()
        elif kind == "restart":
            tokens.clear()
        else:
            rec["status"] = val
    rec["tokens"] = tokens
    rec["t_end"] = time.perf_counter()


async def _run_leg(cfg, params, trace, *, injector=None, monitor=None,
                   snapshot_every=0, slots=4, seg_len=4, page_size=4,
                   n_pages=None, buckets=(4, 8), chunk_len=4,
                   max_prompt=12, max_new_cap=8, max_queue=64,
                   prefix_cache=False):
    from repro.runtime.router import Router
    router = Router(cfg, params, slots=slots, seg_len=seg_len, kv="int8",
                    page_size=page_size, n_pages=n_pages, buckets=buckets,
                    chunk_len=chunk_len, max_prompt=max_prompt,
                    max_new_cap=max_new_cap, max_queue=max_queue,
                    prepare=False, injector=injector, monitor=monitor,
                    snapshot_every=snapshot_every, prefix_cache=prefix_cache,
                    log=lambda *a: None)
    await router.start()
    t0 = time.perf_counter()
    recs = [{"status": None, "tokens": []} for _ in trace]
    await asyncio.gather(*[_client(router, s, t0, r)
                           for s, r in zip(trace, recs)])
    await router.close("drain")
    wall = time.perf_counter() - t0
    return recs, router.stats(), wall


def _metrics(recs, stats, wall):
    from repro.runtime.router import TERMINAL_STATUSES
    lat = sorted(r["t_end"] - r["t_submit"] for r in recs
                 if r["status"] not in (None, "refused"))
    counts = {s: sum(1 for r in recs if r["status"] == s)
              for s in TERMINAL_STATUSES}
    n = len(recs)
    useful = sum(len(r["tokens"]) for r in recs)
    pct = (lambda q: lat[min(len(lat) - 1, int(q * (len(lat) - 1)))]) \
        if lat else (lambda q: 0.0)
    return {
        "requests": n,
        "statuses": counts,
        "p50_ms": pct(0.50) * 1e3,
        "p99_ms": pct(0.99) * 1e3,
        "tok_s": useful / wall,
        "useful_tokens": useful,
        "refusal_rate": counts["refused"] / max(n, 1),
        "occupancy": stats["occupancy"],
        "replays": stats["replays"],
        "quarantined": stats["counters"]["quarantined"],
        "pages": stats["pages"],
        "wall_s": wall,
    }


def _row(kind, tag, m):
    pg = m["pages"]
    st = m["statuses"]
    return {
        "name": f"serve/router_{kind}/{tag}",
        "us": m["wall_s"] * 1e6,
        "derived": (f"p50_ms={m['p50_ms']:.2f};p99_ms={m['p99_ms']:.2f};"
                    f"tok_s={m['tok_s']:.2f};"
                    f"refusal_rate={m['refusal_rate']:.4f};"
                    f"occupancy={m['occupancy']:.3f};"
                    f"requests={m['requests']};"
                    f"ok={st['ok']};deadline={st['deadline']};"
                    f"refused={st['refused']};cancelled={st['cancelled']};"
                    f"degraded={st['degraded']};"
                    f"replays={m['replays']};"
                    f"quarantined={m['quarantined']};"
                    f"pages_live={pg['live_pages']};"
                    f"pages_high_water={pg['high_water']};"
                    f"pages_refusals={pg['refusals']}"),
    }


def _assert_terminal(recs, stats, leg):
    bad = [i for i, r in enumerate(recs) if r["status"] is None]
    assert not bad, f"{leg}: requests without terminal status: {bad[:10]}"
    assert stats["pages"]["live_pages"] == 0, \
        f"{leg}: page leak at drain: {stats['pages']}"


def _check_bitwise(trace, plain, chaos):
    """Requests ok in both legs must agree bitwise (greedy serving is
    schedule- and replay-independent)."""
    both = [i for i in range(len(trace))
            if plain[i]["status"] == chaos[i]["status"] == "ok"]
    for i in both:
        assert plain[i]["tokens"] == chaos[i]["tokens"], (
            f"request {i}: plain {plain[i]['tokens']} != "
            f"chaos {chaos[i]['tokens']}")
    return len(both)


def _check_vs_continuous(cfg, params, trace, plain, *, buckets, seg_len,
                         page_size):
    """The plain leg's bucket-length ok subset replayed straight through
    serve_continuous must match bitwise."""
    from repro.launch.serve import serve_continuous
    checked = 0
    for S in buckets:
        rows = [i for i, s in enumerate(trace)
                if len(s["prompt"]) == S and plain[i]["status"] == "ok"]
        if not rows:
            continue
        rows = rows[:16]        # a sample per bucket keeps this cheap
        prompts = np.stack([trace[i]["prompt"] for i in rows])
        budgets = [trace[i]["max_new"] for i in rows]
        outs, _ = serve_continuous(
            cfg, params, prompts, max(budgets), slots=2, seg_len=seg_len,
            kv="int8", page_size=page_size, max_new=budgets, eos_id=-1,
            prepare=False, log=lambda *a: None)
        for j, i in enumerate(rows):
            assert plain[i]["tokens"] == outs[j].tolist(), (
                f"request {i} (S={S}): router {plain[i]['tokens']} != "
                f"serve_continuous {outs[j].tolist()}")
            checked += 1
    return checked


def run_loadtest(smoke: bool = True, *, requests: int | None = None,
                 seed: int = 0, chaos_seed: int = 0, arch: str = "qwen3-0.6b",
                 prefix: bool = False, log=print):
    """Both legs + invariants; returns (rows, plain_metrics,
    chaos_metrics).  ``smoke``: mini trace for CI; full mode runs >= 1000
    requests and the serve_continuous bitwise replay.  ``prefix``
    (ISSUE 10): add a shared-system-prompt trace served by an all-chunked
    cold router and a ``prefix_cache=True`` router — ok-vs-ok outputs are
    asserted bitwise (the hit-vs-cold contract under real traffic,
    disconnects and deadlines included) and a ``serve/prefix_router`` row
    records the dedup ledger."""
    import jax

    from repro.configs import get_arch
    from repro.launch.serve import _place
    from repro.models import get_model
    from repro.runtime.failover import FailureInjector
    from repro.runtime.watchdog import AccuracyWatchdog

    cfg = get_arch(arch).reduced()
    model = get_model(cfg)
    params = _place(cfg, model.init_params(cfg, jax.random.PRNGKey(0)),
                    None, True)
    n = requests if requests is not None else (24 if smoke else 1000)
    slots, seg_len, page_size = (2, 2, 4) if smoke else (4, 4, 4)
    buckets, chunk_len, max_prompt, max_new_cap = (4, 8), 4, 12, 8
    kn = dict(slots=slots, seg_len=seg_len, page_size=page_size,
              buckets=buckets, chunk_len=chunk_len, max_prompt=max_prompt,
              max_new_cap=max_new_cap,
              max_queue=max(16, n // 4),
              # an underprovisioned pool so admission control works for a
              # living: ~half the slots' worth of full-size grants
              n_pages=slots * ((max_prompt + max_new_cap + chunk_len)
                               // page_size + 1))
    trace = make_trace(seed, n, buckets=buckets, max_prompt=max_prompt,
                       max_new_cap=max_new_cap,
                       mean_gap_s=0.001 if smoke else 0.002)
    tag = f"R{n}s{slots}x{max_prompt}+{max_new_cap}"

    # warm the jit caches (one admit per bucket, the extend/segment
    # programs) so the timed legs measure serving, not compilation
    log("[loadtest] warmup: compiling admit/extend/segment programs")
    rng = np.random.default_rng(seed + 1)
    warm = [{"t": 0.0, "prompt": rng.integers(1, 1000, S, dtype=np.int32),
             "max_new": 2, "priority": 0, "deadline_s": None,
             "deadline_steps": None, "disconnect_after": None}
            for S in tuple(buckets) + (max_prompt - 1,)]
    asyncio.run(_run_leg(cfg, params, warm, **kn))

    log(f"[loadtest] plain leg: {n} requests")
    plain, st_p, wall_p = asyncio.run(_run_leg(cfg, params, trace, **kn))
    _assert_terminal(plain, st_p, "plain")
    m_plain = _metrics(plain, st_p, wall_p)

    log(f"[loadtest] chaos leg: fault schedule seed={chaos_seed}")
    segs = max(16, st_p["segments"])
    inj = FailureInjector.sampled(
        chaos_seed, segments=segs, slots=slots, n_layers=cfg.n_layers,
        page_size=page_size, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
        device_losses=1 if smoke else 3, flips=2 if smoke else 6)
    chaos, st_c, wall_c = asyncio.run(_run_leg(
        cfg, params, trace, injector=inj, monitor=AccuracyWatchdog(None),
        snapshot_every=4, **kn))
    _assert_terminal(chaos, st_c, "chaos")
    m_chaos = _metrics(chaos, st_c, wall_c)

    n_both = _check_bitwise(trace, plain, chaos)
    log(f"[loadtest] bitwise ok-vs-ok agreement: {n_both} requests")
    if not smoke:
        n_direct = _check_vs_continuous(cfg, params, trace, plain,
                                        buckets=buckets, seg_len=seg_len,
                                        page_size=page_size)
        log(f"[loadtest] bitwise vs serve_continuous: {n_direct} requests")
    rows = [_row("plain", tag, m_plain), _row("chaos", tag, m_chaos)]
    if prefix:
        sysp = np.random.default_rng(seed + 3).integers(1, 1000, 8,
                                                        dtype=np.int32)
        ptrace = make_trace(seed + 2, n, buckets=buckets,
                            max_prompt=max_prompt, max_new_cap=max_new_cap,
                            mean_gap_s=0.001 if smoke else 0.002,
                            shared_prefix=sysp, shared_frac=0.75)
        # cold reference: the same all-chunked page-aligned admission
        # path with sharing off — the bitwise-comparable leg
        kn_c = dict(kn, buckets=(), chunk_len=page_size)
        log(f"[loadtest] prefix cold leg: {n} requests (all-chunked)")
        pcold, st_pc, _ = asyncio.run(_run_leg(cfg, params, ptrace, **kn_c))
        _assert_terminal(pcold, st_pc, "prefix-cold")
        log("[loadtest] prefix warm leg: prefix_cache=True")
        pwarm, st_pw, wall_pw = asyncio.run(
            _run_leg(cfg, params, ptrace, prefix_cache=True, **kn_c))
        _assert_terminal(pwarm, st_pw, "prefix-warm")
        n_hit = _check_bitwise(ptrace, pcold, pwarm)
        px = st_pw["prefix"]
        assert px["hits"] > 0, f"shared trace produced no hits: {px}"
        removed = 1.0 - px["prefill_positions_computed"] \
            / max(px["prefill_positions_total"], 1)
        log(f"[loadtest] prefix: bitwise ok-vs-ok {n_hit} requests, "
            f"{px['hits']}/{px['lookups']} hits, "
            f"{removed:.2f} prefill removed")
        m_pfx = _metrics(pwarm, st_pw, wall_pw)
        row = _row("plain", tag, m_pfx)     # base fields, then the ledger
        rows.append({
            "name": f"serve/prefix_router/{tag}",
            "us": row["us"],
            "derived": (f"{row['derived']};hits={px['hits']};"
                        f"lookups={px['lookups']};"
                        f"hit_tokens={px['hit_tokens']};"
                        f"pages_deduped={px['pages_deduped']};"
                        f"prefill_removed_frac={removed:.3f};"
                        f"pages_retained="
                        f"{st_pw['pages']['retained_pages']};"
                        f"bitwise_ok={n_hit}")})
    for kind, m in (("plain", m_plain), ("chaos", m_chaos)):
        log(f"[loadtest] {kind}: p50 {m['p50_ms']:.1f}ms "
            f"p99 {m['p99_ms']:.1f}ms {m['tok_s']:.1f} tok/s "
            f"refusal {m['refusal_rate']:.3f} occupancy "
            f"{m['occupancy']:.2f} statuses {m['statuses']}")
    return rows, m_plain, m_chaos


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="mini CI trace (scripts/ci_smoke.py router)")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace size (default: 24 smoke / 1000 full)")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace seed (arrivals, lengths, budgets)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="FailureInjector.sampled seed — reproduce a CI "
                         "fault schedule exactly")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="add the shared-system-prompt prefix legs "
                         "(ISSUE 10): bitwise hit-vs-cold under real "
                         "traffic + a serve/prefix_router row")
    ap.add_argument("--no-append", action="store_true",
                    help="skip the BENCH_kernels.json append")
    args = ap.parse_args(argv)
    rows, _, _ = run_loadtest(args.smoke, requests=args.requests,
                              seed=args.seed, chaos_seed=args.chaos_seed,
                              arch=args.arch, prefix=args.prefix_cache)
    if not args.no_append:
        from benchmarks.run import append_trajectory
        append_trajectory(rows)
    for r in rows:
        print(f"{r['name']},{r['us']:.0f},{r['derived']}")


if __name__ == "__main__":
    main()
