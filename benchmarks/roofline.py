"""Roofline report generator: reads experiments/dryrun/*.json (produced by
repro.launch.dryrun) and emits the per-cell table for EXPERIMENTS.md
§Dry-run and §Roofline."""
from __future__ import annotations

import glob
import json
import os

V5E = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}


def load(dirname: str = "experiments/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        recs.append(json.load(open(p)))
    return recs


def one_line(r) -> str:
    if r.get("skipped"):
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip | — | — "
                f"| — | — | — | full-attention arch (spec skip) |")
    if not r.get("ok"):
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | — | — "
                f"| — | — | — | {r.get('error', '')[:60]} |")
    rf = r["roofline"]
    mem_gib = r["memory"].get("total_per_device", 0) / 2 ** 30
    t = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
    frac = rf["t_compute_s"] / t if t else 0.0
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {mem_gib:.1f} | {rf['t_compute_s']:.2e} "
            f"| {rf['t_memory_s']:.2e} | {rf['t_collective_s']:.2e} "
            f"| {rf['dominant']} | useful={r['useful_flops_ratio']:.2f} "
            f"roofline_frac={frac:.3f} |")


def summarize(recs):
    ok = [r for r in recs if r.get("ok")]
    print(f"cells: {len(recs)} total, {len(ok)} compiled, "
          f"{sum(1 for r in recs if r.get('skipped'))} spec-skips, "
          f"{sum(1 for r in recs if not r.get('ok') and not r.get('skipped'))}"
          " failures")
    by_dom = {}
    for r in ok:
        by_dom.setdefault(r["roofline"]["dominant"], []).append(r)
    for dom, rs in sorted(by_dom.items()):
        print(f"  dominant={dom}: {len(rs)} cells")
    return ok


def main():
    recs = load()
    print("| arch | shape | mesh | status | GiB/dev | t_comp | t_mem "
          "| t_coll | dominant | notes |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        print(one_line(r))
    summarize(recs)


if __name__ == "__main__":
    main()
