"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Sections:
  t1_rmse       — Table I RMSE rows (DS-CIM1/2 x L, paper + beyond-paper)
  t1_accuracy   — Table I accuracy methodology (synthetic classifier)
  t2_llm        — Table II methodology (trained LM + FP8->INT8 DS-CIM)
  t3_efficiency — Table III + Fig. 4 + Fig. 7 (calibrated hw model)
  fig6_sparsity — Fig. 6(c) saturation-vs-sparsity
  seedsearch    — Sec. IV-C PRNG/seed optimization
  kernel_bench  — Pallas kernel microbench + TPU roofline terms
  serve_bench   — host-loop vs scanned device-resident generation tok/s
  roofline      — per-(arch x shape x mesh) table from the dry-run JSONs

The kernel_bench and serve_bench sections additionally append their rows
(name, µs, derived roofline/dispatch terms, git rev, timestamp) to
``BENCH_kernels.json`` at the repo root — a perf trajectory across PRs, so
future changes have a baseline to compare against.

Run everything:  PYTHONPATH=src python -m benchmarks.run
One section:     PYTHONPATH=src python -m benchmarks.run t1_rmse
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import traceback

SECTIONS = ("t1_rmse", "fig6_sparsity", "t3_efficiency", "seedsearch",
            "t1_accuracy", "t2_llm", "kernel_bench", "serve_bench",
            "roofline")
TRAJECTORY_SECTIONS = ("kernel_bench", "serve_bench")

TRAJECTORY = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernels.json")


def _git_rev() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(TRAJECTORY),
            stderr=subprocess.DEVNULL).decode().strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _dedupe(data: dict, rows: list, rev: str) -> tuple[list, int]:
    """Drop rows already recorded for this rev — re-running a bench section
    before committing must update the rev's rows, not accumulate copies
    (tools/check_artifacts.py rejects duplicate (name, rev) pairs).  The
    newest append wins: matching rows are removed from earlier same-rev
    runs (runs left empty are dropped), and the incoming list keeps only
    the last row per name."""
    seen: set = set()
    fresh = []
    for row in reversed(rows):
        if row.get("name") not in seen:
            seen.add(row.get("name"))
            fresh.append(row)
    fresh.reverse()
    dropped = len(rows) - len(fresh)
    kept_runs = []
    for run in data.get("runs", []):
        if run.get("rev") != rev:
            kept_runs.append(run)
            continue
        kept = [r for r in run.get("rows", []) if r.get("name") not in seen]
        dropped += len(run.get("rows", [])) - len(kept)
        if kept:
            kept_runs.append(dict(run, rows=kept))
    data["runs"] = kept_runs
    return fresh, dropped


def append_trajectory(rows, path: str = TRAJECTORY) -> None:
    """Append one benchmark run to the BENCH_kernels.json trajectory,
    deduplicating by (row name, git rev) — newest run wins."""
    data = {"runs": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {"runs": []}
    rev = _git_rev()
    data.setdefault("runs", [])
    rows, dropped = _dedupe(data, rows, rev)
    data["runs"].append({
        "rev": rev,
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "rows": rows,
    })
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
    extra = f" ({dropped} stale same-rev rows dropped)" if dropped else ""
    print(f"# trajectory: {len(rows)} rows -> {path}{extra}", flush=True)


def main() -> None:
    want = sys.argv[1:] or SECTIONS
    for name in want:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            rows = mod.main()
            if name in TRAJECTORY_SECTIONS and rows:
                append_trajectory(rows)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001 — keep the harness going
            print(f"# {name} FAILED:\n{traceback.format_exc()[-1500:]}",
                  flush=True)


if __name__ == "__main__":
    main()
