"""Sec. IV-C reproduction: the PRNG-type x seed search (small grid by
default; `--wide` reruns the full calibration grid)."""
from __future__ import annotations

import sys

from repro.core.seed_search import search


def run(wide: bool = False):
    rows = []
    seeds = (1, 7, 23, 51, 91, 151, 199, 233) if wide else (1, 91, 233)
    params = (0, 1) if wide else (0,)
    for variant, k in (("dscim1", 2), ("dscim2", 3)):
        for L in ((64, 128, 256) if wide else (64, 256)):
            best = search(k, L, trunc="floor",
                          kinds=("lfsr", "galois", "lcg"),
                          seeds=seeds, params=params,
                          n_vec=24, n_cols=128, top=3)
            b = best[0]
            rows.append({
                "name": f"seedsearch/{variant}/L{L}",
                "best": f"{b.kind}(su={b.seed_u},sv={b.seed_v})",
                "rmse": b.rmse_unsigned,
                "second": best[1].rmse_unsigned,
            })
    return rows


def main():
    wide = "--wide" in sys.argv
    for r in run(wide):
        print(f"{r['name']},0,best={r['best']};rmse={r['rmse']:.3f}%;"
              f"runnerup={r['second']:.3f}%")


if __name__ == "__main__":
    main()
