"""Serving-loop microbench: host-loop vs scanned generation (ISSUE 3),
plus the only-live-work rows (ISSUE 4) — EOS early-exit + continuous
batching vs the fixed-length scan on a skewed-completion-length queue,
and the int8 block-paged KV cache vs the dense float cache.

Accounting (the ISSUE 4 fix): every serve row now carries
``live_slot_steps`` and ``occupancy`` — the fixed-length drivers burn a
slot-step per (slot, step) whether or not the slot still has useful work,
so their occupancy on a skewed workload is sum(budgets)/(B*n_tokens) and
the old all-slots tok/s over-credited padded/finished slots.  ``tok_s``
on queue rows counts *useful* tokens only (each request's budget-long
prefix), which is exactly the live-slot-step-credited rate: a live
slot-step emits one useful token, a dead one earns nothing.

The paged-KV rows record resident decode-cache bytes (dense fixed-
capacity float cache vs int8 pages + per-page scales + bf16 tails +
page table, core/kvcache.py) and the logit drift measured on the
teacher-matched prefix — per row, decode steps up to the first token
divergence — so feedback of a flipped argmax doesn't masquerade as
quantization error.  Compile time is excluded everywhere (warmed runs).

ISSUE 5 adds the paged *read-path* A/B on the same int8 cache: the fused
Pallas paged-attention kernel (kernels/paged_attention.py) vs the jnp
gather reference, with the per-step HBM bytes the kernel stops staging
(gathered int8 pages + their f32 dequant copies) in the derived fields.

ISSUE 7 adds the self-speculative decoding rows (``serve/spec_*``):
dscim2-draft -> dscim1-verify vs the plain driver at asserted-bitwise
greedy outputs, with accepted-tokens-per-verify / acceptance-rate in the
derived fields, and page-pool occupancy read from ``PageAllocator.stats()``
on the continuous rows.

ISSUE 10 adds the prefix-cache rows (``serve/prefix_hit0|hit50|hit90``):
the same queue with a shared system prompt on 0/50/90% of requests,
served warm vs cold, with prefill-positions-removed and hit-vs-cold
admission latency in the derived fields — both CI-bounded by
tools/bench_regression.py.
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed

DSCIM = "kernel:dscim1:256"


def _host_loop(prefill, decode, params, batch, n_tokens):
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for _ in range(n_tokens - 1):
        tok, cache = decode(params, {"token": tok}, cache)
        out.append(tok)
    return jnp.stack(out, axis=1)


def _dispatch_rows(cfg, params, smoke):
    """PR 3 rows: host loop vs scanned generate, dispatch accounting."""
    from repro.launch.steps import (make_decode_step, make_generate_fn,
                                    make_prefill_step)
    n_tokens = 4 if smoke else 16
    prompt_len = 8
    reps = 1 if smoke else 3
    rows = []
    rng = np.random.default_rng(0)
    for B in ([1] if smoke else [1, 8, 16]):
        prompts = rng.integers(0, cfg.vocab, (B, prompt_len), dtype=np.int32)
        batch = {"tokens": jnp.asarray(prompts)}
        prefill = jax.jit(make_prefill_step(cfg, None,
                                            capacity=prompt_len + n_tokens))
        # cache donated between steps exactly like serve_batch's host loop
        decode = jax.jit(make_decode_step(cfg, None), donate_argnums=(2,))
        generate = make_generate_fn(cfg, None, n_tokens)
        us_host = timed(lambda: _host_loop(prefill, decode, params, batch,
                                           n_tokens), n=reps)
        us_scan = timed(lambda: generate(params, batch)[0], n=reps)
        tok = jnp.zeros((B,), jnp.int32)
        noop = jax.jit(lambda t: t + 0)
        us_dispatch = timed(lambda: noop(tok), n=max(reps, 3))
        # fixed-length drivers: every slot-step is counted live (no EOS),
        # which is exactly the over-credit the queue rows below expose
        shared = (f"n_tokens={n_tokens};dispatches_host={n_tokens};"
                  f"dispatches_scanned=1;"
                  f"dispatch_us={us_dispatch:.1f};"
                  f"dispatch_overhead_removed_us="
                  f"{(n_tokens - 1) * us_dispatch:.1f};"
                  f"live_slot_steps={B * n_tokens};occupancy=1.00")
        rows.append({
            "name": f"serve/host_loop/{DSCIM}/B{B}x{prompt_len}+{n_tokens}",
            "us": us_host,
            "derived": (f"tok_s={B * n_tokens / us_host * 1e6:.1f};"
                        f"{shared}")})
        rows.append({
            "name": f"serve/scanned/{DSCIM}/B{B}x{prompt_len}+{n_tokens}",
            "us": us_scan,
            "derived": (f"tok_s={B * n_tokens / us_scan * 1e6:.1f};"
                        f"speedup_vs_host_loop={us_host / us_scan:.2f}x;"
                        f"{shared}")})
    return rows


def _queue_rows(cfg, params, smoke):
    """ISSUE 4 A/B at skewed completion lengths: a queue of R requests with
    budgets 2..n_tokens served by (a) the PR 3 fixed-length scan in
    R/slots-sized batches, (b) the EOS early-exit while_loop on the same
    batches (exits at each batch's max budget), (c) continuous batching
    (early-exit segments + admission into freed slots)."""
    from repro.launch.serve import serve_batch, serve_continuous
    n_tokens = 4 if smoke else 16
    slots = 2 if smoke else 4
    R = 4 if smoke else 8
    prompt_len = 8
    reps = 1 if smoke else 3    # odd, so timed()'s median is a real median
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (R, prompt_len), dtype=np.int32)
    budgets = np.linspace(2, n_tokens, R).round().astype(np.int32)
    rng.shuffle(budgets)                     # admission order is skewed too
    useful = int(budgets.sum())
    tag = f"{DSCIM}/R{R}s{slots}x{prompt_len}+{n_tokens}"

    def fixed_queue():
        for i in range(0, R, slots):
            serve_batch(cfg, params, prompts[i:i + slots], n_tokens,
                        prepare=False)

    def early_exit_queue():
        for i in range(0, R, slots):
            serve_batch(cfg, params, prompts[i:i + slots], n_tokens,
                        prepare=False, eos_id=-1,
                        max_new=budgets[i:i + slots])

    stats = {}     # filled by the timed runs (no extra serve just for it)

    def continuous_queue():
        outs, st = serve_continuous(cfg, params, prompts, n_tokens,
                                    slots=slots, seg_len=4, max_new=budgets,
                                    eos_id=-1, prepare=False)
        stats.update(st)
        return outs

    us_fixed = timed(fixed_queue, n=reps)
    us_ee = timed(early_exit_queue, n=reps)
    us_cont = timed(continuous_queue, n=reps)
    # early exit runs each batch to its max budget (tokens incl. prefill,
    # so max-1 decode steps after the batch prefill step)
    ee_slot_steps = sum(slots * int(budgets[i:i + slots].max())
                        for i in range(0, R, slots))
    rows = [{
        "name": f"serve/fixed_scan_queue/{tag}",
        "us": us_fixed,
        "derived": (f"tok_s={useful / us_fixed * 1e6:.1f};"
                    f"useful_tokens={useful};"
                    f"live_slot_steps={useful};"
                    f"slot_steps={R * n_tokens};"
                    f"occupancy={useful / (R * n_tokens):.2f}"),
    }, {
        "name": f"serve/early_exit_queue/{tag}",
        "us": us_ee,
        "derived": (f"tok_s={useful / us_ee * 1e6:.1f};"
                    f"useful_tokens={useful};"
                    f"live_slot_steps={useful};"
                    f"slot_steps={ee_slot_steps};"
                    f"occupancy={useful / ee_slot_steps:.2f};"
                    f"speedup_vs_fixed={us_fixed / us_ee:.2f}x"),
    }, {
        # stated on the same token-slot basis as the other two rows
        # (admission tokens count as live slot-steps, one slot-step per
        # token emitted), so the three occupancy numbers are comparable —
        # serve_continuous's own stats count decode steps only
        "name": f"serve/continuous_queue/{tag}",
        "us": us_cont,
        "derived": (f"tok_s={useful / us_cont * 1e6:.1f};"
                    f"useful_tokens={useful};"
                    f"live_slot_steps={useful};"
                    f"slot_steps={stats['slot_steps'] + R};"
                    f"occupancy={useful / (stats['slot_steps'] + R):.2f};"
                    f"speedup_vs_fixed={us_fixed / us_cont:.2f}x"),
    }]
    return rows


def _paged_kernel_rows(cfg_float, params, smoke):
    """ISSUE 5 rows: the fused Pallas paged-attention read path vs the jnp
    gather reference on the same int8 paged cache.  The derived fields
    carry the HBM traffic the kernel removes *per decode step*: the jnp
    path stages the gathered int8 k+v pages and their dequantized f32
    copies in HBM before the QK contraction (gather -> dequant -> einsum
    are separate XLA ops), while the kernel streams the int8 pages
    HBM->VMEM once and dequantizes in VMEM — on TPU that staged traffic is
    the bandwidth term the int8 cache was supposed to save.  Logit drift
    between the two paths is the tools/bench_regression.py CI metric
    (matched-prefix RMSE, threshold tools/ci_thresholds.json)."""
    from repro.core.kvcache import n_pages_for
    from repro.launch.serve import logit_drift_rmse, serve_batch
    from repro.launch.steps import make_generate_fn
    B, prompt_len = 4, 16
    n_tokens = 16 if smoke else 112
    page_size = 4
    reps = 1 if smoke else 3
    capacity = prompt_len + n_tokens
    MP = n_pages_for(capacity, page_size)
    L, KV, HD = cfg_float.n_layers, cfg_float.n_kv, cfg_float.head_dim
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg_float.vocab, (B, prompt_len),
                           dtype=np.int32)
    batch = {"tokens": jnp.asarray(prompts)}

    def timed_path(path):
        # the read-path pin keys the builder cache — no env state, no
        # stale-executable hazard between the two timed paths
        gen = make_generate_fn(cfg_float, None, n_tokens, kv="int8",
                               page_size=page_size, paged_attn=path)
        us = timed(lambda: gen(params, batch)[0], n=reps)
        toks, trace = serve_batch(cfg_float, params, prompts, n_tokens,
                                  trace_logits=True, prepare=False,
                                  kv="int8", page_size=page_size,
                                  paged_attn=path)
        return us, toks, trace

    us_k, tk, lk = timed_path("kernel")
    us_j, tj, lj = timed_path("jnp")
    drift = logit_drift_rmse(tj, tk, lj, lk)
    # per decode step, per layer: gathered int8 k+v pages (2x) + their f32
    # dequantized copies (8x) staged in HBM by the jnp path — all removed
    # by the kernel (pages go HBM->VMEM once, dequant stays in VMEM)
    page_elems = B * MP * page_size * KV * HD
    staged = L * page_elems * (2 * 1 + 2 * 4)
    shared = (f"page_size={page_size};capacity={capacity};"
              f"hbm_staged_bytes_per_step_gather={staged};"
              f"hbm_staged_bytes_per_step_kernel=0;"
              f"hbm_bytes_removed_per_step={staged};"
              f"logit_drift_rmse={drift:.3e};"
              f"token_agreement={float((tk == tj).mean()):.3f}")
    tag = f"float/B{B}x{prompt_len}+{n_tokens}"
    return [{
        "name": f"serve/paged_read_gather/{tag}",
        "us": us_j,
        "derived": f"tok_s={B * n_tokens / us_j * 1e6:.1f};{shared}",
    }, {
        "name": f"serve/paged_read_kernel/{tag}",
        "us": us_k,
        "derived": (f"tok_s={B * n_tokens / us_k * 1e6:.1f};"
                    f"speedup_vs_gather={us_j / us_k:.2f}x;{shared}"),
    }]


def _paged_kv_rows(cfg_float, params, smoke):
    """Int8 block-paged KV cache vs the dense float cache: tok/s, resident
    decode-cache bytes, and teacher-matched-prefix logit drift."""
    from repro.core.kvcache import (dense_cache_bytes, kv_cache_bytes,
                                    paged_cache_specs)
    from repro.launch.serve import logit_drift_rmse, serve_batch
    from repro.launch.steps import make_generate_fn
    B, prompt_len = 4, 16
    n_tokens = 16 if smoke else 112        # capacity 32 / 128
    page_size = 4
    reps = 1 if smoke else 3
    capacity = prompt_len + n_tokens
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg_float.vocab, (B, prompt_len),
                           dtype=np.int32)
    batch = {"tokens": jnp.asarray(prompts)}
    gen_f = make_generate_fn(cfg_float, None, n_tokens)
    gen_q = make_generate_fn(cfg_float, None, n_tokens, kv="int8",
                             page_size=page_size)
    us_f = timed(lambda: gen_f(params, batch)[0], n=reps)
    us_q = timed(lambda: gen_q(params, batch)[0], n=reps)
    bytes_f = dense_cache_bytes(cfg_float, B, capacity)
    bytes_q = kv_cache_bytes(paged_cache_specs(cfg_float, B, capacity,
                                               page_size))
    # drift on the teacher-matched prefix (same fed-back tokens)
    tf, lf = serve_batch(cfg_float, params, prompts, n_tokens,
                         trace_logits=True, prepare=False)
    tq, lq = serve_batch(cfg_float, params, prompts, n_tokens,
                         trace_logits=True, prepare=False, kv="int8",
                         page_size=page_size)
    drift = logit_drift_rmse(tf, tq, lf, lq)
    # fraction of the trace before the first per-row divergence — a raw
    # all-positions agreement would be dominated by the feedback cascade
    # after one argmax flip, not by the quantization under test
    prefix = np.mean([(np.nonzero(tf[b] != tq[b])[0][0] + 1) / n_tokens
                      if (tf[b] != tq[b]).any() else 1.0
                      for b in range(B)])
    shared = (f"kv_bytes_float={bytes_f};kv_bytes_int8={bytes_q};"
              f"kv_bytes_ratio={bytes_f / bytes_q:.2f};"
              f"logit_drift_rmse={drift:.5f};"
              f"matched_prefix_frac={prefix:.3f};"
              f"page_size={page_size};capacity={capacity}")
    tag = f"float/B{B}x{prompt_len}+{n_tokens}"
    return [{
        "name": f"serve/kv_float/{tag}",
        "us": us_f,
        "derived": f"tok_s={B * n_tokens / us_f * 1e6:.1f};{shared}",
    }, {
        "name": f"serve/kv_int8_paged/{tag}",
        "us": us_q,
        "derived": (f"tok_s={B * n_tokens / us_q * 1e6:.1f};"
                    f"speedup_vs_float_kv={us_f / us_q:.2f}x;{shared}"),
    }]


def _spec_rows(cfg, params, smoke):
    """ISSUE 7 rows: self-speculative decoding A/B — the dscim2 drafter in
    front of the dscim1 verifier vs the plain (target-only) driver, greedy,
    on the int8 paged cache.  Greedy spec is *bitwise* the plain output
    (asserted here — a spec row whose tokens drifted would be a lie), so
    ``tok_s`` differences are pure draft-amortization: the useful-tok/s
    win is ``accepted_tok_per_verify`` cheap-draft tokens per full-model
    verify forward.  ``acceptance_rate`` = accepted draft tokens / k
    drafted is the CI-bounded metric (tools/bench_regression.py).

    The continuous leg reports the scheduler's occupancy on the same
    verifier-position basis the deadline ledger uses, plus the
    PageAllocator's own ``stats()`` counters (live/high-water/refusals) —
    the occupancy fields read the allocator, not a recomputation."""
    from repro.launch.serve import serve_batch, serve_continuous
    B, prompt_len = 4, 8
    n_tokens = 8 if smoke else 32
    k = 4
    reps = 1 if smoke else 3
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (B, prompt_len), dtype=np.int32)
    kw = dict(prepare=False, kv="int8", page_size=4)
    tag = f"{DSCIM}/B{B}x{prompt_len}+{n_tokens}"

    us_plain = timed(lambda: serve_batch(cfg, params, prompts, n_tokens,
                                         **kw)[0], n=reps)
    us_spec = timed(lambda: serve_batch(cfg, params, prompts, n_tokens,
                                        spec=f"dscim2:{k}", **kw)[0],
                    n=reps)
    t_ref, _ = serve_batch(cfg, params, prompts, n_tokens, **kw)
    t_spec, _, ss = serve_batch(cfg, params, prompts, n_tokens,
                                spec=f"dscim2:{k}", spec_stats=True, **kw)
    np.testing.assert_array_equal(
        np.asarray(t_spec), np.asarray(t_ref),
        err_msg="greedy self-spec output drifted from the plain driver")
    windows = int(ss["windows"].sum())
    accepted = int((ss["emitted"] - 1).sum())  # tok0 isn't a drafted token
    tpv = accepted / max(windows, 1)
    useful = B * n_tokens
    shared = (f"k={k};windows={windows};"
              f"accepted_tok_per_verify={tpv:.3f};"
              f"acceptance_rate={tpv / k:.3f};tokens_match=1")
    rows = [{
        "name": f"serve/spec_off/{tag}",
        "us": us_plain,
        "derived": f"tok_s={useful / us_plain * 1e6:.1f};{shared}",
    }, {
        "name": f"serve/spec_dscim2_k{k}/{tag}",
        "us": us_spec,
        "derived": (f"tok_s={useful / us_spec * 1e6:.1f};"
                    f"speedup_vs_plain={us_plain / us_spec:.2f}x;{shared}"),
    }]

    R, slots, seg_len = (4, 2, 2) if smoke else (8, 4, 2)
    cprompts = rng.integers(0, cfg.vocab, (R, prompt_len), dtype=np.int32)
    st = {}

    def continuous():
        outs, s = serve_continuous(cfg, params, cprompts, n_tokens,
                                   slots=slots, seg_len=seg_len, eos_id=-1,
                                   spec=f"dscim2:{k}", prepare=False,
                                   kv="int8", page_size=4)
        st.update(s)
        return outs

    us_cont = timed(continuous, n=reps)
    pg = st["pages"]
    rows.append({
        "name": f"serve/spec_continuous/{DSCIM}/R{R}s{slots}"
                f"x{prompt_len}+{n_tokens}",
        "us": us_cont,
        "derived": (f"tok_s={st['useful_tokens'] / us_cont * 1e6:.1f};"
                    f"useful_tokens={st['useful_tokens']};"
                    f"occupancy={st['occupancy']:.2f};"
                    f"segments={st['segments']};k={k};"
                    f"pages_live={pg['live_pages']};"
                    f"pages_high_water={pg['high_water']};"
                    f"pages_refusals={pg['refusals']};"
                    f"pages_total={pg['n_pages']}")})
    return rows


def _prefix_rows(cfg, params, smoke):
    """ISSUE 10 rows: prefix caching with refcounted copy-on-write pages.
    The same request queue — a 3-page shared system prompt on 0% / 50% /
    90% of the requests — is served warm (``prefix_cache='on'``) and
    cold (``prefix_cache='cold'``: the identical page-aligned chunked
    admission path with lookup/registration disabled, so the warm leg's
    outputs are asserted bitwise against it by tests/test_prefix_cache.py
    and the prefix CI smoke, and timing differences are pure dedup).

    The derived fields carry the two CI-bounded metrics
    (tools/bench_regression.py): ``prefill_removed_frac`` — the fraction
    of prefill positions never computed because their pages were shared
    (>= 0.4 at the 90% trace is the ISSUE 10 acceptance bar) — and
    ``admit_latency_ratio`` — mean wall admission latency of a prefix
    *hit* over the cold leg's miss admissions (hits feed fewer chunks,
    so the ratio must stay well under 1)."""
    from repro.launch.serve import serve_continuous
    ps, S = 4, 16
    R = 6 if smoke else 10
    n_tokens = 4 if smoke else 8
    slots = 2 if smoke else 4
    reps = 1 if smoke else 3
    rng = np.random.default_rng(0)
    budgets = np.clip(np.linspace(2, n_tokens, R).round(), 2,
                      n_tokens).astype(np.int32)
    base = rng.integers(0, cfg.vocab, (R, S), dtype=np.int32)
    sysp = rng.integers(0, cfg.vocab, 12, dtype=np.int32)  # 3 shared pages
    knobs = dict(slots=slots, seg_len=2, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=ps, prepare=False,
                 log=lambda *a: None)
    rows = []
    for frac, kind in ((0.0, "hit0"), (0.5, "hit50"), (0.9, "hit90")):
        prompts = base.copy()
        n_shared = int(round(frac * R))
        if n_shared:
            prompts[:n_shared, :12] = sysp
        st_w, st_c = {}, {}

        def cold():
            outs, s = serve_continuous(cfg, params, prompts, n_tokens,
                                       prefix_cache="cold", **knobs)
            st_c.clear()
            st_c.update(s)
            return outs

        def warm():
            outs, s = serve_continuous(cfg, params, prompts, n_tokens,
                                       prefix_cache="on", **knobs)
            st_w.clear()
            st_w.update(s)
            return outs

        us_cold = timed(cold, n=reps)
        us_warm = timed(warm, n=reps)
        pw = st_w["prefix"]
        removed = 1.0 - pw["prefill_positions_computed"] \
            / max(pw["prefill_positions_total"], 1)
        lat_cold = float(np.mean(st_c["prefix"]["admit_lat_miss"])) * 1e6
        lat_hit = float(np.mean(pw["admit_lat_hit"])) * 1e6 \
            if pw["admit_lat_hit"] else lat_cold
        useful = int(budgets.sum())
        pg = st_w["pages"]
        tag = f"{DSCIM}/R{R}s{slots}x{S}+{n_tokens}"
        rows.append({
            "name": f"serve/prefix_{kind}/{tag}",
            "us": us_warm,
            "derived": (f"tok_s={useful / us_warm * 1e6:.1f};"
                        f"hit_rate_target={frac:.2f};"
                        f"hits={pw['hits']};lookups={pw['lookups']};"
                        f"hit_tokens={pw['hit_tokens']};"
                        f"pages_deduped={pw['pages_deduped']};"
                        f"prefill_removed_frac={removed:.3f};"
                        f"admit_us_hit={lat_hit:.1f};"
                        f"admit_us_cold={lat_cold:.1f};"
                        f"admit_latency_ratio={lat_hit / max(lat_cold, 1e-9):.3f};"
                        f"speedup_vs_cold={us_cold / us_warm:.2f}x;"
                        f"pages_live={pg['live_pages']};"
                        f"pages_retained={pg['retained_pages']};"
                        f"pages_shares={pg['shares']}")})
    return rows


def _chaos_rows(cfg, params, smoke):
    """ISSUE 6 rows: fault-free monitoring cost of the fault-tolerant
    serving runtime.  The same continuous queue is served plain and with
    the full monitoring stack armed (accuracy-watchdog probes every
    ``probe_every`` segments, a restorable host snapshot every
    ``snapshot_every`` segments) — no faults injected, so
    ``overhead_vs_plain`` is pure monitoring cost, the ratio
    tools/bench_regression.py bounds in CI.  Full mode adds a chaos-drill
    counters row (runtime/serving.chaos_drill: injected segment failure +
    page-pool bit flips + deadline expiry + stuck-at macro fault)."""
    from repro.launch.serve import serve_continuous
    from repro.runtime.serving import chaos_drill, watchdog_for_spec
    n_tokens = 4 if smoke else 16
    slots = 2 if smoke else 4
    R = 4 if smoke else 8
    seg_len = 4
    probe_every, snapshot_every = 8, 8
    prompt_len = 8
    reps = 1 if smoke else 3
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (R, prompt_len), dtype=np.int32)
    budgets = np.linspace(2, n_tokens, R).round().astype(np.int32)
    rng.shuffle(budgets)
    useful = int(budgets.sum())
    tag = f"{DSCIM}/R{R}s{slots}x{prompt_len}+{n_tokens}"
    knobs = dict(slots=slots, seg_len=seg_len, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=4, prepare=False)

    def plain():
        return serve_continuous(cfg, params, prompts, n_tokens, **knobs)[0]

    mon_stats = {}
    # threshold calibration (ErrorModel moment sampling) is cold-start
    # cost, not per-request serving overhead — build the watchdog once
    monitor = watchdog_for_spec(DSCIM, probe_every=probe_every)

    def monitored():
        # per-run counters (the watchdog object is reused across reps)
        monitor.n_probes = monitor.n_trips = 0
        monitor.history = []
        outs, st = serve_continuous(
            cfg, params, prompts, n_tokens, **knobs, monitor=monitor,
            snapshot_every=snapshot_every)
        mon_stats.update(st)
        return outs

    us_plain = timed(plain, n=reps)
    us_mon = timed(monitored, n=reps)
    shared = (f"useful_tokens={useful};probe_every={probe_every};"
              f"snapshot_every={snapshot_every}")
    rows = [{
        "name": f"serve/chaos_plain/{tag}",
        "us": us_plain,
        "derived": f"tok_s={useful / us_plain * 1e6:.1f};{shared}",
    }, {
        "name": f"serve/chaos_monitored/{tag}",
        "us": us_mon,
        "derived": (f"tok_s={useful / us_mon * 1e6:.1f};"
                    f"overhead_vs_plain={us_mon / us_plain:.3f};"
                    f"probes={mon_stats['probes']};"
                    f"probe_trips={mon_stats['probe_trips']};"
                    f"replays={mon_stats['replays']};"
                    # page-pool occupancy straight from PageAllocator.stats()
                    f"pages_live={mon_stats['pages']['live_pages']};"
                    f"pages_high_water={mon_stats['pages']['high_water']};"
                    f"pages_refusals={mon_stats['pages']['refusals']};"
                    f"{shared}"),
    }]
    if not smoke:
        import time
        t0 = time.perf_counter()
        rep = chaos_drill(log=lambda *a, **k: None)
        us_drill = (time.perf_counter() - t0) * 1e6
        rows.append({
            "name": "serve/chaos_drill/kernel:dscim2:64/R6s3x8+8",
            "us": us_drill,
            "derived": (f"requests={rep['requests']};"
                        f"clean={len(rep['clean'])};"
                        f"replays={rep['replays']};"
                        f"probe_trips={rep['probe_trips']};"
                        f"escalations={rep['escalations']};"
                        f"deadline_cancelled={rep['deadline_cancelled']};"
                        f"corrupted={len(rep['corrupted_requests'])}")})
    return rows


def _integrity_rows(cfg, params, smoke):
    """ISSUE 9 rows: cost and coverage of the checksummed-state integrity
    layer.  The same continuous queue is served with ``integrity='off'``
    and ``integrity='scrub:2'`` — no faults injected, so
    ``overhead_vs_off`` is pure scrubbing cost (digest plane upkeep in
    the jitted write paths + the boundary sweeps), the ratio
    tools/bench_regression.py bounds in CI.  Full mode adds a counters
    row from the self-verifying integrity drill (runtime/serving.py
    ``integrity_drill``: scripted page + weight-plane flips, exact-
    coordinate detection, bitwise-identical repaired outputs)."""
    from repro.launch.serve import serve_continuous
    from repro.runtime.serving import integrity_drill
    n_tokens = 4 if smoke else 16
    slots = 2 if smoke else 4
    R = 4 if smoke else 8
    seg_len = 4
    prompt_len = 8
    reps = 1 if smoke else 3
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (R, prompt_len), dtype=np.int32)
    budgets = np.linspace(2, n_tokens, R).round().astype(np.int32)
    rng.shuffle(budgets)
    useful = int(budgets.sum())
    tag = f"{DSCIM}/R{R}s{slots}x{prompt_len}+{n_tokens}"
    knobs = dict(slots=slots, seg_len=seg_len, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=4, prepare=False)

    def off():
        return serve_continuous(cfg, params, prompts, n_tokens, **knobs)[0]

    ig_stats = {}

    def scrubbed():
        outs, st = serve_continuous(cfg, params, prompts, n_tokens,
                                    **knobs, integrity="scrub:2")
        ig_stats.update(st["integrity"])
        return outs

    us_off = timed(off, n=reps)
    us_scrub = timed(scrubbed, n=reps)
    shared = f"useful_tokens={useful};period=2"
    rows = [{
        "name": f"serve/integrity_off/{tag}",
        "us": us_off,
        "derived": f"tok_s={useful / us_off * 1e6:.1f};{shared}",
    }, {
        "name": f"serve/integrity_scrub/{tag}",
        "us": us_scrub,
        "derived": (f"tok_s={useful / us_scrub * 1e6:.1f};"
                    f"overhead_vs_off={us_scrub / us_off:.3f};"
                    f"checks={ig_stats['checks']};"
                    f"pages_verified={ig_stats['pages_verified']};"
                    f"weight_planes_verified="
                    f"{ig_stats['weight_planes_verified']};"
                    f"mismatches={ig_stats['page_mismatches'] + ig_stats['weight_mismatches']};"
                    f"repairs={ig_stats['page_repairs'] + ig_stats['weight_repairs']};"
                    f"scrub_time_us={ig_stats['scrub_time_s'] * 1e6:.0f};"
                    f"{shared}"),
    }]
    if not smoke:
        import time
        t0 = time.perf_counter()
        rep = integrity_drill(log=lambda *a, **k: None)
        us_drill = (time.perf_counter() - t0) * 1e6
        rows.append({
            "name": "serve/integrity_drill/kernel:dscim2:64/R6s3x8+8",
            "us": us_drill,
            "derived": (f"requests={rep['requests']};"
                        f"page_repairs={rep['leg1']['page_repairs']};"
                        f"weight_repairs={rep['leg1']['weight_repairs'] + rep['leg2']['weight_repairs']};"
                        f"replays={rep['leg1']['replays'] + rep['leg2']['replays']};"
                        f"checks={rep['leg1']['checks'] + rep['leg2']['checks']};"
                        f"scrub_period={rep['scrub_period']}")})
    return rows


def run(smoke: bool = False):
    from repro.configs import get_arch
    from repro.launch.steps import prepare_serving_params
    from repro.models import get_model

    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(), dscim=DSCIM)
    model = get_model(cfg)
    params = prepare_serving_params(
        cfg, model.init_params(cfg, jax.random.PRNGKey(0)))
    rows = _dispatch_rows(cfg, params, smoke)
    rows += _queue_rows(cfg, params, smoke)
    rows += _spec_rows(cfg, params, smoke)
    rows += _prefix_rows(cfg, params, smoke)
    rows += _chaos_rows(cfg, params, smoke)
    rows += _integrity_rows(cfg, params, smoke)
    cfg_float = dataclasses.replace(cfg, dscim="off")
    params_float = model.init_params(cfg_float, jax.random.PRNGKey(0))
    rows += _paged_kv_rows(cfg_float, params_float, smoke)
    rows += _paged_kernel_rows(cfg_float, params_float, smoke)
    return rows


def main():
    """Prints CSV rows and returns them (benchmarks.run appends them to the
    BENCH_kernels.json trajectory)."""
    smoke = "--smoke" in sys.argv[1:]
    rows = run(smoke=smoke)
    for r in rows:
        emit(r["name"], r["us"], r["derived"])
    return rows


if __name__ == "__main__":
    main()
