"""Serving-loop microbench: host-loop vs device-resident scanned generation
(ISSUE 3 acceptance rows).

Times the two ``serve_batch`` drivers on the reduced serve config with
prepared (resident int8) DS-CIM weights at decode batch sizes M in
{1, 8, 16}: the legacy host loop dispatches one jitted decode per token
(n_tokens host round trips), the scanned path dispatches one jitted
prefill+scan per request (launch/steps.py ``make_generate_fn``).  The
derived fields record the dispatch accounting the scan removes:
``dispatches`` per request for each driver, plus
``dispatch_overhead_removed_us`` = (n_tokens-1) x the *directly measured*
per-dispatch host cost (a warmed jitted identity on the token array — the
fixed dispatch+transfer cost every host-loop step pays and the scan
doesn't).  The direct measurement is used because on interpret-mode CPU
the Pallas kernel time dominates and wobbles by ~10%, burying the ~ms
dispatch cost in an end-to-end subtraction; on a real TPU the same fields
apply unchanged.  Compile time is excluded (both drivers are warmed
before timing).
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed

DSCIM = "kernel:dscim1:256"


def _host_loop(prefill, decode, params, batch, n_tokens):
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for _ in range(n_tokens - 1):
        tok, cache = decode(params, {"token": tok}, cache)
        out.append(tok)
    return jnp.stack(out, axis=1)


def run(smoke: bool = False):
    from repro.configs import get_arch
    from repro.launch.steps import (make_decode_step, make_generate_fn,
                                    make_prefill_step,
                                    prepare_serving_params)
    from repro.models import get_model

    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(), dscim=DSCIM)
    model = get_model(cfg)
    params = prepare_serving_params(
        cfg, model.init_params(cfg, jax.random.PRNGKey(0)))
    n_tokens = 4 if smoke else 16
    prompt_len = 8
    reps = 1 if smoke else 3
    rows = []
    rng = np.random.default_rng(0)
    for B in ([1] if smoke else [1, 8, 16]):
        prompts = rng.integers(0, cfg.vocab, (B, prompt_len), dtype=np.int32)
        batch = {"tokens": jnp.asarray(prompts)}
        prefill = jax.jit(make_prefill_step(cfg, None,
                                            capacity=prompt_len + n_tokens))
        # cache donated between steps exactly like serve_batch's host loop
        # (each timed rep starts from its own fresh prefill cache)
        decode = jax.jit(make_decode_step(cfg, None), donate_argnums=(2,))
        generate = make_generate_fn(cfg, None, n_tokens)
        us_host = timed(lambda: _host_loop(prefill, decode, params, batch,
                                           n_tokens), n=reps)
        us_scan = timed(lambda: generate(params, batch)[0], n=reps)
        # per-dispatch host cost, measured directly on a warmed jitted
        # identity over the token array (what each removed dispatch pays)
        tok = jnp.zeros((B,), jnp.int32)
        noop = jax.jit(lambda t: t + 0)
        us_dispatch = timed(lambda: noop(tok), n=max(reps, 3))
        shared = (f"n_tokens={n_tokens};dispatches_host={n_tokens};"
                  f"dispatches_scanned=1;"
                  f"dispatch_us={us_dispatch:.1f};"
                  f"dispatch_overhead_removed_us="
                  f"{(n_tokens - 1) * us_dispatch:.1f}")
        rows.append({
            "name": f"serve/host_loop/{DSCIM}/B{B}x{prompt_len}+{n_tokens}",
            "us": us_host,
            "derived": (f"tok_s={B * n_tokens / us_host * 1e6:.1f};"
                        f"{shared}")})
        rows.append({
            "name": f"serve/scanned/{DSCIM}/B{B}x{prompt_len}+{n_tokens}",
            "us": us_scan,
            "derived": (f"tok_s={B * n_tokens / us_scan * 1e6:.1f};"
                        f"speedup_vs_host_loop={us_host / us_scan:.2f}x;"
                        f"{shared}")})
    return rows


def main():
    """Prints CSV rows and returns them (benchmarks.run appends them to the
    BENCH_kernels.json trajectory)."""
    smoke = "--smoke" in sys.argv[1:]
    rows = run(smoke=smoke)
    for r in rows:
        emit(r["name"], r["us"], r["derived"])
    return rows


if __name__ == "__main__":
    main()
