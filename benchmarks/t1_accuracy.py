"""Table I (accuracy rows) methodology: train a small conv-ish classifier
on a synthetic 10-class image task (CIFAR-10 is not available offline),
quantize INT8, and evaluate under DS-CIM error vs exact-INT8 — the same
pipeline the paper runs on ResNet18/CIFAR-10.

The classifier is a patchify-MLP (conv-as-matmul: every MVM goes through
DSCIMLinear), trained in float, evaluated in {float, exact-int8,
paper_inject dscim1/dscim2}."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dscim_layer import make_linear


def make_task(n: int = 2048, d: int = 192, classes: int = 10, seed: int = 0,
              task_seed: int = 42):
    """Separable blobs with structured noise (CIFAR stand-in).

    ``task_seed`` fixes the class prototypes (the task); ``seed`` draws the
    samples — train and eval share the task, never the samples."""
    protos = np.random.default_rng(task_seed).normal(0, 1, (classes, d))
    rng = np.random.default_rng(seed)
    y = rng.integers(0, classes, n)
    x = protos[y] + rng.normal(0, 1.0, (n, d))
    x = x / np.linalg.norm(x, axis=1, keepdims=True) * np.sqrt(d)
    return x.astype(np.float32), y.astype(np.int32)


def init_net(key, d: int, h: int, classes: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (d, h)) * d ** -0.5,
        "w2": jax.random.normal(k2, (h, h)) * h ** -0.5,
        "w3": jax.random.normal(k3, (h, classes)) * h ** -0.5,
    }


def fwd(p, x, linear=None):
    mm = (lambda a, w: a @ w) if linear is None else linear
    h = jax.nn.relu(mm(x, p["w1"]))
    h = jax.nn.relu(mm(h, p["w2"]))
    return mm(h, p["w3"])


def run(steps: int = 300, widths=(256, 1024, 2048)):
    """Sweep the contraction width K (layer width): the paper-style
    injection's accuracy drop vanishes at ResNet-like K (>=1k), while the
    physically-accumulated path needs the beyond-paper zero-bias calibration
    ('opt') to stay accurate — the central finding of our reproduction
    (EXPERIMENTS.md §Paper-validation)."""
    rows = []
    for h in widths:
        x, y = make_task()
        xe, ye = make_task(512, seed=1)
        p = init_net(jax.random.PRNGKey(0), x.shape[1], h, 10)

        @jax.jit
        def step(p, xb, yb):
            def loss(p):
                lg = fwd(p, xb)
                return -jnp.mean(jnp.take_along_axis(
                    jax.nn.log_softmax(lg), yb[:, None], 1))
            g = jax.grad(loss)(p)
            return jax.tree.map(lambda a, b: a - 0.01 * b, p, g)

        rng = np.random.default_rng(0)
        for i in range(steps):
            idx = rng.integers(0, len(x), 128)
            p = step(p, jnp.asarray(x[idx]), jnp.asarray(y[idx]))

        def acc(linear=None):
            lg = fwd(p, jnp.asarray(xe), linear)
            return float((np.asarray(lg).argmax(-1) == ye).mean())

        base = acc()
        rows.append({"name": f"t1acc/K{h}/float", "acc": base, "drop": 0.0})
        for nm, lin in [
            ("int8_exact", make_linear("dscim1", 256, "exact")),
            ("dscim1_L256_inject", make_linear("dscim1", 256,
                                               "paper_inject")),
            ("dscim2_L64_inject", make_linear("dscim2", 64, "paper_inject")),
            ("dscim1_L256_lut_paper", make_linear("dscim1", 256, "lut")),
            ("dscim1_L256_lut_opt", make_linear("dscim1", 256, "lut",
                                                "opt")),
        ]:
            a = acc(lin)
            rows.append({"name": f"t1acc/K{h}/{nm}", "acc": a,
                         "drop": base - a})
    return rows


def main():
    for r in run():
        print(f"{r['name']},0,acc={r['acc']:.4f};drop={r['drop']:+.4f}")


if __name__ == "__main__":
    main()
