"""Table I (RMSE rows): DS-CIM1/2 x L in {64,128,256}, paper-faithful
(searched classic PRNGs, floor truncation) and beyond-paper (scrambled
low-discrepancy points + midpoint correction), vs the paper's numbers.

Normalization: RMS(psum_err) / (H * 255^2) * 100%  (unsigned fullscale of
the 128-row accumulation window — the convention under which the paper's
Table I is reproducible; see EXPERIMENTS.md §Calibration-notes).
"""
from __future__ import annotations

import time

from repro.core.macro import DSCIMMacro
from repro.core.seed_search import calibrated_config

PAPER = {("dscim1", 64): 3.57, ("dscim1", 128): 2.03, ("dscim1", 256): 0.74,
         ("dscim2", 64): 3.81, ("dscim2", 128): 2.63, ("dscim2", 256): 0.84}


def run(n_cols: int = 256, n_vec: int = 48):
    rows = []
    for variant in ("dscim1", "dscim2"):
        for L in (64, 128, 256):
            for mode in ("paper", "opt"):
                t0 = time.perf_counter()
                mac = DSCIMMacro(calibrated_config(variant, L, mode))
                r = mac.rmse(n_cols=n_cols, n_vec=n_vec)
                us = (time.perf_counter() - t0) * 1e6
                rows.append({
                    "name": f"t1_rmse/{variant}/L{L}/{mode}",
                    "us": us,
                    "rmse_pct": r["unsigned_fullscale"],
                    "paper_pct": PAPER[(variant, L)],
                    "bias": r["bias"],
                })
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us']:.0f},"
              f"rmse={r['rmse_pct']:.3f}%;paper={r['paper_pct']}%;"
              f"bias={r['bias']:.0f}")


if __name__ == "__main__":
    main()
