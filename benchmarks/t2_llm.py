"""Table II methodology on our own stack: train a small LM, then evaluate
with FP8->INT8-aligned DS-CIM error injection vs exact, reporting the
accuracy/perplexity deltas (LLaMA-7B weights are not available offline; the
paper's *mechanism* — FP8 quantize, align to INT8 groups of 128, apply the
DS-CIM error pattern to MVM outputs — is reproduced end-to-end).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs import ARCHS
from repro.data.synthetic import SyntheticLM
from repro.launch.train import TrainLoop
from repro.models import get_model
from repro.models.lm import lm_loss


def run(steps: int = 120, eval_batches: int = 4):
    cfg = ARCHS["qwen3-0.6b"].reduced()
    loop = TrainLoop(cfg, steps=steps, batch=8, seq=32, ckpt_dir=None,
                     lr=2e-3, log=lambda *a: None)
    state = loop.run()
    params = state["params"]
    model = get_model(cfg)
    # same synthetic language as training (seed 0); unseen steps >= 10k
    data = SyntheticLM(cfg.vocab, seed=0)

    def eval_under(dscim_spec: str):
        c = dataclasses.replace(cfg, dscim=dscim_spec)
        losses, accs = [], []
        for i in range(eval_batches):
            b = data.batch(8, 32, step=10_000 + i)
            logits, _ = model.forward(params, c, {
                "tokens": b["tokens"], "labels": b["labels"]})
            losses.append(float(lm_loss(logits, b["labels"])))
            accs.append(float((np.asarray(logits).argmax(-1)
                               == b["labels"]).mean()))
        return float(np.mean(losses)), float(np.mean(accs))

    rows = []
    base_loss, base_acc = eval_under("off")
    rows.append({"name": "t2/float", "loss": base_loss, "acc": base_acc,
                 "delta": 0.0})
    for spec in ("exact:dscim1:256", "paper_inject:dscim1:256",
                 "paper_inject:dscim2:64", "lut:dscim1:256",
                 "lut:dscim1:256:opt"):
        loss, acc = eval_under(spec)
        rows.append({"name": f"t2/{spec.replace(':', '_')}",
                     "loss": loss, "acc": acc,
                     "delta": base_acc - acc})
    # NOTE: this reduced LM has K = 64-96 (<< one 128-row window), the
    # worst case for DS-CIM — see t1_accuracy's K-sweep for the trend that
    # reconciles these drops with the paper's near-zero ResNet/LLaMA drops.
    return rows


def main():
    for r in run():
        print(f"{r['name']},0,loss={r['loss']:.4f};acc={r['acc']:.4f};"
              f"acc_drop={r['delta']:+.4f}")


if __name__ == "__main__":
    main()
