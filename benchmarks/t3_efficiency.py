"""Table III + Fig. 4 + Fig. 7: hardware efficiency from the calibrated
analytical 40nm model (hwmodel.py).  These are model numbers reproducing the
paper's post-layout results — labeled as such."""
from __future__ import annotations

from repro.core.hwmodel import DSCIM1_HW, DSCIM2_HW

PAPER = {
    ("dscim1", 256): (669.7, 117.1), ("dscim1", 64): (2677.2, 468.4),
    ("dscim2", 64): (3566.1, 363.7), ("dscim2", 256): (891.5, 90.9),
}


def run():
    rows = []
    for variant, mk in (("dscim1", DSCIM1_HW), ("dscim2", DSCIM2_HW)):
        for L in (64, 128, 256):
            hw = mk(L)
            s = hw.summary(signed=True)
            paper = PAPER.get((variant, L))
            rows.append({
                "name": f"t3/{variant}/L{L}",
                "tops_w": s["tops_per_watt"],
                "tops_mm2": s["tops_per_mm2"],
                "area_mm2": s["area_mm2"],
                "paper": paper,
                "pwr_breakdown": s["power_breakdown"],
            })
    # Fig. 4: CMR sweep
    for cmr in (1, 4, 16, 64):
        hw = DSCIM2_HW(64, cmr=cmr)
        rows.append({
            "name": f"fig4/cmr{cmr}",
            "tops_w": hw.tops_per_watt(),
            "tops_mm2": hw.tops_per_mm2(),
            "area_mm2": hw.area_mm2(),
            "paper": None,
            "pwr_breakdown": None,
        })
    return rows


def main():
    for r in run():
        extra = ""
        if r["paper"]:
            extra = f";paper={r['paper'][0]}/{r['paper'][1]}"
        if r["pwr_breakdown"]:
            top = sorted(r["pwr_breakdown"].items(),
                         key=lambda kv: -kv[1])[:3]
            extra += ";pwr=" + "+".join(f"{k}:{v:.0%}" for k, v in top)
        print(f"{r['name']},0,TOPS/W={r['tops_w']:.1f};"
              f"TOPS/mm2={r['tops_mm2']:.1f};area={r['area_mm2']:.3f}mm2"
              + extra)


if __name__ == "__main__":
    main()
