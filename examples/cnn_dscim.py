"""Table-I accuracy methodology end to end (CIFAR stand-in):

train a small classifier in float -> quantize every MVM to int8 -> evaluate
exact-int8 vs DS-CIM1/DS-CIM2 (paper-style injection AND bit-exact LUT),
reporting accuracy drops — the paper's ResNet18/CIFAR-10 experiment shape,
run on a synthetic 10-class task (no datasets offline).

  PYTHONPATH=src python examples/cnn_dscim.py --steps 300
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.t1_accuracy import run  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    rows = run(steps=args.steps)
    print(f"{'config':28s} {'accuracy':>9s} {'drop':>8s}")
    for r in rows:
        print(f"{r['name']:28s} {r['acc']:9.4f} {r['drop']:+8.4f}")
    print("\n(cf. paper Table I: ResNet18@CIFAR10 94.54% float ->"
          " 94.45% DS-CIM1/L256, 94.31% DS-CIM2/L256)")


if __name__ == "__main__":
    main()
