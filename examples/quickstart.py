"""Quickstart: the DS-CIM approximate MVM in five minutes.

Runs an int8 MVM three ways — exact (DCIM adder-tree baseline), DS-CIM1
(precise), DS-CIM2 (efficient) — through the bit-exact LUT backend, prints
Table-I-style RMSE numbers and the hardware model's efficiency projections.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import DSCIMMacro, calibrated_config
from repro.core.hwmodel import DSCIM1_HW, DSCIM2_HW


def main():
    rng = np.random.default_rng(0)
    H = 128                                  # one macro column accumulation
    x = jnp.asarray(rng.integers(-128, 128, (4, H)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (H, 8)), jnp.int32)
    exact = np.asarray(x) @ np.asarray(w)

    print("int8 MVM, 128-row accumulation window")
    print(f"  exact (adder tree): psum[0,:4] = {exact[0, :4]}")
    for variant, L in (("dscim1", 256), ("dscim2", 64)):
        for mode in ("paper", "opt"):
            mac = DSCIMMacro(calibrated_config(variant, L, mode))
            est = np.asarray(mac.mvm(x, w))
            rmse = 100 * np.sqrt(((est - exact) ** 2).mean()) / (H * 255 * 255)
            print(f"  {mac.cfg.name:22s}: psum[0,:4] ~ {est[0, :4].astype(int)}"
                  f"  RMSE {rmse:.2f}% of fullscale")

    print("\ncalibrated 40nm hardware model (paper Table III):")
    for name, hw in (("DS-CIM1 @L=256", DSCIM1_HW(256)),
                     ("DS-CIM2 @L=64", DSCIM2_HW(64))):
        s = hw.summary()
        print(f"  {name}: {s['tops_per_watt']:.0f} TOPS/W, "
              f"{s['tops_per_mm2']:.0f} TOPS/mm2, {s['area_mm2']:.2f} mm2")


if __name__ == "__main__":
    main()
