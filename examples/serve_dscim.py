"""Batched serving with the DS-CIM compute path (paper Table II workflow):

1. build a small LM (trained weights if a checkpoint exists, else random),
2. serve a request batch on the float path,
3. re-serve with DS-CIM1 (precise) and DS-CIM2 (efficient) macro emulation,
4. report throughput, greedy-token agreement and logit RMSE.

  PYTHONPATH=src python examples/serve_dscim.py --tokens 16

Generation is device-resident by default: ``serve_batch`` jits prefill plus
an n-token ``lax.scan`` of decode steps into one dispatch per request
(launch/steps.py ``make_generate_fn``) — the KV cache rides the scan carry
and the per-token logit trace stays off the hot path (only the prefill
logits come back; the RMSE report below needs nothing more).  Pass
--host-loop to A/B the legacy one-dispatch-per-token driver.

Weights are prepared once by default: every DS-CIM-eligible matrix becomes
a resident window-packed int8 QuantizedLinearWeight before jitting — the
paper-faithful model (the CIM array stores static int8; quantization
happens at load, not per MVM), bit-identical to the per-call path under
f32 compute (this example's reduced configs).  Pass --no-prepare to A/B
the legacy per-token weight requantization.

Multi-chip: --mesh model=K serves the whole scanned loop under a
('data', 'model') mesh — prepared int8 planes + scales shard on N over
'model' (the paper's array banking across chips), e.g.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python examples/serve_dscim.py --mesh model=4

Only-live-work serving (ISSUE 4):
  --eos ID        EOS early exit — the scanned loop becomes a
                  lax.while_loop that stops once every row has emitted
                  EOS; finished rows are done-masked (cache position
                  frozen, tokens pinned to pad)
  --temp/--top-k/--top-p  sampling inside the scan (greedy stays the
                  default; the PRNG key rides the loop carry; --top-p is
                  nucleus sampling, ISSUE 5)
  --kv int8       block-paged int8 KV cache (core/kvcache.py): per-page
                  per-kv-head scales, ~4x fewer resident decode cache
                  bytes, dequant fused into the paged flash inner loop —
                  since ISSUE 5 read by the single-launch Pallas
                  paged-attention kernel for 'kernel' dscim modes
                  (kernels/paged_attention.py)
For continuous batching (admission into freed slots between scan
segments) use the serving driver:  python -m repro.launch.serve
--continuous --eos 7 --kv int8 --dscim kernel:dscim1:256
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch.serve import serve_batch
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=12)
    ap.add_argument("--no-prepare", action="store_true",
                    help="re-quantize weights every call (legacy hot path) "
                         "instead of the default prepare-once int8 weights")
    ap.add_argument("--host-loop", action="store_true",
                    help="legacy one-dispatch-per-token host loop instead "
                         "of the scanned device-resident generate")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="serve under a mesh, e.g. 'model=4' (needs that "
                         "many jax devices; prepared qweights shard N over "
                         "'model')")
    ap.add_argument("--eos", type=int, default=None, metavar="ID",
                    help="EOS early exit (lax.while_loop generation with "
                         "done-masked ragged completion)")
    ap.add_argument("--temp", type=float, default=None,
                    help="temperature sampling inside the scan")
    ap.add_argument("--top-k", type=int, default=None,
                    help="top-k sampling inside the scan")
    ap.add_argument("--top-p", type=float, default=None,
                    help="top-p (nucleus) sampling inside the scan "
                         "(exclusive with --top-k)")
    ap.add_argument("--kv", choices=("float", "int8"), default="float",
                    help="dense float KV cache or the block-paged int8 one "
                         "(read through the fused Pallas paged-attention "
                         "kernel for 'kernel' dscim modes; "
                         "REPRO_PAGED_ATTN=jnp forces the gather "
                         "reference)")
    args = ap.parse_args()
    from repro.launch.serve import _sample_spec
    sample = _sample_spec(args)

    par = None
    if args.mesh:
        from repro.launch.mesh import parallel_ctx_from_spec
        par = parallel_ctx_from_spec(args.mesh)
    cfg = get_arch(args.arch).reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32)

    results = {}
    for tag, spec in [("float", "off"),
                      ("dscim1/L256", "paper_inject:dscim1:256"),
                      ("dscim2/L64", "paper_inject:dscim2:64"),
                      ("dscim1/L256/exact-lut", "lut:dscim1:256"),
                      ("dscim1/L256/fused-kernel", "kernel:dscim1:256")]:
        c = dataclasses.replace(cfg, dscim=spec)
        t0 = time.time()
        toks, logits = serve_batch(c, params, prompts, args.tokens, par=par,
                                   prepare=not args.no_prepare,
                                   scan=not args.host_loop,
                                   eos_id=args.eos, sample=sample,
                                   kv=args.kv)
        dt = time.time() - t0
        results[tag] = (toks, logits[0], args.batch * args.tokens / dt)

    loop = "host-loop" if args.host_loop else "scanned"
    mesh = f", mesh {args.mesh}" if args.mesh else ""
    base_toks, base_lg, base_tps = results["float"]
    print(f"float ({loop}{mesh}): {base_tps:.1f} tok/s")
    for tag in list(results)[1:]:
        toks, lg, tps = results[tag]
        agree = float((toks == base_toks).mean())
        rmse = float(np.sqrt(np.mean((np.asarray(lg) -
                                      np.asarray(base_lg)) ** 2)))
        print(f"{tag}: {tps:.1f} tok/s, token agreement {agree:.2f}, "
              f"logit RMSE {rmse:.3f}")


if __name__ == "__main__":
    main()
