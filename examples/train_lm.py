"""End-to-end driver: train an LM on the synthetic corpus with checkpoints,
watchdog, and failover — then evaluate it under DS-CIM serving.

Presets:
  tiny  (default) — ~1M params, 300 steps, finishes in a few minutes on CPU.
  100m            — olmo-style ~100M params (d=768, 12L); the full-scale
                    config a real deployment would launch on the 16x16 mesh
                    (hours on this CPU container; run it on hardware).

  PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 300
"""
import argparse
import dataclasses

from repro.configs import get_arch
from repro.launch.train import TrainLoop


def preset_cfg(name: str):
    base = get_arch("olmo-1b")
    if name == "tiny":
        return dataclasses.replace(
            base.reduced(), d_model=128, n_heads=4, n_kv=4, head_dim=32,
            d_ff=384, vocab=512, n_layers=4)
    if name == "100m":
        return dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv=12,
            head_dim=64, d_ff=3072, compute_dtype="float32", remat=False)
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=("tiny", "100m"))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir (default: fresh temp dir; pass an "
                         "existing dir to resume)")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject a simulated hardware fault at these steps")
    args = ap.parse_args()

    cfg = preset_cfg(args.preset)
    ckpt = args.ckpt
    if ckpt is None:
        import tempfile
        ckpt = tempfile.mkdtemp(prefix="repro_train_lm_")
    loop = TrainLoop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=ckpt, lr=2e-3,
                     fail_at=tuple(args.fail_at))
    state = loop.run()

    import numpy as np
    losses = [h["loss"] for h in loop.history]
    if losses:
        print(f"\nloss: {np.mean(losses[:10]):.3f} -> "
              f"{np.mean(losses[-10:]):.3f} "
              f"({args.steps} steps, {cfg.name} {args.preset})")
    else:
        print(f"\n(already trained to step {state['step']}; resumed "
              f"checkpoint from {ckpt})")

    # quick DS-CIM serving check on the trained weights.  NOTE: this tiny
    # model's contraction width (d_model=128) is below one 128-row macro
    # window — the worst case for DS-CIM (see EXPERIMENTS.md K-sweep); the
    # int8-exact path shows the quantization-only baseline.
    from repro.launch.serve import serve_batch
    import numpy as np
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (4, 16), dtype=np.int32)
    toks_f, _ = serve_batch(cfg, state["params"], prompts, 8)
    for tag, spec in [("int8-exact", "exact:dscim1:256"),
                      ("DS-CIM1/L256", "paper_inject:dscim1:256")]:
        cfg_ds = dataclasses.replace(cfg, dscim=spec)
        toks_d, _ = serve_batch(cfg_ds, state["params"], prompts, 8)
        agree = float((toks_f == toks_d).mean())
        print(f"{tag} serving: greedy-token agreement {agree:.2f} "
              f"vs float path")


if __name__ == "__main__":
    main()
