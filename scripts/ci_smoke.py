"""CI serve-smoke driver (ISSUE 5 satellite): one named entry per smoke
instead of four copy-pasted arg soups in .github/workflows/ci.yml.

Each smoke is a named argv preset for ``repro.launch.serve.main`` — the
same entry point operators use — run in-process so one CI step can chain
several smokes while reusing the warmed jax runtime.  The workflow legs
shrink to ``python -m scripts.ci_smoke <name> [<name> ...]`` and adding a
smoke is a one-line dict edit, not a YAML block.

Smokes (all interpret-mode, reduced configs):
  continuous         staggered admission, EOS early-exit, int8 paged KV
  paged-kernel       --kv int8 through the fused Pallas paged-attention
                     read path (--paged-attn kernel)
  paged-jnp          the same serve through the jnp gather reference
                     (--paged-attn jnp) — the A/B leg
  mesh               scanned generate under --mesh model=4
  mesh-paged         int8 paged KV under --mesh model=4 through the jnp
                     gather reference (--paged-attn jnp — GSPMD
                     partitioning of the reference path)
  mesh-paged-kernel  the Pallas read path under --mesh model=4 (the
                     shard_map placement smoke; multidevice job only)
  chaos              the fault-tolerant serving drill (--chaos,
                     runtime/serving.chaos_drill): injected segment
                     failure + page-pool bit flips + deadline expiry +
                     stuck-at macro fault; asserts every request gets a
                     definite status, unaffected requests stay bitwise
                     equal to the fault-free run, and the watchdog
                     escalates dscim2 -> dscim1
  spec               self-speculative decoding through the continuous
                     scheduler (--spec dscim2:4, ISSUE 7): dscim2 drafts,
                     dscim1 verifies, int8 paged KV — the full
                     draft/verify/rollback window machinery under
                     staggered admission and EOS early-exit
  integrity          continuous int8 paged serving with checksummed-state
                     integrity checks armed (--integrity scrub:2, ISSUE 9)
                     under the sampled chaos schedule (--sampled-chaos
                     --chaos-seed 21): device losses + page/weight bit
                     upsets detected, repaired, and replayed in-run
  integrity-drill    the self-verifying integrity drill
                     (runtime/serving.py integrity_drill): scripted page
                     and weight-plane flips under scrub:2; asserts exact-
                     coordinate detection, surgical repair, zero ladder
                     escalations, and bitwise-identical outputs vs the
                     fault-free run
  prefix             the prefix-cache drill (--prefix-drill,
                     runtime/serving.prefix_drill, ISSUE 10): staggered
                     admissions where 4 of 6 requests share a 3-page
                     system prompt; asserts the warm (prefix_cache=on)
                     outputs are bitwise the cold chunked reference's,
                     the hit/dedup ledger matches the trace exactly
                     (4 hits, 12 pages deduped, 48 prompt positions
                     skipped, > 40% of prefill removed), shared pages
                     are quantized once and refcount-freed to the
                     retained pool, and the pool drains to zero live
  prefix-router      the asyncio router replaying a 75%-shared-prefix
                     trace warm vs the all-chunked cold reference under
                     real traffic (deadlines, disconnects, reclaim;
                     benchmarks/loadtest.py --prefix-cache): asserts
                     terminal statuses, zero live pages, and ok-vs-ok
                     bitwise agreement between legs
  router             the asyncio serving router under a mini heavy-tailed
                     load-test trace with the sampled fault schedule
                     armed (benchmarks/loadtest.py --smoke, ISSUE 8):
                     asserts every request reaches a definite terminal
                     status, zero live pages at drain, and ok-vs-ok
                     bitwise agreement between the plain and chaos legs
                     (this one dispatches to ``benchmarks.loadtest.main``
                     rather than ``serve.main``)

Usage:  PYTHONPATH=src python -m scripts.ci_smoke continuous paged-kernel
        PYTHONPATH=src python -m scripts.ci_smoke --list
"""
from __future__ import annotations

import sys

_DSCIM = "kernel:dscim1:256"
_PAGED = ["--kv", "int8", "--page-size", "4", "--eos", "7"]

SMOKES: dict = {
    "continuous": ["--continuous", "--requests", "6", "--batch", "2",
                   "--segment-len", "2", "--tokens", "6",
                   "--dscim", _DSCIM, *_PAGED],
    "paged-kernel": ["--tokens", "8", "--batch", "4", "--dscim", _DSCIM,
                     *_PAGED, "--paged-attn", "kernel"],
    "paged-jnp": ["--tokens", "8", "--batch", "4", "--dscim", _DSCIM,
                  *_PAGED, "--paged-attn", "jnp"],
    "mesh": ["--tokens", "8", "--batch", "4", "--dscim", _DSCIM,
             "--mesh", "model=4"],
    "mesh-paged": ["--tokens", "8", "--batch", "4", "--dscim", _DSCIM,
                   "--mesh", "model=4", *_PAGED, "--paged-attn", "jnp"],
    "mesh-paged-kernel": ["--tokens", "8", "--batch", "4",
                          "--dscim", _DSCIM, "--mesh", "model=4", *_PAGED,
                          "--paged-attn", "kernel"],
    "chaos": ["--chaos"],
    "integrity": ["--continuous", "--requests", "6", "--batch", "2",
                  "--segment-len", "2", "--tokens", "6", "--dscim", _DSCIM,
                  *_PAGED, "--integrity", "scrub:2", "--sampled-chaos",
                  "--chaos-seed", "21"],
    "integrity-drill": ["--integrity-drill"],
    "spec": ["--continuous", "--requests", "6", "--batch", "2",
             "--segment-len", "2", "--tokens", "6", "--dscim", _DSCIM,
             *_PAGED, "--spec", "dscim2:4"],
    "router": ["--smoke", "--no-append"],
    "prefix": ["--prefix-drill"],
    "prefix-router": ["--smoke", "--prefix-cache", "--no-append"],
}

# smokes whose preset drives a different entry point than serve.main
_ENTRY = {"router": "benchmarks.loadtest",
          "prefix-router": "benchmarks.loadtest"}


def run(names) -> int:
    from repro.launch import serve

    for name in names:
        if name not in SMOKES:
            print(f"unknown smoke {name!r}; have {sorted(SMOKES)}",
                  file=sys.stderr)
            return 2
        argv = SMOKES[name]
        entry = _ENTRY.get(name, "launch.serve")
        print(f"# === ci_smoke {name}: {entry} {' '.join(argv)} ===",
              flush=True)
        if name in _ENTRY:
            import importlib
            rc = importlib.import_module(_ENTRY[name]).main(argv)
        else:
            # --paged-attn is a builder-cache-keyed parameter (not env
            # state), so chained smokes can A/B read paths without cache
            # hygiene
            rc = serve.main(argv)
        if rc:
            print(f"# ci_smoke {name} FAILED (rc={rc})", file=sys.stderr)
            return rc
        print(f"# ci_smoke {name} OK", flush=True)
    return 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv or "--list" in argv:
        for name, args in SMOKES.items():
            print(f"{name}: serve {' '.join(args)}")
        return 0 if "--list" in argv else 2
    return run(argv)


if __name__ == "__main__":
    sys.exit(main())
