"""Sharded checkpointing: npz leaf files + JSON manifest, atomic renames,
async writes, keep-N retention, and reshard-on-restore (elastic restarts).

Layout:
  <dir>/step_000123/
      manifest.json          # tree structure, shapes, dtypes, step, extras
      arr_00000.npy ...      # one file per leaf (host-local full arrays)
  <dir>/LATEST               # atomic pointer file

Single-host container: each leaf is saved unsharded (device arrays are
gathered);  restore re-`device_put`s against *whatever shardings the new
mesh provides*, so a 16x16 checkpoint restores onto 2x16x16 or 1-device
meshes unchanged — that is the elastic-restart contract, covered by
tests/test_checkpoint.py.  On multi-host deployments the same manifest
format extends with per-host shard files (process_index suffix).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree, extras: dict | None = None,
             blocking: bool = False):
        """Async by default; the previous pending save is joined first."""
        self.wait()
        host_leaves = [np.asarray(x) for x in _flatten(tree)[0]]
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            for i, leaf in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), leaf)
            manifest = {
                "step": step,
                "n_leaves": len(host_leaves),
                "treedef": str(treedef),
                "extras": extras or {},
                "time": time.time(),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                      # atomic publish
            with open(os.path.join(self.dir, ".LATEST_tmp"), "w") as f:
                f.write(os.path.basename(final))
            os.replace(os.path.join(self.dir, ".LATEST_tmp"),
                       os.path.join(self.dir, "LATEST"))
            self._gc()

        t = threading.Thread(target=_write, daemon=True)
        t.start()
        self._pending = t
        if blocking:
            self.wait()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def latest_step(self) -> int | None:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        name = open(p).read().strip()
        if not os.path.exists(os.path.join(self.dir, name, "manifest.json")):
            return None
        return int(name.split("_")[1])

    def restore(self, step: int, target_tree, shardings=None):
        """Load leaves and place them against ``shardings`` (or CPU).

        ``target_tree`` provides the pytree structure (values ignored).
        Reshard-on-restore: shardings may describe any mesh.
        """
        d = os.path.join(self.dir, f"step_{step:09d}")
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        leaves, treedef = _flatten(target_tree)
        assert manifest["n_leaves"] == len(leaves), (
            "checkpoint/model structure mismatch",
            manifest["n_leaves"], len(leaves))
        out = []
        shard_leaves = (_flatten(shardings)[0] if shardings is not None
                        else [None] * len(leaves))
        for i, (ref, shard) in enumerate(zip(leaves, shard_leaves)):
            arr = np.load(os.path.join(d, f"arr_{i:05d}.npy"))
            assert tuple(arr.shape) == tuple(ref.shape), (
                f"leaf {i} shape {arr.shape} != expected {ref.shape}")
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extras"]

    def restore_latest(self, target_tree, shardings=None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extras = self.restore(step, target_tree, shardings)
        return step, tree, extras
