"""Assigned-architecture registry: --arch <id> resolves here."""
from .base import ArchConfig, LM_SHAPES, Shape  # noqa: F401

from . import (olmo_1b, qwen3_0p6b, starcoder2_7b, codeqwen1p5_7b,
               deepseek_moe_16b, granite_moe_1b, rwkv6_7b, zamba2_7b,
               musicgen_large, pixtral_12b)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (olmo_1b, qwen3_0p6b, starcoder2_7b, codeqwen1p5_7b,
              deepseek_moe_16b, granite_moe_1b, rwkv6_7b, zamba2_7b,
              musicgen_large, pixtral_12b)
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
