"""ArchConfig: one dataclass describing every assigned architecture, its
input-shape set, and the reduced smoke-test variant.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = ["ArchConfig", "LM_SHAPES", "Shape"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq: int
    batch: int
    kind: str              # train | prefill | decode
    subquadratic_only: bool = False


# The assigned LM shape set (same for all 10 archs).
LM_SHAPES = (
    Shape("train_4k", 4096, 256, "train"),
    Shape("prefill_32k", 32768, 32, "prefill"),
    Shape("decode_32k", 32768, 128, "decode"),
    Shape("long_500k", 524288, 1, "decode", subquadratic_only=True),
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    mlp_kind: str = "swiglu"       # swiglu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm | layernorm_np (olmo)
    qk_norm: bool = False
    head_pad_to: int = 0           # pad q heads for clean TP (zero wo rows)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    stub_frontend: bool = False    # musicgen/pixtral: inputs are embeddings
    # moe
    moe_experts: int = 0
    moe_topk: int = 0
    moe_shared: int = 0
    moe_capacity: float = 1.25     # capacity factor (tokens may drop above)
    # ssm / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    mamba_per_block: int = 3       # zamba: mamba layers per shared-attn block
    # execution knobs
    remat: bool = True
    q_chunk: int = 512
    kv_chunk: int = 1024
    scan_chunk: int = 64
    cache_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    vocab_pad_mult: int = 256
    shapes: tuple = LM_SHAPES
    source: str = ""               # provenance tag [paper/hf; tier]
    # DS-CIM serving path: "off" or "<mode>:<variant>:<L>[:<calib>]",
    # e.g. "lut:dscim1:256" (bit-exact) or "paper_inject:dscim2:64:opt".
    dscim: str = "off"
    # Injected macro hardware fault for chaos testing (runtime/failover.py):
    # "" (healthy) or "stuck:<stride>:<value>" — every <stride>-th output
    # column of each DS-CIM linear reads back the constant <value>, the
    # trace-level model of stuck-at failures in the CIM array's
    # OR-accumulation columns.  Ignored when dscim is "off".
    dscim_fault: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        return math.ceil(self.vocab / self.vocab_pad_mult) * self.vocab_pad_mult

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def shape(self, name: str) -> Shape:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name} has no shape {name}")

    def runnable(self, shape_name: str) -> bool:
        s = self.shape(shape_name)
        return self.is_subquadratic or not s.subquadratic_only

    # -- smoke-test reduction --------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Same family/topology, tiny dims: runs a real step on 1 CPU core."""
        def rd(v, lo, cap):
            return max(lo, min(v, cap))
        return dataclasses.replace(
            self,
            n_layers=2 if self.family != "hybrid" else 4,
            d_model=64,
            n_heads=rd(self.n_heads, 2, 4),
            n_kv=rd(self.n_kv, 1, 2),
            head_dim=16,
            d_ff=96,
            vocab=128,
            vocab_pad_mult=32,
            moe_experts=min(self.moe_experts, 8),
            moe_topk=min(self.moe_topk, 2),
            moe_shared=min(self.moe_shared, 1),
            moe_capacity=8.0,   # no drops: decode == prefill determinism

            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            mamba_per_block=min(self.mamba_per_block, 2),
            q_chunk=8, kv_chunk=8, scan_chunk=4,
            compute_dtype="float32", cache_dtype="float32",
            remat=False,
        )

    # -- input specs (ShapeDtypeStruct stand-ins, no allocation) ---------------
    def input_specs(self, shape_name: str):
        """Returns (kind, batch_pytree) of ShapeDtypeStructs for the step fn."""
        s = self.shape(shape_name)
        f = jax.ShapeDtypeStruct
        if s.kind == "train":
            if self.stub_frontend:
                batch = {"embeds": f((s.batch, s.seq, self.d_model),
                                     jnp.bfloat16),
                         "labels": f((s.batch, s.seq), jnp.int32)}
            else:
                batch = {"tokens": f((s.batch, s.seq), jnp.int32),
                         "labels": f((s.batch, s.seq), jnp.int32)}
        elif s.kind == "prefill":
            if self.stub_frontend:
                batch = {"embeds": f((s.batch, s.seq, self.d_model),
                                     jnp.bfloat16)}
            else:
                batch = {"tokens": f((s.batch, s.seq), jnp.int32)}
        elif s.kind == "decode":
            if self.stub_frontend:
                batch = {"embed": f((s.batch, 1, self.d_model), jnp.bfloat16)}
            else:
                batch = {"token": f((s.batch,), jnp.int32)}
        else:
            raise ValueError(s.kind)
        return s.kind, batch

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        attn = D * self.n_heads * self.head_dim * 2 \
            + D * self.n_kv * self.head_dim * 2
        if self.family == "moe":
            ff = self.moe_experts * 3 * D * F + D * self.moe_experts \
                + self.moe_shared * 3 * D * F
        elif self.mlp_kind == "swiglu":
            ff = 3 * D * F
        else:
            ff = 2 * D * F
        if self.family == "ssm":                      # rwkv6
            per_layer = 5 * D * D + 2 * D * F + D * F  # approx: 5 proj + ffn
        elif self.family == "hybrid":
            mamba = 2 * D * D + 2 * D * self.ssm_state + D * D
            per_layer = mamba
        else:
            per_layer = attn + ff
        total = self.n_layers * per_layer
        if self.family == "hybrid":                   # one shared attn block
            total += attn
        emb = (0 if self.stub_frontend else V * D) + V * D
        return int(total + emb)

    def active_param_count(self) -> int:
        """MoE: active params per token (top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        D, F = self.d_model, self.d_ff
        attn = D * self.n_heads * self.head_dim * 2 \
            + D * self.n_kv * self.head_dim * 2
        ff_active = (self.moe_topk + self.moe_shared) * 3 * D * F \
            + D * self.moe_experts
        emb = (0 if self.stub_frontend else self.vocab * D) + self.vocab * D
        return int(self.n_layers * (attn + ff_active) + emb)
