"""codeqwen1.5-7b [dense]: 32L d=4096 32H (kv=32 = MHA) ff=13440 vocab=92416.
qwen1.5 architecture. [hf:Qwen/CodeQwen1.5-7B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv=32, d_ff=13440, vocab=92416, head_dim=128,
    mlp_kind="swiglu", norm="rmsnorm", rope_theta=1e6,
    source="hf:Qwen/CodeQwen1.5-7B; hf")
