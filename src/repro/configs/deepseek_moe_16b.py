"""deepseek-moe-16b [moe]: 28L d=2048 16H (kv=16) expert_ff=1408
vocab=102400, 64 routed top-6 + 2 shared (fine-grained).
[arXiv:2401.06066; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv=16, d_ff=1408, vocab=102400, head_dim=128,
    mlp_kind="swiglu", norm="rmsnorm", rope_theta=10000.0,
    moe_experts=64, moe_topk=6, moe_shared=2,
    source="arXiv:2401.06066; hf")
