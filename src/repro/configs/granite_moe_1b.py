"""granite-moe-1b-a400m [moe]: 24L d=1024 16H (GQA kv=8) expert_ff=512
vocab=49155, 32 routed top-8, no shared experts.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
    n_heads=16, n_kv=8, d_ff=512, vocab=49155, head_dim=64,
    mlp_kind="swiglu", norm="rmsnorm", rope_theta=10000.0,
    moe_experts=32, moe_topk=8, moe_shared=0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf")
