"""musicgen-large [audio]: 48L d=2048 32H (kv=32) ff=8192 vocab=2048,
decoder-only over EnCodec tokens; modality frontend is a STUB -
input_specs provides precomputed frame embeddings (B,S,D).  RoPE replaces
the original learned absolute positions (documented adaptation).
[arXiv:2306.05284; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="dense", n_layers=48, d_model=2048,
    n_heads=32, n_kv=32, d_ff=8192, vocab=2048, head_dim=64,
    mlp_kind="gelu", norm="layernorm", stub_frontend=True,
    source="arXiv:2306.05284; hf")
