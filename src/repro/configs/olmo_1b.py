"""olmo-1b [dense]: 16L d=2048 16H (kv=16) ff=8192 vocab=50304.
Non-parametric LayerNorm. [arXiv:2402.00838; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048, n_heads=16,
    n_kv=16, d_ff=8192, vocab=50304, head_dim=128, mlp_kind="swiglu",
    norm="layernorm_np", rope_theta=10000.0,
    source="arXiv:2402.00838; hf")
