"""pixtral-12b [vlm]: 40L d=5120 32H (GQA kv=8) ff=14336 vocab=131072,
mistral-nemo-style decoder backbone (head_dim=128); pixtral-ViT frontend is
a STUB - input_specs provides precomputed patch embeddings (B,S,D).
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv=8, d_ff=14336, vocab=131072, head_dim=128,
    mlp_kind="swiglu", norm="rmsnorm", rope_theta=1e6, stub_frontend=True,
    source="hf:mistralai/Pixtral-12B-2409; unverified")
