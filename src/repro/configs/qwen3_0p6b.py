"""qwen3-0.6b [dense]: 28L d=1024 16H (GQA kv=8) ff=3072 vocab=151936.
qk_norm, GQA, head_dim=128 (projected), tied embeddings.
[hf:Qwen/Qwen3-8B (family); hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense", n_layers=28, d_model=1024, n_heads=16,
    n_kv=8, d_ff=3072, vocab=151936, head_dim=128, mlp_kind="swiglu",
    norm="rmsnorm", qk_norm=True, rope_theta=1e6, tie_embeddings=True,
    source="hf:Qwen/Qwen3-0.6B; hf")
