"""rwkv6-7b [ssm] "Finch": 32L d=4096 attn-free ff=14336 vocab=65536,
data-dependent decay, head_dim 64. [arXiv:2404.05892; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096, n_heads=64,
    n_kv=64, d_ff=14336, vocab=65536, head_dim=64, ssm_head_dim=64,
    norm="rmsnorm", scan_chunk=16,   # two-sided WKV: chunk*DECAY_CLIP <= 80
    source="arXiv:2404.05892; hf")
