"""starcoder2-7b [dense]: 32L d=4608 36H (GQA kv=4) ff=18432 vocab=49152.
GQA + RoPE; GELU MLP (starcoder2 uses gelu, non-gated).
[arXiv:2402.19173; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
    n_heads=36, n_kv=4, d_ff=18432, vocab=49152, head_dim=128,
    head_pad_to=48,  # 36 heads pad to 48 for clean 16-way TP (zero wo rows)
    mlp_kind="gelu", norm="layernorm", rope_theta=1e5,
    source="arXiv:2402.19173; hf")
