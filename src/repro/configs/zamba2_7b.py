"""zamba2-7b [hybrid]: 81L d=3584 32H (kv=32) ff=14336 vocab=32000,
Mamba2 backbone (ssm_state=64) + shared GQA attention block (weight-shared,
applied once per 3-mamba-layer super-block -> 27 applications).
[arXiv:2411.15242; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584, n_heads=32,
    n_kv=32, d_ff=14336, vocab=32000, head_dim=112, ssm_state=64,
    ssm_head_dim=64, mamba_per_block=3, norm="rmsnorm", scan_chunk=64,
    source="arXiv:2411.15242; unverified")
