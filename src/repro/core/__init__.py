"""DS-CIM core: the paper's contribution as composable JAX modules.

Layering:
  prng      — 8-bit PRNG / low-discrepancy point sequences (PRNGA, PRNGW)
  remap     — sample-region remapping (reflected fold) + count LUT
  ormac     — cycle-accurate OR-MAC oracle + naive saturating baseline [27]
  macro     — DS-CIM1/2 MVM estimator (cycle / lut / bitmatmul backends)
  quant     — int8 / FP8 quantization + FP8->INT8 group alignment [30]
  seed_search — Sec. IV-C PRNG/seed optimization + calibrated presets
  error_model — calibrated statistical injection (big-model fast path)
  dscim_layer — DSCIMLinear: drop-in quantized linear for the LM framework
  hwmodel   — analytical 40nm energy/area model (Tables III, Figs. 4/7)
"""
from .macro import DSCIMConfig, DSCIMMacro, dscim1, dscim2  # noqa: F401
from .dscim_layer import DSCIMLinear, make_linear           # noqa: F401
from .seed_search import calibrated_config                  # noqa: F401
