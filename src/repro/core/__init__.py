"""DS-CIM core: the paper's contribution as composable JAX modules.

Layering:
  prng      — 8-bit PRNG / low-discrepancy point sequences (PRNGA, PRNGW)
  remap     — sample-region remapping (reflected fold) + count LUT
  ormac     — cycle-accurate OR-MAC oracle + naive saturating baseline [27]
  macro     — DS-CIM1/2 MVM estimator (cycle / lut / bitmatmul backends)
  quant     — int8 / FP8 quantization + FP8->INT8 group alignment [30]
  seed_search — Sec. IV-C PRNG/seed optimization + calibrated presets
  error_model — calibrated statistical injection (big-model fast path)
  dscim_layer — DSCIMLinear: drop-in quantized linear for the LM framework
  qweights  — prepared (quantize-once) weights: the CIM array's resident
              int8 storage as a pytree; serve-startup param-tree conversion
  hwmodel   — analytical 40nm energy/area model (Tables III, Figs. 4/7)
"""
from .macro import DSCIMConfig, DSCIMMacro, dscim1, dscim2  # noqa: F401
from .dscim_layer import DSCIMLinear, make_linear           # noqa: F401
from .qweights import (QuantizedLinearWeight,               # noqa: F401
                       prepare_dscim_params, prepare_linear_weight)
from .seed_search import calibrated_config                  # noqa: F401
