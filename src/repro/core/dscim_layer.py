"""DSCIMLinear — the framework integration point of the paper's technique.

A drop-in replacement for ``x @ W`` that quantizes to int8 (per-channel) and
computes the matmul the way a DS-CIM accelerator would:

* ``exact``        — int8 matmul, float rescale (the DCIM adder-tree baseline);
* ``lut``          — bit-exact DS-CIM emulation via the joint-count LUT;
* ``bitmatmul``    — bit-exact DS-CIM via the {0,1}-expanded MXU matmul (the
                     Pallas kernel's math; pure-jnp twin here);
* ``kernel``       — the serving hot path: fused single-launch Pallas kernel
                     (kernels/dscim_fused.py) — windows iterated inside the
                     grid, sign-correction + dequant in-kernel, batched;
* ``statistical``  — calibrated Gaussian injection (fast big-model path).

Every backend accepts ``w`` as either a float ``(K, N)`` matrix (training /
tests: quantized on the fly per call) or a prepared
``core.qweights.QuantizedLinearWeight`` (serving: the int8 window planes and
per-window scales are resident, mirroring the CIM array's static int8
storage — only activations are quantized per call).  The two are
bit-identical; ``prepare_dscim_params`` converts a whole param tree once at
serve startup.

The hardware accumulates in windows of ``cfg.rows`` (=128) physical rows and
sums window results digitally (exact), so K > 128 decomposes into exact sums
of 128-row stochastic MACs — which is what all backends implement (the error
process is per-row i.i.d.-across-windows, so no explicit windowing is needed
for lut/bitmatmul; ``statistical`` scales moments by K directly).

Noise keys (``statistical`` / ``paper_inject``): when no explicit ``key`` is
threaded from the serve/train step, the fallback key folds in the operand
shape and the call-site ``salt`` (layer index × matmul site, threaded by
models/lm.py) — distinct layers and distinct matmuls inside one layer draw
distinct noise instead of replaying PRNGKey(0) everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .error_model import ErrorModel
from .macro import DSCIMConfig, DSCIMMacro
from .quant import quantize_int8
from .qweights import QuantizedLinearWeight, prepare_linear_weight
from .seed_search import calibrated_config

__all__ = ["DSCIMLinear", "make_linear"]


def _env_tune() -> bool:
    """The ``REPRO_DSCIM_TUNE`` knob, read at trace time — DSCIMLinear
    instances are lru-cached behind models/lm.py ``_linear_for``, so a
    construction-time read would freeze the knob's first value for the
    process lifetime.  Note jit caching still applies: the knob must be
    set before a given (cfg, shapes) combination first compiles; already
    compiled executables are reused without re-tracing."""
    import os
    return os.environ.get("REPRO_DSCIM_TUNE", "") not in ("", "0")

Mode = Literal["exact", "lut", "bitmatmul", "kernel", "statistical",
               "paper_inject", "float"]


@dataclasses.dataclass
class DSCIMLinear:
    """Functional quantized-linear operator with a DS-CIM compute backend.

    ``group_k`` — quantization granularity along the contraction dim.  The
    paper's LLaMA recipe ([30], Sec. V) uses granularity 128, matching the
    macro's 128-row accumulation window: each window gets its own int8
    scales, windows are computed stochastically and summed digitally (exact),
    which keeps heavy-tailed outliers from wasting the int8 range.
    ``group_k=None`` = one scale over all of K (plain per-channel quant).
    """
    cfg: DSCIMConfig
    mode: Mode = "lut"
    group_k: int | None = 128
    tune: bool = False              # kernel mode: autotune fused-kernel tiles
    seed: int = 0                   # base of the fallback noise key
    # kernel mode under a mesh: route through the model-axis sharded fused
    # MVM (a Pallas call must live inside shard_map on a multi-device mesh;
    # the N-sharded decomposition is bit-identical to single-device).
    # batch_axes: DP mesh axes the leading batch dim of x/out additionally
    # shards over (so 'data=2,model=4' meshes don't redo the whole batch in
    # every data group).  The pure-jnp backends partition under GSPMD and
    # ignore these.
    mesh: jax.sharding.Mesh | None = None
    shard_axis: str = "model"
    batch_axes: tuple = ()

    def __post_init__(self):
        self.macro = DSCIMMacro(self.cfg)
        self._errmodel = (ErrorModel.from_macro(self.macro)
                          if self.mode in ("statistical", "paper_inject")
                          else None)

    def _windowed(self, x2, w2):
        """Split K into group_k windows -> (x3 (M,nw,g), w3 (nw,g,N))."""
        M, K = x2.shape
        g = self.group_k or K
        pad = (-K) % g
        if pad:
            x2 = jnp.pad(x2, ((0, 0), (0, pad)))
            w2 = jnp.pad(w2, ((0, pad), (0, 0)))
        nw = x2.shape[1] // g
        return x2.reshape(M, nw, g), w2.reshape(nw, g, -1), nw, g

    def _check_prepared(self, x, qw: QuantizedLinearWeight):
        K = x.shape[-1]
        if qw.k_orig != K:
            raise ValueError(f"prepared weight K={qw.k_orig} vs x K={K}")
        g = self.group_k or K
        if qw.g != g:
            raise ValueError(
                f"prepared weight granularity g={qw.g} does not match the "
                f"layer's group_k={self.group_k} (effective g={g}); "
                "re-run prepare_dscim_params with the serving group_k")

    def _resolve_key(self, key, salt, K: int, N: int):
        """Explicit key wins (salt still decorrelates call sites sharing
        one key); the fallback key folds in shape + call-site salt."""
        if key is None:
            key = jax.random.PRNGKey(self.seed)
            key = jax.random.fold_in(jax.random.fold_in(key, K), N)
        if salt is not None:
            key = jax.random.fold_in(key, salt)
        return key

    def __call__(self, x, w, key=None, *, salt=None):
        """x: (..., K) float; w: (K, N) float or QuantizedLinearWeight
        -> (..., N) float32.  ``salt``: static or traced int decorrelating
        the fallback noise key across call sites (see module docstring)."""
        prepared = isinstance(w, QuantizedLinearWeight)
        if self.mode == "float":
            if prepared:
                raise TypeError("mode='float' needs float weights; "
                                "don't prepare params for the float path")
            return x @ w
        if self.mode == "kernel":
            # fused single-launch Pallas path: quantization windows iterate
            # inside the kernel grid; sign-correction terms and per-window
            # dequant scales are applied in-kernel, leading batch dims ride
            # a batch grid axis (kernels/dscim_fused.py).
            from repro.kernels.dscim_fused import (dscim_fused_mvm,
                                                   dscim_fused_mvm_prepared,
                                                   dscim_fused_mvm_sharded)
            tune = self.tune or _env_tune()
            if self.mesh is not None:
                qw = w if prepared else prepare_linear_weight(w, self.group_k)
                self._check_prepared(x, qw)
                return dscim_fused_mvm_sharded(x, qw, self.cfg, self.mesh,
                                               axis=self.shard_axis,
                                               batch_axes=self.batch_axes,
                                               tune=tune)
            if prepared:
                self._check_prepared(x, w)
                return dscim_fused_mvm_prepared(x, w, self.cfg, tune=tune)
            return dscim_fused_mvm(x, w, self.cfg, group_k=self.group_k,
                                   tune=tune)
        lead = x.shape[:-1]
        K = x.shape[-1]
        xf = x.reshape(-1, K)
        if prepared:
            self._check_prepared(x, w)
            nw, g, N = w.nw, w.g, w.n
            pad = nw * g - K
            x3 = jnp.pad(xf, ((0, 0), (0, pad))) if pad else xf
            x3 = x3.reshape(-1, nw, g)
            w2 = w.q.astype(jnp.int32)                 # (nw,g,N) resident
            wscale = w.scale                           # (nw,N) resident
        else:
            N = w.shape[-1]
            x3, w3, nw, g = self._windowed(xf, w)      # float windows
            wq = quantize_int8(w3, axis=1)             # (nw,1,N) scales
            w2 = wq.q.astype(jnp.int32)                # (nw,g,N)
            wscale = wq.scale.reshape(nw, N)
        xq = quantize_int8(x3, axis=-1)                # (M,nw,1) scales
        x2 = xq.q.astype(jnp.int32)                    # (M,nw,g)
        if self.mode == "exact":
            psum = jnp.einsum("mug,ugn->mun", x2, w2).astype(jnp.float32)
        elif self.mode in ("lut", "bitmatmul"):
            fn = (self.macro.counts_lut if self.mode == "lut"
                  else self.macro.counts_bitmatmul)
            mvm_w = jax.vmap(
                lambda xw, ww: self.macro.mvm_from_counts(xw, ww, fn(xw, ww)),
                in_axes=(1, 0), out_axes=1)
            psum = mvm_w(x2, w2)                       # (M,nw,N)
        elif self.mode == "statistical":
            psum = jnp.einsum("mug,ugn->mun", x2, w2).astype(jnp.float32)
            psum = self._errmodel.inject(
                psum, self._resolve_key(key, salt, K, N), g)
        elif self.mode == "paper_inject":
            psum = jnp.einsum("mug,ugn->mun", x2, w2).astype(jnp.float32)
        else:
            raise ValueError(self.mode)
        out = jnp.einsum("mun,mu,un->mn", psum,
                         xq.scale.reshape(-1, nw), wscale)
        if self.mode == "paper_inject":
            # Sec. V convention: one 128-row-window error magnitude added per
            # *output* of the MVM result, in float units of the mean window
            # scale (see EXPERIMENTS.md §Calibration-notes).
            key = self._resolve_key(key, salt, K, N)
            rows = self.macro.cfg.rows
            s = (jnp.mean(xq.scale.reshape(-1, nw), axis=1, keepdims=True)
                 * jnp.mean(wscale, axis=0, keepdims=True))
            noise = (self._errmodel.mu1 * rows
                     + self._errmodel.sig1 * float(np.sqrt(rows))
                     * jax.random.normal(key, out.shape, out.dtype))
            out = out + noise * s
        return out.reshape(*lead, N).astype(jnp.float32)


def make_linear(variant: str = "dscim1", length: int = 256,
                mode: Mode = "lut", calib: str = "paper", *,
                mesh: jax.sharding.Mesh | None = None,
                shard_axis: str = "model", batch_axes: tuple = (),
                tune: bool = False) -> DSCIMLinear:
    """Convenience: calibrated DS-CIM1/2 linear ('paper' or 'opt' point
    sets).  ``mesh``/``shard_axis``/``batch_axes`` wire the kernel mode
    through the sharded fused MVM (multi-chip serving: N over the model
    axis, the request batch over the DP axes); ``tune`` — or the
    ``REPRO_DSCIM_TUNE`` env knob, read when the kernel call is traced
    (set it before first compile; cached executables don't re-trace) —
    consults the fused-tile autotuner; with the checked-in autotune cache
    (kernels/autotune.py) this is a lookup, not a re-tune, for the serving
    shapes."""
    if variant in ("dscim1", "dscim2"):
        cfg = calibrated_config(variant, length, calib)
    else:
        raise ValueError(variant)
    return DSCIMLinear(cfg, mode, tune=tune, mesh=mesh,
                       shard_axis=shard_axis, batch_axes=tuple(batch_axes))
