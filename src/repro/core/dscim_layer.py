"""DSCIMLinear — the framework integration point of the paper's technique.

A drop-in replacement for ``x @ W`` that quantizes to int8 (per-channel) and
computes the matmul the way a DS-CIM accelerator would:

* ``exact``        — int8 matmul, float rescale (the DCIM adder-tree baseline);
* ``lut``          — bit-exact DS-CIM emulation via the joint-count LUT;
* ``bitmatmul``    — bit-exact DS-CIM via the {0,1}-expanded MXU matmul (the
                     Pallas kernel's math; pure-jnp twin here);
* ``kernel``       — the serving hot path: fused single-launch Pallas kernel
                     (kernels/dscim_fused.py) — windows iterated inside the
                     grid, sign-correction + dequant in-kernel, batched;
* ``statistical``  — calibrated Gaussian injection (fast big-model path).

The hardware accumulates in windows of ``cfg.rows`` (=128) physical rows and
sums window results digitally (exact), so K > 128 decomposes into exact sums
of 128-row stochastic MACs — which is what all backends implement (the error
process is per-row i.i.d.-across-windows, so no explicit windowing is needed
for lut/bitmatmul; ``statistical`` scales moments by K directly).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .error_model import ErrorModel
from .macro import DSCIMConfig, DSCIMMacro
from .quant import quantize_int8
from .seed_search import calibrated_config

__all__ = ["DSCIMLinear", "make_linear"]

Mode = Literal["exact", "lut", "bitmatmul", "kernel", "statistical",
               "paper_inject", "float"]


@dataclasses.dataclass
class DSCIMLinear:
    """Functional quantized-linear operator with a DS-CIM compute backend.

    ``group_k`` — quantization granularity along the contraction dim.  The
    paper's LLaMA recipe ([30], Sec. V) uses granularity 128, matching the
    macro's 128-row accumulation window: each window gets its own int8
    scales, windows are computed stochastically and summed digitally (exact),
    which keeps heavy-tailed outliers from wasting the int8 range.
    ``group_k=None`` = one scale over all of K (plain per-channel quant).
    """
    cfg: DSCIMConfig
    mode: Mode = "lut"
    group_k: int | None = 128
    tune: bool = False              # kernel mode: autotune fused-kernel tiles

    def __post_init__(self):
        self.macro = DSCIMMacro(self.cfg)
        self._errmodel = (ErrorModel.from_macro(self.macro)
                          if self.mode in ("statistical", "paper_inject")
                          else None)

    def _windowed(self, x2, w2):
        """Split K into group_k windows -> (x3 (M,nw,g), w3 (nw,g,N))."""
        M, K = x2.shape
        g = self.group_k or K
        pad = (-K) % g
        if pad:
            x2 = jnp.pad(x2, ((0, 0), (0, pad)))
            w2 = jnp.pad(w2, ((0, pad), (0, 0)))
        nw = x2.shape[1] // g
        return x2.reshape(M, nw, g), w2.reshape(nw, g, -1), nw, g

    def __call__(self, x, w, key=None):
        """x: (..., K) float; w: (K, N) float -> (..., N) float32."""
        if self.mode == "float":
            return x @ w
        if self.mode == "kernel":
            # fused single-launch Pallas path: quantization windows iterate
            # inside the kernel grid; sign-correction terms and per-window
            # dequant scales are applied in-kernel, leading batch dims ride
            # a batch grid axis (kernels/dscim_fused.py).
            from repro.kernels.dscim_fused import dscim_fused_mvm
            return dscim_fused_mvm(x, w, self.cfg, group_k=self.group_k,
                                   tune=self.tune)
        lead = x.shape[:-1]
        K = x.shape[-1]
        N = w.shape[-1]
        xf = x.reshape(-1, K)
        x3, w3, nw, g = self._windowed(xf, w)          # float windows
        xq = quantize_int8(x3, axis=-1)                # (M,nw,1) scales
        wq = quantize_int8(w3, axis=1)                 # (nw,1,N) scales
        x2 = xq.q.astype(jnp.int32)                    # (M,nw,g)
        w2 = wq.q.astype(jnp.int32)                    # (nw,g,N)
        if self.mode == "exact":
            psum = jnp.einsum("mug,ugn->mun", x2, w2).astype(jnp.float32)
        elif self.mode in ("lut", "bitmatmul"):
            fn = (self.macro.counts_lut if self.mode == "lut"
                  else self.macro.counts_bitmatmul)
            mvm_w = jax.vmap(
                lambda xw, ww: self.macro.mvm_from_counts(xw, ww, fn(xw, ww)),
                in_axes=(1, 0), out_axes=1)
            psum = mvm_w(x2, w2)                       # (M,nw,N)
        elif self.mode == "statistical":
            psum = jnp.einsum("mug,ugn->mun", x2, w2).astype(jnp.float32)
            key = key if key is not None else jax.random.PRNGKey(0)
            psum = self._errmodel.inject(psum, key, g)
        elif self.mode == "paper_inject":
            psum = jnp.einsum("mug,ugn->mun", x2, w2).astype(jnp.float32)
        else:
            raise ValueError(self.mode)
        out = jnp.einsum("mun,mu,un->mn", psum,
                         xq.scale.reshape(-1, nw), wq.scale.reshape(nw, N))
        if self.mode == "paper_inject":
            # Sec. V convention: one 128-row-window error magnitude added per
            # *output* of the MVM result, in float units of the mean window
            # scale (see EXPERIMENTS.md §Calibration-notes).
            key = key if key is not None else jax.random.PRNGKey(0)
            rows = self.macro.cfg.rows
            s = (jnp.mean(xq.scale.reshape(-1, nw), axis=1, keepdims=True)
                 * jnp.mean(wq.scale.reshape(nw, N), axis=0, keepdims=True))
            noise = (self._errmodel.mu1 * rows
                     + self._errmodel.sig1 * float(np.sqrt(rows))
                     * jax.random.normal(key, out.shape, out.dtype))
            out = out + noise * s
        return out.reshape(*lead, N).astype(jnp.float32)


def make_linear(variant: str = "dscim1", length: int = 256,
                mode: Mode = "lut", calib: str = "paper") -> DSCIMLinear:
    """Convenience: calibrated DS-CIM1/2 linear ('paper' or 'opt' point sets)."""
    if variant in ("dscim1", "dscim2"):
        cfg = calibrated_config(variant, length, calib)
    else:
        raise ValueError(variant)
    return DSCIMLinear(cfg, mode)
