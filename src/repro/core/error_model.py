"""Calibrated statistical DS-CIM error injection (fast big-model path).

The exact backends (lut/bitmatmul) emulate the macro bit-exactly but cost a
K-scan of gathers or an L-times-expanded matmul.  For model-level accuracy
sweeps over millions of MVMs, we inject a Gaussian error with moments
*measured from the exact LUT process* (the paper itself evaluates networks by
"adding the DS-CIM error pattern to the MVM results", Sec. V).

Per-row error moments (mu1, sig1) are estimated once per macro config by
Monte-Carlo over the data distribution; a K-length accumulation then has
mean K*mu1 and std sqrt(K)*sig1 (rows are sampled from disjoint regions —
cross-row covariance is zero by the remapping property; cross-*output*
correlation through shared activations is ignored, documented approximation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .macro import DSCIMMacro

__all__ = ["ErrorModel"]


@dataclasses.dataclass(frozen=True)
class ErrorModel:
    mu1: float      # mean per-row psum error (int units)
    sig1: float     # std per-row psum error
    name: str = "dscim-errmodel"

    @staticmethod
    def from_macro(macro: DSCIMMacro, n_samples: int = 200_000,
                   seed: int = 0, dist: str = "uniform") -> "ErrorModel":
        """Measure per-row error moments of scale*count(a,w) - x*w + corr."""
        cfg = macro.cfg
        rng = np.random.default_rng(seed)
        if dist == "uniform":
            x = rng.integers(-128, 128, n_samples).astype(np.int64)
            w = rng.integers(-128, 128, n_samples).astype(np.int64)
        elif dist == "gaussian":
            x = np.clip(np.round(rng.normal(0, 42, n_samples)), -128, 127).astype(np.int64)
            w = np.clip(np.round(rng.normal(0, 42, n_samples)), -128, 127).astype(np.int64)
        else:
            raise ValueError(dist)
        k = cfg.k
        a = (x + 128) >> k
        b = (w + 128) >> k
        g = rng.integers(0, cfg.group, n_samples)
        counts = macro.lut_np[g, a, b].astype(np.float64)
        est = cfg.scale * counts - 128.0 * x - 128.0 * (w + 128)
        if cfg.trunc == "center":
            delta = (2 ** k - 1) / 2.0
            est = est + (2 ** k) * delta * (a + b) + delta * delta
        err = est - (x * w).astype(np.float64)
        return ErrorModel(float(err.mean()), float(err.std()),
                          name=f"errmodel[{cfg.name}]")

    def inject(self, exact_psum, key, k_dim: int):
        """Physical model: k_dim-row accumulation, err mean/var scale with K.

        exact_psum: (..., N) float accumulations over k_dim rows."""
        noise = self.mu1 * k_dim + jnp.sqrt(jnp.asarray(self.sig1 ** 2 * k_dim)) \
            * jax.random.normal(key, exact_psum.shape, exact_psum.dtype)
        return exact_psum + noise

    def relative_moment_bound(self, rows: int = 128) -> float:
        """Expected *relative* per-output psum error of one ``rows``-row
        accumulation window — the moment-derived scale the serving
        accuracy watchdog (runtime/serving.py) turns into a logit-drift
        threshold.

        Numerator: |bias| + 1-sigma of the window error, ``|mu1|*rows +
        sqrt(rows)*sig1`` (rows are independent by the remapping
        property).  Denominator: the typical magnitude of an exact
        ``rows``-row int8 psum under the calibration distribution,
        ``sqrt(rows) * E[|x*w|]`` with x, w ~ U[-128, 128) (so E[x^2] =
        E[w^2] ~ 128^2/3 and E[|xw|] = E|x|E|w| = 64^2).  A healthy
        estimator's logit-level relative RMSE sits within a small multiple
        of this bound (layer error partially averages out); a hard macro
        fault is orders of magnitude above it."""
        err = abs(self.mu1) * rows + np.sqrt(rows) * self.sig1
        signal = np.sqrt(rows) * 64.0 * 64.0
        return float(err / signal)

    def inject_paper(self, exact_psum, key, window: int = 128):
        """Paper-style injection (Sec. V: 'the DS-CIM error pattern was added
        to the MVM results'): one window-magnitude error per *output*,
        independent of how many 128-row windows the K dim spans.  This is the
        convention under which Table I/II model accuracies are consistent;
        the physical per-window accumulation is sqrt(K/128) larger (see
        EXPERIMENTS.md §Calibration-notes)."""
        noise = self.mu1 * window + self.sig1 * np.sqrt(window) \
            * jax.random.normal(key, exact_psum.shape, exact_psum.dtype)
        return exact_psum + noise
