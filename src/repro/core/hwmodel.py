"""Analytical 40nm energy/area model of the DS-CIM macro (Tables III, Fig. 4/7).

The paper's TOPS/W / TOPS/mm2 are post-layout silicon numbers; offline we
reproduce them with a component-level analytical model: per-cycle energies of
SNGs, OR gates, adders and accumulators, plus SRAM/PRNG overheads.  The
component constants below are *calibrated* so the model reproduces the
paper's headline numbers (documented in EXPERIMENTS.md §Paper-validation);
the model then extrapolates across CMR / bitstream length (Fig. 4) and
produces the power/area breakdown (Fig. 7).

Conventions (matching Table III footnotes):
* "ops" are 1b-equivalent: one 8b x 8b MAC = 2 * 64 = 128 ops.
* Efficiency at the macro level (SRAM + SNG + MAC + accumulator), 40nm.
"""
from __future__ import annotations

import dataclasses

__all__ = ["MacroGeometry", "EnergyParams", "AreaParams", "HWModel",
           "DSCIM1_HW", "DSCIM2_HW"]

OPS_PER_MAC_1B = 128.0  # 8b x 8b MAC in 1b-op units (Table III footnote 1)


@dataclasses.dataclass(frozen=True)
class MacroGeometry:
    rows: int = 128          # SRAM rows (accumulation window) per column
    cols: int = 32           # weight columns
    cmr: int = 64            # OR-MAC replicas per column (compute/memory ratio)
    group: int = 16          # rows per OR gate (16 -> DS-CIM1, 64 -> DS-CIM2)
    length: int = 256        # bitstream length L
    latch_cached: bool = False  # DS-CIM2's latch-cached accumulator
    freq_ghz: float = 1.0    # post-layout clock (OR-MAC64 path is 0.4 ns)

    @property
    def n_or(self) -> int:
        return self.rows // self.group

    @property
    def adder_width(self) -> int:
        return max(1, (self.n_or - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class EnergyParams:
    """Per-event energies in fJ (40nm, ~0.7-0.9V), calibrated to Table III.

    Calibration (closed-form, see EXPERIMENTS.md §Paper-validation): the
    paper's TOPS/W scale exactly as 1/L at fixed variant, pinning the
    per-cycle macro energy to 195.7 pJ (DS-CIM1) / 147 pJ (DS-CIM2+latch);
    components split per Fig. 7 proportions (accumulator ~40% pre-latch,
    SNGs dominant, OR/adder cheap)."""
    sng: float = 4.61        # one 8b comparator toggle (SNG), per cycle
    or_in: float = 0.0597    # OR tree, per input bit per cycle
    add_bit: float = 0.478   # per adder output bit per cycle
    acc_bit: float = 1.91    # accumulator register+add, per bit per cycle
    latch_bit: float = 0.6   # D-latch cache write, per bit per cycle
    sram_row: float = 130.0  # one row read (amortized over SC window)
    prng_cycle: float = 3000.0  # shared 8b PRNG pair, per cycle (whole macro)
    acc_width: int = 20      # accumulator width (L<=256, <=8 groups)

    def sparsity_factor(self, signed: bool) -> float:
        """Signed ops map data to [0,255] -> denser bitstreams -> more toggles.
        Paper Fig. 7: signed mode costs noticeably more in DS-CIM1."""
        return 1.0 if not signed else 1.45


@dataclasses.dataclass(frozen=True)
class AreaParams:
    """Block areas in um^2, calibrated jointly to: the 0.78/0.72 mm^2 macro
    totals, the Table-III TOPS/mm^2 set, AND Fig. 4's "64x throughput at
    ~2x area" CMR claim (which pins the non-replicated SRAM+weight-SNG base
    at ~half the macro).  sram_cell is the *effective* per-bit area incl.
    wordline/bitline periphery share."""
    sram_cell: float = 6.8       # 6T cell + periphery share, per bit
    sng: float = 9.0             # 8b SNG comparator
    or_in: float = 0.2           # OR tree per input
    add_bit: float = 10.0        # adder per output bit (fast custom cell [28])
    acc_bit: float = 2.0         # accumulator per bit
    latch_bit: float = 0.5       # D-latch per bit
    prng: float = 2600.0         # two shared 8b PRNGs + distribution
    overhead: float = 1.455      # routing/ctrl/pipeline fill factor


class HWModel:
    """Analytical throughput/energy/area for one DS-CIM macro."""

    def __init__(self, geo: MacroGeometry,
                 ep: EnergyParams | None = None,
                 ap: AreaParams | None = None):
        self.geo = geo
        self.ep = ep or EnergyParams()
        self.ap = ap or AreaParams()

    # -- throughput -----------------------------------------------------------
    def macs_per_cycle(self) -> float:
        g = self.geo
        return g.rows * g.cols * g.cmr / g.length

    def tops_1b(self) -> float:
        return self.macs_per_cycle() * OPS_PER_MAC_1B * self.geo.freq_ghz * 1e9 / 1e12

    # -- energy ---------------------------------------------------------------
    def energy_per_cycle_fj(self, signed: bool = True) -> dict:
        g, ep = self.geo, self.ep
        sf = ep.sparsity_factor(signed)
        # weight SNGs: one per row per column; activation SNGs: one per row,
        # shared across the 32 columns (broadcast).
        e_sng_w = g.rows * g.cols * ep.sng * sf
        e_sng_a = g.rows * g.cmr * ep.sng * sf
        e_or = g.rows * g.cols * g.cmr * ep.or_in * sf
        e_add = g.adder_width * g.cols * g.cmr * ep.add_bit
        if g.latch_cached:
            e_acc = (g.cols * g.cmr *
                     (4 * g.adder_width * ep.latch_bit          # latch fills
                      + ep.acc_width * ep.acc_bit / 4.0))       # 1-in-4 accum
        else:
            e_acc = g.cols * g.cmr * ep.acc_width * ep.acc_bit
        # SRAM: weights are stationary during the SC window; one row refresh
        # per L cycles (pipelined channel loading, Fig. 5).
        e_sram = g.rows * g.cols * ep.sram_row / g.length
        e_prng = ep.prng_cycle
        return {"sng": e_sng_w + e_sng_a, "or": e_or, "adder": e_add,
                "accum": e_acc, "sram": e_sram, "prng": e_prng}

    def tops_per_watt(self, signed: bool = True) -> float:
        e = sum(self.energy_per_cycle_fj(signed).values())  # fJ / cycle
        ops = self.macs_per_cycle() * OPS_PER_MAC_1B        # ops / cycle
        return ops / (e * 1e-15) / 1e12                     # ops/J -> TOPS/W

    # -- area -----------------------------------------------------------------
    def area_um2(self) -> dict:
        g, ap = self.geo, self.ap
        a_sram = g.rows * g.cols * 8 * ap.sram_cell
        a_sng = (g.rows * g.cols + g.rows * g.cmr) * ap.sng
        a_or = g.rows * g.cols * g.cmr * ap.or_in
        a_add = g.adder_width * g.cols * g.cmr * ap.add_bit
        acc_unit = self.ep.acc_width * ap.acc_bit
        if g.latch_cached:
            acc_unit += 4 * g.adder_width * ap.latch_bit
        a_acc = g.cols * g.cmr * acc_unit
        a_prng = ap.prng
        return {"sram": a_sram, "sng": a_sng, "or": a_or, "adder": a_add,
                "accum": a_acc, "prng": a_prng}

    def area_mm2(self) -> float:
        return sum(self.area_um2().values()) * self.ap.overhead / 1e6

    def tops_per_mm2(self) -> float:
        return self.tops_1b() / self.area_mm2()

    def summary(self, signed: bool = True) -> dict:
        e = self.energy_per_cycle_fj(signed)
        a = self.area_um2()
        return {
            "tops_1b": self.tops_1b(),
            "tops_per_watt": self.tops_per_watt(signed),
            "area_mm2": self.area_mm2(),
            "tops_per_mm2": self.tops_per_mm2(),
            "power_breakdown": {k: v / sum(e.values()) for k, v in e.items()},
            "area_breakdown": {k: v / sum(a.values()) for k, v in a.items()},
            "latency_us_per_mvm": self.geo.length / (self.geo.freq_ghz * 1e3),
        }


def DSCIM1_HW(length: int = 256, cmr: int = 64,
              freq_ghz: float = 0.697) -> HWModel:
    """Precise variant: 8x OR-MAC16 / column (post-layout corner 0.7 GHz)."""
    return HWModel(MacroGeometry(group=16, length=length, cmr=cmr,
                                 latch_cached=False, freq_ghz=freq_ghz))


def DSCIM2_HW(length: int = 64, cmr: int = 64,
              freq_ghz: float = 0.4995) -> HWModel:
    """Efficient variant: 2x OR-MAC64 / column + latch-cached accumulator."""
    return HWModel(MacroGeometry(group=64, length=length, cmr=cmr,
                                 latch_cached=True, freq_ghz=freq_ghz))
