"""Int8 block-paged KV cache for the decode hot path (ISSUE 4 tentpole).

Decode HBM traffic on a served DS-CIM model is dominated by the KV cache
long before the int8 MVMs are (the paper's premise is cheap low-precision
compute; Khatamifard et al. and Stoch-IMC make the same point about the
memory system being the real bottleneck of stochastic pipelines).  This
module stores the cache as **int8 pages with per-page, per-kv-head dequant
scales**, cutting resident decode KV bytes ~4x, and indexes them through a
**page table** so cache capacity is a pool-size knob decoupled from
per-request length (continuous batching re-uses freed pages immediately).

Layout (a plain dict, riding the generation scan carry like the dense
cache does):

  k_pages / v_pages  int8  (L, P, ps, KV, HD)   page pool, P physical pages
  k_scale / v_scale  f32   (L, P, KV)           per-page per-kv-head scales
  k_tail  / v_tail   bf16  (L, B, ps, KV, HD)   the partially-filled page
                                                 per slot, kept unquantized
  page_table         int32 (B, MP)              logical block -> physical page
  pos                int32 (B,)                  per-slot token counts

Write path: each decoded token lands in its slot's *tail* page at offset
``pos % ps`` (bf16 — the most recent tokens attend at higher precision);
when the tail fills, it is quantized once (fresh per-page absmax scales)
and flushed to the physical page given by the page table.  Tokens are
therefore quantized exactly once — no incremental requantization drift.

Read paths — ``decode_attention_paged`` (layers/attention.py) walks the
logical pages flash-style with the int8 dequant fused into the
online-softmax inner loop and the tail overlaying its logical slot in
full precision, through one of two implementations (ISSUE 5):

* the **fused Pallas kernel** (kernels/paged_attention.py) — one launch
  per decode step; the page table is a scalar-prefetch operand, so each
  physical int8 page streams HBM->VMEM directly and is dequantized
  in-VMEM inside the softmax update.  Default for 'kernel' dscim serving
  modes (the TPU bandwidth path); under a mesh it runs inside shard_map
  (batch over DP, pool gathered per shard).
* the **jnp gather scan** — a ``lax.scan`` over logical pages gathering
  ``k_pages[table[:, j]]`` per step.  The reference semantics: default
  for every non-'kernel' mode, partitions under plain GSPMD, and the
  baseline the kernel is CI-diffed against (tools/bench_regression.py).

``--paged-attn kernel|jnp`` (a cache-keyed option on the whole serve
stack) pins either path explicitly; ``REPRO_PAGED_ATTN`` forces the
'auto' fallback at trace time.  Both walk pages in the same order with
f32 statistics, agreeing to <=1e-5 logits (tests/test_paged_kernel.py).

Page allocation is host-side (``PageAllocator``): the continuous-batching
scheduler (launch/serve.py) grants a request its pages at admission and
returns them at completion, so the jitted segment never allocates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["quantize_page", "dequantize_page", "paged_from_dense",
           "init_paged_cache", "admit_request", "admit_dense",
           "paged_cache_specs", "kv_cache_bytes", "dense_cache_bytes",
           "PageAllocator", "n_pages_for", "admission_pages",
           "extract_slot_pages", "insert_slot_pages", "spec_rollback",
           "page_checksums", "refresh_page_checksums", "CHECKSUM_KEY"]

TAIL_DTYPE = jnp.bfloat16

# integrity layer (ISSUE 9): the per-physical-page checksum plane rides
# the cache dict under this key — (L, P) uint32, one digest per (layer,
# physical page) over the int8 planes and the bitcast f32 scales.  It is
# created only under ``init_paged_cache(..., integrity=True)`` so the
# default cache pytree (and every jitted program traced against it) is
# byte-for-byte the pre-integrity layout.
CHECKSUM_KEY = "page_sum"
_CSUM_MULT = np.uint32(2654435761)        # Knuth's golden-ratio multiplier


def _csum_u32(x):
    """uint32 view of a plane for checksumming: integer dtypes widen,
    float dtypes go through a same-width bitcast (bit-exact, so a digest
    mismatch localizes a *bit* flip, not a value drift)."""
    if jnp.issubdtype(x.dtype, jnp.floating):
        bits = {2: jnp.uint16, 4: jnp.uint32}[jnp.dtype(x.dtype).itemsize]
        x = jax.lax.bitcast_convert_type(x, bits)
    return x.astype(jnp.uint32)


def _csum_fold(x, n_lead: int, mult: int):
    """Weighted modular sum over everything past the leading ``n_lead``
    axes: sum((2i+1) * GOLD * mult * x_i) mod 2**32.  Every per-element
    weight is odd, hence invertible mod 2**32 — a change to any single
    element (any bit, the sign bit of a f32 scale included) always moves
    the digest; ``mult`` (odd, distinct per plane) stops a flip in one
    plane cancelling against a flip at the same offset in another."""
    lead = x.shape[:n_lead]
    flat = _csum_u32(x).reshape(*lead, -1)
    n = flat.shape[-1]
    w = (2 * jnp.arange(n, dtype=jnp.uint32) + 1) \
        * _CSUM_MULT * jnp.uint32(mult)
    return jnp.sum(flat * w, axis=-1)


def page_checksums(k_pages, v_pages, k_scale, v_scale):
    """Per-(layer, page) uint32 digest of the quantized pool state:
    k/v int8 planes (L, P, ps, KV, HD) + bitcast f32 scales (L, P, KV)
    -> (L, P) uint32.  Deterministic integer arithmetic, so the digest of
    a page is a pure function of its bits — recomputing it over live
    planes and comparing against the stored ``page_sum`` plane detects
    any single-element corruption at an exact (layer, page) coordinate
    (runtime/integrity.py)."""
    return (_csum_fold(k_pages, 2, 1) + _csum_fold(v_pages, 2, 3)
            + _csum_fold(k_scale, 2, 5) + _csum_fold(v_scale, 2, 7))


def _update_page_sums(cache, phys):
    """Refresh the ``page_sum`` plane for the physical pages ``phys`` (any
    shape; flattened) from the pool's *current* contents.  No-op when the
    cache was built without the integrity plane.  Called after every bulk
    page write (``_scatter_pages``, ``insert_slot_pages``) so the stored
    digests always describe the bits actually resident."""
    if CHECKSUM_KEY not in cache:
        return cache
    idx = jnp.asarray(phys, jnp.int32).reshape(-1)
    s = page_checksums(cache["k_pages"][:, idx], cache["v_pages"][:, idx],
                       cache["k_scale"][:, idx], cache["v_scale"][:, idx])
    return dict(cache, **{CHECKSUM_KEY:
                          cache[CHECKSUM_KEY].at[:, idx].set(s)})


def refresh_page_checksums(cache, pos0, upper, max_span: int):
    """Re-digest every physical page a decode segment may have flushed.

    Tail pages quantize-and-flush *inside* the jitted segment scan
    (layers/attention.py), per layer, per step — threading the checksum
    plane through those write sites would touch every attention variant.
    Instead the segment builders (launch/steps.py) call this once after
    the scan: any logical page whose last token index lies in
    ``[pos0, upper)`` was completely filled during the segment, so its
    digest is recomputed from the live pool bits.

    ``pos0`` (B,) committed positions entering the segment, ``upper`` (B,)
    one past the highest position the segment may have written (includes
    speculative draft overhang), ``max_span`` a *static* bound on
    ``upper - pos0`` sizing the candidate window.  Done/idle slots pass an
    empty range and refresh nothing.  Recomputing from live content is
    self-consistent by construction: a page flushed then superseded (e.g.
    a rejected speculative window rewritten by ``spec_rollback``-adjacent
    logic) digests to whatever is actually resident."""
    if CHECKSUM_KEY not in cache:
        return cache
    table = cache["page_table"]
    mp = table.shape[1]
    P, ps = cache["k_pages"].shape[1:3]
    J = max_span // ps + 2
    js = pos0[:, None] // ps + jnp.arange(J, dtype=jnp.int32)[None, :]
    last_tok = js * ps + (ps - 1)                       # (B, J)
    hit = (last_tok >= pos0[:, None]) & (last_tok < upper[:, None]) \
        & (js < mp)
    phys = jnp.take_along_axis(table, jnp.minimum(js, mp - 1), axis=1)
    idx = jnp.where(hit, phys, P).reshape(-1)           # P == out-of-range
    safe = jnp.minimum(idx, P - 1)
    s = page_checksums(cache["k_pages"][:, safe], cache["v_pages"][:, safe],
                       cache["k_scale"][:, safe], cache["v_scale"][:, safe])
    return dict(cache, **{CHECKSUM_KEY:
                          cache[CHECKSUM_KEY].at[:, idx].set(
                              s, mode="drop")})


def quantize_page(x):
    """Symmetric int8 page quantization with per-kv-head scales.

    x (..., ps, KV, HD) float -> (q int8 same shape, scale (..., KV) f32);
    absmax taken over the page's (token, head_dim) axes so every kv head
    gets its own dequant scale (outlier heads don't poison the page)."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=(-3, -1))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None, :, None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_page(q, scale):
    """Inverse of ``quantize_page``: q (..., ps, KV, HD) int8 -> f32."""
    return q.astype(jnp.float32) * scale[..., None, :, None]


def n_pages_for(capacity: int, page_size: int) -> int:
    """Logical pages needed for one sequence of ``capacity`` tokens."""
    return -(-capacity // page_size)


def admission_pages(prompt_len: int, budget: int, page_size: int,
                    headroom: int = 0) -> int:
    """Physical pages one admission must be granted: prompt + generation
    budget + in-flight headroom (speculative draft positions, chunked-
    prefill window padding).  The single accounting rule shared by the
    continuous scheduler (runtime/serving.py) and the router's per-bucket
    admission control (runtime/router.py) — if the two computed this
    independently, a drift would show up as mid-stream pool corruption
    rather than an admission-time refusal.

    Non-positive ``page_size``/``budget`` raise instead of silently
    returning a nonsense page count (``page_size <= 0`` used to divide by
    zero or flip the ceiling-division sign; ``budget <= 0`` means the
    request can never emit a token, so its admission is a caller bug)."""
    if page_size <= 0:
        raise ValueError(f"admission_pages: page_size must be positive, "
                         f"got {page_size}")
    if budget <= 0:
        raise ValueError(f"admission_pages: generation budget must be "
                         f"positive, got {budget}")
    if prompt_len < 0 or headroom < 0:
        raise ValueError(f"admission_pages: prompt_len/headroom must be "
                         f">= 0, got {prompt_len}/{headroom}")
    return n_pages_for(prompt_len + budget + headroom, page_size)


def default_page_table(batch: int, max_pages: int):
    """Slot-major contiguous assignment (slot b owns pages [b*MP,(b+1)*MP))
    — the one-shot ``serve_batch`` layout; the continuous scheduler assigns
    rows from its allocator instead."""
    return jnp.arange(batch * max_pages, dtype=jnp.int32).reshape(
        batch, max_pages)


def init_paged_cache(n_layers: int, batch: int, n_pages: int, page_size: int,
                     max_pages: int, n_kv: int, head_dim: int,
                     integrity: bool = False):
    """Empty pool + idle slots (pos 0, slot-major default page table,
    clamped into the pool so an undersized pool — n_pages < batch *
    max_pages, legal for the continuous scheduler — never leaves idle
    slots gathering out of bounds before their first admission).

    ``integrity=True`` adds the ``page_sum`` digest plane (initialized
    consistent with the zero/ones pool, so a verify pass is clean from
    step 0); the default pytree is unchanged."""
    table = jnp.minimum(default_page_table(batch, max_pages), n_pages - 1)
    cache = {
        "k_pages": jnp.zeros((n_layers, n_pages, page_size, n_kv, head_dim),
                             jnp.int8),
        "v_pages": jnp.zeros((n_layers, n_pages, page_size, n_kv, head_dim),
                             jnp.int8),
        "k_scale": jnp.ones((n_layers, n_pages, n_kv), jnp.float32),
        "v_scale": jnp.ones((n_layers, n_pages, n_kv), jnp.float32),
        "k_tail": jnp.zeros((n_layers, batch, page_size, n_kv, head_dim),
                            TAIL_DTYPE),
        "v_tail": jnp.zeros((n_layers, batch, page_size, n_kv, head_dim),
                            TAIL_DTYPE),
        "page_table": table,
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if integrity:
        cache[CHECKSUM_KEY] = page_checksums(
            cache["k_pages"], cache["v_pages"],
            cache["k_scale"], cache["v_scale"])
    return cache


def _scatter_pages(cache, ks, vs, phys):
    """Quantize full pages ks/vs (L, ..., nf, ps, KV, HD) and scatter them
    into the pool at physical indices ``phys`` (..., nf)."""
    qk, sk = quantize_page(ks)
    qv, sv = quantize_page(vs)
    out = dict(
        cache,
        k_pages=cache["k_pages"].at[:, phys].set(qk),
        v_pages=cache["v_pages"].at[:, phys].set(qv),
        k_scale=cache["k_scale"].at[:, phys].set(sk),
        v_scale=cache["v_scale"].at[:, phys].set(sv))
    return _update_page_sums(out, phys)


def paged_from_dense(ks, vs, page_size: int, n_pages: int | None = None,
                     max_pages: int | None = None):
    """Convert a dense prefill cache (L, B, S, KV, HD) into a paged one.

    Full pages are quantized (per-page absmax scales); the S % ps remainder
    stays unquantized in the tail.  The default page table is slot-major
    over ``max_pages`` logical pages per slot; callers that decode past
    ``max_pages * page_size`` total tokens MUST pass ``max_pages`` sized
    for prompt + generation (launch/steps.py does) — the default only
    guarantees one decode page of headroom past the prompt."""
    L, B, S, KV, HD = ks.shape
    ps = page_size
    nf, rem = divmod(S, ps)
    if max_pages is None:
        # always include the page the next decoded token lands in: for
        # rem == 0 that is page nf (fresh), for rem > 0 the tail page
        max_pages = nf + 1
    if n_pages is None:
        n_pages = B * max_pages
    # the slot-major default table needs a page per (slot, logical page);
    # undersized pools are a scheduler feature (explicit page_table rows
    # via admit_request), not a conversion one
    assert n_pages >= B * max_pages, (n_pages, B, max_pages)
    cache = init_paged_cache(L, B, n_pages, ps, max_pages, KV, HD)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    if nf:
        pk = ks[:, :, :nf * ps].reshape(L, B, nf, ps, KV, HD)
        pv = vs[:, :, :nf * ps].reshape(L, B, nf, ps, KV, HD)
        cache = _scatter_pages(cache, pk, pv, cache["page_table"][:, :nf])
    if rem:
        cache["k_tail"] = cache["k_tail"].at[:, :, :rem].set(
            ks[:, :, nf * ps:].astype(TAIL_DTYPE))
        cache["v_tail"] = cache["v_tail"].at[:, :, :rem].set(
            vs[:, :, nf * ps:].astype(TAIL_DTYPE))
    return cache


def admit_request(cache, ks1, vs1, slot, page_ids):
    """Write one request's prefill KV (dense, (L, 1, S, KV, HD)) into slot
    ``slot`` of a live paged cache, onto host-allocated physical pages
    ``page_ids`` ((MP,) int32 — entries past the request's need unused).
    Jittable with traced slot/page_ids (S and shapes static)."""
    L, _, S, KV, HD = ks1.shape
    ps = cache["k_tail"].shape[2]
    nf, rem = divmod(S, ps)
    cache = dict(cache,
                 page_table=cache["page_table"].at[slot].set(page_ids),
                 pos=cache["pos"].at[slot].set(S))
    if nf:
        pk = ks1[:, 0, :nf * ps].reshape(L, nf, ps, KV, HD)
        pv = vs1[:, 0, :nf * ps].reshape(L, nf, ps, KV, HD)
        cache = _scatter_pages(cache, pk, pv, page_ids[:nf])
    tail_k = jnp.zeros((L, ps, KV, HD), cache["k_tail"].dtype)
    tail_v = jnp.zeros((L, ps, KV, HD), cache["v_tail"].dtype)
    if rem:
        tail_k = tail_k.at[:, :rem].set(
            ks1[:, 0, nf * ps:].astype(tail_k.dtype))
        tail_v = tail_v.at[:, :rem].set(
            vs1[:, 0, nf * ps:].astype(tail_v.dtype))
    return dict(cache,
                k_tail=cache["k_tail"].at[:, slot].set(tail_k),
                v_tail=cache["v_tail"].at[:, slot].set(tail_v))


def admit_dense(cache, ks1, vs1, slot):
    """Dense-cache counterpart of ``admit_request``: overwrite batch row
    ``slot`` of a (L, B, T, KV, HD) cache with a B=1 prefill padded to T."""
    L, _, S, KV, HD = ks1.shape
    T = cache["k"].shape[2]
    pad = [(0, 0), (0, 0), (0, T - S), (0, 0), (0, 0)]
    kp = jnp.pad(ks1.astype(cache["k"].dtype), pad)
    vp = jnp.pad(vs1.astype(cache["v"].dtype), pad)
    return dict(cache,
                k=jax.lax.dynamic_update_slice(cache["k"], kp,
                                               (0, slot, 0, 0, 0)),
                v=jax.lax.dynamic_update_slice(cache["v"], vp,
                                               (0, slot, 0, 0, 0)),
                pos=cache["pos"].at[slot].set(S))


def paged_cache_specs(cfg, batch: int, capacity: int, page_size: int,
                      n_pages: int | None = None, integrity: bool = False):
    """ShapeDtypeStruct tree of the paged cache (sharding-rule input)."""
    mp = n_pages_for(capacity, page_size)
    if n_pages is None:
        n_pages = batch * mp
    f = jax.ShapeDtypeStruct
    L, KV, HD = cfg.n_layers, cfg.n_kv, cfg.head_dim
    specs = {
        "k_pages": f((L, n_pages, page_size, KV, HD), jnp.int8),
        "v_pages": f((L, n_pages, page_size, KV, HD), jnp.int8),
        "k_scale": f((L, n_pages, KV), jnp.float32),
        "v_scale": f((L, n_pages, KV), jnp.float32),
        "k_tail": f((L, batch, page_size, KV, HD), TAIL_DTYPE),
        "v_tail": f((L, batch, page_size, KV, HD), TAIL_DTYPE),
        "page_table": f((batch, mp), jnp.int32),
        "pos": f((batch,), jnp.int32),
    }
    if integrity:
        specs[CHECKSUM_KEY] = f((L, n_pages), jnp.uint32)
    return specs


def _nbytes(spec) -> int:
    return int(np.prod(spec.shape)) * np.dtype(spec.dtype).itemsize


def kv_cache_bytes(cache_or_specs) -> int:
    """Resident decode-cache bytes (pages + scales + tails + page table;
    the per-slot positions and the integrity digest plane are
    bookkeeping, not cache traffic — excluding ``page_sum`` keeps byte
    accounting comparable across integrity on/off)."""
    skip = {"pos", CHECKSUM_KEY}
    tree = {k: v for k, v in cache_or_specs.items() if k not in skip}
    return sum(_nbytes(v) for v in jax.tree.leaves(tree))


def dense_cache_bytes(cfg, batch: int, capacity: int) -> int:
    """k+v bytes of the dense fixed-capacity cache at cfg.cache_dtype."""
    itemsize = jnp.dtype(cfg.cache_dtype).itemsize
    return 2 * cfg.n_layers * batch * capacity * cfg.n_kv * cfg.head_dim \
        * itemsize


def spec_rollback(cache, pos0, new_pos, tails0=None, win_kv=None):
    """Truncate a speculative draft/verify window back to its committed
    length (launch/steps.py) — the write-then-rollback discipline.

    ``pos0`` (B,) is the position the window started from, ``new_pos`` (B,)
    the committed position after accept/reject (pos0 <= new_pos <= pos0+T).
    Both cache layouts are append-only with read masks on ``pos``, so
    rejected positions never need erasing:

    * dense: rolled-back indices are masked (``tj <= pos``) until a later
      decode rewrites them write-before-read — truncating ``pos`` is the
      whole rollback.
    * paged: same masking argument for pages and for tail offsets past
      ``new_pos % ps`` — but if the window crossed a page boundary, the
      committed tail page's *low* offsets were flushed out of the tail (and
      the physical page they went to may hold rejected tokens quantized
      into its scale).  Those pages sit at logical index >= new_pos // ps,
      so reads never see them before a future flush rewrites them whole;
      the tail itself is rebuilt here from the window's K/V projections
      (``win_kv``, the verifier's writes in tail dtype — positions
      >= pos0) and the pre-window tails (``tails0`` — positions < pos0).
      Physical pages are never allocated or freed: the slot's grant is
      sized for prompt + budget + k up front, so the PageAllocator is
      untouched by speculation.

    Entries past ``new_pos % ps`` in the rebuilt tail are don't-care
    (rewritten write-before-read, exactly like the dense case); they are
    filled from the same gather rather than masked.
    """
    if "k_pages" not in cache:
        return dict(cache, pos=new_pos)
    k_tail0, v_tail0 = tails0
    win_k, win_v = win_kv
    ps = cache["k_tail"].shape[2]
    T = win_k.shape[2]
    o = jnp.arange(ps, dtype=jnp.int32)
    i = (new_pos // ps * ps)[:, None] + o[None, :]            # (B, ps) stream
    t = jnp.clip(i - pos0[:, None], 0, T - 1)                 # window index
    use_w = (i >= pos0[:, None])[None, :, :, None, None]

    def rebuild(win, tail0):
        g = jnp.take_along_axis(win, t[None, :, :, None, None], axis=2)
        return jnp.where(use_w, g, tail0)

    return dict(cache,
                k_tail=rebuild(win_k, k_tail0),
                v_tail=rebuild(win_v, v_tail0),
                pos=new_pos)


class PageAllocator:
    """Host-side free-list over the physical page pool.  The continuous
    scheduler allocates a request's pages at admission and frees them at
    completion — capacity is the pool size, not slots x max_len.

    ``free`` validates its ids (ISSUE 6): a double-free or an out-of-range
    id would silently put the same physical page on the free list twice,
    and two live slots would later scatter into one page — corruption with
    no error at the corrupting site.  Raise here instead."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))
        self._live: set = set()
        self._high_water = 0
        self._refusals = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int):
        """n physical page ids, or None if the pool can't cover them.
        ``n <= 0`` raises: a zero/negative grant is always a caller
        accounting bug (``admission_pages`` never returns one), and
        ``alloc(0) -> []`` would read as a successful admission that
        owns no pages — the slot's first tail flush would then scatter
        through an unowned page-table row."""
        if n <= 0:
            raise ValueError(
                f"PageAllocator.alloc: page count must be positive, got {n}")
        if n > len(self._free):
            self._refusals += 1
            return None
        ids = [self._free.pop() for _ in range(n)]
        self._live.update(ids)
        self._high_water = max(self._high_water, len(self._live))
        return ids

    def stats(self) -> dict:
        """Occupancy counters for serve_bench / the scheduler's stats dict:
        live pages now, the high-water mark since construction (peak
        concurrent grant), and how many ``alloc`` calls were refused
        (admission backpressure events)."""
        return {"n_pages": self.n_pages,
                "live_pages": len(self._live),
                "high_water": self._high_water,
                "refusals": self._refusals}

    def free(self, ids) -> None:
        ids = [int(i) for i in ids]
        seen: set = set()
        for i in ids:
            if not 0 <= i < self.n_pages:
                raise ValueError(
                    f"PageAllocator.free: page id {i} out of range for a "
                    f"{self.n_pages}-page pool")
            if i in seen or i not in self._live:
                raise ValueError(
                    f"PageAllocator.free: double free of page {i} (not "
                    "currently allocated) — two live slots would share a "
                    "physical page")
            seen.add(i)
        # validate-then-commit: a raise above must leave the pool unchanged
        self._live.difference_update(seen)
        self._free.extend(ids)

    # -- snapshot/restore (serve-state failover, runtime/serving.py) --------
    def snapshot(self) -> dict:
        """Plain-data copy of the allocator state (host snapshot leaf)."""
        return {"n_pages": self.n_pages, "free": list(self._free),
                "live": sorted(self._live),
                "high_water": self._high_water,
                "refusals": self._refusals}

    @classmethod
    def from_snapshot(cls, snap: dict) -> "PageAllocator":
        a = cls.__new__(cls)
        a.n_pages = int(snap["n_pages"])
        a._free = [int(i) for i in snap["free"]]
        a._live = {int(i) for i in snap["live"]}
        a._high_water = int(snap.get("high_water", len(a._live)))
        a._refusals = int(snap.get("refusals", 0))
        return a


def extract_slot_pages(cache, slot: int, page_ids) -> dict:
    """Bit-exact host-side snapshot of one slot's share of a paged cache:
    its granted physical pages (int8 planes + f32 scales), its bf16 tail,
    and its position.  The preemptive-eviction path (runtime/serving.py)
    parks this blob host-side so the request's KV never has to be
    re-prefilled — requantization or a different float reduction order
    would break bitwise replay parity."""
    ids = np.asarray([int(i) for i in page_ids], np.int32)
    g = np.asarray
    return {"page_count": len(ids),
            "k_pages": g(cache["k_pages"][:, ids]),
            "v_pages": g(cache["v_pages"][:, ids]),
            "k_scale": g(cache["k_scale"][:, ids]),
            "v_scale": g(cache["v_scale"][:, ids]),
            "k_tail": g(cache["k_tail"][:, slot]),
            "v_tail": g(cache["v_tail"][:, slot]),
            "pos": int(cache["pos"][slot])}


def insert_slot_pages(cache, slot: int, page_ids, blob: dict):
    """Inverse of ``extract_slot_pages`` onto freshly granted physical
    pages: scatter the parked planes/scales to ``page_ids``, restore the
    slot's tail and position, and rewrite its page-table row (padded to MP
    with the last id, exactly like admission).  The restored slot decodes
    bit-identically to one that was never evicted — only the *physical*
    page ids differ, and reads go through the page table."""
    ids = [int(i) for i in page_ids]
    if len(ids) != blob["page_count"]:
        raise ValueError(f"insert_slot_pages: {blob['page_count']} pages "
                         f"parked but {len(ids)} granted")
    mp = cache["page_table"].shape[1]
    row = jnp.asarray(ids + [ids[-1]] * (mp - len(ids)), jnp.int32)
    idx = jnp.asarray(ids, jnp.int32)
    out = dict(
        cache,
        k_pages=cache["k_pages"].at[:, idx].set(jnp.asarray(blob["k_pages"])),
        v_pages=cache["v_pages"].at[:, idx].set(jnp.asarray(blob["v_pages"])),
        k_scale=cache["k_scale"].at[:, idx].set(jnp.asarray(blob["k_scale"])),
        v_scale=cache["v_scale"].at[:, idx].set(jnp.asarray(blob["v_scale"])),
        k_tail=cache["k_tail"].at[:, slot].set(
            jnp.asarray(blob["k_tail"]).astype(cache["k_tail"].dtype)),
        v_tail=cache["v_tail"].at[:, slot].set(
            jnp.asarray(blob["v_tail"]).astype(cache["v_tail"].dtype)),
        page_table=cache["page_table"].at[slot].set(row),
        pos=cache["pos"].at[slot].set(blob["pos"]))
    return _update_page_sums(out, idx)
