"""Int8 block-paged KV cache for the decode hot path (ISSUE 4 tentpole).

Decode HBM traffic on a served DS-CIM model is dominated by the KV cache
long before the int8 MVMs are (the paper's premise is cheap low-precision
compute; Khatamifard et al. and Stoch-IMC make the same point about the
memory system being the real bottleneck of stochastic pipelines).  This
module stores the cache as **int8 pages with per-page, per-kv-head dequant
scales**, cutting resident decode KV bytes ~4x, and indexes them through a
**page table** so cache capacity is a pool-size knob decoupled from
per-request length (continuous batching re-uses freed pages immediately).

Layout (a plain dict, riding the generation scan carry like the dense
cache does):

  k_pages / v_pages  int8  (L, P, ps, KV, HD)   page pool, P physical pages
  k_scale / v_scale  f32   (L, P, KV)           per-page per-kv-head scales
  k_tail  / v_tail   bf16  (L, B, ps, KV, HD)   the partially-filled page
                                                 per slot, kept unquantized
  page_table         int32 (B, MP)              logical block -> physical page
  pos                int32 (B,)                  per-slot token counts

Write path: each decoded token lands in its slot's *tail* page at offset
``pos % ps`` (bf16 — the most recent tokens attend at higher precision);
when the tail fills, it is quantized once (fresh per-page absmax scales)
and flushed to the physical page given by the page table.  Tokens are
therefore quantized exactly once — no incremental requantization drift.

Read paths — ``decode_attention_paged`` (layers/attention.py) walks the
logical pages flash-style with the int8 dequant fused into the
online-softmax inner loop and the tail overlaying its logical slot in
full precision, through one of two implementations (ISSUE 5):

* the **fused Pallas kernel** (kernels/paged_attention.py) — one launch
  per decode step; the page table is a scalar-prefetch operand, so each
  physical int8 page streams HBM->VMEM directly and is dequantized
  in-VMEM inside the softmax update.  Default for 'kernel' dscim serving
  modes (the TPU bandwidth path); under a mesh it runs inside shard_map
  (batch over DP, pool gathered per shard).
* the **jnp gather scan** — a ``lax.scan`` over logical pages gathering
  ``k_pages[table[:, j]]`` per step.  The reference semantics: default
  for every non-'kernel' mode, partitions under plain GSPMD, and the
  baseline the kernel is CI-diffed against (tools/bench_regression.py).

``--paged-attn kernel|jnp`` (a cache-keyed option on the whole serve
stack) pins either path explicitly; ``REPRO_PAGED_ATTN`` forces the
'auto' fallback at trace time.  Both walk pages in the same order with
f32 statistics, agreeing to <=1e-5 logits (tests/test_paged_kernel.py).

Page allocation is host-side (``PageAllocator``): the continuous-batching
scheduler (runtime/serving.py) and the async router (runtime/router.py)
grant a request its pages at admission and return them at completion, so
the jitted segment never allocates.  The allocator is **refcounted**
(ISSUE 10): ``alloc`` hands out pages at refcount 1, ``share`` takes an
additional reference on pages another request already owns, and ``free``
*decrements* — a physical page leaves the live set only when its last
sharer releases it.  Pages the prefix index marks *retainable* park in a
recently-freed LRU set at refcount 0 instead of returning to the free
list (their int8 bytes stay valid: pool pages are only rewritten on
reallocation) and are reclaimed oldest-first, via registered drop hooks,
only when an ``alloc`` would otherwise refuse — so prefix retention can
never cause an admission refusal the unretained pool would not have had.

**Prefix cache** (``PrefixCache``, ISSUE 10 tentpole): a rolling hash
over page-aligned token chunks of each prompt keys full flushed prefix
pages.  A new admission whose prompt shares a page-aligned prefix with a
live or retained entry maps its leading page-table rows at the *same*
physical pages (``acquire`` -> ``PageAllocator.share``) — quantized
once, ever — and prefill runs only from the first divergent page.
Invariants, in one place:

* Sharing covers only **full flushed pages strictly below the slot's
  write frontier** (``pos // ps``): the tail is always private, decode
  flushes land at logical index >= pos // ps, and extension prefill
  feeds from the first divergent page — so the jitted write paths never
  touch a shared page and both read paths work unchanged (they already
  resolve arbitrary permuted page tables, the PR 5 parity property).
* A host-side write into a slot's granted range must first call
  ``cow_fork``: any page there with refcount > 1 is forked to a fresh
  private copy (bytes + digest) before the scatter.  In the aligned
  admission flow this is a checked no-op; it is the enforcement point,
  not a hot path.
* ``page_checksums`` digests are per *physical* page, so they stay
  correct under sharing, and repairing a corrupted shared page heals
  every sharer at once.  ``extract_slot_pages``/``insert_slot_pages``
  copy bytes by physical id and always restore onto freshly granted
  private pages — eviction round trips never re-enter the shared set.
* ``PageAllocator.snapshot()`` carries refcounts, the retained LRU, and
  the retainable mark set; ``PrefixCache.snapshot()`` carries the hash
  index and hit counters — failover restores both and replays
  bit-identically.

Integrity (ISSUE 9): ``init_paged_cache(..., integrity=True)`` adds a
device-resident ``page_sum`` plane — one uint32 digest per (layer,
physical page) over the int8 planes and bitcast f32 scales — kept
current by every bulk write path here and by
``refresh_page_checksums`` after each decode segment.  Only granted AND
fully-flushed pages are under warranty; see runtime/integrity.py for
the scrub/repair contract.
"""
from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["quantize_page", "dequantize_page", "paged_from_dense",
           "init_paged_cache", "admit_request", "admit_dense",
           "paged_cache_specs", "kv_cache_bytes", "dense_cache_bytes",
           "PageAllocator", "PrefixCache", "cow_fork", "prefix_chunk_keys",
           "n_pages_for", "admission_pages",
           "extract_slot_pages", "insert_slot_pages", "spec_rollback",
           "page_checksums", "refresh_page_checksums", "CHECKSUM_KEY"]

TAIL_DTYPE = jnp.bfloat16

# integrity layer (ISSUE 9): the per-physical-page checksum plane rides
# the cache dict under this key — (L, P) uint32, one digest per (layer,
# physical page) over the int8 planes and the bitcast f32 scales.  It is
# created only under ``init_paged_cache(..., integrity=True)`` so the
# default cache pytree (and every jitted program traced against it) is
# byte-for-byte the pre-integrity layout.
CHECKSUM_KEY = "page_sum"
_CSUM_MULT = np.uint32(2654435761)        # Knuth's golden-ratio multiplier


def _csum_u32(x):
    """uint32 view of a plane for checksumming: integer dtypes widen,
    float dtypes go through a same-width bitcast (bit-exact, so a digest
    mismatch localizes a *bit* flip, not a value drift)."""
    if jnp.issubdtype(x.dtype, jnp.floating):
        bits = {2: jnp.uint16, 4: jnp.uint32}[jnp.dtype(x.dtype).itemsize]
        x = jax.lax.bitcast_convert_type(x, bits)
    return x.astype(jnp.uint32)


def _csum_fold(x, n_lead: int, mult: int):
    """Weighted modular sum over everything past the leading ``n_lead``
    axes: sum((2i+1) * GOLD * mult * x_i) mod 2**32.  Every per-element
    weight is odd, hence invertible mod 2**32 — a change to any single
    element (any bit, the sign bit of a f32 scale included) always moves
    the digest; ``mult`` (odd, distinct per plane) stops a flip in one
    plane cancelling against a flip at the same offset in another."""
    lead = x.shape[:n_lead]
    flat = _csum_u32(x).reshape(*lead, -1)
    n = flat.shape[-1]
    w = (2 * jnp.arange(n, dtype=jnp.uint32) + 1) \
        * _CSUM_MULT * jnp.uint32(mult)
    return jnp.sum(flat * w, axis=-1)


def page_checksums(k_pages, v_pages, k_scale, v_scale):
    """Per-(layer, page) uint32 digest of the quantized pool state:
    k/v int8 planes (L, P, ps, KV, HD) + bitcast f32 scales (L, P, KV)
    -> (L, P) uint32.  Deterministic integer arithmetic, so the digest of
    a page is a pure function of its bits — recomputing it over live
    planes and comparing against the stored ``page_sum`` plane detects
    any single-element corruption at an exact (layer, page) coordinate
    (runtime/integrity.py)."""
    return (_csum_fold(k_pages, 2, 1) + _csum_fold(v_pages, 2, 3)
            + _csum_fold(k_scale, 2, 5) + _csum_fold(v_scale, 2, 7))


def _update_page_sums(cache, phys):
    """Refresh the ``page_sum`` plane for the physical pages ``phys`` (any
    shape; flattened) from the pool's *current* contents.  No-op when the
    cache was built without the integrity plane.  Called after every bulk
    page write (``_scatter_pages``, ``insert_slot_pages``) so the stored
    digests always describe the bits actually resident."""
    if CHECKSUM_KEY not in cache:
        return cache
    idx = jnp.asarray(phys, jnp.int32).reshape(-1)
    s = page_checksums(cache["k_pages"][:, idx], cache["v_pages"][:, idx],
                       cache["k_scale"][:, idx], cache["v_scale"][:, idx])
    return dict(cache, **{CHECKSUM_KEY:
                          cache[CHECKSUM_KEY].at[:, idx].set(s)})


def refresh_page_checksums(cache, pos0, upper, max_span: int):
    """Re-digest every physical page a decode segment may have flushed.

    Tail pages quantize-and-flush *inside* the jitted segment scan
    (layers/attention.py), per layer, per step — threading the checksum
    plane through those write sites would touch every attention variant.
    Instead the segment builders (launch/steps.py) call this once after
    the scan: any logical page whose last token index lies in
    ``[pos0, upper)`` was completely filled during the segment, so its
    digest is recomputed from the live pool bits.

    ``pos0`` (B,) committed positions entering the segment, ``upper`` (B,)
    one past the highest position the segment may have written (includes
    speculative draft overhang), ``max_span`` a *static* bound on
    ``upper - pos0`` sizing the candidate window.  Done/idle slots pass an
    empty range and refresh nothing.  Recomputing from live content is
    self-consistent by construction: a page flushed then superseded (e.g.
    a rejected speculative window rewritten by ``spec_rollback``-adjacent
    logic) digests to whatever is actually resident."""
    if CHECKSUM_KEY not in cache:
        return cache
    table = cache["page_table"]
    mp = table.shape[1]
    P, ps = cache["k_pages"].shape[1:3]
    J = max_span // ps + 2
    js = pos0[:, None] // ps + jnp.arange(J, dtype=jnp.int32)[None, :]
    last_tok = js * ps + (ps - 1)                       # (B, J)
    hit = (last_tok >= pos0[:, None]) & (last_tok < upper[:, None]) \
        & (js < mp)
    phys = jnp.take_along_axis(table, jnp.minimum(js, mp - 1), axis=1)
    idx = jnp.where(hit, phys, P).reshape(-1)           # P == out-of-range
    safe = jnp.minimum(idx, P - 1)
    s = page_checksums(cache["k_pages"][:, safe], cache["v_pages"][:, safe],
                       cache["k_scale"][:, safe], cache["v_scale"][:, safe])
    return dict(cache, **{CHECKSUM_KEY:
                          cache[CHECKSUM_KEY].at[:, idx].set(
                              s, mode="drop")})


def quantize_page(x):
    """Symmetric int8 page quantization with per-kv-head scales.

    x (..., ps, KV, HD) float -> (q int8 same shape, scale (..., KV) f32);
    absmax taken over the page's (token, head_dim) axes so every kv head
    gets its own dequant scale (outlier heads don't poison the page)."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=(-3, -1))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None, :, None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_page(q, scale):
    """Inverse of ``quantize_page``: q (..., ps, KV, HD) int8 -> f32."""
    return q.astype(jnp.float32) * scale[..., None, :, None]


def n_pages_for(capacity: int, page_size: int) -> int:
    """Logical pages needed for one sequence of ``capacity`` tokens."""
    return -(-capacity // page_size)


def admission_pages(prompt_len: int, budget: int, page_size: int,
                    headroom: int = 0) -> int:
    """Physical pages one admission must be granted: prompt + generation
    budget + in-flight headroom (speculative draft positions, chunked-
    prefill window padding).  The single accounting rule shared by the
    continuous scheduler (runtime/serving.py) and the router's per-bucket
    admission control (runtime/router.py) — if the two computed this
    independently, a drift would show up as mid-stream pool corruption
    rather than an admission-time refusal.

    Non-positive ``page_size``/``budget`` raise instead of silently
    returning a nonsense page count (``page_size <= 0`` used to divide by
    zero or flip the ceiling-division sign; ``budget <= 0`` means the
    request can never emit a token, so its admission is a caller bug)."""
    if page_size <= 0:
        raise ValueError(f"admission_pages: page_size must be positive, "
                         f"got {page_size}")
    if budget <= 0:
        raise ValueError(f"admission_pages: generation budget must be "
                         f"positive, got {budget}")
    if prompt_len < 0 or headroom < 0:
        raise ValueError(f"admission_pages: prompt_len/headroom must be "
                         f">= 0, got {prompt_len}/{headroom}")
    return n_pages_for(prompt_len + budget + headroom, page_size)


def default_page_table(batch: int, max_pages: int):
    """Slot-major contiguous assignment (slot b owns pages [b*MP,(b+1)*MP))
    — the one-shot ``serve_batch`` layout; the continuous scheduler assigns
    rows from its allocator instead."""
    return jnp.arange(batch * max_pages, dtype=jnp.int32).reshape(
        batch, max_pages)


def init_paged_cache(n_layers: int, batch: int, n_pages: int, page_size: int,
                     max_pages: int, n_kv: int, head_dim: int,
                     integrity: bool = False):
    """Empty pool + idle slots (pos 0, slot-major default page table,
    clamped into the pool so an undersized pool — n_pages < batch *
    max_pages, legal for the continuous scheduler — never leaves idle
    slots gathering out of bounds before their first admission).

    ``integrity=True`` adds the ``page_sum`` digest plane (initialized
    consistent with the zero/ones pool, so a verify pass is clean from
    step 0); the default pytree is unchanged."""
    table = jnp.minimum(default_page_table(batch, max_pages), n_pages - 1)
    cache = {
        "k_pages": jnp.zeros((n_layers, n_pages, page_size, n_kv, head_dim),
                             jnp.int8),
        "v_pages": jnp.zeros((n_layers, n_pages, page_size, n_kv, head_dim),
                             jnp.int8),
        "k_scale": jnp.ones((n_layers, n_pages, n_kv), jnp.float32),
        "v_scale": jnp.ones((n_layers, n_pages, n_kv), jnp.float32),
        "k_tail": jnp.zeros((n_layers, batch, page_size, n_kv, head_dim),
                            TAIL_DTYPE),
        "v_tail": jnp.zeros((n_layers, batch, page_size, n_kv, head_dim),
                            TAIL_DTYPE),
        "page_table": table,
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if integrity:
        cache[CHECKSUM_KEY] = page_checksums(
            cache["k_pages"], cache["v_pages"],
            cache["k_scale"], cache["v_scale"])
    return cache


def _scatter_pages(cache, ks, vs, phys):
    """Quantize full pages ks/vs (L, ..., nf, ps, KV, HD) and scatter them
    into the pool at physical indices ``phys`` (..., nf)."""
    qk, sk = quantize_page(ks)
    qv, sv = quantize_page(vs)
    out = dict(
        cache,
        k_pages=cache["k_pages"].at[:, phys].set(qk),
        v_pages=cache["v_pages"].at[:, phys].set(qv),
        k_scale=cache["k_scale"].at[:, phys].set(sk),
        v_scale=cache["v_scale"].at[:, phys].set(sv))
    return _update_page_sums(out, phys)


def paged_from_dense(ks, vs, page_size: int, n_pages: int | None = None,
                     max_pages: int | None = None):
    """Convert a dense prefill cache (L, B, S, KV, HD) into a paged one.

    Full pages are quantized (per-page absmax scales); the S % ps remainder
    stays unquantized in the tail.  The default page table is slot-major
    over ``max_pages`` logical pages per slot; callers that decode past
    ``max_pages * page_size`` total tokens MUST pass ``max_pages`` sized
    for prompt + generation (launch/steps.py does) — the default only
    guarantees one decode page of headroom past the prompt."""
    L, B, S, KV, HD = ks.shape
    ps = page_size
    nf, rem = divmod(S, ps)
    if max_pages is None:
        # always include the page the next decoded token lands in: for
        # rem == 0 that is page nf (fresh), for rem > 0 the tail page
        max_pages = nf + 1
    if n_pages is None:
        n_pages = B * max_pages
    # the slot-major default table needs a page per (slot, logical page);
    # undersized pools are a scheduler feature (explicit page_table rows
    # via admit_request), not a conversion one
    assert n_pages >= B * max_pages, (n_pages, B, max_pages)
    cache = init_paged_cache(L, B, n_pages, ps, max_pages, KV, HD)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    if nf:
        pk = ks[:, :, :nf * ps].reshape(L, B, nf, ps, KV, HD)
        pv = vs[:, :, :nf * ps].reshape(L, B, nf, ps, KV, HD)
        cache = _scatter_pages(cache, pk, pv, cache["page_table"][:, :nf])
    if rem:
        cache["k_tail"] = cache["k_tail"].at[:, :, :rem].set(
            ks[:, :, nf * ps:].astype(TAIL_DTYPE))
        cache["v_tail"] = cache["v_tail"].at[:, :, :rem].set(
            vs[:, :, nf * ps:].astype(TAIL_DTYPE))
    return cache


def admit_request(cache, ks1, vs1, slot, page_ids):
    """Write one request's prefill KV (dense, (L, 1, S, KV, HD)) into slot
    ``slot`` of a live paged cache, onto host-allocated physical pages
    ``page_ids`` ((MP,) int32 — entries past the request's need unused).
    Jittable with traced slot/page_ids (S and shapes static)."""
    L, _, S, KV, HD = ks1.shape
    ps = cache["k_tail"].shape[2]
    nf, rem = divmod(S, ps)
    cache = dict(cache,
                 page_table=cache["page_table"].at[slot].set(page_ids),
                 pos=cache["pos"].at[slot].set(S))
    if nf:
        pk = ks1[:, 0, :nf * ps].reshape(L, nf, ps, KV, HD)
        pv = vs1[:, 0, :nf * ps].reshape(L, nf, ps, KV, HD)
        cache = _scatter_pages(cache, pk, pv, page_ids[:nf])
    tail_k = jnp.zeros((L, ps, KV, HD), cache["k_tail"].dtype)
    tail_v = jnp.zeros((L, ps, KV, HD), cache["v_tail"].dtype)
    if rem:
        tail_k = tail_k.at[:, :rem].set(
            ks1[:, 0, nf * ps:].astype(tail_k.dtype))
        tail_v = tail_v.at[:, :rem].set(
            vs1[:, 0, nf * ps:].astype(tail_v.dtype))
    return dict(cache,
                k_tail=cache["k_tail"].at[:, slot].set(tail_k),
                v_tail=cache["v_tail"].at[:, slot].set(tail_v))


def admit_dense(cache, ks1, vs1, slot):
    """Dense-cache counterpart of ``admit_request``: overwrite batch row
    ``slot`` of a (L, B, T, KV, HD) cache with a B=1 prefill padded to T."""
    L, _, S, KV, HD = ks1.shape
    T = cache["k"].shape[2]
    pad = [(0, 0), (0, 0), (0, T - S), (0, 0), (0, 0)]
    kp = jnp.pad(ks1.astype(cache["k"].dtype), pad)
    vp = jnp.pad(vs1.astype(cache["v"].dtype), pad)
    return dict(cache,
                k=jax.lax.dynamic_update_slice(cache["k"], kp,
                                               (0, slot, 0, 0, 0)),
                v=jax.lax.dynamic_update_slice(cache["v"], vp,
                                               (0, slot, 0, 0, 0)),
                pos=cache["pos"].at[slot].set(S))


def paged_cache_specs(cfg, batch: int, capacity: int, page_size: int,
                      n_pages: int | None = None, integrity: bool = False):
    """ShapeDtypeStruct tree of the paged cache (sharding-rule input)."""
    mp = n_pages_for(capacity, page_size)
    if n_pages is None:
        n_pages = batch * mp
    f = jax.ShapeDtypeStruct
    L, KV, HD = cfg.n_layers, cfg.n_kv, cfg.head_dim
    specs = {
        "k_pages": f((L, n_pages, page_size, KV, HD), jnp.int8),
        "v_pages": f((L, n_pages, page_size, KV, HD), jnp.int8),
        "k_scale": f((L, n_pages, KV), jnp.float32),
        "v_scale": f((L, n_pages, KV), jnp.float32),
        "k_tail": f((L, batch, page_size, KV, HD), TAIL_DTYPE),
        "v_tail": f((L, batch, page_size, KV, HD), TAIL_DTYPE),
        "page_table": f((batch, mp), jnp.int32),
        "pos": f((batch,), jnp.int32),
    }
    if integrity:
        specs[CHECKSUM_KEY] = f((L, n_pages), jnp.uint32)
    return specs


def _nbytes(spec) -> int:
    return int(np.prod(spec.shape)) * np.dtype(spec.dtype).itemsize


def kv_cache_bytes(cache_or_specs) -> int:
    """Resident decode-cache bytes (pages + scales + tails + page table;
    the per-slot positions and the integrity digest plane are
    bookkeeping, not cache traffic — excluding ``page_sum`` keeps byte
    accounting comparable across integrity on/off)."""
    skip = {"pos", CHECKSUM_KEY}
    tree = {k: v for k, v in cache_or_specs.items() if k not in skip}
    return sum(_nbytes(v) for v in jax.tree.leaves(tree))


def dense_cache_bytes(cfg, batch: int, capacity: int) -> int:
    """k+v bytes of the dense fixed-capacity cache at cfg.cache_dtype."""
    itemsize = jnp.dtype(cfg.cache_dtype).itemsize
    return 2 * cfg.n_layers * batch * capacity * cfg.n_kv * cfg.head_dim \
        * itemsize


def spec_rollback(cache, pos0, new_pos, tails0=None, win_kv=None):
    """Truncate a speculative draft/verify window back to its committed
    length (launch/steps.py) — the write-then-rollback discipline.

    ``pos0`` (B,) is the position the window started from, ``new_pos`` (B,)
    the committed position after accept/reject (pos0 <= new_pos <= pos0+T).
    Both cache layouts are append-only with read masks on ``pos``, so
    rejected positions never need erasing:

    * dense: rolled-back indices are masked (``tj <= pos``) until a later
      decode rewrites them write-before-read — truncating ``pos`` is the
      whole rollback.
    * paged: same masking argument for pages and for tail offsets past
      ``new_pos % ps`` — but if the window crossed a page boundary, the
      committed tail page's *low* offsets were flushed out of the tail (and
      the physical page they went to may hold rejected tokens quantized
      into its scale).  Those pages sit at logical index >= new_pos // ps,
      so reads never see them before a future flush rewrites them whole;
      the tail itself is rebuilt here from the window's K/V projections
      (``win_kv``, the verifier's writes in tail dtype — positions
      >= pos0) and the pre-window tails (``tails0`` — positions < pos0).
      Physical pages are never allocated or freed: the slot's grant is
      sized for prompt + budget + k up front, so the PageAllocator is
      untouched by speculation.

    Entries past ``new_pos % ps`` in the rebuilt tail are don't-care
    (rewritten write-before-read, exactly like the dense case); they are
    filled from the same gather rather than masked.
    """
    if "k_pages" not in cache:
        return dict(cache, pos=new_pos)
    k_tail0, v_tail0 = tails0
    win_k, win_v = win_kv
    ps = cache["k_tail"].shape[2]
    T = win_k.shape[2]
    o = jnp.arange(ps, dtype=jnp.int32)
    i = (new_pos // ps * ps)[:, None] + o[None, :]            # (B, ps) stream
    t = jnp.clip(i - pos0[:, None], 0, T - 1)                 # window index
    use_w = (i >= pos0[:, None])[None, :, :, None, None]

    def rebuild(win, tail0):
        g = jnp.take_along_axis(win, t[None, :, :, None, None], axis=2)
        return jnp.where(use_w, g, tail0)

    return dict(cache,
                k_tail=rebuild(win_k, k_tail0),
                v_tail=rebuild(win_v, v_tail0),
                pos=new_pos)


class PageAllocator:
    """Host-side refcounted free-list over the physical page pool.  The
    continuous scheduler allocates a request's pages at admission and
    frees them at completion — capacity is the pool size, not
    slots x max_len.

    Lifecycle of a physical page (ISSUE 10):

    * ``alloc`` — free -> live at refcount 1 (the classic grant).
    * ``share`` — +1 reference on a live page, or revive a *retained*
      page back to live at refcount 1 (the prefix-cache hit path: a new
      request maps its leading page-table entries at pages another
      request already filled).
    * ``free`` — -1 reference; a page leaves the live set only at
      refcount 0, and then returns to the free list **unless** it is
      marked retainable (``set_retainable``, the prefix index's mark),
      in which case it parks in a recently-freed LRU set with its bytes
      intact (pool pages are only rewritten on reallocation).
    * retained pages are reclaimed oldest-first — notifying registered
      ``on_reclaim`` hooks so the prefix index drops its entries — only
      when an ``alloc`` would otherwise refuse.  Retention therefore
      never costs an admission the unretained pool could have served,
      and retained pages are *not* live: the drain invariant
      ``live_pages == 0`` still certifies a leak-free shutdown.

    ``free`` validates its ids (ISSUE 6): a double-free or an out-of-range
    id would silently put the same physical page on the free list twice,
    and two live slots would later scatter into one page — corruption with
    no error at the corrupting site.  Raise here instead."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))
        self._live: set = set()
        self._refs: dict = {}               # live pid -> refcount >= 1
        self._retained: OrderedDict = OrderedDict()   # ref-0 parked, LRU
        self._retainable: set = set()       # pids the prefix index marked
        self._drop_hooks: list = []
        self._high_water = 0
        self._refusals = 0
        self._shares = 0
        self._reclaimed = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def available_pages(self) -> int:
        """Pages an ``alloc`` could hand out: free + reclaimable retained."""
        return len(self._free) + len(self._retained)

    def refcount(self, pid: int) -> int:
        """Current reference count of a page (0 for free/retained)."""
        return self._refs.get(int(pid), 0)

    def _reclaim_one(self) -> None:
        pid, _ = self._retained.popitem(last=False)     # oldest first
        self._retainable.discard(pid)
        for hook in self._drop_hooks:
            hook(pid)
        self._free.append(pid)
        self._reclaimed += 1

    def alloc(self, n: int):
        """n private physical page ids (refcount 1 each), or None if the
        pool can't cover them.  ``n <= 0`` raises: a zero/negative grant
        is always a caller accounting bug (``admission_pages`` never
        returns one), and ``alloc(0) -> []`` would read as a successful
        admission that owns no pages — the slot's first tail flush would
        then scatter through an unowned page-table row."""
        if n <= 0:
            raise ValueError(
                f"PageAllocator.alloc: page count must be positive, got {n}")
        if n > len(self._free) + len(self._retained):
            self._refusals += 1
            return None
        while n > len(self._free):
            self._reclaim_one()
        ids = [self._free.pop() for _ in range(n)]
        self._live.update(ids)
        for i in ids:
            self._refs[i] = 1
        self._high_water = max(self._high_water, len(self._live))
        return ids

    def share(self, ids) -> None:
        """Take one additional reference on each page in ``ids``: +1 on a
        live page, or revive a retained page to live at refcount 1.  A
        page that is neither live nor retained cannot be shared — its
        bytes are gone (free pages are reallocation fodder), so the
        caller's index is stale; raise rather than alias garbage."""
        ids = [int(i) for i in ids]
        for i in ids:
            if not (i in self._live or i in self._retained):
                raise ValueError(
                    f"PageAllocator.share: page {i} is neither live nor "
                    "retained — a stale prefix-index entry would alias a "
                    "reallocated page")
        # validate-then-commit
        for i in ids:
            if i in self._retained:
                del self._retained[i]
                self._live.add(i)
                self._refs[i] = 1
            else:
                self._refs[i] += 1
            self._shares += 1
        self._high_water = max(self._high_water, len(self._live))

    def set_retainable(self, pid: int, flag: bool = True) -> None:
        """Mark/unmark a page for retention at refcount 0 (the prefix
        index marks the pages it holds keys for).  Unmarking a currently
        retained page releases it to the free list immediately."""
        pid = int(pid)
        if flag:
            self._retainable.add(pid)
        else:
            self._retainable.discard(pid)
            if pid in self._retained:
                del self._retained[pid]
                self._free.append(pid)

    def on_reclaim(self, hook) -> None:
        """Register ``hook(pid)`` to fire when a retained page is
        reclaimed for reallocation (the prefix index purges its key)."""
        self._drop_hooks.append(hook)

    def stats(self) -> dict:
        """Occupancy counters for serve_bench / the scheduler's stats dict:
        live pages now, the high-water mark since construction (peak
        concurrent grant), how many ``alloc`` calls were refused
        (admission backpressure events), plus the sharing ledger —
        pages currently referenced more than once, retained ref-0 pages,
        cumulative ``share`` references taken, and retained pages
        reclaimed back into circulation."""
        return {"n_pages": self.n_pages,
                "live_pages": len(self._live),
                "high_water": self._high_water,
                "refusals": self._refusals,
                "shared_pages": sum(1 for r in self._refs.values() if r > 1),
                "retained_pages": len(self._retained),
                "shares": self._shares,
                "reclaimed": self._reclaimed}

    def free(self, ids) -> None:
        ids = [int(i) for i in ids]
        seen: set = set()
        for i in ids:
            if not 0 <= i < self.n_pages:
                raise ValueError(
                    f"PageAllocator.free: page id {i} out of range for a "
                    f"{self.n_pages}-page pool")
            if i in seen or i not in self._live:
                raise ValueError(
                    f"PageAllocator.free: double free of page {i} (not "
                    "currently allocated) — two live slots would share a "
                    "physical page")
            seen.add(i)
        # validate-then-commit: a raise above must leave the pool unchanged
        for i in ids:
            self._refs[i] -= 1
            if self._refs[i] > 0:
                continue                     # another sharer still holds it
            del self._refs[i]
            self._live.discard(i)
            if i in self._retainable:
                self._retained[i] = None     # park, newest at the LRU back
            else:
                self._free.append(i)

    # -- snapshot/restore (serve-state failover, runtime/serving.py) --------
    def snapshot(self) -> dict:
        """Plain-data copy of the allocator state (host snapshot leaf):
        free list (order preserved — reuse order is replay-visible),
        live set with refcounts, the retained LRU (order preserved), and
        the retainable mark set.  Drop hooks are process state, not
        snapshot state — the restoring driver re-registers them."""
        return {"n_pages": self.n_pages, "free": list(self._free),
                "live": sorted(self._live),
                "refs": {int(k): int(v) for k, v in self._refs.items()},
                "retained": list(self._retained),
                "retainable": sorted(self._retainable),
                "high_water": self._high_water,
                "refusals": self._refusals,
                "shares": self._shares,
                "reclaimed": self._reclaimed}

    @classmethod
    def from_snapshot(cls, snap: dict) -> "PageAllocator":
        a = cls.__new__(cls)
        a.n_pages = int(snap["n_pages"])
        a._free = [int(i) for i in snap["free"]]
        a._live = {int(i) for i in snap["live"]}
        # pre-ISSUE-10 snapshots carry no refcounts: every live page was
        # singly owned
        a._refs = {int(k): int(v)
                   for k, v in snap.get("refs", {}).items()} \
            or {i: 1 for i in a._live}
        a._retained = OrderedDict(
            (int(i), None) for i in snap.get("retained", ()))
        a._retainable = {int(i) for i in snap.get("retainable", ())}
        a._drop_hooks = []
        a._high_water = int(snap.get("high_water", len(a._live)))
        a._refusals = int(snap.get("refusals", 0))
        a._shares = int(snap.get("shares", 0))
        a._reclaimed = int(snap.get("reclaimed", 0))
        return a


_PREFIX_MULT = 1099511628211          # FNV-1a prime, odd -> invertible
_PREFIX_SEED = 14695981039346656037   # FNV-1a offset basis
_U64 = (1 << 64) - 1


def prefix_chunk_keys(tokens, page_size: int) -> list:
    """Rolling hash over page-aligned chunks of a token sequence.

    One uint64 key per *full* page of tokens; key j digests the entire
    prefix ``tokens[:(j+1) * page_size]`` (the hash rolls, it does not
    reset per page), so equal keys at chunk j mean equal full prefixes
    up to that page boundary — the property that lets the prefix index
    match the *longest* shared page-aligned prefix by scanning keys
    left to right.  ``tok + 1`` keeps a zero token from being absorbed
    (h * m + 0 == h * m would make [0] and [] collide)."""
    h = _PREFIX_SEED
    keys = []
    toks = np.asarray(tokens).reshape(-1)
    n_full = len(toks) // page_size
    for j in range(n_full):
        for t in toks[j * page_size:(j + 1) * page_size]:
            h = (h * _PREFIX_MULT + int(t) + 1) & _U64
        keys.append(h)
    return keys


class PrefixCache:
    """Prefix-hash index over full flushed physical pages (ISSUE 10).

    Maps rolling prefix-chunk keys (``prefix_chunk_keys``) to physical
    page ids so a new admission sharing a page-aligned prompt prefix
    with a live or retained request reuses those pages instead of
    re-prefilling and re-quantizing them:

    * ``acquire(tokens, max_chunks)`` — longest indexed prefix of the
      prompt, capped at ``max_chunks`` pages; takes a reference on each
      matched page (``PageAllocator.share``) and returns
      ``(n_shared_tokens, page_ids)``.  The caller maps those ids at
      page-table indices ``[0, d)`` and prefills from token
      ``n_shared_tokens``.
    * ``register(tokens, page_ids)`` — index a served request's full
      flushed prefix pages (``len(tokens) // ps`` of them) and mark
      them retainable.  First writer wins: a key already indexed keeps
      its existing page (typically the very page this request shared).
    * reclaim — the index registers an ``on_reclaim`` hook, so when the
      allocator recycles a retained page the key is purged before the
      page's bytes can be rewritten; index entries therefore always
      point at live-or-retained pages and ``share`` never aliases.

    The index never copies KV bytes and never blocks the pool: retained
    pages are reclaimed LRU-oldest-first the moment an allocation needs
    them."""

    def __init__(self, alloc: "PageAllocator", page_size: int):
        self.alloc = alloc
        self.page_size = int(page_size)
        self._index: dict = {}       # chunk key -> physical page id
        self._by_pid: dict = {}      # physical page id -> chunk key
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.pages_deduped = 0
        alloc.on_reclaim(self._on_reclaim)

    def __len__(self) -> int:
        return len(self._index)

    def _on_reclaim(self, pid: int) -> None:
        key = self._by_pid.pop(int(pid), None)
        if key is not None:
            self._index.pop(key, None)

    def acquire(self, tokens, max_chunks: int):
        """Match the longest indexed page-aligned prefix of ``tokens``
        (at most ``max_chunks`` pages), take a reference on each matched
        page, and return ``(n_shared_tokens, page_ids)``.  A miss is
        ``(0, [])``.  Callers cap ``max_chunks`` at
        ``(len(tokens) - 1) // page_size`` so at least one prompt token
        is always left to feed — the first sampled token needs the last
        prompt position's logits."""
        self.lookups += 1
        keys = prefix_chunk_keys(tokens, self.page_size)[:max(max_chunks, 0)]
        pids = []
        for key in keys:
            pid = self._index.get(key)
            if pid is None:
                break
            pids.append(pid)
        if not pids:
            return 0, []
        self.alloc.share(pids)
        self.hits += 1
        self.hit_tokens += len(pids) * self.page_size
        self.pages_deduped += len(pids)
        return len(pids) * self.page_size, list(pids)

    def register(self, tokens, page_ids) -> int:
        """Index a request's full flushed prefix pages: chunk j's key ->
        ``page_ids[j]`` for every fully-flushed page (``len(tokens) //
        page_size`` of them, clipped to the grant).  Pages now indexed
        are marked retainable so their bytes survive the request's
        release.  Returns the number of *new* index entries."""
        n_flushed = min(len(np.asarray(tokens).reshape(-1))
                        // self.page_size, len(page_ids))
        keys = prefix_chunk_keys(tokens, self.page_size)[:n_flushed]
        added = 0
        for key, pid in zip(keys, page_ids):
            pid = int(pid)
            if key in self._index:
                continue                     # first writer wins
            if pid in self._by_pid:
                continue                     # page already keyed elsewhere
            self._index[key] = pid
            self._by_pid[pid] = key
            self.alloc.set_retainable(pid, True)
            added += 1
        return added

    def stats(self) -> dict:
        return {"entries": len(self._index),
                "lookups": self.lookups,
                "hits": self.hits,
                "hit_tokens": self.hit_tokens,
                "pages_deduped": self.pages_deduped}

    # -- snapshot/restore (failover: the index must survive a replay) ------
    def snapshot(self) -> dict:
        return {"page_size": self.page_size,
                "index": [[int(k), int(p)] for k, p in self._index.items()],
                "lookups": self.lookups, "hits": self.hits,
                "hit_tokens": self.hit_tokens,
                "pages_deduped": self.pages_deduped}

    @classmethod
    def from_snapshot(cls, snap: dict,
                      alloc: "PageAllocator") -> "PrefixCache":
        pc = cls(alloc, int(snap["page_size"]))
        for k, p in snap["index"]:
            pc._index[int(k)] = int(p)
            pc._by_pid[int(p)] = int(k)
        pc.lookups = int(snap.get("lookups", 0))
        pc.hits = int(snap.get("hits", 0))
        pc.hit_tokens = int(snap.get("hit_tokens", 0))
        pc.pages_deduped = int(snap.get("pages_deduped", 0))
        return pc


def cow_fork(cache, alloc: "PageAllocator", page_ids, start_idx: int = 0):
    """Copy-on-write fork: make every page of a slot's grant from logical
    index ``start_idx`` on *private* before a write can land there.

    Any page in that range with refcount > 1 is copied — int8 planes,
    f32 scales, and (if present) its ``page_sum`` digest — onto a fresh
    page from the allocator, the original's refcount is decremented (the
    other sharers keep it), and the grant list is updated in place of
    return.  Pages already private pass through untouched.

    In the aligned admission flow this is a checked no-op: sharing stops
    strictly below the write frontier, so the writable range holds only
    private pages.  It exists as the enforcement point — the invariant
    "no write ever lands on a page with refcount > 1" is guaranteed by
    calling this before granting write access, not by hoping the
    alignment argument holds everywhere forever.

    Returns ``(cache, new_page_ids, n_forked)``.  Raises RuntimeError if
    the pool cannot supply a fork target (callers size grants so this
    cannot happen on the admission path)."""
    ids = [int(i) for i in page_ids]
    out = cache
    forked = 0
    for j in range(max(start_idx, 0), len(ids)):
        old = ids[j]
        if alloc.refcount(old) <= 1:
            continue
        got = alloc.alloc(1)
        if got is None:
            raise RuntimeError(
                "cow_fork: page pool exhausted while forking a shared "
                f"page (id {old}) — the grant was undersized")
        new = got[0]
        out = dict(
            out,
            k_pages=out["k_pages"].at[:, new].set(out["k_pages"][:, old]),
            v_pages=out["v_pages"].at[:, new].set(out["v_pages"][:, old]),
            k_scale=out["k_scale"].at[:, new].set(out["k_scale"][:, old]),
            v_scale=out["v_scale"].at[:, new].set(out["v_scale"][:, old]))
        if CHECKSUM_KEY in out:
            out = dict(out, **{CHECKSUM_KEY: out[CHECKSUM_KEY].at[:, new].set(
                out[CHECKSUM_KEY][:, old])})
        alloc.free([old])
        ids[j] = new
        forked += 1
    return out, ids, forked


def extract_slot_pages(cache, slot: int, page_ids) -> dict:
    """Bit-exact host-side snapshot of one slot's share of a paged cache:
    its granted physical pages (int8 planes + f32 scales), its bf16 tail,
    and its position.  The preemptive-eviction path (runtime/serving.py)
    parks this blob host-side so the request's KV never has to be
    re-prefilled — requantization or a different float reduction order
    would break bitwise replay parity."""
    ids = np.asarray([int(i) for i in page_ids], np.int32)
    g = np.asarray
    return {"page_count": len(ids),
            "k_pages": g(cache["k_pages"][:, ids]),
            "v_pages": g(cache["v_pages"][:, ids]),
            "k_scale": g(cache["k_scale"][:, ids]),
            "v_scale": g(cache["v_scale"][:, ids]),
            "k_tail": g(cache["k_tail"][:, slot]),
            "v_tail": g(cache["v_tail"][:, slot]),
            "pos": int(cache["pos"][slot])}


def insert_slot_pages(cache, slot: int, page_ids, blob: dict):
    """Inverse of ``extract_slot_pages`` onto freshly granted physical
    pages: scatter the parked planes/scales to ``page_ids``, restore the
    slot's tail and position, and rewrite its page-table row (padded to MP
    with the last id, exactly like admission).  The restored slot decodes
    bit-identically to one that was never evicted — only the *physical*
    page ids differ, and reads go through the page table."""
    ids = [int(i) for i in page_ids]
    if len(ids) != blob["page_count"]:
        raise ValueError(f"insert_slot_pages: {blob['page_count']} pages "
                         f"parked but {len(ids)} granted")
    mp = cache["page_table"].shape[1]
    row = jnp.asarray(ids + [ids[-1]] * (mp - len(ids)), jnp.int32)
    idx = jnp.asarray(ids, jnp.int32)
    out = dict(
        cache,
        k_pages=cache["k_pages"].at[:, idx].set(jnp.asarray(blob["k_pages"])),
        v_pages=cache["v_pages"].at[:, idx].set(jnp.asarray(blob["v_pages"])),
        k_scale=cache["k_scale"].at[:, idx].set(jnp.asarray(blob["k_scale"])),
        v_scale=cache["v_scale"].at[:, idx].set(jnp.asarray(blob["v_scale"])),
        k_tail=cache["k_tail"].at[:, slot].set(
            jnp.asarray(blob["k_tail"]).astype(cache["k_tail"].dtype)),
        v_tail=cache["v_tail"].at[:, slot].set(
            jnp.asarray(blob["v_tail"]).astype(cache["v_tail"].dtype)),
        page_table=cache["page_table"].at[slot].set(row),
        pos=cache["pos"].at[slot].set(blob["pos"]))
    return _update_page_sums(out, idx)
