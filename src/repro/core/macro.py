"""DS-CIM macro model: the paper's MVM estimator with three bit-exact backends.

``psum_hat = scale * C  -  128*Σx  -  128*Σw'``        (Eq. 4)

where ``C`` is the OR-accumulated count over L cycles, ``scale =
4^k * 2^16 / L``, term (c) ``128*Σx`` is an exact runtime reduction and term
(d) ``128*Σw'`` is exact/offline.  Backends:

* ``cycle``     — numpy cycle-accurate oracle (ormac.py), O(H*L) per column;
* ``lut``       — joint-count LUT gather, bit-exact == cycle, fast on CPU;
* ``bitmatmul`` — {0,1} bitstream-expansion matmul, bit-exact == cycle, the
                  formulation the Pallas TPU kernel implements.

DS-CIM1 = OR-MAC16 (k=2, 8 OR gates / 128-row column), accuracy-oriented.
DS-CIM2 = OR-MAC64 (k=3, 2 OR gates / column), efficiency-oriented.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from . import ormac, prng
from .remap import build_count_lut, fold_jnp, group_size, row_block, shifted_bits

__all__ = ["DSCIMConfig", "DSCIMMacro", "dscim1", "dscim2", "RMSE_NORMS"]

Backend = Literal["cycle", "lut", "bitmatmul"]


@dataclasses.dataclass(frozen=True)
class DSCIMConfig:
    """Static configuration of one DS-CIM macro variant."""
    k: int = 2                      # region-remap shift: OR group = 4^k rows
    length: int = 256               # bitstream length L
    points: str = "sobol"           # PRNG pair kind (see core.prng)
    seed_u: int = 0
    seed_v: int = 0
    param_u: int | None = None
    param_v: int | None = None
    trunc: Literal["floor", "center"] = "floor"   # 'center' = beyond-paper midpoint corr.
    rows: int = 128                 # physical rows per column (accumulation window)
    name: str = "dscim"

    @property
    def group(self) -> int:
        return group_size(self.k)

    @property
    def sbits(self) -> int:
        return shifted_bits(self.k)

    @property
    def scale(self) -> float:
        return (4 ** self.k) * 65536.0 / self.length


def dscim1(length: int = 256, **kw) -> "DSCIMConfig":
    """Paper's precise variant: 8x OR-MAC16 per 128-row column."""
    return DSCIMConfig(k=2, length=length, name=f"DS-CIM1/L{length}", **kw)


def dscim2(length: int = 64, **kw) -> "DSCIMConfig":
    """Paper's efficient variant: 2x OR-MAC64 per 128-row column."""
    return DSCIMConfig(k=3, length=length, name=f"DS-CIM2/L{length}", **kw)


# normalizations for "RMSE %" (the paper does not spell out its convention;
# calibration in EXPERIMENTS.md selects the one matching Table I)
RMSE_NORMS = ("signed_fullscale", "unsigned_fullscale")


class DSCIMMacro:
    """Stateful wrapper: point sequence + LUT constants + jit'd MVM paths."""

    def __init__(self, cfg: DSCIMConfig):
        self.cfg = cfg
        self.u, self.v = prng.make_points(
            cfg.points, cfg.length, cfg.seed_u, cfg.seed_v,
            cfg.param_u, cfg.param_v)
        self.lut_np = build_count_lut(self.u, self.v, cfg.k)   # (G, S, S) i32
        # NOTE: only numpy is cached on self — jnp constants are materialized
        # per trace (caching device arrays created inside a jit trace leaks
        # tracers into later traces).

    # -- helpers ------------------------------------------------------------
    def _shift(self, x_i8, w_i8):
        """int8 -> (a, b) shifted unsigned values in [0, S)."""
        k = self.cfg.k
        a = (x_i8.astype(jnp.int32) + 128) >> k
        b = (w_i8.astype(jnp.int32) + 128) >> k
        return a, b

    def _corrections(self, x_i8, w_i8, a, b):
        """Exact terms: -128Σx (runtime SIMD), -128Σw' (offline LUT), and the
        optional beyond-paper midpoint truncation correction."""
        cfg = self.cfg
        x32 = x_i8.astype(jnp.int32)
        w32 = w_i8.astype(jnp.int32)
        term_c = 128.0 * jnp.sum(x32, axis=-1, keepdims=True)       # (M,1)
        term_d = 128.0 * jnp.sum(w32 + 128, axis=0, keepdims=True)  # (1,N)
        corr = -term_c - term_d
        if cfg.trunc == "center":
            delta = (2 ** cfg.k - 1) / 2.0
            K = x_i8.shape[-1]
            corr = corr + (2 ** cfg.k) * delta * (
                jnp.sum(a, axis=-1, keepdims=True)
                + jnp.sum(b, axis=0, keepdims=True)) + K * delta * delta
        return corr

    # -- backends -----------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=0)
    def counts_lut(self, x_i8, w_i8):
        """C[m,n] = Σ_h LUT[h mod G, a[m,h], b[h,n]] via a K-scan of gathers."""
        a, b = self._shift(x_i8, w_i8)
        K = a.shape[-1]
        G = self.cfg.group
        blk = jnp.arange(K, dtype=jnp.int32) % G
        lut = jnp.asarray(self.lut_np)

        def body(acc, inp):
            a_h, b_h, g_h = inp            # (M,), (N,), ()
            tab = lut[g_h]                 # (S, S)
            acc = acc + tab[a_h][:, b_h]   # (M, N)
            return acc, None

        M, N = a.shape[0], b.shape[-1]
        init = jnp.zeros((M, N), jnp.int32)
        counts, _ = jax.lax.scan(body, init, (a.T, b, blk))
        return counts

    @functools.partial(jax.jit, static_argnums=0)
    def counts_bitmatmul(self, x_i8, w_i8):
        """C = A'W' over {0,1} bitstreams — the MXU/Pallas formulation."""
        cfg = self.cfg
        a, b = self._shift(x_i8, w_i8)                  # (M,K), (K,N)
        K = a.shape[-1]
        blk = jnp.arange(K, dtype=jnp.int32) % cfg.group
        bc, br = row_block(blk, cfg.k)
        cu, lu = fold_jnp(jnp.asarray(self.u.astype(np.int32)), cfg.k)  # (L,)
        cv, lv = fold_jnp(jnp.asarray(self.v.astype(np.int32)), cfg.k)
        abits = ((cu[None, None, :] == bc[None, :, None])
                 & (lu[None, None, :] < a[:, :, None])).astype(jnp.float32)
        wbits = ((cv[None, :, None] == br[:, None, None])
                 & (lv[None, :, None] < b[:, None, :])).astype(jnp.float32)
        counts = jnp.einsum("mkt,ktn->mn", abits, wbits)
        return counts.astype(jnp.int32)

    def counts_cycle(self, x_i8, w_i8):
        """Numpy cycle-accurate oracle (small shapes only)."""
        x = np.asarray(x_i8); w = np.asarray(w_i8)
        k = self.cfg.k
        a = ((x.astype(np.int32) + 128) >> k)
        b = ((w.astype(np.int32) + 128) >> k)
        M, K = a.shape
        N = b.shape[-1]
        out = np.zeros((M, N), np.int64)
        for m in range(M):
            for nn in range(N):
                c, _ = ormac.dscim_group_count(
                    a[m], b[:, nn], self.u, self.v, k, assert_disjoint=True)
                out[m, nn] = c
        return out

    # -- full MVM estimate ----------------------------------------------------
    def mvm_from_counts(self, x_i8, w_i8, counts):
        """psum estimate from a precomputed OR-accumulated count matrix."""
        a, b = self._shift(jnp.asarray(x_i8), jnp.asarray(w_i8))
        b_hat = self.cfg.scale * counts.astype(jnp.float32)
        return b_hat + self._corrections(jnp.asarray(x_i8), jnp.asarray(w_i8), a, b)

    def mvm(self, x_i8, w_i8, backend: Backend = "lut"):
        """DS-CIM estimate of x_i8 @ w_i8 (int8 signed matmul), float32."""
        if backend == "lut":
            counts = self.counts_lut(x_i8, w_i8)
        elif backend == "bitmatmul":
            counts = self.counts_bitmatmul(x_i8, w_i8)
        elif backend == "cycle":
            counts = jnp.asarray(self.counts_cycle(x_i8, w_i8).astype(np.float32))
        else:
            raise ValueError(backend)
        return self.mvm_from_counts(x_i8, w_i8, counts)

    # -- error statistics ------------------------------------------------------
    def rmse(self, n_cols: int = 512, n_vec: int = 64, seed: int = 0,
             dist: str = "uniform"):
        """Monte-Carlo RMSE of the H-row MAC vs exact int8 matmul.

        Returns dict with absolute RMS error and both %-normalizations
        (signed fullscale H*128*128, unsigned fullscale H*255*255).
        """
        H = self.cfg.rows
        rng = np.random.default_rng(seed)
        if dist == "uniform":
            x = rng.integers(-128, 128, (n_vec, H), dtype=np.int64)
            w = rng.integers(-128, 128, (H, n_cols), dtype=np.int64)
        elif dist == "gaussian":
            x = np.clip(np.round(rng.normal(0, 42, (n_vec, H))), -128, 127).astype(np.int64)
            w = np.clip(np.round(rng.normal(0, 42, (H, n_cols))), -128, 127).astype(np.int64)
        elif dist == "sparse":
            x = rng.integers(-128, 128, (n_vec, H), dtype=np.int64)
            x *= rng.random((n_vec, H)) < 0.25
            w = rng.integers(-128, 128, (H, n_cols), dtype=np.int64)
        else:
            raise ValueError(dist)
        exact = x @ w
        est = np.asarray(self.mvm(jnp.asarray(x, jnp.int32),
                                  jnp.asarray(w, jnp.int32)))
        err = est - exact
        rms = float(np.sqrt(np.mean(err ** 2)))
        return {
            "rms_abs": rms,
            "bias": float(err.mean()),
            "signed_fullscale": 100.0 * rms / (H * 128 * 128),
            "unsigned_fullscale": 100.0 * rms / (H * 255 * 255),
        }
