"""Cycle-accurate OR-MAC simulation — the hardware oracle.

Two circuits:

* :func:`dscim_group_count` — the paper's remapped OR-MAC (DS-CIM): shared
  PRNG pair, region-remapped rows, OR per group, per-cycle adder across
  groups, accumulator over L cycles.  Because regions are disjoint the OR
  equals the sum; :func:`check_disjoint` asserts that invariant.

* :func:`naive_or_count` — the conventional stochastic OR-MAC of [27]:
  independent PRNG streams per row, no remapping, so simultaneous 1s
  *collide* in the OR gate (1s saturation error).  Used for the Fig. 6(c)
  reproduction and as the paper's baseline.

These run the explicit bitstream × OR × adder pipeline and are O(H·L); the
LUT/bitmatmul backends in :mod:`repro.core.macro` are the fast bit-exact
equivalents validated against this module.
"""
from __future__ import annotations

import numpy as np

from . import prng as prng_lib
from .remap import fires, fold, group_size, row_block, shifted_bits

__all__ = [
    "sng_bits", "dscim_bitstreams", "dscim_group_count", "check_disjoint",
    "naive_or_count",
]


def sng_bits(values: np.ndarray, rand: np.ndarray) -> np.ndarray:
    """Plain SNG: bit_t = (rand_t < value). values (...,), rand (L,) -> (..., L)."""
    return (rand[None, :] < values[..., None].astype(np.int32)).astype(np.uint8)


def dscim_bitstreams(a_shift: np.ndarray, w_shift: np.ndarray,
                     u: np.ndarray, v: np.ndarray, k: int):
    """Per-row remapped A_SC / W_SC bitstreams, shape (H, L).

    a_shift/w_shift: shifted unsigned values in [0, S), one per row (H,).
    The SNG for row g fires iff the fold of its PRNG coordinate matches the
    row's block code and the local coordinate is below the data value — the
    comparator-with-inverted-bits of Fig. 6(d)/(e).
    """
    H = a_shift.shape[0]
    G = group_size(k)
    g = np.arange(H) % G
    bc, br = row_block(g, k)
    cu, lu = fold(u.astype(np.int32), k)
    cv, lv = fold(v.astype(np.int32), k)
    a_bits = ((cu[None, :] == bc[:, None]) &
              (lu[None, :] < a_shift[:, None].astype(np.int32)))
    w_bits = ((cv[None, :] == br[:, None]) &
              (lv[None, :] < w_shift[:, None].astype(np.int32)))
    return a_bits.astype(np.uint8), w_bits.astype(np.uint8)


def check_disjoint(p_bits: np.ndarray, k: int) -> bool:
    """Invariant: within every OR group, at most one product bit fires/cycle."""
    H, L = p_bits.shape
    G = group_size(k)
    per_group = p_bits.reshape(H // G, G, L).sum(axis=1)
    return bool((per_group <= 1).all())


def dscim_group_count(a_shift: np.ndarray, w_shift: np.ndarray,
                      u: np.ndarray, v: np.ndarray, k: int,
                      assert_disjoint: bool = False):
    """Cycle-accurate DS-CIM column: returns (total_count, per_cycle_sums).

    per_cycle_sums[t] = adder output at cycle t (sum of the OR-gate outputs
    of all H/G groups) — bounded by H/G, e.g. <=8 for DS-CIM1, <=2 for
    DS-CIM2, matching the paper's addition bitwidths.
    """
    a_bits, w_bits = dscim_bitstreams(a_shift, w_shift, u, v, k)
    p_bits = a_bits & w_bits
    if assert_disjoint and not check_disjoint(p_bits, k):
        raise AssertionError("remapped OR groups are not collision-free")
    H, L = p_bits.shape
    G = group_size(k)
    or_out = p_bits.reshape(H // G, G, L).max(axis=1)   # the OR gates
    per_cycle = or_out.sum(axis=0)                      # the per-cycle adder
    return int(per_cycle.sum()), per_cycle              # the accumulator


def naive_or_count(a_u8: np.ndarray, w_u8: np.ndarray, L: int, group: int,
                   seed: int = 0, kind: str = "lfsr"):
    """[27]-style conventional OR-MAC: independent PRNGs/row, no remapping.

    a_u8/w_u8: *unshifted* unsigned values in [0, 256).  Each row compares
    its own PRNG pair; the OR gate saturates when several product bits are 1
    in the same cycle.  Returns (count, ideal_sum_of_product_bits) so callers
    can quantify the saturation loss.
    """
    H = a_u8.shape[0]
    rng = np.random.default_rng(seed)
    counts_or = 0
    counts_sum = 0
    for g0 in range(0, H, group):
        rows = slice(g0, min(g0 + group, H))
        n = a_u8[rows].shape[0]
        # independent hardware PRNG per row (distinct seeds/taps)
        p = np.empty((n, L), np.uint8)
        for i in range(n):
            su, sv = rng.integers(1, 255, 2)
            uu = prng_lib.make_points(kind, L, int(su), int(sv),
                                      param_u=i, param_v=i + 1)
            a_b = sng_bits(a_u8[rows][i:i + 1], uu[0])[0]
            w_b = sng_bits(w_u8[rows][i:i + 1], uu[1])[0]
            p[i] = a_b & w_b
        counts_or += int(p.max(axis=0).sum())
        counts_sum += int(p.sum())
    return counts_or, counts_sum
