"""8-bit pseudo-random / low-discrepancy sequence generators for DS-CIM.

The paper (Sec. IV-C) searches "mainstream 8-bit PRNGs" and initial seeds to
minimize the RMSE of the OR-MAC.  Everything here is a *deterministic*
host-side generator returning ``np.uint8`` arrays of length L; the chosen
sequence pair (PRNGA, PRNGW) is baked into the macro as constants (exactly
like the hardware, where the PRNG wiring is fixed at tape-out and the seed is
a register).

Hardware-faithful generators: LFSR (Fibonacci + Galois, several taps), LCG,
Weyl adder, xorshift.  Beyond-paper low-discrepancy generators (our accuracy
hillclimb): van-der-Corput, 2D Sobol (0,2)-sequence, R2/Kronecker.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "lfsr8", "galois_lfsr8", "lcg8", "weyl8", "xorshift8", "counter8",
    "vdc8", "sobol2d_8", "r2_8", "make_points", "PAIR_KINDS",
]

# ---------------------------------------------------------------------------
# scalar-recurrence PRNGs (hardware-typical)
# ---------------------------------------------------------------------------

# maximal-period 8-bit Fibonacci LFSR tap masks (period 255)
FIB_TAPS = (0xB8, 0xE1, 0xD4, 0xC6, 0x8E, 0x95, 0xAF, 0xB1)
# maximal-period Galois LFSR feedback polynomials
GAL_TAPS = (0x1D, 0x2B, 0x2D, 0x4D, 0x5F, 0x63, 0x65, 0x69)


def lfsr8(length: int, seed: int = 1, taps: int = 0xB8) -> np.ndarray:
    """Fibonacci LFSR over GF(2^8); emits the full 8-bit state per cycle."""
    state = np.uint8(seed if seed % 256 != 0 else 1)
    out = np.empty(length, np.uint8)
    for t in range(length):
        out[t] = state
        fb = bin(int(state) & taps).count("1") & 1
        state = np.uint8(((int(state) << 1) | fb) & 0xFF)
    return out


def galois_lfsr8(length: int, seed: int = 1, taps: int = 0x1D) -> np.ndarray:
    state = int(seed) % 256 or 1
    out = np.empty(length, np.uint8)
    for t in range(length):
        out[t] = state
        msb = state >> 7
        state = ((state << 1) & 0xFF) ^ (taps if msb else 0)
    return out


def lcg8(length: int, seed: int = 1, a: int = 141, c: int = 3) -> np.ndarray:
    """Full-period 8-bit LCG (a ≡ 1 mod 4, c odd)."""
    state = int(seed) % 256
    out = np.empty(length, np.uint8)
    for t in range(length):
        out[t] = state
        state = (a * state + c) % 256
    return out


def weyl8(length: int, seed: int = 0, alpha: int = 159) -> np.ndarray:
    """Additive Weyl sequence (x0 + t*alpha) mod 256; alpha odd => period 256.

    alpha = 159 ~ 256*(golden ratio - 1): a 1D low-discrepancy lattice.
    """
    t = np.arange(length, dtype=np.int64)
    return ((int(seed) + t * int(alpha)) % 256).astype(np.uint8)


def xorshift8(length: int, seed: int = 1, shifts=(3, 5, 4)) -> np.ndarray:
    s1, s2, s3 = shifts
    state = int(seed) % 256 or 1
    out = np.empty(length, np.uint8)
    for t in range(length):
        out[t] = state
        state ^= (state << s1) & 0xFF
        state ^= state >> s2
        state ^= (state << s3) & 0xFF
        state &= 0xFF
        if state == 0:
            state = 1
    return out


def counter8(length: int, seed: int = 0) -> np.ndarray:
    t = np.arange(length, dtype=np.int64)
    return ((int(seed) + t) % 256).astype(np.uint8)


# ---------------------------------------------------------------------------
# low-discrepancy sequences (beyond-paper accuracy option)
# ---------------------------------------------------------------------------

def _bitrev8(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint16)
    r = np.zeros_like(x)
    for i in range(8):
        r |= ((x >> i) & 1) << (7 - i)
    return r.astype(np.uint8)


def vdc8(length: int, seed: int = 0) -> np.ndarray:
    """van der Corput base 2, scaled to 8 bits, XOR-scrambled by ``seed``."""
    t = np.arange(length, dtype=np.uint16) % 256
    return (_bitrev8(t) ^ np.uint8(seed % 256)).astype(np.uint8)


# Sobol direction numbers for dimension 2 (primitive poly x^2 + x + 1, m1=1).
def _sobol_dim2_directions(bits: int = 8) -> np.ndarray:
    m = [1, 3]  # m_k (odd), standard Joe-Kuo initialisation for dim 2
    a = 1       # poly coefficient bits for x^2+x+1 (excluding leading/trailing)
    s = 2
    for k in range(s, bits):
        new = m[k - s] ^ (m[k - s] << s)
        for i in range(1, s):
            if (a >> (s - 1 - i)) & 1:
                new ^= m[k - i] << i
        m.append(new)
    # v_k = m_k * 2^(bits-k-1)
    return np.array([m[k] << (bits - k - 1) for k in range(bits)], np.uint16)


_SOBOL_V2 = _sobol_dim2_directions(8)


def sobol2d_8(length: int, seed_u: int = 0, seed_v: int = 0):
    """2D Sobol (0,2)-sequence scaled to [0,256)²; XOR digit-scrambled.

    Property: any elementary dyadic box of area 2^-ceil(log2 L) contains the
    expected number of points — per-block stratification is near-perfect for
    the DS-CIM 2^k×2^k partition.
    """
    t = np.arange(length, dtype=np.uint32)
    # dim 1: bit-reversed counter
    u = _bitrev8((t % 256).astype(np.uint16))
    # dim 2: Sobol via gray-code XOR of direction numbers
    v = np.zeros(length, np.uint16)
    gray = t ^ (t >> 1)
    for k in range(8):
        v ^= np.where((gray >> k) & 1, _SOBOL_V2[k], 0).astype(np.uint16)
    return (u ^ np.uint8(seed_u % 256)).astype(np.uint8), (
        (v & 0xFF).astype(np.uint8) ^ np.uint8(seed_v % 256)
    )


def r2_8(length: int, seed: int = 0):
    """R2 Kronecker sequence (plastic constant), 2D, scaled to 8 bits."""
    g = 1.32471795724474602596  # plastic number
    a1, a2 = 1.0 / g, 1.0 / (g * g)
    t = np.arange(length, dtype=np.float64) + 1 + seed
    u = np.floor((t * a1 % 1.0) * 256).astype(np.uint8)
    v = np.floor((t * a2 % 1.0) * 256).astype(np.uint8)
    return u, v


# ---------------------------------------------------------------------------
# paired-point factory
# ---------------------------------------------------------------------------

PAIR_KINDS = (
    "lfsr", "galois", "lcg", "weyl", "xorshift", "vdc", "sobol", "r2",
    "lfsr_weyl", "counter_vdc",
)


def make_points(kind: str, length: int, seed_u: int = 1, seed_v: int = 7,
                param_u: int | None = None, param_v: int | None = None):
    """Return (u, v) uint8 arrays of ``length`` sampling coordinates.

    ``param_*`` select taps/multipliers where applicable; defaults differ per
    axis so (u,v) are decorrelated even for equal seeds.
    """
    if kind == "lfsr":
        return (lfsr8(length, seed_u, FIB_TAPS[(param_u or 0) % len(FIB_TAPS)]),
                lfsr8(length, seed_v, FIB_TAPS[(param_v or 1) % len(FIB_TAPS)]))
    if kind == "galois":
        return (galois_lfsr8(length, seed_u, GAL_TAPS[(param_u or 0) % len(GAL_TAPS)]),
                galois_lfsr8(length, seed_v, GAL_TAPS[(param_v or 1) % len(GAL_TAPS)]))
    if kind == "lcg":
        return (lcg8(length, seed_u, a=141, c=3),
                lcg8(length, seed_v, a=205, c=57))
    if kind == "weyl":
        return (weyl8(length, seed_u, alpha=param_u or 159),
                weyl8(length, seed_v, alpha=param_v or 97))
    if kind == "xorshift":
        return (xorshift8(length, seed_u, (3, 5, 4)),
                xorshift8(length, seed_v, (5, 3, 1)))
    if kind == "vdc":
        return vdc8(length, seed_u), vdc8(length, seed_v ^ 0xA5)
    if kind == "sobol":
        return sobol2d_8(length, seed_u, seed_v)
    if kind == "r2":
        return r2_8(length, seed_u)
    if kind == "lfsr_weyl":
        return lfsr8(length, seed_u, 0xB8), weyl8(length, seed_v, alpha=159)
    if kind == "counter_vdc":
        return counter8(length, seed_u), vdc8(length, seed_v)
    raise ValueError(f"unknown point kind {kind!r}; one of {PAIR_KINDS}")
