"""Quantization substrate: INT8 (per-channel / per-group) and FP8(E4M3),
including the paper's FP8->INT8 group-128 alignment recipe ([30], used for
the LLaMA-7B experiment in Table II).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "quantize_int8", "dequantize_int8", "fp8_cast", "fp8_to_int8_aligned",
    "QuantizedTensor",
]


class QuantizedTensor(tuple):
    """(q: int8 values, scale: f32 per-channel/group scales, axis meta)."""
    __slots__ = ()

    def __new__(cls, q, scale, axis):
        return super().__new__(cls, (q, scale, axis))

    @property
    def q(self):
        return self[0]

    @property
    def scale(self):
        return self[1]

    @property
    def axis(self):
        return self[2]


def quantize_int8(x, axis=-1, eps: float = 1e-8) -> QuantizedTensor:
    """Symmetric per-channel int8: q = round(x / s), s = max|x| / 127.

    The scale is computed as ``amax * (1/127)`` — written as an explicit
    reciprocal multiply because XLA's algebraic simplifier rewrites
    divide-by-constant into exactly that inside jitted graphs; spelling it
    out makes eager quantization (the prepare-once weight path,
    core/qweights.py) bit-identical to in-graph quantization (the on-the-fly
    path), instead of differing by 1 ulp on borderline values."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, eps) * (1.0 / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q, scale.astype(jnp.float32), axis)


def dequantize_int8(qt: QuantizedTensor):
    return qt.q.astype(jnp.float32) * qt.scale


def fp8_cast(x):
    """Round-trip through float8_e4m3fn (the paper's LLM-FP4/FP8 recipe [29])."""
    return x.astype(jnp.float8_e4m3fn).astype(jnp.float32)


def fp8_to_int8_aligned(x, group: int = 128):
    """Paper Sec. V: 'FP8 activations and weights were aligned to INT8 with a
    granularity of 128 as inputs for DS-CIM' (method of RedCIM [30]).

    The FP8 values within each contiguous group of ``group`` along the last
    axis share one power-capped scale; each group is then re-quantized to
    int8 so the DS-CIM macro sees pure int8 operands.  Returns
    (int8 values, per-group scales); error = fp8 cast error + alignment.
    """
    xf = fp8_cast(x)
    shp = xf.shape
    pad = (-shp[-1]) % group
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, pad)])
    g = xf.reshape(*xf.shape[:-1], -1, group)
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), pad
