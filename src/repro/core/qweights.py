"""Prepared (quantize-once) DS-CIM linear weights.

The paper's macro stores weights as static int8 in the CIM array: weight
quantization happens once, when the array is programmed, never per MVM.
This module is the software twin of that property — a
``QuantizedLinearWeight`` pytree holding the window-packed int8 planes and
per-window dequant scales that a real DS-CIM chip keeps resident, plus a
``prepare_dscim_params`` tree-walk that converts every DS-CIM-eligible
matrix of a model's param tree once at serve startup.

All ``DSCIMLinear`` backends and the fused Pallas kernel accept either a
float ``(K, N)`` matrix (training / tests — quantized on the fly, the old
behavior) or a ``QuantizedLinearWeight`` (serving — only activations are
quantized per call).  The two paths are bit-identical by construction:
``prepare_linear_weight`` is exactly the weight half of the old joint
quantization (pad K with float zeros to a whole number of ``group_k``
windows *before* quantizing, one symmetric int8 scale per window).

Layout (matching the macro's 128-row accumulation windows):

* ``q``     — int8 ``(*stack, nw, g, N)`` window planes; ``stack`` carries
              scan-stacked layer dims (slicing under ``lax.scan`` preserves
              the pytree aux data, so a stacked weight slices into per-layer
              prepared weights for free);
* ``scale`` — f32 ``(*stack, nw, N)`` per-window dequant scales — these
              shard together with ``q`` on the N axis (launch/sharding.py);
* ``k_orig``/``group_k`` — static pad metadata: the unpadded contraction
              length and the requested window granularity.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .quant import quantize_int8

__all__ = ["QuantizedLinearWeight", "prepare_linear_weight",
           "dequantize_linear_weight", "prepare_dscim_params",
           "qweight_replicated_specs", "split_dscim_mode", "path_str",
           "ELIGIBLE_PATTERNS", "ATTN_PATTERNS",
           "plane_digest", "iter_qweight_planes", "weight_plane_index",
           "weight_plane_digests", "golden_weight_copy",
           "restore_weight_plane"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedLinearWeight:
    """Window-packed int8 weight planes + per-window scales (see module
    docstring).  A registered pytree: ``q``/``scale`` are children, the pad
    metadata is static aux data — so jit/scan/shard_map treat it natively.
    """
    q: Any             # int8 (*stack, nw, g, N)
    scale: Any         # f32  (*stack, nw, N)
    k_orig: int        # unpadded K (static)
    group_k: int | None  # requested quantization granularity (static)

    def tree_flatten(self):
        return (self.q, self.scale), (self.k_orig, self.group_k)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    # --- logical float-matrix view (so call sites like ``w.shape[1]`` and
    # --- stacked-layer slicing keep working unchanged) ---------------------
    @property
    def nw(self) -> int:
        return self.q.shape[-3]

    @property
    def g(self) -> int:
        return self.q.shape[-2]

    @property
    def n(self) -> int:
        return self.q.shape[-1]

    @property
    def stack(self) -> tuple:
        return tuple(self.q.shape[:-3])

    @property
    def shape(self) -> tuple:
        return (*self.stack, self.k_orig, self.n)

    @property
    def ndim(self) -> int:
        return len(self.shape)


def prepare_linear_weight(w, group_k: int | None = 128
                          ) -> QuantizedLinearWeight:
    """Float ``(*stack, K, N)`` -> prepared weight (quantize once).

    Bit-identical to the on-the-fly path: K is padded with float zeros to a
    whole number of ``group_k`` windows *before* quantizing, and each window
    gets one symmetric int8 scale over its (g, 1) slice.
    """
    *stack, K, N = w.shape
    g = group_k or K
    pad = (-K) % g
    if pad:
        widths = [(0, 0)] * len(stack) + [(0, pad), (0, 0)]
        w = jnp.pad(w, widths)
    nw = (K + pad) // g
    qt = quantize_int8(w.reshape(*stack, nw, g, N), axis=-2)
    return QuantizedLinearWeight(
        qt.q, qt.scale.reshape(*stack, nw, N).astype(jnp.float32),
        K, group_k)


def qweight_replicated_specs(qw: QuantizedLinearWeight
                             ) -> QuantizedLinearWeight:
    """All-``None`` PartitionSpec subtree for one prepared weight: every
    device holds the whole int8 planes + scales.  The single source for the
    replicated MoE shared-expert convention — launch/sharding.py placement
    and the models/lm.py shard_map in_specs must agree, so both call this.
    """
    from jax.sharding import PartitionSpec as P
    return QuantizedLinearWeight(P(*([None] * qw.q.ndim)),
                                 P(*([None] * qw.scale.ndim)),
                                 qw.k_orig, qw.group_k)


def dequantize_linear_weight(qw: QuantizedLinearWeight):
    """Prepared -> float ``(*stack, K, N)`` (pad rows stripped)."""
    wf = qw.q.astype(jnp.float32) * qw.scale[..., :, None, :]
    wf = wf.reshape(*qw.stack, qw.nw * qw.g, qw.n)
    return wf[..., :qw.k_orig, :]


# Name patterns (flattened-path substrings) of the matrices the DS-CIM
# serving path routes through DSCIMLinear — the MLP matmuls, the MoE shared
# expert (dense on every token) and the LM head.  Attention projections are
# exact by default (DESIGN.md §6) and only prepared for '<mode>+attn' specs.
ELIGIBLE_PATTERNS = (
    "mlp/w_up", "mlp/w_gate", "mlp/w_down",
    "moe/shared/w_up", "moe/shared/w_gate", "moe/shared/w_down",
    "lm_head",
)
ATTN_PATTERNS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def path_str(path) -> str:
    """Flattened-pytree path -> 'a/b/c' (shared with launch/sharding.py)."""
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def split_dscim_mode(spec: str) -> tuple[str, bool]:
    """dscim spec -> (base mode, attn opt-in): 'kernel+attn:...' ->
    ('kernel', True); 'off' -> ('off', False)."""
    mode = spec.split(":")[0]
    if mode.endswith("+attn"):
        return mode[:-len("+attn")], True
    return mode, False


# --- integrity digests (ISSUE 9) -----------------------------------------
# A prepared model's int8 planes and f32 scales are static for the whole
# serve lifetime — the software twin of the paper's programmed CIM array —
# so one digest per plane, computed at prepare time, detects any later
# in-memory bit flip deterministically.  Raw float leaves (norms, the
# embedding lookup) are NOT covered here: they change under no-op dtype
# casts and are the accuracy watchdog's statistical territory instead
# (docs/serving.md "Fault model & integrity contract").

_DIGEST_MULT = np.uint32(2654435761)      # Knuth multiplier, as kvcache


def plane_digest(x):
    """uint32 digest of one array: sum((2i+1) * GOLD * x_i) mod 2**32 over
    the flattened uint view (floats bitcast same-width).  Odd per-element
    weights are invertible mod 2**32, so a change to any single element —
    any bit, f32 sign bit included — always moves the digest."""
    if jnp.issubdtype(x.dtype, jnp.floating):
        bits = {2: jnp.uint16, 4: jnp.uint32}[jnp.dtype(x.dtype).itemsize]
        x = jax.lax.bitcast_convert_type(x, bits)
    flat = x.reshape(-1).astype(jnp.uint32)
    w = (2 * jnp.arange(flat.shape[0], dtype=jnp.uint32) + 1) * _DIGEST_MULT
    return jnp.sum(flat * w)


def iter_qweight_planes(params):
    """Deterministic (path, 'q'|'scale', array) walk over every prepared
    ``QuantizedLinearWeight`` in ``params`` — the canonical plane order
    shared by digest sweeps, golden copies, and mismatch attribution."""
    leaves = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantizedLinearWeight))[0]
    out = []
    for path, leaf in leaves:
        if isinstance(leaf, QuantizedLinearWeight):
            p = path_str(path)
            out.append((p, "q", leaf.q))
            out.append((p, "scale", leaf.scale))
    return out


def weight_plane_index(params):
    """[(path, 'q'|'scale'), ...] in ``iter_qweight_planes`` order."""
    return [(p, which) for p, which, _ in iter_qweight_planes(params)]


def weight_plane_digests(params):
    """(n_planes,) uint32 digest vector in ``weight_plane_index`` order.
    Jittable — the scrubber runs it as one compiled sweep per check."""
    planes = iter_qweight_planes(params)
    if not planes:
        return jnp.zeros((0,), jnp.uint32)
    return jnp.stack([plane_digest(x) for _, _, x in planes])


def golden_weight_copy(params):
    """Host-side golden copy of every prepared plane + its digest vector,
    taken once at ``prepare_serving_params``.  Repair source of truth:
    ``restore_weight_plane`` re-installs these exact bytes, so a repaired
    model is bit-identical to the freshly prepared one."""
    planes = {(p, which): np.asarray(x)
              for p, which, x in iter_qweight_planes(params)}
    return {"index": weight_plane_index(params),
            "digests": np.asarray(weight_plane_digests(params)),
            "planes": planes}


def restore_weight_plane(params, path: str, which: str, golden):
    """Rebuild ``params`` with the (path, which) plane replaced by its
    golden bytes; every other leaf is passed through untouched (same
    device buffers — no re-prepare, no requantization drift)."""
    arr = jnp.asarray(golden["planes"][(path, which)])

    def fix(p, leaf):
        if isinstance(leaf, QuantizedLinearWeight) and path_str(p) == path:
            return QuantizedLinearWeight(
                arr if which == "q" else leaf.q,
                arr if which == "scale" else leaf.scale,
                leaf.k_orig, leaf.group_k)
        return leaf

    return jax.tree_util.tree_map_with_path(
        fix, params, is_leaf=lambda x: isinstance(x, QuantizedLinearWeight))


def prepare_dscim_params(params, cfg=None, *, group_k: int | None = 128,
                         include_attn: bool = False,
                         include_moe_shared: bool = True):
    """Convert every DS-CIM-eligible matrix of ``params`` once (serve
    startup).  Returns a new tree; float originals are dropped.

    ``cfg`` (optional, ArchConfig-like): consulted for the ``dscim`` spec
    ('off'/'float' specs return ``params`` unchanged; a '+attn' mode suffix
    adds the attention projections) and for ``tie_embeddings`` — tied models
    have no ``lm_head`` param, so a prepared head is materialized from
    ``embed.T`` (the embedding itself stays float for the lookup).

    ``include_moe_shared=False`` leaves the MoE shared expert float (it then
    runs through the FSDP-shard + gather path under a mesh).  Prepared
    shared experts serve fine both single-device and distributed — their
    planes replicate and the shard_map MoE body computes them locally
    (models/lm.py ``_moe_apply``, launch/sharding.py) — so this is an
    escape hatch, not a requirement.
    """
    if cfg is not None:
        spec = getattr(cfg, "dscim", "off")
        mode, attn = split_dscim_mode(spec)
        if mode in ("off", "float"):
            return params
        include_attn = include_attn or attn
    pats = ELIGIBLE_PATTERNS if include_moe_shared else tuple(
        p for p in ELIGIBLE_PATTERNS if "moe/shared" not in p)
    pats += ATTN_PATTERNS if include_attn else ()

    def assign(path, leaf):
        p = path_str(path)
        if getattr(leaf, "ndim", 0) >= 2 and any(t in p for t in pats):
            return prepare_linear_weight(leaf, group_k)
        return leaf

    out = jax.tree_util.tree_map_with_path(assign, params)
    if (cfg is not None and getattr(cfg, "tie_embeddings", False)
            and not getattr(cfg, "stub_frontend", False)
            and "lm_head" not in out):
        out = dict(out,
                   lm_head=prepare_linear_weight(params["embed"].T, group_k))
    return out
