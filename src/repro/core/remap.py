"""Sample-region remapping (the paper's core trick, Sec. IV-B).

Rows sharing an OR gate right-shift their (unsigned) data by ``k`` bits and
are remapped into the 4^k disjoint blocks of a 2^k x 2^k partition of the 2D
sampling map.  In hardware the remap is "invert data bits + flip comparator
direction"; mathematically that is a *reflected binary fold* of each
coordinate: at every level the upper half of the interval is mirrored onto
the lower half, and the choice bit becomes one block-address bit.  Mirroring
(rather than plain slicing) makes adjacent regions share anchor corners,
which anti-correlates adjacent rows' sampling errors.

Everything here is pure NumPy (host-side, used to build LUT constants) plus
a jnp twin for in-graph use.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = [
    "fold", "fold_jnp", "row_block", "point_block", "fires",
    "build_count_lut", "group_size", "shifted_bits",
]


def group_size(k: int) -> int:
    """Rows per OR gate: OR4 (k=1), OR16 (k=2), OR64 (k=3)."""
    return 4 ** k


def shifted_bits(k: int) -> int:
    """Post-shift data width S = 2^(8-k); shifted values live in [0, S)."""
    return 256 >> k


def fold(u: np.ndarray, k: int):
    """Reflected fold of 8-bit coords -> (block_code in [0,2^k), local in [0,S)).

    Level i: if the coordinate is in the upper half of the remaining
    interval, mirror it (x -> size-1-x) and set block bit i.  This is the
    vectorized equivalent of the paper's per-row bit inversion + comparator
    direction flip.
    """
    cur = u.astype(np.int32)
    code = np.zeros_like(cur)
    size = 256
    for _ in range(k):
        half = size >> 1
        hi = cur >= half
        cur = np.where(hi, size - 1 - cur, cur)
        code = (code << 1) | hi.astype(np.int32)
        size = half
    return code, cur


def fold_jnp(u, k: int):
    """jnp twin of :func:`fold` (used by the bitmatmul backend & kernels)."""
    cur = u.astype(jnp.int32)
    code = jnp.zeros_like(cur)
    size = 256
    for _ in range(k):
        half = size >> 1
        hi = cur >= half
        cur = jnp.where(hi, size - 1 - cur, cur)
        code = (code << 1) | hi.astype(jnp.int32)
        size = half
    return code, cur


def row_block(row_in_group, k: int):
    """Fixed wiring row -> (u-block code, v-block code).

    Row g of a 4^k group owns block (g mod 2^k, g div 2^k).
    """
    n = 1 << k
    return row_in_group % n, row_in_group // n


def point_block(cu, cv, k: int):
    """Fixed wiring sampling point -> owning row: flat block code.

    Inverse pairing of :func:`row_block`: a point with folded block codes
    (cu, cv) lands in the region of row ``cv * 2^k + cu`` of the group, so
    ``point_block(*row_block(g, k), k) == g`` for every row g.  All kernels
    must use this pair (and not re-derive the % / // arithmetic) so the
    row->block wiring stays consistent across the LUT, bitmatmul, baseline
    and blocked/fused Pallas paths.  Works on numpy and jnp arrays.
    """
    return cv * (1 << k) + cu


def fires(u, v, a, w, row_in_group, k: int, xp=np):
    """Bit: does sampling point (u,v) land in this row's remapped region?

    a, w are the *shifted* unsigned values in [0, S).  Broadcasts over any
    leading shapes.
    """
    fold_fn = fold if xp is np else fold_jnp
    cu, lu = fold_fn(u, k)
    cv, lv = fold_fn(v, k)
    bc, br = row_block(row_in_group, k)
    return (cu == bc) & (cv == br) & (lu < a) & (lv < w)


def build_count_lut(points_u: np.ndarray, points_v: np.ndarray, k: int) -> np.ndarray:
    """Joint-count LUT: LUT[g, a, w] = #{t : point_t in region_g(a, w)}.

    Shape (4^k, S, S) int32 with S = 2^(8-k).  Bit-exact against the
    cycle-accurate simulation by construction: the count for rectangle side
    lengths (a, w) is the 2D cumulative histogram of the folded in-block
    points.  LUT[g, a, w] counts points with local coords (lu < a, lv < w),
    so index 0 is zero and index S-1 covers [0, S-1) (the max shifted value
    S-1 leaves the last row/col of each block unreachable -- faithful to the
    hardware's truncation).
    """
    S = shifted_bits(k)
    G = group_size(k)
    cu, lu = fold(points_u.astype(np.int32), k)
    cv, lv = fold(points_v.astype(np.int32), k)
    lut = np.zeros((G, S, S), np.int32)
    n = 1 << k
    for g in range(G):
        bc, br = g % n, g // n
        m = (cu == bc) & (cv == br)
        if not m.any():
            continue
        hist, _, _ = np.histogram2d(
            lu[m], lv[m], bins=(S, S), range=((0, S), (0, S)))
        # cumulative, exclusive on both axes: count of (lu < a, lv < w)
        cs = np.cumsum(np.cumsum(hist, axis=0), axis=1)
        lut[g, 1:, 1:] = cs[:-1, :-1]
    return lut
