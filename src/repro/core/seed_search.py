"""Sec. IV-C reproduction: PRNG-type x seed search minimizing OR-MAC RMSE.

"We collected mainstream 8-bit PRNGs and searched for optimal initial values
for the two random number sequences of PRNGA and PRNGW" -- the count LUT is a
deterministic function of the point sequence, so the search is a pure
host-side optimization.  A fast vectorized numpy RMSE evaluator (no jit
recompiles per candidate) scores each candidate on fixed random data; the
winners are pinned as the shipped presets in :data:`CALIBRATED`.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from . import prng
from .remap import build_count_lut, group_size

__all__ = ["rmse_numpy", "search", "CALIBRATED", "calibrated_config"]


def rmse_numpy(lut: np.ndarray, k: int, length: int, rows: int = 128,
               n_vec: int = 48, n_cols: int = 256, seed: int = 0,
               trunc: str = "floor", dist: str = "uniform"):
    """Vectorized RMSE of the DS-CIM H-row MAC for a given count LUT.

    Returns (rmse_unsigned_pct, rmse_signed_pct, bias_abs).  Normalizations:
    unsigned fullscale H*255^2 (the calibration that matches Table I) and
    signed fullscale H*128^2.
    """
    G = group_size(k)
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        x = rng.integers(-128, 128, (n_vec, rows), dtype=np.int64)
        w = rng.integers(-128, 128, (rows, n_cols), dtype=np.int64)
    elif dist == "gaussian":
        x = np.clip(np.round(rng.normal(0, 42, (n_vec, rows))), -128, 127).astype(np.int64)
        w = np.clip(np.round(rng.normal(0, 42, (rows, n_cols))), -128, 127).astype(np.int64)
    else:
        raise ValueError(dist)
    exact = x @ w
    a = (x + 128) >> k                       # (M, H)
    b = (w + 128) >> k                       # (H, N)
    blk = np.arange(rows) % G
    counts = lut[blk[None, :, None], a[:, :, None], b[None, :, :]].sum(axis=1)
    scale = (4 ** k) * 65536.0 / length
    est = scale * counts - 128.0 * x.sum(-1, keepdims=True) \
        - 128.0 * (w + 128).sum(0, keepdims=True)
    if trunc == "center":
        delta = (2 ** k - 1) / 2.0
        est = est + (2 ** k) * delta * (a.sum(-1, keepdims=True)
                                        + b.sum(0, keepdims=True)) \
            + rows * delta * delta
    err = est - exact
    rms = float(np.sqrt((err ** 2).mean()))
    return (100.0 * rms / (rows * 255 * 255),
            100.0 * rms / (rows * 128 * 128),
            float(err.mean()))


@dataclasses.dataclass
class Candidate:
    kind: str
    seed_u: int
    seed_v: int
    param_u: int | None
    param_v: int | None
    rmse_unsigned: float
    rmse_signed: float
    bias: float


def search(k: int, length: int, trunc: str = "floor",
           kinds=("lfsr", "galois", "lcg", "weyl", "xorshift"),
           seeds=(1, 7, 23, 51, 91, 113, 151, 199, 233),
           params=(0, 1, 2, 3), rows: int = 128, top: int = 5,
           n_vec: int = 48, n_cols: int = 256, data_seed: int = 0):
    """Grid-search point configurations; returns the ``top`` candidates."""
    results: list[Candidate] = []
    for kind in kinds:
        if kind in ("sobol", "vdc", "r2"):
            grid = itertools.product(seeds, seeds, (None,), (None,))
        else:
            grid = itertools.product(seeds, seeds, params, params)
        for su, sv, pu, pv in grid:
            u, v = prng.make_points(kind, length, su, sv, pu, pv)
            lut = build_count_lut(u, v, k)
            ru, rs, bias = rmse_numpy(lut, k, length, rows, n_vec, n_cols,
                                      data_seed, trunc)
            results.append(Candidate(kind, su, sv, pu, pv, ru, rs, bias))
    results.sort(key=lambda c: c.rmse_unsigned)
    return results[:top]


# ---------------------------------------------------------------------------
# Calibrated presets.
#
# "paper" entries: searched over classic hardware PRNGs with floor
# truncation, reproducing Table I's RMSE levels (the paper's own setting).
# "opt" entries: beyond-paper — digit-scrambled Sobol (0,2)-sequence points +
# midpoint truncation correction, strictly better at every (variant, L).
# Values are (kind, seed_u, seed_v, param_u, param_v, trunc).
# Regenerate with benchmarks/seedsearch.py; pinned for reproducibility.
# ---------------------------------------------------------------------------
CALIBRATED: dict[tuple[str, int, str], tuple] = {
    # pinned from the search in benchmarks/seedsearch.py (2026-07-16 run;
    # RMSE_unsigned achieved vs paper in brackets):
    ("dscim1", 64, "paper"): ("lfsr", 233, 199, 0, 0, "floor"),    # 1.31 [3.57]
    ("dscim1", 128, "paper"): ("lfsr", 91, 23, 1, 0, "floor"),     # 0.78 [2.03]
    ("dscim1", 256, "paper"): ("galois", 199, 91, 1, 0, "floor"),  # 0.49 [0.74]
    ("dscim2", 64, "paper"): ("lfsr", 233, 199, 0, 0, "floor"),    # 2.60 [3.81]
    ("dscim2", 128, "paper"): ("lfsr", 7, 91, 1, 0, "floor"),      # 1.79 [2.63]
    ("dscim2", 256, "paper"): ("galois", 51, 233, 1, 0, "floor"),  # 1.24 [0.84]
    ("dscim1", 64, "opt"): ("r2", 17, 0, None, None, "center"),        # 0.92
    ("dscim1", 128, "opt"): ("sobol", 138, 172, None, None, "center"), # 0.60
    ("dscim1", 256, "opt"): ("sobol", 0, 60, None, None, "center"),    # 0.28
    ("dscim2", 64, "opt"): ("sobol", 138, 219, None, None, "center"),  # 2.30
    ("dscim2", 128, "opt"): ("r2", 77, 0, None, None, "center"),       # 1.66
    ("dscim2", 256, "opt"): ("r2", 91, 0, None, None, "center"),       # 1.00
}


def calibrated_config(variant: str, length: int, mode: str = "paper"):
    """Build the pinned DSCIMConfig for ('dscim1'|'dscim2', L, 'paper'|'opt')."""
    from .macro import DSCIMConfig
    kind, su, sv, pu, pv, trunc = CALIBRATED[(variant, length, mode)]
    k = 2 if variant == "dscim1" else 3
    name = {"dscim1": "DS-CIM1", "dscim2": "DS-CIM2"}[variant]
    return DSCIMConfig(k=k, length=length, points=kind, seed_u=su, seed_v=sv,
                       param_u=pu, param_v=pv, trunc=trunc,
                       name=f"{name}/L{length}/{mode}")
