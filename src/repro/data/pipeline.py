"""Host data pipeline: deterministic sharding + background prefetch.

Production posture: each host computes its own shard of the global batch
from the (step, host) key — no data service needed, restarts are exactly
resumable from the step counter alone (the checkpoint stores it).
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from .synthetic import SyntheticLM

__all__ = ["DataPipeline"]


class DataPipeline:
    def __init__(self, source: SyntheticLM, global_batch: int, seq: int,
                 host: int = 0, n_hosts: int = 1, prefetch: int = 2,
                 start_step: int = 0):
        assert global_batch % n_hosts == 0
        self.source = source
        self.local_batch = global_batch // n_hosts
        self.seq = seq
        self.host, self.n_hosts = host, n_hosts
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            b = self.source.batch(self.local_batch, self.seq, step=step,
                                  host=self.host, n_hosts=self.n_hosts)
            b["step"] = step
            while not self._stop.is_set():
                try:
                    self._q.put(b, timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> dict:
        return self._q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
