"""Deterministic synthetic LM data: a zipf-unigram + bigram-chain mixture.

Gives the training loop *learnable structure* (bigram transitions drive the
loss well below the unigram entropy), fully offline, identical across hosts
given the same seed — so multi-host data sharding is a pure index
calculation (production pattern: shard by (host, step)).
"""
from __future__ import annotations

import numpy as np

__all__ = ["SyntheticLM"]


class SyntheticLM:
    def __init__(self, vocab: int, seed: int = 0, bigram_rank: int = 8,
                 zipf_a: float = 1.2):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        # low-rank bigram logits -> deterministic transition structure
        u = rng.normal(0, 1.0, (vocab, bigram_rank))
        v = rng.normal(0, 1.0, (bigram_rank, vocab))
        base = 1.0 / np.arange(1, vocab + 1) ** zipf_a
        logits = (u @ v) * 2.0 + np.log(base)[None, :]
        e = np.exp(logits - logits.max(-1, keepdims=True))
        self.trans = (e / e.sum(-1, keepdims=True)).astype(np.float64)
        self.cum = np.cumsum(self.trans, axis=-1)

    def sample(self, batch: int, seq: int, *, step: int, host: int = 0,
               n_hosts: int = 1) -> np.ndarray:
        """Deterministic (step, host)-keyed batch of token ids (B, S+1)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([step, host, n_hosts, 0xD5C1]))
        out = np.empty((batch, seq + 1), np.int32)
        out[:, 0] = rng.integers(0, self.vocab, batch)
        u = rng.random((batch, seq))
        for t in range(seq):
            row = self.cum[out[:, t]]
            out[:, t + 1] = (u[:, t:t + 1] < row).argmax(axis=1)
        return out

    def batch(self, batch: int, seq: int, *, step: int, host: int = 0,
              n_hosts: int = 1) -> dict:
        toks = self.sample(batch, seq, step=step, host=host, n_hosts=n_hosts)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def unigram_entropy(self) -> float:
        p = self.trans.mean(0)
        return float(-(p * np.log(p + 1e-12)).sum())
