"""Tiny tile autotuner for the DS-CIM Pallas kernels.

Sweeps a small candidate list of tile shapes per (kernel kind, shape, cfg)
key, times each candidate on shared synthetic operands of the requested
shape, and caches the winner — in memory always, on disk when
``REPRO_AUTOTUNE_CACHE`` points at a JSON file (so serving processes
inherit tuned tiles across restarts), and from the **checked-in serving
cache** ``autotune_cache.json`` next to this module, which ships winners
for the DS-CIM decode serving shapes (skinny-M GEMV tiles, B on the batch
grid axis) so cold-start serving never re-tunes them.  Lookup order:
memory -> env-pointed cache -> packaged cache; only the env-pointed file
is ever written.

Deliberately simple: a handful of curated candidates beats an exhaustive
sweep for these kernels (the tile space is tiny — MXU-aligned bm/bn, the
pad-free bm=M decode tiles, and a couple of contraction sub-tile sizes),
and timing happens at most once per key per process.
"""
from __future__ import annotations

import json
import os
import time

import jax

__all__ = ["best", "fused_tiles", "mvm_tiles", "paged_attn_tiles", "clear",
           "DEFAULT_CACHE"]

_CACHE: dict[str, tuple] = {}
_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
# checked-in winners for the serving shapes (benchmarks/autotune_serving.py
# regenerates it; keys embed shape/cfg/bits/backend so stale entries can
# never match a different geometry)
DEFAULT_CACHE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "autotune_cache.json")


def clear():
    _CACHE.clear()


def _disk_path() -> str | None:
    return os.environ.get(_CACHE_ENV) or None


def _read_json(path: str) -> dict:
    if path and os.path.exists(path):
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, ValueError):
            pass
    return {}


def _load_disk() -> dict:
    """Merged on-disk caches: packaged serving defaults first, the
    env-pointed (writable) cache overriding them."""
    data: dict = {}
    for path in (DEFAULT_CACHE, _disk_path()):
        data.update(_read_json(path))
    return data


def _save_disk(key: str, val: tuple):
    path = _disk_path()
    if not path:
        return
    # read back only the env-pointed file itself — merging the packaged
    # cache in would freeze its current entries there, where they'd shadow
    # future updates to the checked-in winners
    data = _read_json(path)
    data[key] = list(val)
    try:
        with open(path, "w") as f:
            json.dump(data, f, indent=0, sort_keys=True)
    except OSError:
        pass


def _time_once(fn, n: int = 2, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best_t = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best_t = min(best_t, time.perf_counter() - t0)
    return best_t


def best(key: str, candidates, bench) -> tuple:
    """Return the cached winner for ``key`` or sweep ``candidates``.

    ``bench(cand)`` must return a zero-arg callable running the kernel with
    that candidate; candidates that fail to trace/launch are skipped.
    """
    if key in _CACHE:
        return _CACHE[key]
    disk = _load_disk()
    if key in disk:
        win = tuple(disk[key])
        _CACHE[key] = win
        return win
    win, win_t = None, float("inf")
    for cand in candidates:
        try:
            t = _time_once(bench(cand))
        except Exception:  # noqa: BLE001 — bad tile shape for this geometry
            continue
        if t < win_t:
            win, win_t = tuple(cand), t
    if win is None:
        raise ValueError(f"autotune: no viable candidate for {key}")
    _CACHE[key] = win
    _save_disk(key, win)
    return win


# --------------------------------------------------------------------------
# kernel-specific entry points
# --------------------------------------------------------------------------

def _mxu_opts(dim: int):
    """Tile options for an MXU-aligned axis of extent ``dim``."""
    up8 = -(-dim // 8) * 8
    return sorted({min(128, up8), min(64, up8), min(256, up8)})


def fused_tiles(shape, cfg, g: int, *, interpret: bool,
                bits: str = "bfloat16"):
    """(bm, bn, bk) winner for dscim_fused_mvm on (B, M, K, N) operands.

    Decode serving shapes (M <= 16 — the skinny GEMV regime, batch riding
    the batch grid axis) get their own candidate set: the pad-free bm=M
    tile plus the 8/16-row aligned ones (candidates that fail to launch on
    a backend — e.g. sub-sublane tiles on TPU — are skipped by ``best``)."""
    import jax.numpy as jnp
    import numpy as np

    from .dscim_fused import dscim_fused_mvm

    B, M, K, N = shape
    key = f"fused/{cfg.name}/k{cfg.k}L{cfg.length}t{cfg.trunc}/" \
          f"{B}x{M}x{K}x{N}/g{g}/{bits}/{'cpu' if interpret else 'tpu'}"
    if M <= 16:
        bms = sorted({M, -(-M // 8) * 8, 16})
    else:
        bms = _mxu_opts(M)[:2]
    cands = [(bm, bn, bk)
             for bm in bms for bn in _mxu_opts(N)[:2]
             for bk in (16, 32) if bk <= max(g, 16)]
    # one shared operand set for all candidates (shape, not data, matters)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (B, M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (K, N)), jnp.float32)

    def bench(cand):
        bm, bn, bk = cand
        return lambda: dscim_fused_mvm(
            x, w, cfg, group_k=(g if g != K else None), bm=bm, bn=bn, bk=bk,
            bits=bits, interpret=interpret)

    return best(key, cands, bench)


def paged_attn_tiles(shape, page_size: int, *, interpret: bool):
    """(gh, qp) winner for the paged-attention decode kernel on a
    (B, KV, n_rep, HD) query against ``page_size``-token int8 pages.

    ``gh`` (kv heads per grid cell — the GQA head-grouping knob: gh > 1
    amortizes one page DMA across head groups sharing the page bytes) and
    ``qp`` (q rows per cell: pad-free n_rep, or n_rep rounded up to the
    8-row sublane tile).  The page count is deliberately NOT part of the
    key — the winning cell shape is a per-page property, and decode MP
    grows with capacity; candidates are swept at a fixed representative
    walk length.  The checked-in cache ships winners for the decode
    serving shapes at page_size in {4, 8, 16}."""
    import jax.numpy as jnp
    import numpy as np

    from .paged_attention import paged_attention_decode

    B, KV, R, HD = shape
    key = f"paged_attn/B{B}/kv{KV}r{R}hd{HD}/ps{page_size}/" \
          f"{'cpu' if interpret else 'tpu'}"
    ghs = sorted({g for g in (1, 2, 4, KV) if KV % g == 0})
    qps = sorted({R, -(-R // 8) * 8})
    cands = [(gh, qp) for gh in ghs for qp in qps]
    MP = 4                               # representative decode page walk
    P = B * MP
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (B, KV, R, HD)), jnp.float32)
    kp = jnp.asarray(rng.integers(-127, 128, (P, page_size, KV, HD)),
                     jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, (P, page_size, KV, HD)),
                     jnp.int8)
    ks = jnp.asarray(rng.uniform(0.005, 0.02, (P, KV)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.005, 0.02, (P, KV)), jnp.float32)
    kt = jnp.asarray(rng.normal(0, 1, (B, page_size, KV, HD)), jnp.bfloat16)
    vt = jnp.asarray(rng.normal(0, 1, (B, page_size, KV, HD)), jnp.bfloat16)
    table = jnp.asarray(rng.permutation(P).reshape(B, MP), jnp.int32)
    pos = jnp.full((B,), MP * page_size - 2, jnp.int32)

    def bench(cand):
        gh, qp = cand
        return lambda: paged_attention_decode(
            q, kp, vp, ks, vs, kt, vt, table, pos, gh=gh, qp=qp,
            interpret=interpret)

    return best(key, cands, bench)


def mvm_tiles(shape, cfg, *, interpret: bool):
    """(bm, bn, bk, bl) winner for ops.dscim_mvm on (M, K, N) operands."""
    import jax.numpy as jnp
    import numpy as np

    M, K, N = shape
    key = f"mvm/{cfg.name}/k{cfg.k}L{cfg.length}t{cfg.trunc}/" \
          f"{M}x{K}x{N}/{'cpu' if interpret else 'tpu'}"
    bls = [bl for bl in (64, 128, 256) if bl <= cfg.length
           and cfg.length % bl == 0] or [cfg.length]
    cands = [(bm, bn, bk, bl)
             for bm in _mxu_opts(M)[:2] for bn in _mxu_opts(N)[:2]
             for bk in (8, 16) for bl in bls[:2]]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int8)

    def bench(cand):
        from .ops import dscim_mvm
        bm, bn, bk, bl = cand
        return lambda: dscim_mvm(x, w, cfg, bm=bm, bn=bn, bk=bk, bl=bl,
                                 interpret=interpret)

    return best(key, cands, bench)
