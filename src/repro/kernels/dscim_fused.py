"""Fused batched DS-CIM MVM: one Pallas launch from float activations to
float output.

The staged path (``DSCIMLinear`` pre-fusion) drove the blocked-points kernel
through a per-window ``jax.vmap`` — one kernel launch per 128-row
quantization window — then applied the four sign-correction terms and the
per-window dequant scales in separate f32 HBM passes, materializing an
``(M, nw, N)`` psum tensor in HBM.  That throws away the macro's headline
property (stochastic bit traffic and partial sums never leave the array).

This kernel folds the window axis into the K grid dimension and finishes the
estimator inside the grid step:

    out[m,n] = Σ_u s_x[m,u] * s_w[u,n] * psum_u[m,n]
    psum_u   = scale*C_u - 128*Σx_u - 128*Σ(w_u+128)  (+ center-trunc terms)

Every term is additive over K sub-tiles of a window, so each grid step
contributes its partial counts *and* partial corrections, already multiplied
by that window's dequant scales — no per-window psum ever exists in HBM; the
only HBM traffic is the int8 operands, the tiny scale vectors and the final
f32 output (same traffic class as a plain int8 matmul).

Further wins over the staged path:

* bit-expansion dot runs on **bf16** operands with f32 accumulation — {0,1}
  values are exact in bf16, counts ≤ K·pmax << 2^24 stay exact in the f32
  accumulator, VMEM for the bit tiles halves and the MXU runs at its
  bf16-input rate (``bits="float32"`` kept for A/B benchmarking);
* leading batch dims map onto a **batch grid axis** (grid (B, M/bm, N/bn,
  nw·spw)) instead of a reshape(-1, K) round-trip through HBM;
* blocked-points tables (disjointness theorem) shrink the contraction from
  K·L to K·pmax exactly as in ``dscim_mvm_blocked``.

In-kernel padding uses the never-fire sentinel x = w = -128 (x' = w' = 0):
counts, Σ(w+128), Σa and Σb pad contributions are all zero by construction,
and the only non-zero pad term (-128·Σx picking up 128²·pad_g per window) is
cancelled by a compile-time per-window constant.

Serving entries (prepare-once weights, core/qweights.py):

* ``dscim_fused_mvm_prepared(x, qw, cfg)`` — the quantize-free hot path:
  the int8 window planes + per-window scales are resident (the CIM array's
  static storage); only activations are quantized per call, so the jitted
  decode step contains no weight quantization at all;
* ``dscim_fused_mvm(x, w, cfg)`` — float-weight wrapper, now literally
  ``prepare_linear_weight`` + the prepared entry (bit-identical by
  construction; kept for training/tests and one-shot calls);
* ``dscim_fused_mvm_sharded(x, qw, cfg, mesh)`` — multi-chip serving: the
  prepared weight and its scales shard on N over the 'model' mesh axis
  (shard_map; x broadcasts, output lands N-sharded).  Quantization windows
  live on the K axis, so every shard computes its output columns exactly —
  no collective in the MVM and bit-identical results to single-device.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.macro import DSCIMConfig
from repro.core.quant import quantize_int8
from repro.core.qweights import QuantizedLinearWeight, prepare_linear_weight

from .dscim_mvm_blocked import block_point_tables, dscim_counts_blocked
from .ops import ON_TPU, default_bits, round_up as _round_up

__all__ = ["dscim_fused_mvm", "dscim_fused_mvm_prepared",
           "dscim_fused_mvm_sharded", "quantize_activations_windowed",
           "dscim_windowed_vmap_mvm"]


def _kernel(x_ref, w_ref, tu_ref, tv_ref, sx_ref, sw_ref, out_ref, *,
            k: int, pmax: int, bk: int, spw: int, scale: float,
            win_const: float, trunc_center: bool, bits: str):
    """One grid step: partial counts + partial corrections of one window
    sub-tile, dequantized by that window's scales, accumulated into out."""
    kk = pl.program_id(3)
    sk = kk % spw                              # step index within the window

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)           # (bm, bk) signed int8 values
    w = w_ref[...].astype(jnp.int32)           # (bk, bn)
    a = (x + 128) >> k                         # shifted unsigned, [0, S)
    b = (w + 128) >> k

    # row -> block wiring restarts at every window (the vmap-per-window
    # semantics): row index *within the window* selects the point table row.
    G = 4 ** k
    rows = sk * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    blk = rows % G
    lu = jnp.take(tu_ref[...], blk, axis=0)    # (bk, pmax)
    lv = jnp.take(tv_ref[...], blk, axis=0)

    bm, bn = x.shape[0], w.shape[1]
    bdt = jnp.dtype(bits)
    abit = (lu[None, :, :] < a[:, :, None]).astype(bdt)   # (bm, bk, pmax)
    wbit = (lv[:, :, None] < b[:, None, :]).astype(bdt)   # (bk, pmax, bn)
    counts = jax.lax.dot_general(
        abit.reshape(bm, bk * pmax), wbit.reshape(bk * pmax, bn),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    psum = scale * counts
    psum = psum - 128.0 * jnp.sum(x, axis=1, keepdims=True).astype(jnp.float32)
    psum = psum - 128.0 * jnp.sum(w + 128, axis=0,
                                  keepdims=True).astype(jnp.float32)
    if trunc_center:
        delta = (2 ** k - 1) / 2.0
        psum = psum + (2 ** k) * delta * (
            jnp.sum(a, axis=1, keepdims=True)
            + jnp.sum(b, axis=0, keepdims=True)).astype(jnp.float32)
    if win_const:
        # once per window: center-trunc constant + pad-sentinel cancellation
        psum = psum + jnp.where(sk == 0, jnp.float32(win_const),
                                jnp.float32(0.0))
    out_ref[...] += psum * sx_ref[...] * sw_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "cfg", "g", "bm", "bn", "bk", "bits", "interpret"))
def _fused_call(xq, wq, sx, sw, cfg: DSCIMConfig, *, g: int, bm: int,
                bn: int, bk: int, bits: str, interpret: bool):
    """xq (B, Mp, nw*gp) int8, wq (nw*gp, Np) int8, sx (B, Mp, nw) f32,
    sw (nw, Np) f32 -> (B, Mp, Np) f32."""
    B, Mp, KL = xq.shape
    Np = wq.shape[1]
    gp = _round_up(g, bk)
    spw = gp // bk
    nw = KL // gp
    tu_np, tv_np, pmax = block_point_tables(cfg)
    tu, tv = jnp.asarray(tu_np), jnp.asarray(tv_np)
    G = cfg.group
    delta = (2 ** cfg.k - 1) / 2.0
    win_const = (g * delta * delta if cfg.trunc == "center" else 0.0) \
        - 128.0 * 128.0 * (gp - g)
    kernel = functools.partial(
        _kernel, k=cfg.k, pmax=pmax, bk=bk, spw=spw, scale=cfg.scale,
        win_const=win_const, trunc_center=(cfg.trunc == "center"), bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(B, Mp // bm, Np // bn, nw * spw),
        in_specs=[
            pl.BlockSpec((None, bm, bk), lambda b, i, j, kk: (b, i, kk)),
            pl.BlockSpec((bk, bn), lambda b, i, j, kk: (kk, j)),
            pl.BlockSpec((G, pmax), lambda b, i, j, kk: (0, 0)),
            pl.BlockSpec((G, pmax), lambda b, i, j, kk: (0, 0)),
            pl.BlockSpec((None, bm, 1),
                         lambda b, i, j, kk, s=spw: (b, i, kk // s)),
            pl.BlockSpec((1, bn), lambda b, i, j, kk, s=spw: (kk // s, j)),
        ],
        out_specs=pl.BlockSpec((None, bm, bn), lambda b, i, j, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, Mp, Np), jnp.float32),
        interpret=interpret,
    )(xq, wq, tu, tv, sx, sw)


def quantize_activations_windowed(x, nw: int, g: int):
    """Float x (..., K) -> per-window int8 activations (DSCIMLinear
    semantics: pad K with float zeros to nw*g *before* quantizing, one scale
    per (row, window)).  Returns a QuantizedTensor with q (..., nw, g)."""
    K = x.shape[-1]
    pad = nw * g - K
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return quantize_int8(x.reshape(*x.shape[:-1], nw, g), axis=-1)


def dscim_fused_mvm_prepared(x, qw: QuantizedLinearWeight, cfg: DSCIMConfig,
                             *, bm: int | None = None, bn: int | None = None,
                             bk: int | None = None, bits: str | None = None,
                             interpret: bool | None = None,
                             tune: bool = False):
    """Quantize-free fused DS-CIM linear: x (..., K) float + prepared weight
    -> (..., N) f32.

    The serving hot path: ``qw`` holds the resident int8 window planes and
    per-window scales (prepared once, core/qweights.py), so the only
    quantization traced here is the per-call activation quantization — no
    ``quantize_int8`` over (K, N) appears in the jitted step.  Single Pallas
    launch covering all quantization windows, sign-correction terms and
    dequant scales; leading batch dims ride a batch grid axis.  ``bits``
    defaults to bf16 on TPU (halved VMEM, doubled MXU rate; {0,1} operands
    are exact) and f32 under interpret mode, where CPU bf16 emulation would
    dominate the runtime.  ``tune=True`` consults the tile autotuner
    (kernels/autotune.py).
    """
    interpret = (not ON_TPU) if interpret is None else interpret
    bits = bits or default_bits(interpret)
    lead = x.shape[:-1]
    K = x.shape[-1]
    if K != qw.k_orig:
        raise ValueError(f"x K={K} vs prepared weight K={qw.k_orig}")
    nw, g, N = qw.nw, qw.g, qw.n
    # native batch: keep the last lead dim as the M grid rows, fold any
    # extra leading dims into the batch grid axis (no flatten through M)
    if x.ndim <= 2:
        x3 = x.reshape(1, -1 if x.ndim == 2 else 1, K)
    else:
        B = math.prod(lead[:-1])
        x3 = x.reshape(B, lead[-1], K)
    B, M, _ = x3.shape

    if tune:
        from . import autotune
        bm, bn, bk = autotune.fused_tiles(
            (B, M, K, N), cfg, g, interpret=interpret, bits=bits)
    bk = bk or min(16, g)
    if bm is None:
        # decode-shape (skinny-M GEMV) default: a pad-free bm=M tile — the
        # M grid dim collapses to 1 and no dead rows are computed (the
        # 8-row rounding would do 8x the M-work at M=1).  Interpret mode
        # has no sublane-alignment constraint; on TPU the aligned default
        # stays (Mosaic min sublane tiles) and the autotuner — whose
        # candidate set includes bm=M — picks whatever actually wins.
        bm = M if (M <= 16 and interpret) else min(128, _round_up(M, 8))
    bn = bn or min(128, _round_up(N, 8))

    xq = quantize_activations_windowed(x3, nw, g)       # (B,M,nw,1) scales
    gp = _round_up(g, bk)
    # never-fire sentinel padding (x' = w' = 0) along the window axis …
    x4 = jnp.pad(xq.q, ((0, 0), (0, 0), (0, 0), (0, gp - g)),
                 constant_values=-128)
    w4 = jnp.pad(qw.q, ((0, 0), (0, gp - g), (0, 0)), constant_values=-128)
    x2 = x4.reshape(B, M, nw * gp)
    w2 = w4.reshape(nw * gp, N)
    sx = xq.scale.reshape(B, M, nw)
    sw = qw.scale
    # … and along M/N (pad rows/cols never read back; scales padded with 0)
    padm, padn = _round_up(M, bm) - M, _round_up(N, bn) - N
    if padm:
        x2 = jnp.pad(x2, ((0, 0), (0, padm), (0, 0)), constant_values=-128)
        sx = jnp.pad(sx, ((0, 0), (0, padm), (0, 0)))
    if padn:
        w2 = jnp.pad(w2, ((0, 0), (0, padn)), constant_values=-128)
        sw = jnp.pad(sw, ((0, 0), (0, padn)))
    out = _fused_call(x2.astype(jnp.int8), w2.astype(jnp.int8), sx, sw, cfg,
                      g=g, bm=bm, bn=bn, bk=bk, bits=bits,
                      interpret=interpret)
    return out[:, :M, :N].reshape(*lead, N)


def dscim_fused_mvm(x, w, cfg: DSCIMConfig, *, group_k: int | None = 128,
                    bm: int | None = None, bn: int | None = None,
                    bk: int | None = None, bits: str | None = None,
                    interpret: bool | None = None, tune: bool = False):
    """Fused DS-CIM linear from float weights: x (..., K), w (K, N) float
    -> (..., N) f32.  Exactly ``prepare_linear_weight`` + the prepared
    entry, so it is bit-identical to the serve path by construction."""
    qw = prepare_linear_weight(w, group_k)
    return dscim_fused_mvm_prepared(x, qw, cfg, bm=bm, bn=bn, bk=bk,
                                    bits=bits, interpret=interpret, tune=tune)


def dscim_fused_mvm_sharded(x, qw: QuantizedLinearWeight, cfg: DSCIMConfig,
                            mesh, *, axis: str = "model",
                            batch_axes: tuple = (), **kw):
    """Model-axis sharded fused MVM (multi-chip serving, ROADMAP item).

    The prepared weight's output columns tile over the ``axis`` mesh axis —
    ``q`` (nw, g, N) and ``scale`` (nw, N) both shard on N, and the output
    lands N-sharded (no collective: quantization windows live on the local
    K axis, the StoX-Net/Stoch-IMC array-banking decomposition).
    ``batch_axes``: DP mesh axes the leading dim of x/out additionally
    shards over (when x has a batch dim and it divides) — on a
    data x model serving mesh each data group then computes only its batch
    slice instead of redoing the full batch; with no batch axes or a
    non-dividing batch, x broadcasts.  Bit-identical to the single-device
    prepared path either way (per-element math is placement-invariant).

    When N does not divide over the axis, the call degrades to a replicated
    shard_map (every device computes the full output — still correct, still
    a legal Pallas-under-mesh placement) instead of failing, and warns once
    per trace: the redundant compute + resident planes on every device are
    a misconfiguration an operator should see.  Serving meshes should pick
    an axis size dividing every DS-CIM matrix's N.
    """
    import math as _math

    from jax.sharding import PartitionSpec as P

    from repro.parallel import shard_map

    nshard = mesh.shape[axis]
    t = axis if qw.n % nshard == 0 else None
    if t is None:
        import warnings
        warnings.warn(
            f"dscim_fused_mvm_sharded: N={qw.n} not divisible by mesh axis "
            f"{axis!r}={nshard}; replicating — every device computes the "
            "full output (no speedup, N-fold redundant work/memory)",
            stacklevel=2)
    # leading batch dim over the DP axes (x ndim >= 2 keeps K unsharded)
    b = None
    if batch_axes and x.ndim >= 2:
        bsize = _math.prod(mesh.shape[a] for a in batch_axes)
        if x.shape[0] % bsize == 0:
            b = tuple(batch_axes)
    qspec = P(*([None] * (qw.q.ndim - 1)), t)
    sspec = P(*([None] * (qw.scale.ndim - 1)), t)
    xdims = [b] + [None] * (x.ndim - 1)       # b is None unless it divides
    # out has x's ndim with K replaced by N; 1D x -> 1D out (N,)
    odims = [b] + [None] * (x.ndim - 2) + [t] if x.ndim >= 2 else [t]
    xspec = P(*xdims)
    ospec = P(*odims)

    def inner(xl, ql, sl):
        qwl = QuantizedLinearWeight(ql, sl, qw.k_orig, qw.group_k)
        return dscim_fused_mvm_prepared(xl, qwl, cfg, **kw)

    return shard_map(inner, mesh=mesh, in_specs=(xspec, qspec, sspec),
                     out_specs=ospec)(x, qw.q, qw.scale)


def dscim_windowed_vmap_mvm(x, w, cfg: DSCIMConfig, *,
                            group_k: int | None = 128,
                            interpret: bool | None = None):
    """The pre-fusion staged path, kept as the perf A/B baseline: one
    blocked-kernel launch per window via vmap, psum (M, nw, N) staged in
    HBM, corrections and dequant applied in separate f32 passes."""
    interpret = (not ON_TPU) if interpret is None else interpret
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    x2 = x.reshape(-1, K)
    wq = prepare_linear_weight(w, group_k)
    nw, g = wq.nw, wq.g
    xq = quantize_activations_windowed(x2, nw, g)
    xw = xq.q.astype(jnp.int32)                    # (M, nw, g)
    ww = wq.q.astype(jnp.int32)                    # (nw, g, N)
    M = xw.shape[0]
    bm = min(128, _round_up(M, 8))
    bn = min(128, _round_up(N, 8))
    bk = min(16, g)
    gp = _round_up(g, bk)

    def one_window(xg, wg):                        # (M, g), (g, N)
        xp = jnp.pad(xg, ((0, _round_up(M, bm) - M), (0, gp - g)),
                     constant_values=-128)
        wp = jnp.pad(wg, ((0, gp - g), (0, _round_up(N, bn) - N)),
                     constant_values=-128)
        counts = dscim_counts_blocked(
            xp.astype(jnp.int8), wp.astype(jnp.int8), cfg, bm=bm, bn=bn,
            bk=bk, interpret=interpret)[:M, :N]
        psum = cfg.scale * counts \
            - 128.0 * jnp.sum(xg, axis=-1, keepdims=True) \
            - 128.0 * jnp.sum(wg + 128, axis=0, keepdims=True)
        if cfg.trunc == "center":
            delta = (2 ** cfg.k - 1) / 2.0
            a = (xg + 128) >> cfg.k
            b = (wg + 128) >> cfg.k
            psum = psum + (2 ** cfg.k) * delta * (
                jnp.sum(a, axis=-1, keepdims=True)
                + jnp.sum(b, axis=0, keepdims=True)) + g * delta * delta
        return psum

    psum = jax.vmap(one_window, in_axes=(1, 0), out_axes=1)(xw, ww)
    out = jnp.einsum("mun,mu,un->mn", psum, xq.scale.reshape(-1, nw),
                     wq.scale.reshape(nw, N))
    return out.reshape(*lead, N).astype(jnp.float32)
