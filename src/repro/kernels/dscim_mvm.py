"""Pallas TPU kernel: DS-CIM OR-MAC MVM via bitstream-expansion MXU matmul.

TPU adaptation of the macro (DESIGN.md §3): the OR fabric's collision-free
accumulation equals a sum of {0,1} products, so the whole stochastic MVM is

    C[m,n] = Σ_{h,t} abit[m,h,t] * wbit[h,t,n]

— a matmul whose contraction dim is K·L.  The kernel generates the bit
tiles *in VMEM* each grid step (SNG = vector compare against the folded
PRNG coordinates, which live in VMEM for the whole kernel) and feeds the
MXU; bitstreams never exist in HBM, so HBM traffic is the same as a plain
int8 matmul while the MXU does the L-fold expanded work (the TPU twin of
the macro's CMR=64 replication of cheap OR fabric).

Tiling: grid (M/bm, N/bn, K/bk); inner python loop over L in bl chunks.
VMEM per step ~ bm*bk*bl + bk*bl*bn floats (default 128·8·128 ≈ 0.5 MB
each) + the (bm,bn) f32 accumulator.  All dims padded to tile multiples by
``ops.dscim_mvm``.  Counts ≤ K·L/4^k << 2^24 so f32 MXU accumulation is
exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.remap import row_block

__all__ = ["dscim_counts_pallas"]


def _kernel(x_ref, w_ref, cu_ref, lu_ref, cv_ref, lv_ref, out_ref, *,
            k: int, bl: int, length: int, bk: int):
    """One (bm, bn) output tile; accumulates over the K grid axis."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)          # (bm, bk) signed int8 values
    w = w_ref[...].astype(jnp.int32)          # (bk, bn)
    a = (x + 128) >> k                        # shifted unsigned, [0, S)
    b = (w + 128) >> k

    # row -> block wiring: global row index mod 4^k, split into (bc, br)
    rows = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    blk = rows % (4 ** k)
    bc, br = row_block(blk, k)                # (u, v) block codes per row

    bm = x.shape[0]
    bn = w.shape[1]
    acc = jnp.zeros((bm, bn), jnp.float32)
    for t0 in range(0, length, bl):
        cu = cu_ref[t0:t0 + bl]               # folded PRNG coords (VMEM)
        lu = lu_ref[t0:t0 + bl]
        cv = cv_ref[t0:t0 + bl]
        lv = lv_ref[t0:t0 + bl]
        # SNG: activation bits (bm, bk, bl) and weight bits (bk, bl, bn)
        abit = ((cu[None, None, :] == bc[None, :, None])
                & (lu[None, None, :] < a[:, :, None])).astype(jnp.float32)
        wbit = ((cv[None, :, None] == br[:, None, None])
                & (lv[None, :, None] < b[:, None, :])).astype(jnp.float32)
        acc += jax.lax.dot_general(
            abit.reshape(bm, bk * bl), wbit.reshape(bk * bl, bn),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("k", "length", "bm", "bn",
                                             "bk", "bl", "interpret"))
def dscim_counts_pallas(x_i8, w_i8, cu, lu, cv, lv, *, k: int, length: int,
                        bm: int = 128, bn: int = 128, bk: int = 8,
                        bl: int = 128, interpret: bool = True):
    """OR-accumulated count matrix C (M,N) f32; inputs must be tile-aligned."""
    M, K = x_i8.shape
    N = w_i8.shape[1]
    assert M % bm == 0 and N % bn == 0 and K % bk == 0 and length % bl == 0, (
        f"pad to tiles first: {(M, K, N)} vs {(bm, bk, bn)}")
    grid = (M // bm, N // bn, K // bk)
    kernel = functools.partial(_kernel, k=k, bl=bl, length=length, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # x tile
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # w tile
            pl.BlockSpec((length,), lambda i, j, kk: (0,)),     # cu (VMEM)
            pl.BlockSpec((length,), lambda i, j, kk: (0,)),     # lu
            pl.BlockSpec((length,), lambda i, j, kk: (0,)),     # cv
            pl.BlockSpec((length,), lambda i, j, kk: (0,)),     # lv
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x_i8, w_i8, cu, lu, cv, lv)
