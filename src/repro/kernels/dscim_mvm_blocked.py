"""Beyond-paper Pallas kernel: blocked-points DS-CIM MVM (§Perf cell C).

Insight: after region remapping, row h's rectangle lives entirely inside its
own block of the 2^k x 2^k partition — points landing in *other* blocks can
never fire for that row (that is the disjointness theorem).  The baseline
kernel (dscim_mvm.py) still compares every row against all L points; here
each row is compared only against the <= pmax points of its own block:

    contraction dim:  K*L  ->  K*pmax,   pmax ~ L/4^k

    DS-CIM1 @L=256 (k=2): 256 -> ~16 points/row  => ~16x fewer MXU flops
    DS-CIM2 @L=64  (k=3): 64  -> ~1-4 points/row => ~32x fewer

Bit-exactness is inherited from the disjointness property (validated against
the LUT/cycle oracle by tests/test_kernels.py).  Host-side prep builds the
per-block padded point lists (pad slots use local coord = S, which no value
a < S can exceed, so pads never fire).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.macro import DSCIMConfig
from repro.core import prng as prng_lib
from repro.core.remap import fold, point_block, shifted_bits

__all__ = ["block_point_tables", "dscim_counts_blocked"]


@functools.lru_cache(maxsize=32)
def block_point_tables(cfg: DSCIMConfig):
    """(G, pmax) int32 tables of per-block local point coords (lu, lv);
    pad slots hold S (= never-fire sentinel)."""
    u, v = prng_lib.make_points(cfg.points, cfg.length, cfg.seed_u,
                                cfg.seed_v, cfg.param_u, cfg.param_v)
    cu, lu = fold(u.astype(np.int32), cfg.k)
    cv, lv = fold(v.astype(np.int32), cfg.k)
    G = cfg.group
    S = shifted_bits(cfg.k)
    blk = point_block(cu, cv, cfg.k)   # owning row of each sampling point
    counts = np.bincount(blk, minlength=G)
    pmax = max(int(counts.max()), 1)
    # round pmax up so bk*pmax hits a lane-friendly contraction size
    pmax = int(np.ceil(pmax / 2) * 2)
    tab_u = np.full((G, pmax), S, np.int32)
    tab_v = np.full((G, pmax), S, np.int32)
    fill = np.zeros(G, np.int32)
    for t in range(cfg.length):
        g = int(blk[t])
        tab_u[g, fill[g]] = lu[t]
        tab_v[g, fill[g]] = lv[t]
        fill[g] += 1
    # numpy only — device constants created per trace (caching jnp arrays
    # made under an active trace leaks tracers into later traces)
    return tab_u, tab_v, pmax


def _kernel(x_ref, w_ref, tu_ref, tv_ref, out_ref, *, k: int, pmax: int,
            bk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)          # (bm, bk)
    w = w_ref[...].astype(jnp.int32)          # (bk, bn)
    a = (x + 128) >> k
    b = (w + 128) >> k

    G = 4 ** k
    rows = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    blk = rows % G
    # per-row point lists: (bk, pmax) gathered from the block tables
    lu = jnp.take(tu_ref[...], blk, axis=0)   # (bk, pmax)
    lv = jnp.take(tv_ref[...], blk, axis=0)

    bm = x.shape[0]
    bn = w.shape[1]
    abit = (lu[None, :, :] < a[:, :, None]).astype(jnp.float32)  # (bm,bk,pmax)
    wbit = (lv[:, :, None] < b[:, None, :]).astype(jnp.float32)  # (bk,pmax,bn)
    acc = jax.lax.dot_general(
        abit.reshape(bm, bk * pmax), wbit.reshape(bk * pmax, bn),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bn", "bk",
                                             "interpret"))
def dscim_counts_blocked(x_i8, w_i8, cfg: DSCIMConfig, *, bm: int = 128,
                         bn: int = 128, bk: int = 16,
                         interpret: bool = True):
    """OR-accumulated counts via the blocked-points kernel (tile-aligned)."""
    M, K = x_i8.shape
    N = w_i8.shape[1]
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, K, N)
    tu_np, tv_np, pmax = block_point_tables(cfg)
    tu, tv = jnp.asarray(tu_np), jnp.asarray(tv_np)
    kernel = functools.partial(_kernel, k=cfg.k, pmax=pmax, bk=bk)
    G = cfg.group
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((G, pmax), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((G, pmax), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x_i8, w_i8, tu, tv)
