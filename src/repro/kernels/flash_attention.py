"""Pallas TPU kernel: causal flash attention (§Perf cell B, iter-4 target).

The pure-JAX chunked attention in layers/attention.py materializes its
score chunks in HBM (the dominant byte term of every *_32k prefill cell);
this kernel keeps the online-softmax state (acc, m, l) in VMEM scratch
across the kv-block grid axis, so HBM traffic is exactly q+k+v+o.

Grid: (batch*kv_heads*n_rep, nq, nk) with the kv axis innermost
(sequential); causal upper-triangle blocks are skipped with pl.when — on
TPU that elides the MXU work entirely (the static-pair-scan trick of the
JAX path, expressed natively).

Validated in interpret mode against ref.flash_attention_ref; wall-clock
benefits require real TPU hardware (documented in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, bq: int, bk: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(kj <= qi)          # causal: skip strictly-future kv blocks
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, bq: int = 256, bk: int = 256,
                           interpret: bool = True):
    """Causal MHA. q/k/v (BH, S, d) — callers fold batch*heads (GQA callers
    repeat-index kv per q-head group before folding).  Returns (BH, S, d).
    """
    BH, S, d = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = d ** -0.5
    kernel = functools.partial(_kernel, scale=scale, bq=bq, bk=bk)
    try:
        from jax.experimental.pallas import tpu as pltpu
        scratch = [pltpu.VMEM((bq, d), jnp.float32),
                   pltpu.VMEM((bq,), jnp.float32),
                   pltpu.VMEM((bq,), jnp.float32)]
    except ImportError:  # pragma: no cover
        scratch = [pl.VMEM((bq, d), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
