"""Pallas TPU kernel: exact tiled int8 matmul -> int32 (the DCIM baseline).

The adder-tree DCIM macro the paper compares against is, on TPU, just an
int8 MXU matmul; this kernel is the baseline for the DS-CIM kernel benches
and the exact backend for DSCIMLinear at production shapes.

Tiling: grid (M/bm, N/bn, K/bk); int8 tiles are dotted with
preferred_element_type=int32 (v5e MXU int8 path), accumulated into the
(bm, bn) int32 output tile across the K grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["int8_matmul_pallas"]


def _kernel(x_ref, w_ref, out_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_pallas(x_i8, w_i8, *, bm: int = 128, bn: int = 128,
                       bk: int = 256, interpret: bool = True):
    """x (M,K) int8 @ w (K,N) int8 -> (M,N) int32; dims must be tile-aligned."""
    M, K = x_i8.shape
    N = w_i8.shape[1]
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"pad to tiles first: {(M, K, N)} vs {(bm, bk, bn)}")
    return pl.pallas_call(
        _kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(x_i8, w_i8)
