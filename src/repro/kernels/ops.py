"""jit'd public wrappers for the Pallas kernels: padding, precomputed fold
constants, fused sign-correction terms, and CPU(interpret)/TPU dispatch —
plus the tile-rounding and bit-dtype policies shared by the fused serving
entries (kernels/dscim_fused.py) and the autotuner.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.macro import DSCIMConfig
from repro.core import prng as prng_lib
from repro.core.remap import fold

from .dscim_mvm import dscim_counts_pallas
from .int8_matmul import int8_matmul_pallas

__all__ = ["dscim_mvm", "int8_matmul", "fold_constants", "ON_TPU",
           "round_up", "default_bits"]

ON_TPU = jax.default_backend() == "tpu"


def round_up(x: int, m: int) -> int:
    """Smallest multiple of m >= x (tile/pad arithmetic)."""
    return -(-x // m) * m


def default_bits(interpret: bool) -> str:
    """Bit-expansion operand dtype policy for the fused DS-CIM kernels:
    bf16 on real TPU ({0,1} values are exact, VMEM halves, MXU runs at its
    bf16 rate); f32 under interpret mode, where CPU bf16 emulation would
    dominate the runtime."""
    return "float32" if interpret else "bfloat16"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.lru_cache(maxsize=32)
def fold_constants(cfg: DSCIMConfig):
    """Precompute folded PRNG coordinates (cu, lu, cv, lv) as int32 arrays."""
    u, v = prng_lib.make_points(cfg.points, cfg.length, cfg.seed_u,
                                cfg.seed_v, cfg.param_u, cfg.param_v)
    cu, lu = fold(u.astype(np.int32), cfg.k)
    cv, lv = fold(v.astype(np.int32), cfg.k)
    return tuple(jnp.asarray(t, jnp.int32) for t in (cu, lu, cv, lv))


def dscim_mvm(x_i8, w_i8, cfg: DSCIMConfig, *, bm: int = 128, bn: int = 128,
              bk: int = 8, bl: int | None = None,
              interpret: bool | None = None, tune: bool = False):
    """Full DS-CIM psum estimate via the Pallas kernel (float32 (M,N)).

    Pads (M, K, N) to tile multiples; the int8 zero-padding contributes
    x'=w'=128 -> shifted a=b=S/2 rectangles whose counts are *not* zero, so
    padding correctness is handled by computing corrections on the padded
    operands too: padded rows/cols estimate 0*0 products (x=w=0 exactly),
    and the estimator is exact-in-expectation for them; the deterministic
    LUT residual of the pad rows is subtracted via a precomputed pad count.
    Simpler and exact: we pad K with x=-128 (x'=0) so pad rows never fire.
    """
    interpret = (not ON_TPU) if interpret is None else interpret
    M, K = x_i8.shape
    N = w_i8.shape[1]
    if tune:
        from . import autotune
        bm, bn, bk, bl = autotune.mvm_tiles((M, K, N), cfg,
                                            interpret=interpret)
    bl = bl or min(cfg.length, 128)
    # K padding with x' = 0 (x = -128): abit always 0 -> zero contribution.
    padk = (-K) % bk
    if padk:
        x_i8 = jnp.pad(x_i8, ((0, 0), (0, padk)), constant_values=-128)
        w_i8 = jnp.pad(w_i8, ((0, padk), (0, 0)), constant_values=0)
    x_i8, padm = _pad_to(x_i8, bm, 0)
    w_i8, padn = _pad_to(w_i8, bn, 1)
    cu, lu, cv, lv = fold_constants(cfg)
    counts = dscim_counts_pallas(
        x_i8.astype(jnp.int8), w_i8.astype(jnp.int8), cu, lu, cv, lv,
        k=cfg.k, length=cfg.length, bm=bm, bn=bn, bk=bk, bl=bl,
        interpret=interpret)
    x32 = x_i8.astype(jnp.int32)
    w32 = w_i8.astype(jnp.int32)
    out = cfg.scale * counts \
        - 128.0 * jnp.sum(x32, axis=-1, keepdims=True) \
        - 128.0 * jnp.sum(w32 + 128, axis=0, keepdims=True)
    # remove the pad-K contribution of term (c)/(d): x=-128 rows add
    # -128*(-128)*1... term c includes pad sum; term d pad w'=128 each.
    if padk:
        out = out + 128.0 * (-128.0) * padk  # undo term-c pad contribution
        out = out + 128.0 * 128.0 * padk     # undo term-d pad contribution
    if cfg.trunc == "center":
        a = (x32 + 128) >> cfg.k
        b = (w32 + 128) >> cfg.k
        delta = (2 ** cfg.k - 1) / 2.0
        # pad rows: a=0 contributes 0 to Σa; b=S/2 per pad row in Σb — but
        # those rows never fire and their true product is 0, so exclude.
        sum_a = jnp.sum(a, axis=-1, keepdims=True)
        sum_b = jnp.sum(b, axis=0, keepdims=True)
        if padk:
            sum_b = sum_b - padk * (128 >> cfg.k)
        out = out + (2 ** cfg.k) * delta * (sum_a + sum_b) + K * delta * delta
    return out[:M, :N]


def int8_matmul(x_i8, w_i8, *, bm: int = 128, bn: int = 128, bk: int = 256,
                interpret: bool | None = None):
    """Exact int8 matmul -> int32 via the Pallas baseline kernel."""
    interpret = (not ON_TPU) if interpret is None else interpret
    M, K = x_i8.shape
    N = w_i8.shape[1]
    x_i8, padm = _pad_to(x_i8.astype(jnp.int8), bm, 0)
    x_i8, _ = _pad_to(x_i8, bk, 1)
    w_i8, padk = _pad_to(w_i8.astype(jnp.int8), bk, 0)
    w_i8, padn = _pad_to(w_i8, bn, 1)
    out = int8_matmul_pallas(x_i8, w_i8, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)
    return out[:M, :N]
