"""Fused Pallas paged-attention decode kernel (ISSUE 5 tentpole).

PR 4's int8 block-paged KV cache won the *bytes* (3.53x fewer resident
decode-cache bytes) but read them through a jnp gather + dequant +
online-softmax ``lax.scan`` in ``decode_attention_paged`` — on TPU that
path stages every gathered page and its dequantized f32 copy in HBM
before the QK contraction sees it, so the bandwidth win the quantization
paid for is handed straight back.  The paper's premise (DS-CIM's fused
in-array sign-correction + dequant) and the SC memory-system literature
(Khatamifard et al.; Stoch-IMC's bit-parallel banking) agree on the fix:
keep the dequant *inside* the bandwidth-bound loop.

This kernel is that loop, in one launch:

* grid ``(B, KV // gh, MP)`` — one cell per (batch slot, kv-head group),
  walking the MP logical pages on the innermost (sequential) grid axis;
* the **page table is a scalar-prefetch operand**, so each step's
  BlockSpec index map resolves ``table[b, j]`` before the body runs and
  the pipeline DMA streams the *physical* int8 page straight into VMEM —
  the gather never materializes in HBM;
* per-page per-kv-head dequant scales ride as (1, gh) blocks and the
  int8 -> f32 dequant happens on the VMEM-resident page inside the flash
  online-softmax update (m/l/acc live in VMEM scratch across the page
  axis, exactly like kernels/flash_attention.py);
* the slot's bf16 **tail page overlays** its logical slot in-kernel
  (``j == pos[b] // ps``) at full precision;
* **ragged slots mask in-kernel**: tokens past ``pos[b]`` get NEG_INF
  scores, and pages entirely past the valid prefix are skipped with
  ``pl.when`` (no MXU work).  Done slots need no extra masking — the
  model freezes a finished slot's ``pos``, so the same predicate covers
  them (their tail write and flush are gated host-side by ``done`` in
  layers/attention.py, which stays the jnp reference semantics).

The attended output (B, KV, n_rep, HD) comes out in f32; the q/k/v
projections, RoPE, tail write and page flush stay in jnp around the call
(they are O(B) scatter work, not the bandwidth term).  Numerics match the
jnp reference scan to float-accumulation tolerance: both walk pages in
the same order with f32 contraction and f32 m/l/acc statistics, but
XLA's einsum layout and the kernel's dot_general round differently, so
end-to-end logit RMSE is ~1e-8 (the CI threshold,
tools/ci_thresholds.json, is 1e-5).

Tile knobs (threaded through kernels/autotune.py ``paged_attn_tiles``,
with winners for the decode serving shapes in the checked-in cache):

* ``gh``  — kv heads per grid cell (GQA head grouping: gh > 1 amortizes
  page DMA across head groups that share the page bytes);
* ``qp``  — q rows per cell, i.e. n_rep padded up (pad rows are zeros,
  sliced off after the call; on TPU this is the sublane-alignment knob).

Validated in interpret mode (tests/test_paged_kernel.py); the TPU-native
run rides the same ROADMAP item as the fused MVM kernel.  Under a mesh
the call must sit inside shard_map (a Pallas call cannot be GSPMD-
partitioned): ``paged_attention_decode_sharded`` shards the batch-carried
operands (q, tails, page table, pos) over the DP axes and gathers the
page pool whole per shard — under continuous batching any slot may
reference any physical page, so the pool cannot shard with the slots.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ops import ON_TPU

__all__ = ["paged_attention_decode", "paged_attention_decode_sharded",
           "use_paged_kernel"]

NEG_INF = -1e30
_ENV_FLAG = "REPRO_PAGED_ATTN"


def use_paged_kernel(dscim_spec: str) -> bool:
    """Fallback read-path selector for ``decode_attention_paged`` when no
    explicit pin was threaded in (``paged_attn='auto'``): the Pallas
    kernel is the default for the 'kernel' serving mode, the jnp gather
    scan stays the reference everywhere else.  ``REPRO_PAGED_ATTN=
    kernel|jnp`` forces either path; like ``REPRO_DSCIM_TUNE`` it is read
    at trace time, so in-process A/Bs should prefer the cache-keyed
    ``paged_attn`` option on the serve stack."""
    env = os.environ.get(_ENV_FLAG, "").strip().lower()
    if env in ("kernel", "pallas", "1"):
        return True
    if env in ("jnp", "gather", "0"):
        return False
    from repro.core.qweights import split_dscim_mode
    return split_dscim_mode(dscim_spec)[0] == "kernel"


def _kernel(table_ref, pos_ref, q_ref, kp_ref, vp_ref, ks_ref, vs_ref,
            kt_ref, vt_ref, o_ref, acc_ref, m_ref, l_ref, *,
            ps: int, scale: float):
    """One grid step: one logical page of one (slot, kv-head-group) cell.

    Blocks (leading size-1 page/slot dim squeezed on read):
      q (1, gh, qp, HD) f32; kp/vp (1, ps, gh, HD) int8 — the *physical*
      page picked by the scalar-prefetched table; ks/vs (1, gh) f32;
      kt/vt (1, ps, gh, HD) bf16.  Scratch acc (gh, qp, HD), m/l (gh, qp)
      carry the online-softmax state across the page axis.
    """
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    posb = pos_ref[b]

    # pages entirely past the slot's valid prefix contribute exactly
    # nothing (the jnp reference's fully-masked page is a no-op update:
    # alpha = 1, p = 0) — skip their dequant + MXU work outright
    @pl.when(j * ps <= posb)
    def _page():
        kj = kp_ref[0].astype(jnp.float32) * ks_ref[0][None, :, None]
        vj = vp_ref[0].astype(jnp.float32) * vs_ref[0][None, :, None]
        is_tail = j == posb // ps
        kj = jnp.where(is_tail, kt_ref[0].astype(jnp.float32), kj)
        vj = jnp.where(is_tail, vt_ref[0].astype(jnp.float32), vj)
        q = q_ref[0].astype(jnp.float32)                     # (gh, qp, HD)
        s = jax.lax.dot_general(                             # (gh, qp, ps)
            q, kj.transpose(1, 2, 0), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        tj = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
        s = jnp.where(tj <= posb, s, NEG_INF)                # ragged mask
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + jax.lax.dot_general(
            p, vj.transpose(1, 0, 2), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]


@functools.partial(jax.jit, static_argnames=("gh", "qp", "interpret"))
def _paged_call(q, k_pages, v_pages, k_scale, v_scale, k_tail, v_tail,
                page_table, pos, *, gh: int, qp: int, interpret: bool):
    B, KV, R, HD = q.shape
    ps = k_pages.shape[1]
    MP = page_table.shape[1]
    if qp > R:
        # zero pad rows: their scores softmax over the same valid tokens,
        # never NaN, and are sliced off below — the TPU sublane-pad knob
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qp - R), (0, 0)))
    kernel = functools.partial(_kernel, ps=ps, scale=HD ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV // gh, MP),
        in_specs=[
            pl.BlockSpec((1, gh, qp, HD), lambda b, g, j, t, p: (b, g, 0, 0)),
            pl.BlockSpec((1, ps, gh, HD),
                         lambda b, g, j, t, p: (t[b, j], 0, g, 0)),
            pl.BlockSpec((1, ps, gh, HD),
                         lambda b, g, j, t, p: (t[b, j], 0, g, 0)),
            pl.BlockSpec((1, gh), lambda b, g, j, t, p: (t[b, j], g)),
            pl.BlockSpec((1, gh), lambda b, g, j, t, p: (t[b, j], g)),
            pl.BlockSpec((1, ps, gh, HD), lambda b, g, j, t, p: (b, 0, g, 0)),
            pl.BlockSpec((1, ps, gh, HD), lambda b, g, j, t, p: (b, 0, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, gh, qp, HD),
                               lambda b, g, j, t, p: (b, g, 0, 0)),
        scratch_shapes=[pltpu.VMEM((gh, qp, HD), jnp.float32),
                        pltpu.VMEM((gh, qp), jnp.float32),
                        pltpu.VMEM((gh, qp), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, qp, HD), jnp.float32),
        interpret=interpret,
    )(page_table, pos, q, k_pages, v_pages, k_scale, v_scale, k_tail, v_tail)
    return out[:, :, :R]


def paged_attention_decode(q, k_pages, v_pages, k_scale, v_scale,
                           k_tail, v_tail, page_table, pos, *,
                           gh: int | None = None, qp: int | None = None,
                           interpret: bool | None = None,
                           tune: bool = False):
    """Single-launch paged decode attention (see module docstring).

    q (B, KV, n_rep, HD) f32 — post-RoPE query, kv-major head layout (the
    ``decode_attention_paged`` ``qf`` reshape); k/v_pages (P, ps, KV, HD)
    int8; k/v_scale (P, KV) f32; k/v_tail (B, ps, KV, HD) bf16 — the tail
    must already hold this step's token (layers/attention.py writes it,
    done-gated, before calling); page_table (B, MP) int32 (physical page
    ids); pos (B,) int32.  Returns the attended (B, KV, n_rep, HD) f32.

    ``gh``/``qp``: kv heads per grid cell / padded q rows per cell —
    ``tune=True`` consults kernels/autotune.py (checked-in winners for
    the decode serving shapes); the defaults are the pad-free cell.
    """
    interpret = (not ON_TPU) if interpret is None else interpret
    B, KV, R, HD = q.shape
    ps = k_pages.shape[1]
    if tune and gh is None and qp is None:
        from . import autotune
        gh, qp = autotune.paged_attn_tiles((B, KV, R, HD), ps,
                                           interpret=interpret)
    gh = gh or 1
    qp = qp or R
    if KV % gh:
        raise ValueError(f"gh={gh} must divide the kv head count {KV}")
    if qp < R:
        raise ValueError(f"qp={qp} < n_rep={R}")
    return _paged_call(q, k_pages, v_pages, k_scale, v_scale,
                       k_tail, v_tail, page_table,
                       pos.astype(jnp.int32), gh=gh, qp=qp,
                       interpret=interpret)


def paged_attention_decode_sharded(q, k_pages, v_pages, k_scale, v_scale,
                                   k_tail, v_tail, page_table, pos, *,
                                   mesh, dp_axes: tuple = (), **kw):
    """Mesh placement of the paged-attention kernel (a Pallas call must run
    inside shard_map on a multi-device mesh, like the fused MVM).

    Batch-carried operands (q, tails, page table, pos) shard over the DP
    axes when B divides; the page pool + scales replicate into each shard
    (in_specs ``P(None, ...)`` gathers the committed DP-sharded pool) —
    under continuous batching the allocator may grant a slot *any*
    physical page, so pool rows cannot be assumed slot-aligned.  Output
    lands batch-sharded.  Bit-identical to the single-device call: the
    per-slot page walk is placement-invariant.
    """
    import math

    from jax.sharding import PartitionSpec as P

    from repro.parallel import shard_map

    b = None
    if dp_axes:
        dp_size = math.prod(mesh.shape[a] for a in dp_axes)
        if q.shape[0] % dp_size == 0:
            b = tuple(dp_axes)
    bspec4 = P(b, None, None, None)
    repl = lambda a: P(*([None] * a.ndim))  # noqa: E731

    def inner(ql, kp, vp, ks, vs, kt, vt, tbl, pl_):
        return paged_attention_decode(ql, kp, vp, ks, vs, kt, vt, tbl, pl_,
                                      **kw)

    return shard_map(
        inner, mesh=mesh,
        in_specs=(bspec4, repl(k_pages), repl(v_pages), repl(k_scale),
                  repl(v_scale), bspec4, bspec4, P(b, None), P(b)),
        out_specs=bspec4,
    )(q, k_pages, v_pages, k_scale, v_scale, k_tail, v_tail, page_table,
      pos.astype(jnp.int32))
