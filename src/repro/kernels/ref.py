"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

These are *independent* implementations (no Pallas, no pl.*) used by the
kernel tests' ``assert_allclose`` sweeps.  ``dscim_counts_ref`` is itself
validated against the cycle-accurate hardware oracle in
``repro.core.ormac`` by the core test suite, closing the chain:

    Pallas kernel (interpret) == ref.py == LUT == cycle-accurate OR-MAC.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.remap import fold_jnp

__all__ = ["dscim_counts_ref", "dscim_mvm_ref", "int8_matmul_ref"]


def dscim_counts_ref(x_i8, w_i8, u, v, k: int):
    """OR-accumulated counts C[m,n] for the remapped DS-CIM column.

    x_i8 (M,K) int, w_i8 (K,N) int, u/v (L,) int32 point coords.
    """
    kk = k
    a = (x_i8.astype(jnp.int32) + 128) >> kk            # (M,K) in [0,S)
    b = (w_i8.astype(jnp.int32) + 128) >> kk            # (K,N)
    K = a.shape[-1]
    n = 1 << kk
    blk = jnp.arange(K, dtype=jnp.int32) % (4 ** kk)
    bc, br = blk % n, blk // n
    cu, lu = fold_jnp(u, kk)
    cv, lv = fold_jnp(v, kk)
    abits = ((cu[None, None, :] == bc[None, :, None])
             & (lu[None, None, :] < a[:, :, None])).astype(jnp.float32)
    wbits = ((cv[None, :, None] == br[:, None, None])
             & (lv[None, :, None] < b[:, None, :])).astype(jnp.float32)
    return jnp.einsum("mkt,ktn->mn", abits, wbits).astype(jnp.int32)


def dscim_mvm_ref(x_i8, w_i8, u, v, k: int, length: int,
                  trunc: str = "floor"):
    """Full DS-CIM psum estimate (Eq. 4) from the counts oracle."""
    counts = dscim_counts_ref(x_i8, w_i8, u, v, k)
    scale = (4 ** k) * 65536.0 / length
    x32 = x_i8.astype(jnp.int32)
    w32 = w_i8.astype(jnp.int32)
    out = scale * counts.astype(jnp.float32) \
        - 128.0 * jnp.sum(x32, axis=-1, keepdims=True) \
        - 128.0 * jnp.sum(w32 + 128, axis=0, keepdims=True)
    if trunc == "center":
        a = (x32 + 128) >> k
        b = (w32 + 128) >> k
        delta = (2 ** k - 1) / 2.0
        out = out + (2 ** k) * delta * (
            jnp.sum(a, axis=-1, keepdims=True)
            + jnp.sum(b, axis=0, keepdims=True)) \
            + x_i8.shape[-1] * delta * delta
    return out


def int8_matmul_ref(x_i8, w_i8):
    """Exact int8 matmul -> int32 (the DCIM adder-tree baseline)."""
    return jnp.matmul(x_i8.astype(jnp.int32), w_i8.astype(jnp.int32),
                      preferred_element_type=jnp.int32)


def flash_attention_ref(q, k, v):
    """Plain causal softmax attention oracle. q/k/v (BH, S, d)."""
    S = q.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * q.shape[-1] ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
