import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Placeholder CPU devices let jax.make_mesh build the production 16x16 /
# 2x16x16 meshes; .lower().compile() is AOT — nothing is allocated.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell and both production meshes,
lower + compile the real step function (train_step with optimizer update /
prefill_step / serve decode_step) under the production shardings, then
record:

* memory_analysis()   — per-device argument/output/temp bytes (fits check)
* cost_analysis()     — HLO FLOPs + bytes accessed
* collective bytes    — parsed from the optimized HLO text, per collective op
* roofline terms      — compute / memory / collective seconds (v5e constants)

Results are cached as JSON under experiments/dryrun/ and consumed by
benchmarks/roofline.py + EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod/--singlepod]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_parallel_ctx, make_production_mesh
from repro.launch.sharding import (batch_specs, cache_partition,
                                   opt_state_specs, param_specs,
                                   to_shardings)
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import get_model
from repro.optim.adamw import AdamW

# ---- v5e roofline constants -------------------------------------------------
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+\S+\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\((?P<args>.*?)\)",
)
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op, keyed by op kind."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(1)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group("args")):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
    out["total"] = sum(out.values())
    return out


def _flops_bytes(compiled) -> tuple[float, float]:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    # CPU backend reports 'bytes accessed' (+ per-space breakdowns)
    byts = float(ca.get("bytes accessed", 0.0))
    return flops, byts


def model_flops(cfg, shape, kind: str) -> float:
    """Reference useful FLOPs: 6*N_active*D train, 2*N_active*D inference."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * shape.batch * shape.seq
    if kind == "prefill":
        return 2.0 * n * shape.batch * shape.seq
    return 2.0 * n * shape.batch  # decode: one token per sequence


def build_cell(cfg, shape_name: str, multi_pod: bool,
               sp: bool | None = None):
    """Lower + compile one cell. Returns the record dict."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    if sp is None:
        sp = os.environ.get("REPRO_SP", "0") == "1"
    par = make_parallel_ctx(mesh, sp=sp)
    model = get_model(cfg)
    kind, batch_struct = cfg.input_specs(shape_name)
    shape = cfg.shape(shape_name)
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_struct = jax.eval_shape(
        lambda k: model.init_params(cfg, k), key_struct)
    pspecs = param_specs(cfg, par, params_struct)
    pshard = to_shardings(mesh, pspecs)
    bshard = to_shardings(mesh, batch_specs(cfg, par, batch_struct))

    t0 = time.time()
    if kind == "train":
        opt = AdamW(lr=3e-4)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        oshard = to_shardings(mesh, opt_state_specs(pspecs))
        step = make_train_step(cfg, par, opt)
        jitted = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_struct, opt_struct, batch_struct)
    elif kind == "prefill":
        step = make_prefill_step(cfg, par)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        lowered = jitted.lower(params_struct, batch_struct)
    elif kind == "decode":
        cache_struct = model.cache_specs(cfg, shape.batch, shape.seq)
        cshard = to_shardings(mesh, cache_partition(cfg, par, cache_struct))
        step = make_decode_step(cfg, par)
        jitted = jax.jit(step, in_shardings=(pshard, bshard, cshard),
                         donate_argnums=(2,))
        lowered = jitted.lower(params_struct, batch_struct, cache_struct)
    else:
        raise ValueError(kind)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        mem["total_per_device"] = (mem["argument_bytes"]
                                   + mem["output_bytes"]
                                   + mem["temp_bytes"]
                                   - mem["alias_bytes"])
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)                # loop-corrected, per-device
    xla_ca = compiled.cost_analysis() or {}
    chips = mesh.devices.size

    # roofline terms (seconds); analyzer values are per-device payloads, so
    # the spec formula coll_global/(chips*link_bw) == coll_per_device/link_bw
    t_comp = cost.flops / PEAK_FLOPS
    t_mem = cost.bytes / HBM_BW
    t_coll = cost.coll_bytes / LINK_BW
    mf = model_flops(cfg, shape, kind)
    record = {
        "arch": cfg.name, "shape": shape_name, "kind": kind,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16", "chips": chips,
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "hlo_flops_per_device": cost.flops,
        "hlo_bytes_per_device": cost.bytes,
        "collective_bytes_per_device": dict(cost.coll_by_kind,
                                            total=cost.coll_bytes),
        "collective_ops": cost.coll_ops,
        "dot_ops": cost.dots,
        "bytes_by_kind_top": dict(sorted(cost.bytes_by_kind.items(),
                                         key=lambda kv: -kv[1])[:8]),
        "xla_cost_analysis": {
            "flops_loop_body_once": float(xla_ca.get("flops", 0.0)),
            "bytes_loop_body_once": float(xla_ca.get("bytes accessed", 0.0)),
        },
        "roofline": {
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": max(
                [("compute", t_comp), ("memory", t_mem),
                 ("collective", t_coll)], key=lambda kv: kv[1])[0],
        },
        "model_flops_global": mf,
        "model_flops_per_device": mf / chips,
        "useful_flops_ratio": (mf / chips) / cost.flops if cost.flops else 0.0,
    }
    return record


def cells(arch_filter=None, shape_filter=None):
    for name, cfg in ARCHS.items():
        if arch_filter and name != arch_filter:
            continue
        for s in cfg.shapes:
            if shape_filter and s.name != shape_filter:
                continue
            yield cfg, s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true", default=None,
                    help="only the 2x16x16 mesh")
    ap.add_argument("--singlepod", action="store_true", default=None,
                    help="only the 16x16 mesh")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = [False, True]
    if args.multipod and not args.singlepod:
        meshes = [True]
    if args.singlepod and not args.multipod:
        meshes = [False]

    n_ok = n_skip = n_fail = 0
    for cfg, shape in cells(args.arch, args.shape):
        for mp in meshes:
            tag = f"{cfg.name}_{shape.name}_{'mp' if mp else 'sp'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                n_skip += 1
                continue
            if not cfg.runnable(shape.name):
                rec = {"arch": cfg.name, "shape": shape.name,
                       "mesh": "pod2x16x16" if mp else "pod16x16",
                       "ok": False, "skipped": True,
                       "reason": "full-attention arch; long-context decode "
                                 "requires sub-quadratic family (DESIGN.md)"}
                json.dump(rec, open(path, "w"), indent=1)
                n_skip += 1
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            t0 = time.time()
            try:
                rec = build_cell(cfg, shape.name, mp)
                n_ok += 1
            except Exception as e:  # noqa: BLE001 — record the failure
                rec = {"arch": cfg.name, "shape": shape.name,
                       "mesh": "pod2x16x16" if mp else "pod16x16",
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                n_fail += 1
            json.dump(rec, open(path, "w"), indent=1)
            status = "ok" if rec.get("ok") else "FAIL"
            print(f"[dryrun] {tag}: {status} ({time.time()-t0:.1f}s)",
                  flush=True)
            if rec.get("ok"):
                r = rec["roofline"]
                print(f"    mem/dev={rec['memory'].get('total_per_device',0)/2**30:.2f}GiB "
                      f"comp={r['t_compute_s']:.2e}s mem={r['t_memory_s']:.2e}s "
                      f"coll={r['t_collective_s']:.2e}s dom={r['dominant']}",
                      flush=True)
    print(f"[dryrun] done: ok={n_ok} skip={n_skip} fail={n_fail}")


if __name__ == "__main__":
    main()
