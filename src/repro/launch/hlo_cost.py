"""Loop-aware cost analysis of compiled HLO text.

XLA's built-in ``cost_analysis()`` counts a ``while`` body **once**, which
undercounts scan-over-layers models by ~n_layers and chunked-attention loops
by ~n_chunks.  This module parses ``compiled.as_text()`` into per-computation
totals (dot FLOPs, bytes moved, collective operand bytes, per-collective-op
kinds) and multiplies nested while bodies by their parsed trip counts —
giving roofline inputs that are exact for the dominant (dot) work and
loop-corrected for everything else.

Conventions:
* FLOPs: 2*prod(result_shape)*prod(contraction_dims) per ``dot``; convs and
  elementwise fusions are not dot-shaped in our models (mamba's conv4 is
  written as 4 fused multiplies) and are covered by the bytes term.
* bytes: result + operand buffer sizes per op (HLO cost-analysis style),
  fusion-internal temporaries excluded (they live in registers/VMEM).
* collective bytes: operand bytes per collective op (result-derived:
  all-gather operand = result/group; reduce-scatter operand = result*group;
  all-reduce/all-to-all/collective-permute operand = result), i.e. the
  per-device payload each chip injects into the interconnect.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)",
    )
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_TRIP = re.compile(r'"known_trip_count":\s*\{\s*"n"\s*:\s*"(\d+)"')
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str):
    m = _SHAPE_TOK.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    rest: str
    operands: list = field(default_factory=list)


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0          # headline: writes + unique reads
    bytes_write: float = 0.0    # lower bound: every buffer written once
    bytes_upper: float = 0.0    # upper: producer+consumer double-counted
    coll_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    bytes_by_kind: dict = field(default_factory=dict)
    coll_ops: int = 0
    dots: int = 0

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.bytes * k, self.bytes_write * k,
                       self.bytes_upper * k,
                       self.coll_bytes * k,
                       {kk: v * k for kk, v in self.coll_by_kind.items()},
                       {kk: v * k for kk, v in self.bytes_by_kind.items()},
                       int(self.coll_ops * k), int(self.dots * k))

    def add(self, o: "HloCost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.bytes_write += o.bytes_write
        self.bytes_upper += o.bytes_upper
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0) + v
        for k, v in o.bytes_by_kind.items():
            self.bytes_by_kind[k] = self.bytes_by_kind.get(k, 0) + v
        self.coll_ops += o.coll_ops
        self.dots += o.dots


def _parse_computations(text: str):
    """Split HLO text into {computation_name: [op lines]}."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and ("{" in line) \
                and not line.startswith("HloModule"):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None and line.strip() != "}":
            comps[cur].append(line)
    return comps


def _group_size(rest: str) -> int:
    m = _GROUPS_EXPL.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    return 1


def _dot_flops(op: _Op, types: dict) -> float:
    out = _shape_elems(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not m or not op.operands:
        return 0.0
    lhs_t = types.get(op.operands[0])
    if lhs_t is None:
        return 0.0
    lhs = _shape_elems(lhs_t)
    cdims = [int(d) for d in m.group(1).split(",") if d]
    k = 1
    for d in cdims:
        if d < len(lhs):
            k *= lhs[d]
    n_out = 1
    for d in out:
        n_out *= d
    return 2.0 * n_out * k


def _trip_count(op_rest: str, cond_lines: list[str]) -> int:
    """Prefer XLA's known_trip_count backend_config; fall back to the max
    integer constant visible in the loop condition computation."""
    m = _TRIP.search(op_rest)
    if m:
        return int(m.group(1))
    best = 1
    for ln in cond_lines:
        for c in _CONST_INT.findall(ln):
            best = max(best, int(c))
    return best


def analyze_hlo(text: str) -> HloCost:
    comps = _parse_computations(text)
    # symbol table: op name -> result type string (per computation, but HLO
    # names are globally unique in optimized dumps)
    types: dict[str, str] = {}
    parsed: dict[str, list[_Op]] = {}
    for cname, lines in comps.items():
        ops = []
        for ln in lines:
            m = _OP_LINE.match(ln)
            if not m:
                continue
            op = _Op(m.group(1), m.group(2), m.group(3), m.group(4))
            op.operands = [o for o in _OPERAND.findall(m.group(4))]
            types[op.name] = op.type_str
            ops.append(op)
        parsed[cname] = ops
    # parameters also define types:  %param.1 = f32[...] parameter(0)
    # (covered: parameter lines match _OP_LINE with kind='parameter')

    memo: dict[str, HloCost] = {}

    def cost_of(cname: str, stack=()) -> HloCost:
        if cname in memo:
            return memo[cname]
        if cname in stack:
            return HloCost()
        total = HloCost()
        read_once: dict[str, float] = {}   # unique operand buffers read
        own_wr = 0.0
        for op in parsed.get(cname, []):
            k = op.kind
            if k in ("parameter", "constant", "get-tuple-element", "tuple",
                     "bitcast", "after-all", "partition-id", "replica-id",
                     "copy-start", "copy-done"):
                continue
            res_b = _shape_bytes(op.type_str)
            if k == "while":
                res_b = 0.0  # loop-state shuffling is not HBM traffic
            # effective write size
            if k == "dynamic-update-slice":
                wr = (_shape_bytes(types.get(op.operands[1], ""))
                      if len(op.operands) > 1 else res_b)
            elif k == "scatter":
                wr = (_shape_bytes(types.get(op.operands[2], ""))
                      if len(op.operands) > 2 else res_b)
            else:
                wr = res_b
            # reads: slicing ops read only what they produce; while's init
            # tuple is loop state, not traffic
            if k in ("dynamic-slice", "gather", "slice",
                     "dynamic-update-slice", "scatter", "while"):
                rd_ops = {}
            else:
                rd_ops = {o: _shape_bytes(types.get(o, ""))
                          for o in op.operands}
            for o, b in rd_ops.items():
                read_once.setdefault(o, b)
            op_b = wr + sum(rd_ops.values()) + (wr if k in (
                "dynamic-slice", "gather", "slice", "dynamic-update-slice",
                "scatter") else 0)
            own_wr += wr
            total.bytes_write += wr
            total.bytes_upper += op_b
            total.bytes_by_kind[k] = total.bytes_by_kind.get(k, 0) + op_b
            if k == "dot":
                total.flops += _dot_flops(op, types)
                total.dots += 1
            elif k == "while":
                body = re.search(r"body=%?([\w\.\-]+)", op.rest)
                cond = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                cond_lines = comps.get(cond.group(1), []) if cond else []
                trips = _trip_count(op.rest, cond_lines)
                if body:
                    total.add(cost_of(body.group(1),
                                      stack + (cname,)).scaled(trips))
            elif k in ("fusion", "call", "conditional", "custom-call",
                       "reduce", "sort", "scatter", "map", "all-reduce"):
                # descend into called computations for nested dots/whiles
                for sub in re.findall(
                        r"(?:calls|to_apply|body|branch_computations)="
                        r"\{?%?([\w\.\-]+)", op.rest):
                    if sub in comps:
                        total.add(cost_of(sub, stack + (cname,)))
            base = k[:-6] if k.endswith("-start") else k
            if base in COLLECTIVES:
                g = _group_size(op.rest)
                if base == "all-gather":
                    payload = res_b / max(g, 1)
                elif base == "reduce-scatter":
                    payload = res_b * g
                else:
                    payload = res_b
                total.coll_bytes += payload
                total.coll_by_kind[base] = (
                    total.coll_by_kind.get(base, 0) + payload)
                total.coll_ops += 1
        # headline traffic: this computation's writes + each distinct buffer
        # it reads charged once (children already folded in via .add())
        total.bytes += own_wr + sum(read_once.values())
        memo[cname] = total
        return total

    # entry computation: the one named in "ENTRY" line, else heuristically
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in parsed:
        # fall back: computation with max ops
        entry = max(parsed, key=lambda c: len(parsed[c]))
    return cost_of(entry)
