"""Production mesh construction (function, not module constant — importing
this module never touches jax device state).

Single pod: (data=16, model=16) = 256 v5e chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is
data-parallel (slow cross-pod links carry only gradient all-reduce, which
optim/compression.py can quantize).
"""
from __future__ import annotations

import jax

from repro.parallel import ParallelCtx

__all__ = ["make_mesh", "make_production_mesh", "make_parallel_ctx",
           "make_debug_mesh", "parallel_ctx_from_spec"]


def make_mesh(shape, axes):
    """Version-portable jax.make_mesh: jax.sharding.AxisType only exists on
    newer jax; Auto is the default axis type there anyway, so omit the
    kwarg when unavailable.  Shared by launch and runtime mesh builders."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CI-grade machinery tests (8 fake devices)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def make_parallel_ctx(mesh, sp: bool = False) -> ParallelCtx:
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return ParallelCtx(mesh=mesh, dp_axes=dp_axes, tp_axis="model", sp=sp)


def parallel_ctx_from_spec(spec: str) -> ParallelCtx:
    """CLI mesh spec -> ParallelCtx: 'model=4' or 'data=2,model=4'.

    The serving convention (launch/serve.py ``--mesh``): a ('data',
    'model') mesh with omitted axes defaulting to 1 — 'model=4' is a pure
    tensor-parallel serving mesh; needs data*model visible jax devices."""
    sizes = {"data": 1, "model": 1}
    for part in spec.split(","):
        axis, _, n = part.partition("=")
        if axis not in sizes or not n.isdigit() or int(n) < 1:
            raise ValueError(f"bad mesh spec {spec!r}; want e.g. 'model=4' "
                             "or 'data=2,model=4'")
        sizes[axis] = int(n)
    mesh = make_mesh((sizes["data"], sizes["model"]), ("data", "model"))
    return make_parallel_ctx(mesh)
