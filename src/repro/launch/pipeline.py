"""Optional pipeline parallelism over the 'pod' axis (GPipe schedule).

The default multi-pod deployment is pod-DP (DESIGN.md §5); this module
provides the PP alternative for regimes where cross-pod gradient all-reduce
dominates (very large models / many pods): each pod owns a contiguous stage
of layers, microbatches stream through `ppermute` handoffs, and the bubble
fraction is (P-1)/(P-1+M).

Implementation: `shard_map` over ('pod',); within a pod, the stage body is
the ordinary pjit-style layer stack (TP/FSDP inside the stage would nest via
the remaining mesh axes — demonstrated here with the stage body running on
the pod's full device slice).  `pp_dryrun` compiles a 2-stage pipeline for
an arch to prove the schedule lowers on the production mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_forward", "pp_dryrun"]


def pipeline_forward(stage_params, x_mb, *, stage_fn, mesh,
                     axis: str = "pod"):
    """GPipe forward over `axis`.

    stage_params: pytree stacked over stages on dim 0 — stage i's slice
    lives on pod i (sharded over `axis`).
    x_mb: (M, mb, S, D) microbatches (replicated across pods at entry).
    stage_fn(params_slice, x) -> x.
    Returns final-stage activations (M, mb, S, D) (valid on the last pod).
    """
    n_stage = mesh.shape[axis]

    def body(params_sl, xs):
        # params_sl: this pod's stage slice (leading stage dim of size 1)
        params_sl = jax.tree.map(lambda a: a[0], params_sl)
        rank = jax.lax.axis_index(axis)
        M = xs.shape[0]
        n_clock = M + n_stage - 1

        def clock(carry, t):
            buf = carry            # (mb, S, D): activation entering this pod
            # stage 0 injects microbatch t; others consume the handoff
            mb_idx = jnp.clip(t - rank, 0, M - 1)
            inject = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0,
                                                  keepdims=False)
            x_in = jnp.where(rank == 0, inject, buf)
            y = stage_fn(params_sl, x_in)
            # hand off to the next stage (ring; last->0 wraps, ignored)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stage) for i in range(n_stage)])
            # collect the finished microbatch on the last stage
            done_idx = t - (n_stage - 1)
            out = jnp.where((rank == n_stage - 1) & (done_idx >= 0), y, 0.0)
            return nxt, (out, done_idx)

        _, (outs, idxs) = jax.lax.scan(
            clock, jnp.zeros_like(xs[0]), jnp.arange(n_clock))
        # scatter outs back into microbatch order
        result = jnp.zeros_like(xs)
        valid = idxs >= 0
        result = result.at[jnp.clip(idxs, 0, M - 1)].add(
            jnp.where(valid[:, None, None, None], outs, 0.0))
        return result

    from repro.parallel import shard_map
    return shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params,
                               is_leaf=lambda x: False), P()),
        out_specs=P(),
    )(stage_params, x_mb)


def pp_dryrun(d_model: int = 1024, d_ff: int = 4096, layers_per_stage: int = 4,
              microbatches: int = 8, mb_size: int = 2, seq: int = 512):
    """Compile the 2-stage pipeline on the multi-pod mesh; returns record."""
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=True)

    def stage_fn(params, x):
        def layer(h, w):
            return jax.nn.gelu(h @ w[0]) @ w[1], None
        h, _ = jax.lax.scan(layer, x, params)
        return h

    p_struct = (jax.ShapeDtypeStruct(
        (2, layers_per_stage, d_model, d_ff), jnp.bfloat16),
        jax.ShapeDtypeStruct(
        (2, layers_per_stage, d_ff, d_model), jnp.bfloat16))
    x_struct = jax.ShapeDtypeStruct((microbatches, mb_size, seq, d_model),
                                    jnp.bfloat16)

    def stage_fn_pair(p, h):
        w1, w2 = p

        def layer(hh, ws):
            a, b = ws
            return jax.nn.gelu(hh @ a) @ b, None
        hh, _ = jax.lax.scan(layer, h, (w1, w2))
        return hh

    def run(w1, w2, x):
        return pipeline_forward((w1, w2), x, stage_fn=stage_fn_pair,
                                mesh=mesh)

    lowered = jax.jit(run).lower(*p_struct, x_struct)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    return {
        "ok": True,
        "stages": 2,
        "microbatches": microbatches,
        "bubble_fraction": (2 - 1) / (2 - 1 + microbatches),
        "temp_bytes": int(ma.temp_size_in_bytes) if ma else None,
        "collective_permutes": compiled.as_text().count("collective-permute"),
    }
