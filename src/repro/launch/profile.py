import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Per-cell HLO profiler — the tool behind the §Perf hillclimbs.

Reports, for one (arch × shape × mesh) cell:
  * the roofline terms and their deltas vs a saved baseline JSON,
  * top-K largest single buffers (what dominates memory_analysis),
  * per-while-loop attribution (body cost × trip count),
  * per-op-kind byte breakdown and per-collective-kind payloads.

Usage:
  PYTHONPATH=src python -m repro.launch.profile --arch rwkv6-7b \
      --shape train_4k [--baseline experiments/perf/cellA_baseline.json]
"""
import argparse
import json
import re

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.launch import hlo_cost as hc
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_parallel_ctx, make_production_mesh
from repro.launch.sharding import (batch_specs, opt_state_specs, param_specs,
                                   to_shardings)
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import get_model
from repro.optim.adamw import AdamW


def _compile_cell(cfg, shape_name, multi_pod, sp):
    mesh = make_production_mesh(multi_pod=multi_pod)
    par = make_parallel_ctx(mesh, sp=sp)
    model = get_model(cfg)
    kind, batch_struct = cfg.input_specs(shape_name)
    shape = cfg.shape(shape_name)
    ps = jax.eval_shape(lambda k: model.init_params(cfg, k),
                        jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = param_specs(cfg, par, ps)
    psh = to_shardings(mesh, pspecs)
    bsh = to_shardings(mesh, batch_specs(cfg, par, batch_struct))
    if kind == "train":
        opt = AdamW()
        osd = jax.eval_shape(opt.init, ps)
        j = jax.jit(make_train_step(cfg, par, opt),
                    in_shardings=(psh, to_shardings(
                        mesh, opt_state_specs(pspecs)), bsh),
                    donate_argnums=(0, 1))
        return j.lower(ps, osd, batch_struct).compile()
    if kind == "prefill":
        j = jax.jit(make_prefill_step(cfg, par), in_shardings=(psh, bsh))
        return j.lower(ps, batch_struct).compile()
    cache = model.cache_specs(cfg, shape.batch, shape.seq)
    from repro.launch.sharding import cache_partition
    csh = to_shardings(mesh, cache_partition(cfg, par, cache))
    j = jax.jit(make_decode_step(cfg, par), in_shardings=(psh, bsh, csh),
                donate_argnums=(2,))
    return j.lower(ps, batch_struct, cache).compile()


def profile(arch: str, shape: str, multi_pod: bool = False, sp: bool = False,
            top: int = 10, baseline: str | None = None):
    cfg = get_arch(arch)
    compiled = _compile_cell(cfg, shape, multi_pod, sp)
    txt = compiled.as_text()
    cost = hc.analyze_hlo(txt)
    comps = hc._parse_computations(txt)

    print(f"== {arch} x {shape} x "
          f"{'2x16x16' if multi_pod else '16x16'}{' +sp' if sp else ''} ==")
    print(f"dot flops/dev {cost.flops:.3e}  bytes/dev {cost.bytes:.3e}  "
          f"coll/dev {cost.coll_bytes:.3e}")
    print(f"t_comp {cost.flops/197e12:.3f}s  t_mem {cost.bytes/819e9:.3f}s  "
          f"t_coll {cost.coll_bytes/50e9:.3f}s")
    if baseline:
        b = json.load(open(baseline))
        rf = b["roofline"]
        print(f"vs baseline: t_mem {rf['t_memory_s']:.2f}->"
              f"{cost.bytes/819e9:.2f} "
              f"({rf['t_memory_s']/(cost.bytes/819e9+1e-12):.1f}x), "
              f"t_coll {rf['t_collective_s']:.2f}->{cost.coll_bytes/50e9:.2f}")

    print("\n-- top buffers --")
    big = []
    for cname, lines in comps.items():
        for ln in lines:
            m = hc._OP_LINE.match(ln)
            if m:
                b = hc._shape_bytes(m.group(2))
                if b > 50e6:
                    big.append((b, m.group(3), m.group(2)[:60], cname[:40]))
    for b, k, t, cn in sorted(big, reverse=True)[:top]:
        print(f"  {b/2**30:7.2f}GiB  {k:<22s} {t}  in {cn}")

    print("\n-- while loops (body x trips) --")
    for cname, lines in comps.items():
        for ln in lines:
            if " while(" not in ln:
                continue
            body = re.search(r"body=%?([\w\.\-]+)", ln)
            cond = re.search(r"condition=%?([\w\.\-]+)", ln)
            trips = hc._trip_count(ln, comps.get(cond.group(1), [])
                                   if cond else [])
            print(f"  trips={trips:<5d} body={body.group(1)[:60]} "
                  f"(in {cname[:40]})")

    print("\n-- bytes by op kind --")
    for k, v in sorted(cost.bytes_by_kind.items(), key=lambda kv: -kv[1])[:top]:
        print(f"  {v/1e12:8.2f} TB  {k}")
    print("\n-- collectives --")
    for k, v in sorted(cost.coll_by_kind.items(), key=lambda kv: -kv[1]):
        print(f"  {v/2**30:8.1f} GiB  {k}")
    return cost


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--baseline")
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()
    profile(args.arch, args.shape, args.multipod, args.sp, args.top,
            args.baseline)


if __name__ == "__main__":
    main()
