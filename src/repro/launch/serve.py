"""Batched serving driver: prefill + greedy decode, with the DS-CIM
approximate-MVM path as a first-class serving option (--dscim).

DS-CIM modes map to DSCIMLinear backends (core/dscim_layer.py):
  exact        — int8 adder-tree baseline (DCIM)
  lut          — bit-exact DS-CIM emulation (joint-count LUT, the oracle)
  kernel       — the serving hot path: fused single-launch Pallas kernel
                 (kernels/dscim_fused.py) — all quantization windows, sign
                 corrections and dequant scales in one launch, batch dims
                 on a batch grid axis, no (M, nw, N) psum in HBM
  paper_inject — paper-style per-output error injection (fast)
A '+attn' mode suffix (e.g. kernel+attn:dscim1:256) additionally routes the
attention projections through the macro.

Prepare-once weights (default, --no-prepare to A/B): before jitting the
steps, every DS-CIM-eligible matrix is converted to a resident window-packed
int8 ``QuantizedLinearWeight`` (launch/steps.py prepare_serving_params) —
the software twin of the CIM array's static int8 storage.  The jitted decode
step then quantizes activations only; per-token weight re-quantization, the
old hot-path behavior, is gone from the HLO.  Outputs are bit-identical to
the per-call path under float32 compute (the reduced/serve-test configs);
under bfloat16 compute the per-call path quantizes the *cast* weights while
prepare-once quantizes the f32 originals — prepared is the more faithful of
the two (no double rounding), matching the hardware flow.  Multi-chip: the
prepared planes + scales shard on N over the 'model' mesh axis
(kernels/dscim_fused.py dscim_fused_mvm_sharded, launch/sharding.py
qweight_specs).

The serve report compares greedy tokens + logit RMSE against the float
path, which is the model-level reproduction of the paper's Table II
methodology on our own checkpoints.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                prepare_serving_params)
from repro.models import get_model

__all__ = ["serve_batch", "main"]


def serve_batch(cfg, params, prompts: np.ndarray, n_tokens: int,
                par=None, prepare: bool = True):
    """prompts (B, S) int32 -> generated (B, n_tokens) int32, logits list.

    ``prepare``: quantize DS-CIM-eligible weights once before jitting
    (no-op when cfg.dscim is 'off'/'float'); pass False to A/B the legacy
    per-call weight-quantization path (bit-identical under f32 compute;
    see the module docstring for the bf16-compute caveat)."""
    model = get_model(cfg)
    if prepare:
        params = prepare_serving_params(cfg, params, par)
    capacity = prompts.shape[1] + n_tokens
    prefill = jax.jit(make_prefill_step(cfg, par, capacity=capacity))
    decode = jax.jit(make_decode_step(cfg, par), donate_argnums=(2,))
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out, logit_trace = [tok], [logits]
    for _ in range(n_tokens - 1):
        tok, cache = decode(params, {"token": tok}, cache)
        out.append(tok)
    return np.stack([np.asarray(t) for t in out], axis=1), logit_trace


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--dscim", default="off",
                    help="off | <mode>[+attn]:<variant>:<L>  e.g. "
                         "kernel:dscim1:256 (fused Pallas hot path) or "
                         "lut:dscim1:256 (oracle)")
    ap.add_argument("--no-prepare", action="store_true",
                    help="keep float weights and re-quantize per call "
                         "(legacy hot path; default is prepare-once int8)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)

    t0 = time.time()
    base_tokens, base_logits = serve_batch(cfg, params, prompts, args.tokens)
    dt = time.time() - t0
    tps = args.batch * args.tokens / dt
    print(f"[serve] float path: {tps:.1f} tok/s "
          f"(batch={args.batch}, {args.tokens} steps)")

    if args.dscim != "off":
        import dataclasses
        cfg2 = dataclasses.replace(cfg, dscim=args.dscim)
        t0 = time.time()
        ds_tokens, ds_logits = serve_batch(cfg2, params, prompts, args.tokens,
                                           prepare=not args.no_prepare)
        dt = time.time() - t0
        agree = float((ds_tokens == base_tokens).mean())
        rmse = float(jnp.sqrt(jnp.mean(
            (ds_logits[0] - base_logits[0]) ** 2)))
        print(f"[serve] dscim={args.dscim}: {args.batch*args.tokens/dt:.1f} "
              f"tok/s, token agreement {agree:.3f}, "
              f"prefill logit RMSE {rmse:.4f}")
    return 0


if __name__ == "__main__":
    main()
