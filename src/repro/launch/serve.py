"""Batched serving driver: device-resident generation with the DS-CIM
approximate-MVM path as a first-class serving option (--dscim).

Generation is **scanned** by default: ``serve_batch`` builds one jitted
``generate`` (launch/steps.py ``make_generate_fn``) that runs prefill plus
an (n_tokens-1)-step ``lax.scan`` of decode steps on device — the host
dispatches exactly once per request instead of once per token, the KV
cache lives in the scan carry (buffers reused in place, never copied back
to host), and tokens accumulate on device.  The legacy host loop (one
jitted decode dispatch per token, cache donated between calls) is kept
behind ``scan=False`` as the dispatch-overhead A/B; benchmarks/serve_bench
records both as tok/s trajectory rows.

DS-CIM modes map to DSCIMLinear backends (core/dscim_layer.py):
  exact        — int8 adder-tree baseline (DCIM)
  lut          — bit-exact DS-CIM emulation (joint-count LUT, the oracle)
  kernel       — the serving hot path: fused single-launch Pallas kernel
                 (kernels/dscim_fused.py) — all quantization windows, sign
                 corrections and dequant scales in one launch, batch dims
                 on a batch grid axis, no (M, nw, N) psum in HBM; decode
                 shapes get pad-free skinny-M tiles from the checked-in
                 autotune cache (kernels/autotune.py)
  paper_inject — paper-style per-output error injection (fast)
A '+attn' mode suffix (e.g. kernel+attn:dscim1:256) additionally routes the
attention projections through the macro.

Prepare-once weights (default, --no-prepare to A/B): before jitting, every
DS-CIM-eligible matrix — including the MoE shared expert, also under a
mesh — is converted to a resident window-packed int8
``QuantizedLinearWeight`` (launch/steps.py prepare_serving_params), the
software twin of the CIM array's static int8 storage.  The jitted loop
then quantizes activations only.  Outputs are bit-identical to the
per-call path under float32 compute; under bfloat16 compute prepared is
the more faithful of the two (no double rounding of cast weights).

Multi-chip (--mesh, e.g. --mesh model=4): ``serve_batch`` takes a
ParallelCtx (launch/mesh.py ``parallel_ctx_from_spec``), places the
prepared params by launch/sharding.py rules — int8 planes + per-window
scales N-sharded over 'model' (``qweight_specs``), prepared shared
experts replicated — and the whole scanned loop runs under the mesh: the
kernel mode routes through ``dscim_fused_mvm_sharded`` (shard_map; windows
stay chip-local on K, no collective in the MVM) with no per-token host
sync anywhere.  Bit-identical to single-device serving.

The serve report compares greedy tokens + logit RMSE against the float
path, which is the model-level reproduction of the paper's Table II
methodology on our own checkpoints.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.steps import (make_decode_step, make_generate_fn,
                                make_prefill_step, prepare_serving_params)
from repro.models import get_model

__all__ = ["serve_batch", "main"]


def serve_batch(cfg, params, prompts: np.ndarray, n_tokens: int,
                par=None, prepare: bool = True, scan: bool = True,
                trace_logits: bool = False):
    """prompts (B, S) int32 -> generated (B, n_tokens) int32, logits list.

    ``par``: ParallelCtx for multi-chip serving — params are placed by the
    launch/sharding.py rules (prepared qweights N-sharded over 'model')
    and the whole generation loop runs under the mesh.
    ``prepare``: quantize DS-CIM-eligible weights once before jitting
    (no-op when cfg.dscim is 'off'/'float'); pass False to A/B the legacy
    per-call weight-quantization path (bit-identical under f32 compute;
    see the module docstring for the bf16-compute caveat).
    ``scan``: device-resident scanned generation (default — one dispatch
    per request); False runs the legacy host loop (one dispatch per
    token, cache donated between steps).
    ``trace_logits``: also return the per-step logit trace (off the hot
    path by default: the returned list then holds only prefill logits)."""
    if prepare:
        params = prepare_serving_params(cfg, params, par)
    if par is not None:
        from repro.launch.sharding import param_specs, to_shardings
        params = jax.device_put(
            params, to_shardings(par.mesh, param_specs(cfg, par, params)))
    batch = {"tokens": jnp.asarray(prompts)}
    if scan:
        generate = make_generate_fn(cfg, par, n_tokens,
                                    trace_logits=trace_logits)
        tokens, logits = generate(params, batch)
        trace = list(np.asarray(logits)) if trace_logits else [logits]
        return np.asarray(tokens), trace
    # legacy host loop (dispatch-overhead A/B baseline)
    capacity = prompts.shape[1] + n_tokens
    prefill = jax.jit(make_prefill_step(cfg, par, capacity=capacity))
    if trace_logits:
        # per-step logits ride along so the two drivers A/B the full trace
        decode_lg = jax.jit(make_decode_step(cfg, par, return_logits=True),
                            donate_argnums=(2,))
    else:
        decode = jax.jit(make_decode_step(cfg, par), donate_argnums=(2,))
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out, logit_trace = [tok], [logits]
    for _ in range(n_tokens - 1):
        if trace_logits:
            tok, logits, cache = decode_lg(params, {"token": tok}, cache)
            logit_trace.append(logits)
        else:
            tok, cache = decode(params, {"token": tok}, cache)
        out.append(tok)
    return np.stack([np.asarray(t) for t in out], axis=1), logit_trace


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--dscim", default="off",
                    help="off | <mode>[+attn]:<variant>:<L>  e.g. "
                         "kernel:dscim1:256 (fused Pallas hot path) or "
                         "lut:dscim1:256 (oracle)")
    ap.add_argument("--no-prepare", action="store_true",
                    help="keep float weights and re-quantize per call "
                         "(legacy hot path; default is prepare-once int8)")
    ap.add_argument("--host-loop", action="store_true",
                    help="legacy one-dispatch-per-token host loop instead "
                         "of the scanned device-resident generate (A/B)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="serve under a mesh, e.g. 'model=4' or "
                         "'data=2,model=4' (needs that many jax devices; "
                         "prepared qweights shard N over 'model')")
    ap.add_argument("--tune", action="store_true",
                    help="consult the fused-kernel tile autotuner (the "
                         "checked-in cache makes this a lookup for the "
                         "serving decode shapes)")
    args = ap.parse_args(argv)

    if args.tune:
        import os
        os.environ["REPRO_DSCIM_TUNE"] = "1"
    par = None
    if args.mesh:
        from repro.launch.mesh import parallel_ctx_from_spec
        par = parallel_ctx_from_spec(args.mesh)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)

    mode = "host-loop" if args.host_loop else "scanned"
    t0 = time.time()
    base_tokens, base_logits = serve_batch(cfg, params, prompts, args.tokens,
                                           par=par, scan=not args.host_loop)
    dt = time.time() - t0
    tps = args.batch * args.tokens / dt
    print(f"[serve] float path ({mode}"
          f"{', mesh ' + args.mesh if args.mesh else ''}): {tps:.1f} tok/s "
          f"(batch={args.batch}, {args.tokens} steps)")

    if args.dscim != "off":
        import dataclasses
        cfg2 = dataclasses.replace(cfg, dscim=args.dscim)
        t0 = time.time()
        ds_tokens, ds_logits = serve_batch(cfg2, params, prompts, args.tokens,
                                           par=par,
                                           prepare=not args.no_prepare,
                                           scan=not args.host_loop)
        dt = time.time() - t0
        agree = float((ds_tokens == base_tokens).mean())
        rmse = float(jnp.sqrt(jnp.mean(
            (ds_logits[0] - base_logits[0]) ** 2)))
        print(f"[serve] dscim={args.dscim}: {args.batch*args.tokens/dt:.1f} "
              f"tok/s, token agreement {agree:.3f}, "
              f"prefill logit RMSE {rmse:.4f}")
    return 0


if __name__ == "__main__":
    main()
