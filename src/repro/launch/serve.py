"""Batched serving driver: device-resident generation with the DS-CIM
approximate-MVM path as a first-class serving option (--dscim).

Generation is **scanned** by default: ``serve_batch`` builds one jitted
``generate`` (launch/steps.py ``make_generate_fn``) that runs prefill plus
up to (n_tokens-1) decode steps on device — the host dispatches exactly
once per request instead of once per token, the KV cache lives in the
loop carry (buffers reused in place, never copied back to host), and
tokens accumulate on device.  The legacy host loop (one jitted decode
dispatch per token, cache donated between calls) is kept behind
``scan=False`` as the dispatch-overhead A/B; benchmarks/serve_bench
records both as tok/s trajectory rows.

Only-live-work serving (ISSUE 4):

* **EOS early exit** (``eos_id=...`` / ``--eos``): the scanned loop
  becomes a ``lax.while_loop`` that stops the moment every slot has
  emitted EOS (or hit its per-slot ``max_new`` budget).  Finished slots
  are done-masked — their cache position stops advancing, their tokens
  pin to ``pad_id`` — so completion is ragged, and no decode steps run
  past the last live slot.
* **Sampling in the scan** (``sample=...`` / ``--temp --top-k --top-p``):
  greedy argmax remains the default; 'temp:<t>', 'topk:<k>[:<t>]' and
  'topp:<p>[:<t>]' (nucleus) draw inside the jitted loop with the PRNG
  key riding the carry (one split per step — the while and scan drivers
  sample identically).
* **Int8 paged KV cache** (``kv='int8'`` / ``--kv int8``): decode reads
  an int8 block-paged cache with per-page per-kv-head scales
  (core/kvcache.py) — ~4x fewer resident decode cache bytes, dequant
  fused into the paged flash attention inner loop, capacity decoupled
  from request length via the page table.  Since ISSUE 5 the read loop
  is a single-launch Pallas kernel for 'kernel' dscim modes
  (kernels/paged_attention.py; ``--paged-attn kernel|jnp`` /
  ``REPRO_PAGED_ATTN`` forces either path — the jnp gather scan stays
  the reference).
* **Continuous batching** (``serve_continuous`` / ``--continuous``): a
  scheduler above the scanned loop — requests are admitted into freed
  slots between fixed-size scan segments (launch/steps.py
  ``make_segment_fn``/``make_admit_fn``), carries (cache, per-slot
  positions, done mask, RNG) persist across segments, pages are
  allocated at admission and recycled at completion, and throughput is
  reported per *live* slot-step so occupancy is visible.
* **Self-speculative decoding** (``spec='dscim2:<k>'`` / ``--spec``,
  ISSUE 7): the *same* prepared weights run twice — k greedy draft
  tokens through the cheaper stochastic estimator (dscim2/L64 or
  dscim1/L256; the paper's two operating points), then one batched
  verify forward over the k+1-token window through the serving
  estimator, accepting drafts by the standard speculative rule.  The
  whole draft/verify/accept window lives inside the device-resident
  loop (``lax.while_loop`` / segment scan carry — never a host
  round-trip per window), the KV cache (dense float and int8 paged)
  follows a write-then-rollback discipline for provisional draft
  positions, and greedy emission is **bitwise-identical** to non-spec
  greedy serving; sampled emission replays the carried PRNG key chain
  (replay-deterministic).  Interaction contract (what the flag means
  next to the ISSUE 6 fault-tolerance knobs):

  - **"step" accounting / deadlines** — one window *attempts* k+1
    verifier positions, so under ``--spec`` a segment advances the
    global step ledger by ``seg_len * (k+1)``: drafted-but-rejected
    positions count toward ``deadline_steps``.  A request therefore
    never outlives the deadline it would have had without speculation
    (rejections only spend budget faster); deadline checks stay at
    segment boundaries.
  - **eviction / re-admission** — rollback happens inside the window
    (before the segment returns), so evicted slots park *committed*
    state only; page grants are sized with +k headroom at admission and
    pages are never allocated, freed, or leaked mid-window.
  - **watchdog / quarantine** — the exact-mode probe compares against
    the segment's first-window *verify* logits at position 0, i.e. it
    probes the verifier estimator on exactly the (token, cache) inputs
    it re-decodes; the drafter is never probed (a bad drafter can only
    cost acceptance rate, never output quality).  A request whose
    verify path trips the watchdog is quarantined and re-served down
    the usual ladder (dscim2 -> dscim1 -> exact) **without
    speculation** — escalation is about trust, so the re-serve takes
    the plain verified path and the request still ends ``'ok'`` (or
    ``'quarantined'`` only if even exact re-serving fails its twin).

DS-CIM modes map to DSCIMLinear backends (core/dscim_layer.py):
  exact        — int8 adder-tree baseline (DCIM)
  lut          — bit-exact DS-CIM emulation (joint-count LUT, the oracle)
  kernel       — the serving hot path: fused single-launch Pallas kernel
                 (kernels/dscim_fused.py)
  paper_inject — paper-style per-output error injection (fast)
A '+attn' mode suffix (e.g. kernel+attn:dscim1:256) additionally routes the
attention projections through the macro.

Prepare-once weights (default, --no-prepare to A/B) and multi-chip meshes
(--mesh, e.g. --mesh model=4) behave as in PR 2/3: prepared int8 planes
shard N over 'model', the whole loop runs under the mesh, bit-identical
to single-device serving.  The paged KV pool shards over the DP axes like
the request batch (launch/sharding.py ``cache_partition``).

The serve report compares greedy tokens + logit RMSE against the float
path, which is the model-level reproduction of the paper's Table II
methodology on our own checkpoints.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.steps import (make_decode_step, make_generate_fn,
                                make_prefill_step, prepare_serving_params)
from repro.models import get_model

__all__ = ["serve_batch", "serve_continuous", "logit_drift_rmse", "main"]


def _place(cfg, params, par, prepare):
    if prepare:
        params = prepare_serving_params(cfg, params, par)
    if par is not None:
        from repro.launch.sharding import param_specs, to_shardings
        params = jax.device_put(
            params, to_shardings(par.mesh, param_specs(cfg, par, params)))
    return params


def serve_batch(cfg, params, prompts: np.ndarray, n_tokens: int,
                par=None, prepare: bool = True, scan: bool = True,
                trace_logits: bool = False, eos_id: int | None = None,
                sample: str = "greedy", kv: str = "float",
                page_size: int = 8, max_new=None, rng_seed: int = 0,
                paged_attn: str = "auto", spec: str | None = None,
                spec_stats: bool = False):
    """prompts (B, S) int32 -> generated (B, n_tokens) int32, logits list.

    ``par``: ParallelCtx for multi-chip serving — params are placed by the
    launch/sharding.py rules (prepared qweights N-sharded over 'model')
    and the whole generation loop runs under the mesh.
    ``prepare``: quantize DS-CIM-eligible weights once before jitting
    (no-op when cfg.dscim is 'off'/'float'); pass False to A/B the legacy
    per-call weight-quantization path.
    ``scan``: device-resident generation (default — one dispatch per
    request); False runs the legacy host loop (one dispatch per token,
    cache donated between steps; greedy/float-KV/fixed-length only).
    ``trace_logits``: also return the per-step logit trace (off the hot
    path by default: the returned list then holds only prefill logits).
    ``eos_id``: EOS early-exit — the loop becomes a ``lax.while_loop``
    that stops once every row is finished; tokens past a row's EOS are
    pinned to pad.  ``max_new`` ((B,) ints, optional) adds per-slot token
    budgets (counted including the first, prefill-sampled token).
    ``sample``: 'greedy' | 'temp:<t>' | 'topk:<k>[:<t>]' | 'topp:<p>[:<t>]'
    (``rng_seed`` seeds the in-scan PRNG key).
    ``kv``: 'float' (dense cache) | 'int8' (block-paged quantized cache,
    ``page_size`` tokens per page).
    ``paged_attn``: int8 read path — 'kernel' (fused Pallas paged
    attention) / 'jnp' (gather reference) pin it (and key the builder
    cache, so in-process A/Bs are safe); 'auto' follows cfg.dscim.
    ``spec``: '<variant>:<k>' self-speculative decoding (module
    docstring) — draft k tokens per window with the cheaper estimator,
    verify in one batched forward; greedy output is bitwise the non-spec
    output.  ``spec_stats=True`` additionally returns a third element
    ``{"windows": (B,), "emitted": (B,)}`` np.int32 — per-row verify
    windows and emitted tokens, whose ratio is accepted-tokens-per-verify
    (serve_bench's serve/spec_* rows)."""
    from repro.launch.steps import _parse_spec
    sp = _parse_spec(spec)
    params = _place(cfg, params, par, prepare)
    batch = {"tokens": jnp.asarray(prompts)}
    if max_new is not None:
        batch["max_new"] = jnp.asarray(max_new, jnp.int32)
        if eos_id is None:
            raise ValueError("max_new budgets need the early-exit variant; "
                             "pass eos_id (any id, e.g. -1, works)")
    if sample != "greedy":
        batch["rng"] = jax.random.PRNGKey(rng_seed)
    if scan:
        generate = make_generate_fn(cfg, par, n_tokens,
                                    trace_logits=trace_logits,
                                    eos_id=eos_id, sample=sample,
                                    kv=kv, page_size=page_size,
                                    paged_attn=paged_attn, spec=spec)
        if sp is not None:
            tokens, logits, sstats = generate(params, batch)
        else:
            tokens, logits = generate(params, batch)
            sstats = None
        trace = list(np.asarray(logits)) if trace_logits else [logits]
        if spec_stats:
            sstats = (None if sstats is None else
                      {k: np.asarray(v) for k, v in sstats.items()})
            return np.asarray(tokens), trace, sstats
        return np.asarray(tokens), trace
    # legacy host loop (dispatch-overhead A/B baseline)
    if eos_id is not None or sample != "greedy" or kv != "float" \
            or sp is not None:
        raise ValueError("the legacy host loop serves the greedy fixed-"
                         "length float-KV path only (scan=True for "
                         "eos/sampling/paged-KV/spec)")
    capacity = prompts.shape[1] + n_tokens
    prefill = jax.jit(make_prefill_step(cfg, par, capacity=capacity))
    if trace_logits:
        # per-step logits ride along so the two drivers A/B the full trace
        decode_lg = jax.jit(make_decode_step(cfg, par, return_logits=True),
                            donate_argnums=(2,))
    else:
        decode = jax.jit(make_decode_step(cfg, par), donate_argnums=(2,))
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out, logit_trace = [tok], [logits]
    for _ in range(n_tokens - 1):
        if trace_logits:
            tok, logits, cache = decode_lg(params, {"token": tok}, cache)
            logit_trace.append(logits)
        else:
            tok, cache = decode(params, {"token": tok}, cache)
        out.append(tok)
    return np.stack([np.asarray(t) for t in out], axis=1), logit_trace


def serve_continuous(cfg, params, prompts: np.ndarray, n_tokens: int, *,
                     slots: int = 4, seg_len: int = 4, max_new=None,
                     eos_id: int | None = None, sample: str = "greedy",
                     kv: str = "float", page_size: int = 8,
                     n_pages: int | None = None, par=None,
                     prepare: bool = True, rng_seed: int = 0,
                     paged_attn: str = "auto", spec: str | None = None,
                     deadline_steps=None,
                     deadline_s=None, priority=None, monitor=None,
                     injector=None, snapshot_every: int = 0,
                     max_replays: int = 3, watchdog=None,
                     integrity: str = "off", prefix_cache=False, log=print):
    """Continuous-batching scheduler: serve a queue of R requests through
    ``slots`` persistent decode slots.

    prompts (R, S) int32 — the request queue (fixed prompt length per
    scheduler; length bucketing is a follow-on).  Between fixed-size scan
    segments (``seg_len`` done-masked decode steps in one dispatch,
    launch/steps.py ``make_segment_fn``) the host admits waiting requests
    into freed slots with one jitted prefill each (``make_admit_fn``) —
    the KV cache, per-slot positions, done mask and RNG key persist across
    segments.  A request completes on EOS (``eos_id``) or its per-request
    budget (``max_new`` (R,), default ``n_tokens``), releasing its slot
    (and, for ``kv='int8'``, its physical pages — ``n_pages`` sizes the
    pool independently of slots x max_len) for the next admission.

    Returns (outputs, stats): ``outputs[r]`` is request r's np.int32 token
    array (<= its budget, ending at EOS if hit); ``stats`` records wall
    time, end-to-end tok/s over *useful* tokens (i.e. credited per live
    slot-step — dead/padded slot-steps earn nothing), batch occupancy
    = live slot-steps / total slot-steps, and the fault-tolerance
    counters below.

    Failure semantics (ISSUE 6 — runtime/serving.py implements these; with
    every knob at its default the scheduler behaves exactly like the
    plain loop):

    * **Statuses.**  ``stats['status'][r]`` is always definite:
      ``'ok'`` — the request ran to EOS/budget (possibly after a failover
      replay, an eviction round trip, or a ladder escalation), its tokens
      are complete and trustworthy; ``'deadline'`` — cancelled at a
      segment boundary when its budget expired, ``outputs[r]`` holds the
      partial tokens generated so far (possibly none if it was still
      queued).  A client should treat ``'deadline'`` as retryable with a
      larger budget; tokens already returned remain valid prefixes.
    * **Deadlines.**  ``deadline_steps`` (R,) — global decode-step budget,
      deterministic and replay-safe (a negative entry = none);
      ``deadline_s`` (R,) — wall-clock seconds from the request's
      *admission* (<= 0 = none): a late admission gets its full budget
      and a queued request never wall-expires (PR 8 — previously
      measured from serve start, silently shrinking late admissions').
      Both are checked between segments only: a request can overrun by
      at most one segment (``seg_len`` steps).
    * **Eviction / re-admission** (``priority`` (R,), int8 KV only).
      When the page pool blocks an admission, live requests of *strictly*
      lower priority are preempted (lowest priority first, youngest on
      ties): their page contents are snapshotted host-side bit-exactly
      and the request re-enters mid-stream once pages free — under greedy
      decoding the round trip is bitwise-invisible in its output.
      ``stats['evictions']/['readmissions']/['evicted_requests']`` count
      the traffic.
    * **Snapshot / restore** (``snapshot_every`` > 0).  Full serve-state
      checkpoints (device pytree + scheduler bookkeeping + allocator)
      every N boundaries; recoverable failures (injected device loss,
      watchdog hangs) restore the latest snapshot and replay bit-
      identically, up to ``max_replays`` times (``stats['replays']``).
      ``injector`` (runtime/failover.py ``FailureInjector``) drives chaos
      tests — device loss, transient page-pool bit flips
      (``stats['corrupted_requests']``), persistent stuck-at macro faults.
    * **Accuracy watchdog + degradation ladder** (``monitor``, an
      ``AccuracyWatchdog``).  NaN/Inf logits are checked every segment;
      every ``probe_every`` segments an exact-mode decode of the same
      (token, cache) inputs bounds the serving path's logit drift.  A
      tripped request is quarantined (poisoned tokens discarded) and
      re-served from its prompt down the ladder dscim2 -> dscim1 ->
      exact (``stats['quarantined']/['escalations']``), each level
      verified against its exact twin before acceptance — so a returned
      ``'ok'`` is trustworthy even under estimator faults.  ``watchdog``
      (a runtime/watchdog.py ``Watchdog``) additionally wraps each
      segment for straggler/hang detection (``stats['stragglers']``).
    * **Prefix caching** (``prefix_cache``, int8 KV only, ISSUE 10).
      ``True``/'on': admissions run page-aligned chunked prefill and
      share physical pages across page-aligned prompt prefixes via the
      refcounted allocator + prefix-hash index — a hit skips prefill
      (and quantization) for the shared pages entirely, bitwise-
      identically to cold serving; 'cold' runs the same chunked path
      with sharing disabled (the drill's reference leg).
      ``stats['prefix']`` reports hits, hit tokens, pages deduped, and
      prefill positions computed vs total (docs/serving.md has the full
      operator contract).
    """
    from repro.runtime.serving import serve_continuous_ft
    params = _place(cfg, params, par, prepare)
    return serve_continuous_ft(
        cfg, params, prompts, n_tokens, slots=slots, seg_len=seg_len,
        max_new=max_new, eos_id=eos_id, sample=sample, kv=kv,
        page_size=page_size, n_pages=n_pages, par=par, rng_seed=rng_seed,
        paged_attn=paged_attn, spec=spec, deadline_steps=deadline_steps,
        deadline_s=deadline_s, priority=priority, monitor=monitor,
        injector=injector, snapshot_every=snapshot_every,
        max_replays=max_replays, watchdog=watchdog, integrity=integrity,
        prefix_cache=prefix_cache, log=log)


def _sample_spec(args) -> str:
    # `is not None` so --temp 0 reaches the sampler's t > 0 validation
    # instead of silently degrading to greedy / t=1
    if args.top_k is not None and args.top_p is not None:
        raise SystemExit("--top-k and --top-p are mutually exclusive")
    if args.top_k is not None:
        return f"topk:{args.top_k}:" \
               f"{args.temp if args.temp is not None else 1.0}"
    if args.top_p is not None:
        return f"topp:{args.top_p}:" \
               f"{args.temp if args.temp is not None else 1.0}"
    if args.temp is not None:
        return f"temp:{args.temp}"
    return "greedy"


def _useful_lengths(tokens: np.ndarray, eos_id: int | None) -> np.ndarray:
    """Per-row token count up to and including the first EOS."""
    n = tokens.shape[1]
    if eos_id is None:
        return np.full((tokens.shape[0],), n)
    out = []
    for row in tokens:
        hits = np.nonzero(row == eos_id)[0]
        out.append(int(hits[0]) + 1 if len(hits) else n)
    return np.asarray(out)


def _useful_tokens(tokens: np.ndarray, eos_id: int | None) -> int:
    """Tokens up to and including each row's first EOS — the early-exit
    report must not credit the pad tokens past it."""
    return int(_useful_lengths(tokens, eos_id).sum())


def logit_drift_rmse(tokens_ref, tokens_alt, logits_ref, logits_alt):
    """RMSE between two drivers' per-step logit traces on the teacher-
    matched prefix: per row, steps up to and including the first token
    divergence — past it the drivers feed different tokens back, so the
    comparison would measure feedback divergence, not the perturbation
    under test (e.g. int8 KV quantization).  ``logits_*`` are the
    trace_logits stacks ((n_steps, B, V) after np.stack), ``tokens_*``
    the (B, n_steps) token outputs.  Shared by benchmarks/serve_bench.py
    and the acceptance test so the metric can't drift between them."""
    lf, lq = np.stack(logits_ref), np.stack(logits_alt)
    tokens_ref, tokens_alt = np.asarray(tokens_ref), np.asarray(tokens_alt)
    n = tokens_ref.shape[1]
    errs = []
    for b in range(tokens_ref.shape[0]):
        mism = np.nonzero(tokens_ref[b] != tokens_alt[b])[0]
        end = mism[0] + 1 if len(mism) else n
        errs.append(((lf[:end, b] - lq[:end, b]) ** 2).ravel())
    return float(np.sqrt(np.mean(np.concatenate(errs))))


def _agreement(a: np.ndarray, b: np.ndarray, eos_id: int | None) -> float:
    """Token agreement over the reference rows' useful prefixes only —
    pad-vs-pad positions past EOS would otherwise inflate the metric."""
    lens = _useful_lengths(b, eos_id)
    hits = sum(int((a[i, :l] == b[i, :l]).sum()) for i, l in enumerate(lens))
    return hits / max(int(lens.sum()), 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--dscim", default="off",
                    help="off | <mode>[+attn]:<variant>:<L>  e.g. "
                         "kernel:dscim1:256 (fused Pallas hot path) or "
                         "lut:dscim1:256 (oracle)")
    ap.add_argument("--no-prepare", action="store_true",
                    help="keep float weights and re-quantize per call "
                         "(legacy hot path; default is prepare-once int8)")
    ap.add_argument("--host-loop", action="store_true",
                    help="legacy one-dispatch-per-token host loop instead "
                         "of the scanned device-resident generate (A/B)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="serve under a mesh, e.g. 'model=4' or "
                         "'data=2,model=4' (needs that many jax devices; "
                         "prepared qweights shard N over 'model')")
    ap.add_argument("--eos", type=int, default=None, metavar="ID",
                    help="EOS token id: the scanned loop becomes a "
                         "lax.while_loop that exits once every row has "
                         "finished (done-masked ragged completion)")
    ap.add_argument("--temp", type=float, default=None,
                    help="temperature sampling inside the scan (default "
                         "greedy argmax)")
    ap.add_argument("--top-k", type=int, default=None,
                    help="top-k sampling inside the scan (combines with "
                         "--temp)")
    ap.add_argument("--top-p", type=float, default=None,
                    help="top-p (nucleus) sampling inside the scan: keep "
                         "the smallest probability mass >= p (combines "
                         "with --temp; exclusive with --top-k)")
    ap.add_argument("--spec", default=None, metavar="VARIANT:K",
                    help="self-speculative decoding, e.g. 'dscim2:4': "
                         "draft K tokens per window with the cheaper "
                         "estimator on the same prepared weights, verify "
                         "with one batched forward through --dscim; "
                         "greedy output is bitwise the non-spec output "
                         "(module docstring documents the deadline/"
                         "eviction/watchdog contract)")
    ap.add_argument("--paged-attn", choices=("auto", "kernel", "jnp"),
                    default="auto",
                    help="--kv int8 read path: the fused Pallas paged-"
                         "attention kernel or the jnp gather reference "
                         "(auto = kernel for 'kernel' dscim modes)")
    ap.add_argument("--kv", choices=("float", "int8"), default="float",
                    help="KV cache layout: dense float (default) or the "
                         "block-paged int8 cache (core/kvcache.py)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page for --kv int8")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: serve --requests prompts "
                         "through --batch persistent slots, admitting "
                         "between --segment-len step scan segments")
    ap.add_argument("--requests", type=int, default=8,
                    help="queue length for --continuous")
    ap.add_argument("--segment-len", type=int, default=4,
                    help="decode steps per scan segment for --continuous")
    ap.add_argument("--chaos", action="store_true",
                    help="run the self-verifying chaos drill "
                         "(runtime/serving.py chaos_drill): injected "
                         "device loss + page-pool bit flips + a stuck-at "
                         "macro fault + a deadline expiry over the fault-"
                         "tolerant scheduler, asserting the failure-"
                         "semantics contract end to end")
    ap.add_argument("--integrity", default="off", metavar="MODE",
                    help="serving integrity checks (runtime/integrity.py): "
                         "'off', 'verify' (every segment boundary) or "
                         "'scrub:<n>' (every n-th) — checksummed int8 KV "
                         "pages + prepared-weight plane digests with "
                         "targeted self-healing; requires --kv int8")
    ap.add_argument("--integrity-drill", action="store_true",
                    help="run the self-verifying integrity drill "
                         "(runtime/serving.py integrity_drill): injected "
                         "page-pool and weight-plane bit flips under "
                         "--integrity scrub:2 — asserts exact-coordinate "
                         "detection, surgical repair, and bitwise-"
                         "identical outputs vs the fault-free run")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share int8 KV pages across page-aligned prompt "
                         "prefixes on the --continuous run (refcounted "
                         "copy-on-write pages + prefix-hash index, "
                         "core/kvcache.py): hits skip prefill for the "
                         "shared pages, bitwise-identically; requires "
                         "--kv int8")
    ap.add_argument("--prefix-drill", action="store_true",
                    help="run the self-verifying prefix-cache drill "
                         "(runtime/serving.py prefix_drill): staggered "
                         "admissions with a shared system prompt, warm "
                         "vs cold legs — asserts bitwise parity, visible "
                         "page dedup, >40% prefill positions removed, "
                         "and a drained pool")
    ap.add_argument("--sampled-chaos", action="store_true",
                    help="arm a FailureInjector.sampled schedule (seeded "
                         "by --chaos-seed) on the --continuous run: device "
                         "losses + page/weight bit upsets; pairs with "
                         "--integrity to exercise detect/repair/replay "
                         "under randomized faults")
    ap.add_argument("--chaos-seed", type=int, default=0, metavar="SEED",
                    help="--chaos determinism pin: seeds the drill's "
                         "params/prompts so a CI chaos failure reproduces "
                         "exactly from the logged seed (default 0, the CI "
                         "seed)")
    ap.add_argument("--tune", action="store_true",
                    help="consult the fused-kernel tile autotuner (the "
                         "checked-in cache makes this a lookup for the "
                         "serving decode shapes)")
    args = ap.parse_args(argv)

    if args.chaos:
        from repro.runtime.serving import chaos_drill
        chaos_drill(args.arch, seed=args.chaos_seed)
        return 0
    if args.integrity_drill:
        from repro.runtime.serving import integrity_drill
        integrity_drill(args.arch, seed=args.chaos_seed)
        return 0
    if args.prefix_drill:
        from repro.runtime.serving import prefix_drill
        prefix_drill(args.arch, seed=args.chaos_seed)
        return 0
    if args.tune:
        import os
        os.environ["REPRO_DSCIM_TUNE"] = "1"
    par = None
    if args.mesh:
        from repro.launch.mesh import parallel_ctx_from_spec
        par = parallel_ctx_from_spec(args.mesh)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.dscim != "off":
        import dataclasses
        cfg_ds = dataclasses.replace(cfg, dscim=args.dscim)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    sample = _sample_spec(args)

    if args.continuous:
        cfgs = [("float", cfg)] + ([(args.dscim, cfg_ds)]
                                   if args.dscim != "off" else [])
        prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt_len),
                               dtype=np.int32)
        if args.prefix_cache:
            # a shared page-aligned "system prompt" over 3/4 of the queue
            # so the prefix index has something to hit
            shared = max(args.prompt_len // 2, args.page_size)
            prompts[:args.requests * 3 // 4, :shared] = prompts[0, :shared]
        # skewed per-request budgets exercise slot recycling
        budgets = rng.integers(max(2, args.tokens // 4), args.tokens + 1,
                               (args.requests,), dtype=np.int32)
        for tag, c in cfgs:
            injector = None
            snapshot_every = 0
            if args.sampled_chaos:
                from repro.core.qweights import split_dscim_mode
                from repro.runtime.failover import FailureInjector
                prepared = split_dscim_mode(
                    getattr(c, "dscim", "off"))[0] not in ("off", "float")
                # a fresh injector per leg: the fired-once set is stateful
                injector = FailureInjector.sampled(
                    args.chaos_seed, segments=8, slots=args.batch,
                    n_layers=c.n_layers, page_size=args.page_size,
                    device_losses=1, flips=2,
                    weight_paths=("layers/mlp/w_up",) if prepared else (),
                    weight_flip_count=1 if prepared else 0)
                snapshot_every = 1
            outs, stats = serve_continuous(
                c, params, prompts, args.tokens, slots=args.batch,
                seg_len=args.segment_len, max_new=budgets,
                eos_id=args.eos if args.eos is not None else -1,
                sample=sample, kv=args.kv, page_size=args.page_size,
                par=par, prepare=not args.no_prepare,
                paged_attn=args.paged_attn, spec=args.spec,
                injector=injector, snapshot_every=snapshot_every,
                integrity=args.integrity,
                prefix_cache=args.prefix_cache)
            extra = ""
            if stats.get("integrity"):
                ig = stats["integrity"]
                extra = (f", integrity: {ig['checks']} checks, "
                         f"{ig['page_mismatches']}p/"
                         f"{ig['weight_mismatches']}w mismatches, "
                         f"{ig['page_repairs'] + ig['weight_repairs']} "
                         f"repairs, {ig['replays']} replays")
            if stats.get("prefix"):
                pf = stats["prefix"]
                extra += (f", prefix: {pf['hits']}/{pf['lookups']} hits, "
                          f"{pf['pages_deduped']} pages deduped, "
                          f"{pf['prefill_positions_computed']}/"
                          f"{pf['prefill_positions_total']} prefill "
                          "positions computed")
            print(f"[serve-cb] {tag}: {stats['tok_s']:.1f} tok/s over "
                  f"{stats['useful_tokens']} useful tokens, occupancy "
                  f"{stats['occupancy']:.2f} "
                  f"({stats['live_slot_steps']}/{stats['slot_steps']} "
                  f"slot-steps live, "
                  f"{stats['segments']} segments of {args.segment_len}"
                  f"){extra}")
        return 0

    mode = "host-loop" if args.host_loop else "scanned"
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.time()
    base_tokens, base_logits = serve_batch(
        cfg, params, prompts, args.tokens, par=par, scan=not args.host_loop,
        eos_id=args.eos, sample=sample, kv=args.kv,
        page_size=args.page_size, paged_attn=args.paged_attn)
    dt = time.time() - t0
    useful = _useful_tokens(base_tokens, args.eos)
    tps = useful / dt
    print(f"[serve] float path ({mode}"
          f"{', mesh ' + args.mesh if args.mesh else ''}"
          f"{', kv=int8' if args.kv == 'int8' else ''}): {tps:.1f} tok/s "
          f"({useful} useful tokens, batch={args.batch}, "
          f"{args.tokens} steps)")

    if args.dscim != "off":
        t0 = time.time()
        out = serve_batch(
            cfg_ds, params, prompts, args.tokens, par=par,
            prepare=not args.no_prepare, scan=not args.host_loop,
            eos_id=args.eos, sample=sample, kv=args.kv,
            page_size=args.page_size, paged_attn=args.paged_attn,
            spec=args.spec, spec_stats=args.spec is not None)
        dt = time.time() - t0
        if args.spec is not None:
            ds_tokens, ds_logits, sstats = out
        else:
            ds_tokens, ds_logits = out
            sstats = None
        agree = _agreement(ds_tokens, base_tokens, args.eos)
        rmse = float(jnp.sqrt(jnp.mean(
            (ds_logits[0] - base_logits[0]) ** 2)))
        acc = ""
        if sstats is not None:
            tpv = (sstats["emitted"] - 1).sum() / max(
                int(sstats["windows"].sum()), 1)
            acc = f", {tpv:.2f} accepted tok/verify (--spec {args.spec})"
        print(f"[serve] dscim={args.dscim}: "
              f"{_useful_tokens(ds_tokens, args.eos) / dt:.1f} "
              f"tok/s, token agreement {agree:.3f}, "
              f"prefill logit RMSE {rmse:.4f}{acc}")
    return 0


if __name__ == "__main__":
    main()
