"""Thin HTTP shim over the asyncio serving router (runtime/router.py).

Stdlib-only (``asyncio.start_server`` + hand-rolled HTTP/1.1 — the
container adds no web framework): the router IS the product, this file
just maps its typed surface onto wire semantics so a curl/load-generator
can drive it.

* ``POST /v1/generate`` — body ``{"prompt": [ids], "max_new": n,
  "deadline_s"?, "deadline_steps"?, "priority"?}``.  Streams
  newline-delimited JSON (chunked transfer): ``{"token": id}`` per
  generated token, ``{"restart": true}`` when a quarantined request is
  re-served down the degradation ladder (previously streamed tokens are
  void), and a final ``{"status": "ok" | "deadline" | "cancelled" |
  "degraded"}``.  A typed ``Refused`` maps to a status code *before* any
  body streams: 429 + ``Retry-After`` (transient queue overload), 413
  (the request can never fit this router), 503 (draining).  Client
  disconnect mid-stream cancels the slot and recycles its pages.
* ``GET /healthz`` — 200 ``{"ok": true, "draining": ...}`` (503 while
  draining, so balancers stop routing here).
* ``GET /stats`` — the router's stats dict as JSON.

``SIGTERM``/``SIGINT`` trigger a graceful drain: admission refuses with
503, live requests stream to completion, then the listener closes.  The
full lifecycle (statuses, codes, drain/failover) is docs/serving.md.
"""
from __future__ import annotations

import asyncio
import json
import signal

import numpy as np

from repro.runtime.router import Refused, Router

__all__ = ["HttpFrontend", "main"]

_REASON_HTTP = {"queue": (429, "Too Many Requests"),
                "too_large": (413, "Payload Too Large"),
                "draining": (503, "Service Unavailable")}


def _resp_head(status: int, phrase: str, headers: dict) -> bytes:
    lines = [f"HTTP/1.1 {status} {phrase}"]
    lines += [f"{k}: {v}" for k, v in headers.items()]
    return ("\r\n".join(lines) + "\r\n\r\n").encode()


def _json_response(status: int, phrase: str, obj) -> bytes:
    body = (json.dumps(obj) + "\n").encode()
    return _resp_head(status, phrase, {
        "Content-Type": "application/json",
        "Content-Length": str(len(body)),
        "Connection": "close"}) + body


async def _read_request(reader) -> tuple[str, str, dict, bytes] | None:
    """Parse one HTTP/1.1 request (method, path, headers, body)."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
        return None
    lines = head.decode("latin1").split("\r\n")
    try:
        method, path, _ = lines[0].split(" ", 2)
    except ValueError:
        return None
    headers = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    body = b""
    n = int(headers.get("content-length", "0") or "0")
    if n:
        body = await reader.readexactly(n)
    return method, path, headers, body


class HttpFrontend:
    """Bind a Router to a TCP listener.  ``await serve()`` runs until a
    drain signal; ``request_drain()`` (wired to SIGTERM/SIGINT) starts a
    graceful shutdown."""

    def __init__(self, router: Router, host: str = "127.0.0.1",
                 port: int = 8080, log=print):
        self.router = router
        self.host = host
        self.port = port
        self.log = log
        self._server = None
        self._drain = asyncio.Event()

    def request_drain(self) -> None:
        self._drain.set()

    async def _stream_generate(self, writer, body: bytes) -> None:
        try:
            req = json.loads(body.decode() or "{}")
            prompt = np.asarray(req["prompt"], np.int32)
            handle = self.router.submit(
                prompt, int(req.get("max_new", 16)),
                deadline_s=req.get("deadline_s"),
                deadline_steps=req.get("deadline_steps"),
                priority=int(req.get("priority", 0)))
        except Refused as e:
            code, phrase = _REASON_HTTP[e.reason]
            hdr = {"Content-Type": "application/json",
                   "Connection": "close"}
            if e.retry_after is not None:
                hdr["Retry-After"] = str(max(1, int(np.ceil(e.retry_after))))
            body = (json.dumps({"status": "refused", "reason": e.reason,
                                "retry_after": e.retry_after}) + "\n"
                    ).encode()
            hdr["Content-Length"] = str(len(body))
            writer.write(_resp_head(code, phrase, hdr) + body)
            return
        except (KeyError, ValueError, TypeError) as e:
            writer.write(_json_response(400, "Bad Request",
                                        {"error": str(e)}))
            return
        writer.write(_resp_head(200, "OK", {
            "Content-Type": "application/x-ndjson",
            "Transfer-Encoding": "chunked",
            "Connection": "close"}))

        def chunk(obj) -> bytes:
            line = (json.dumps(obj) + "\n").encode()
            return f"{len(line):x}\r\n".encode() + line + b"\r\n"

        try:
            async for kind, val in handle.events():
                if kind == "token":
                    writer.write(chunk({"token": int(val)}))
                elif kind == "restart":
                    writer.write(chunk({"restart": True}))
                else:
                    writer.write(chunk({"status": val}))
                await writer.drain()
            writer.write(b"0\r\n\r\n")
        except (ConnectionResetError, BrokenPipeError):
            handle.cancel()            # client went away: recycle the slot

    async def _handle(self, reader, writer) -> None:
        try:
            parsed = await _read_request(reader)
            if parsed is None:
                return
            method, path, _, body = parsed
            if method == "POST" and path == "/v1/generate":
                await self._stream_generate(writer, body)
            elif method == "GET" and path == "/healthz":
                draining = self.router._draining
                writer.write(_json_response(
                    503 if draining else 200,
                    "Service Unavailable" if draining else "OK",
                    {"ok": not draining, "draining": draining}))
            elif method == "GET" and path == "/stats":
                writer.write(_json_response(200, "OK", self.router.stats()))
            else:
                writer.write(_json_response(404, "Not Found",
                                            {"error": f"no route {path}"}))
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def serve(self) -> None:
        await self.router.start()
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        addr = self._server.sockets[0].getsockname()
        self.log(f"[server] listening on {addr[0]}:{addr[1]}")
        await self._drain.wait()
        self.log("[server] drain requested: refusing admission, "
                 "finishing live requests")
        await self.router.close("drain")
        self._server.close()
        await self._server.wait_closed()
        self.log(f"[server] drained; final stats: {self.router.stats()}")


def main(argv=None):
    import argparse

    import jax

    from repro.configs import get_arch
    from repro.models import get_model

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--segment-len", type=int, default=4)
    ap.add_argument("--kv", choices=("float", "int8"), default="int8")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--buckets", default="8,16,32", metavar="S1,S2,...",
                    help="one-shot prefill prompt lengths (others chunk)")
    ap.add_argument("--chunk-len", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=256)
    ap.add_argument("--max-new-cap", type=int, default=64)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--dscim", default="off")
    ap.add_argument("--snapshot-every", type=int, default=0, metavar="N",
                    help="failover snapshot cadence in segments (0 = off)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.dscim != "off":
        import dataclasses
        cfg = dataclasses.replace(cfg, dscim=args.dscim)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))

    async def run():
        router = Router(cfg, params, slots=args.slots,
                        seg_len=args.segment_len, kv=args.kv,
                        page_size=args.page_size,
                        buckets=tuple(int(b) for b in
                                      args.buckets.split(",") if b),
                        chunk_len=args.chunk_len,
                        max_prompt=args.max_prompt,
                        max_new_cap=args.max_new_cap,
                        max_queue=args.max_queue,
                        snapshot_every=args.snapshot_every)
        front = HttpFrontend(router, args.host, args.port)
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGTERM, signal.SIGINT):
            loop.add_signal_handler(sig, front.request_drain)
        await front.serve()

    asyncio.run(run())


if __name__ == "__main__":
    main()
