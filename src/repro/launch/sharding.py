"""PartitionSpec rules: param tree -> spec tree, cache -> spec tree,
batch -> spec tree.  Conventions (DESIGN.md §5):

* TP ('model'): attention QKV / MLP up+gate column-split; O / down row-split;
  vocab-sharded embedding + lm_head; MoE experts (EP) + RWKV head projections.
* FSDP ('data'): every large matrix additionally sharded on its non-TP dim.
* DP ('pod','data'): batch dims; 'pod' replicates params (pure DP across
  pods — cross-pod traffic is gradient all-reduce only).

Rules are name-based over the flattened param tree, with divisibility
fallbacks (e.g. starcoder2's kv=4 heads can't split 16 ways -> cache shards
sequence instead; batch=1 long-context cells leave batch unsharded).

Prepared DS-CIM weights (core/qweights.py ``QuantizedLinearWeight``) get a
dedicated rule: the int8 window planes (*, nw, g, N) and per-window scales
(*, nw, N) both shard their trailing N (output-column) dim over the TP
'model' axis — the paper's multi-chip array banking: quantization windows
stay chip-local on K, output columns tile across chips.  The window dims
are never sharded (a window is one physical 128-row accumulation).
Exception: a prepared MoE *shared-expert* weight replicates instead — the
shard_map MoE body computes the dense shared expert locally per token
slice (models/lm.py), so its int8 planes must be whole on every device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.qweights import (QuantizedLinearWeight, path_str as _path_str,
                                 qweight_replicated_specs)
from repro.parallel import ParallelCtx

__all__ = ["param_specs", "batch_specs", "cache_partition", "to_shardings",
           "opt_state_specs", "qweight_specs"]

# name -> (spec for the trailing dims of the param, i.e. ignoring stacking)
# fsdp axis written as 'F', tensor axis as 'T'; stacking dims get None.
_RULES = [
    # generic transformer
    ("embed", ("T", "F")),
    ("lm_head", ("F", "T")),
    ("attn/wq", ("F", "T")), ("attn/wk", ("F", "T")), ("attn/wv", ("F", "T")),
    ("attn/wo", ("T", "F")),
    ("shared_attn/wq", ("F", "T")), ("shared_attn/wk", ("F", "T")),
    ("shared_attn/wv", ("F", "T")), ("shared_attn/wo", ("T", "F")),
    ("mlp/w_up", ("F", "T")), ("mlp/w_gate", ("F", "T")),
    ("mlp/w_down", ("T", "F")),
    # moe (must match the shard_map in_specs in models/lm.py)
    ("moe/router", (None, None)),
    ("moe/experts/w_gate", ("T", None, "F")),
    ("moe/experts/w_up", ("T", None, "F")),
    ("moe/experts/w_down", ("T", "F", None)),
    ("moe/shared/w_gate", (None, "F")), ("moe/shared/w_up", (None, "F")),
    ("moe/shared/w_down", ("F", None)),
    # rwkv6
    ("/wr", ("F", "T")), ("/wk", ("F", "T")), ("/wv", ("F", "T")),
    ("/wg", ("F", "T")), ("/wo", ("T", "F")),
    ("/wk_ffn", ("F", "T")), ("/wv_ffn", ("T", "F")), ("/wr_ffn", ("F", "T")),
    ("maa_w1", ("F", None)), ("decay_w1", ("F", None)),
    # mamba2 / zamba
    ("mamba/wz", ("F", "T")), ("mamba/wx", ("F", "T")),
    ("mamba/wB", ("F", None)), ("mamba/wC", ("F", None)),
    ("mamba/wdt", ("F", None)), ("mamba/wo", ("T", "F")),
]


def _spec_for(path: str, ndim: int, shape, fsdp: str, tp: str, mesh):
    for pat, dims in _RULES:
        if pat in path:
            lead = ndim - len(dims)
            spec = [None] * lead
            for ax, d in zip(dims, shape[lead:]):
                if ax == "F":
                    spec.append(fsdp if d % mesh.shape[fsdp] == 0 else None)
                elif ax == "T":
                    spec.append(tp if d % mesh.shape[tp] == 0 else None)
                else:
                    spec.append(None)
            return P(*spec)
    return P()  # small params (norms, biases, u, mu, A_log...) replicated


def qweight_specs(qw: QuantizedLinearWeight, tp: str, mesh
                  ) -> QuantizedLinearWeight:
    """Spec subtree for one prepared weight: N over the TP axis (divisible),
    windows/groups/stack dims replicated.  Returned as a
    QuantizedLinearWeight whose children are PartitionSpecs, so the spec
    tree keeps the params' treedef (device_put / jit in_shardings work
    unchanged)."""
    t = tp if qw.q.shape[-1] % mesh.shape[tp] == 0 else None
    return QuantizedLinearWeight(
        P(*([None] * (qw.q.ndim - 1)), t),
        P(*([None] * (qw.scale.ndim - 1)), t),
        qw.k_orig, qw.group_k)


def param_specs(cfg: ArchConfig, par: ParallelCtx, params_struct):
    """PartitionSpec pytree matching the (Shape/DtypeStruct or real) params."""
    fsdp = par.dp_axes[-1]
    tp = par.tp_axis

    def assign(path, leaf):
        if isinstance(leaf, QuantizedLinearWeight):
            if "moe/shared" in _path_str(path):
                # prepared shared expert: replicate the resident int8
                # planes — the shard_map MoE body (models/lm.py) computes
                # the dense-on-every-token shared expert locally with no
                # FSDP gather, bit-identical to single-device serving
                return qweight_replicated_specs(leaf)
            return qweight_specs(leaf, tp, par.mesh)
        return _spec_for(_path_str(path), leaf.ndim, leaf.shape, fsdp, tp,
                         par.mesh)
    return jax.tree_util.tree_map_with_path(
        assign, params_struct,
        is_leaf=lambda x: isinstance(x, QuantizedLinearWeight))


def opt_state_specs(pspecs):
    """AdamW m/v mirror the params; count is replicated."""
    return {"m": pspecs, "v": pspecs, "count": P()}


def _div(n, axes, mesh) -> bool:
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0


def batch_specs(cfg: ArchConfig, par: ParallelCtx, batch_struct):
    """Shard batch dims over DP where divisible; everything else replicated."""
    dp = par.dp_axes

    def assign(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 0
        first = dp if (leaf.ndim and _div(b, dp, par.mesh)) else None
        return P(first, *([None] * max(leaf.ndim - 1, 0)))
    return jax.tree_util.tree_map_with_path(assign, batch_struct)


def cache_partition(cfg: ArchConfig, par: ParallelCtx, cache_struct):
    """KV caches / recurrent states.

    k/v (L, B, T, kvH, hd): batch->DP (if divisible); kv heads -> TP when
    divisible, else the sequence axis takes TP (GQA with few kv heads).
    Recurrent states: heads->TP when divisible.
    """
    dp = par.dp_axes
    tp = par.tp_axis
    tp_n = par.mesh.shape[tp]

    def assign(path, leaf):
        p = _path_str(path)
        shp = leaf.shape
        if p.endswith("pos"):
            # scalar (lockstep decode) replicates; per-slot (B,) positions
            # shard over DP with the request batch
            if getattr(leaf, "ndim", 0) == 1:
                return P(dp if _div(shp[0], dp, par.mesh) else None)
            return P()
        # int8 block-paged KV cache (core/kvcache.py): the physical page
        # pool shards over the DP axes like the request batch (slot-major
        # allocation keeps a slot's pages on its own DP shard); kv heads
        # take TP when divisible, pages/window dims never split
        if "k_pages" in p or "v_pages" in p:      # (L, P, ps, KV, HD)
            pspec = dp if _div(shp[1], dp, par.mesh) else None
            return P(None, pspec, None,
                     tp if shp[3] % tp_n == 0 else None, None)
        if "k_scale" in p or "v_scale" in p:      # (L, P, KV)
            pspec = dp if _div(shp[1], dp, par.mesh) else None
            return P(None, pspec, tp if shp[2] % tp_n == 0 else None)
        if "k_tail" in p or "v_tail" in p:        # (L, B, ps, KV, HD)
            bspec = dp if _div(shp[1], dp, par.mesh) else None
            return P(None, bspec, None,
                     tp if shp[3] % tp_n == 0 else None, None)
        if "page_table" in p:                     # (B, MP)
            return P(dp if _div(shp[0], dp, par.mesh) else None, None)
        if p in ("k", "v") or p.endswith("/k") or p.endswith("/v"):
            L, B, T, KV, HD = shp
            bspec = dp if _div(B, dp, par.mesh) else None
            taxes = []          # axes assigned to the sequence dim
            if bspec is None:
                taxes += list(dp)   # idle DP axes take the sequence
            kvspec = tp if KV % tp_n == 0 else None
            if kvspec is None:
                taxes.append(tp)    # few kv heads: TP shards sequence too
            if taxes and not _div(T, tuple(taxes), par.mesh):
                taxes = []
            return P(None, bspec, tuple(taxes) if taxes else None,
                     kvspec, None)
        if "wkv" in p:                      # rwkv state (L,B,H,dk,dv)
            L, B, H, dk, dv = shp
            bspec = dp if _div(B, dp, par.mesh) else None
            return P(None, bspec, tp if H % tp_n == 0 else None, None, None)
        if "x_att" in p or "x_ffn" in p:    # (L,B,D)
            bspec = dp if _div(shp[1], dp, par.mesh) else None
            return P(None, bspec, tp if shp[2] % tp_n == 0 else None)
        if "conv" in p:                     # (nb,mpb,B,K-1,Dc)
            bspec = dp if _div(shp[2], dp, par.mesh) else None
            return P(None, None, bspec, None,
                     tp if shp[4] % tp_n == 0 else None)
        if p.endswith("/h") or p == "mamba/h":  # (nb,mpb,B,H,P,N)
            bspec = dp if _div(shp[2], dp, par.mesh) else None
            return P(None, None, bspec,
                     tp if shp[3] % tp_n == 0 else None, None, None)
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(assign, cache_struct)


def to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
