"""Step-function builders shared by train.py, serve.py and dryrun.py —
plus ``prepare_serving_params``, the quantize-once entry of the DS-CIM
serve path (convert every eligible weight matrix to a resident int8
``QuantizedLinearWeight`` before jitting the prefill/decode steps, so no
weight quantization appears in the decode-step HLO), and
``make_generate_fn``, the device-resident generation loop (prefill + an
n-token ``lax.scan`` of decode steps inside one jit — the host sees one
dispatch per request instead of one per token)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import get_model
from repro.models.lm import lm_loss
from repro.optim.adamw import AdamW
from repro.parallel import ParallelCtx

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "make_eval_step", "make_generate_fn", "prepare_serving_params"]


def prepare_serving_params(cfg: ArchConfig, params,
                           par: ParallelCtx | None = None):
    """Quantize-once weight preparation for DS-CIM serving.

    No-op for 'off'/'float' specs.  Otherwise every DS-CIM-eligible matrix
    (MLP, MoE shared expert, LM head — plus attention projections for
    '+attn' modes) is converted to a window-packed int8
    ``QuantizedLinearWeight`` with the serving layer's ``group_k``, matching
    the on-the-fly quantization bit for bit under f32 compute; under bf16
    compute the per-call path quantizes cast weights, prepare-once the f32
    originals (core/qweights.py).

    The MoE shared expert is prepared under a mesh too: its resident int8
    planes replicate across the mesh (launch/sharding.py) and the shard_map
    MoE body computes it locally, bit-identically to single-device serving
    (models/lm.py ``_moe_apply``) — the former float-only guard is gone."""
    from repro.core.qweights import prepare_dscim_params, split_dscim_mode
    spec = getattr(cfg, "dscim", "off")
    if split_dscim_mode(spec)[0] in ("off", "float"):
        return params
    from repro.models.lm import _linear_for
    lin = _linear_for(spec)
    return prepare_dscim_params(params, cfg,
                                group_k=lin.group_k if lin else 128)

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def make_train_step(cfg: ArchConfig, par: ParallelCtx | None,
                    opt: AdamW):
    model = get_model(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = model.forward(p, cfg, batch, par)
            return lm_loss(logits, batch["labels"]) + AUX_WEIGHT * aux

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, gnorm = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_eval_step(cfg: ArchConfig, par: ParallelCtx | None = None):
    model = get_model(cfg)

    def eval_step(params, batch):
        logits, _ = model.forward(params, cfg, batch, par)
        return lm_loss(logits, batch["labels"])

    return eval_step


def make_prefill_step(cfg: ArchConfig, par: ParallelCtx | None,
                      capacity: int | None = None):
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, cfg, batch, par, capacity=capacity)

    return prefill_step


def make_decode_step(cfg: ArchConfig, par: ParallelCtx | None,
                     greedy: bool = True, return_logits: bool = False):
    """One greedy decode step.  ``return_logits``: also return the step's
    logits — the host-loop logit-trace driver (launch/serve.py) rides the
    same step function instead of re-implementing it."""
    model = get_model(cfg)

    def decode_step(params, batch, cache):
        logits, cache = model.decode(params, cfg, batch, cache, par)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if return_logits:
            return token, logits, cache
        return token, cache

    return decode_step


@functools.lru_cache(maxsize=8)
def make_generate_fn(cfg: ArchConfig, par: ParallelCtx | None = None,
                     n_tokens: int = 16, *, trace_logits: bool = False,
                     jit: bool = True):
    """Device-resident greedy generation: prefill + an (n_tokens-1)-step
    ``lax.scan`` of decode steps inside a single jit.

    The host dispatches exactly once per request; the KV cache lives in the
    scan carry (XLA reuses its buffers in place — no per-token host round
    trip, no per-token cache copy), and the generated tokens accumulate on
    device in the scan ys.  ``generate(params, batch)`` with ``batch =
    {"tokens": (B, S) int32}`` returns ``(tokens (B, n_tokens) int32,
    logits)`` where ``logits`` is the prefill last-token logits by default —
    the per-token logit trace is off the hot path and only materialized
    (stacked, (n_tokens, B, Vp)) under ``trace_logits=True``.

    Under a mesh (``par`` given) the whole scanned loop runs inside the one
    jit with the params' committed shardings — prepared DS-CIM weights route
    through the model-axis sharded fused MVM (core/dscim_layer.py) with no
    per-token host sync.  The builder is cached, so repeated ``serve_batch``
    calls with the same (cfg, par, n_tokens) reuse the compiled executable.
    """
    model = get_model(cfg)

    def generate(params, batch):
        capacity = batch["tokens"].shape[1] + n_tokens
        logits0, cache = model.prefill(params, cfg, batch, par,
                                       capacity=capacity)
        tok0 = jnp.argmax(logits0, axis=-1).astype(jnp.int32)

        def step(carry, _):
            tok, cache = carry
            logits, cache = model.decode(params, cfg, {"token": tok},
                                         cache, par)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (tok, cache), ((tok, logits) if trace_logits else tok)

        (_, cache), ys = jax.lax.scan(step, (tok0, cache), None,
                                      length=n_tokens - 1)
        toks = ys[0] if trace_logits else ys
        tokens = jnp.concatenate(
            [tok0[:, None], jnp.moveaxis(toks, 0, 1)], axis=1)
        if trace_logits:
            return tokens, jnp.concatenate([logits0[None], ys[1]], axis=0)
        return tokens, logits0

    return jax.jit(generate) if jit else generate
