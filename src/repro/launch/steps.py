"""Step-function builders shared by train.py, serve.py and dryrun.py —
plus ``prepare_serving_params``, the quantize-once entry of the DS-CIM
serve path (convert every eligible weight matrix to a resident int8
``QuantizedLinearWeight`` before jitting the prefill/decode steps, so no
weight quantization appears in the decode-step HLO), and
``make_generate_fn``, the device-resident generation loop (prefill + an
n-token ``lax.scan`` of decode steps inside one jit — the host sees one
dispatch per request instead of one per token).

ISSUE 4 additions — "only do live work" on the decode hot path:
  * ``make_generate_fn(eos_id=...)`` switches the fixed-length scan to a
    ``lax.while_loop`` that exits as soon as every slot has emitted EOS
    (or hit its optional per-slot ``batch["max_new"]`` budget), with
    per-slot done-masking: finished rows stop advancing their cache
    position and their tokens are pinned to ``pad_id``.
  * in-scan sampling: ``sample`` selects greedy (default, bit-compatible
    with PR 3) or ``'temp:<t>'`` / ``'topk:<k>[:<t>]'`` /
    ``'topp:<p>[:<t>]'`` (nucleus, ISSUE 5) — the PRNG key rides the
    scan/while carry, one split per step in both variants so the drivers
    draw identically.
  * ``kv='int8'`` serves from the block-paged int8 KV cache
    (core/kvcache.py) instead of the dense fixed-capacity one.
  * ``make_admit_fn`` / ``make_segment_fn`` / ``init_serve_state`` are the
    jitted halves of the continuous-batching scheduler (launch/serve.py):
    admission prefills one request into a free slot of a live batch
    (carries persist), segments run fixed-size scans of done-masked
    decode steps and report per-step live-slot occupancy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import get_model
from repro.models.lm import lm_loss
from repro.optim.adamw import AdamW
from repro.parallel import ParallelCtx

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "make_eval_step", "make_generate_fn", "prepare_serving_params",
           "make_admit_fn", "make_segment_fn", "make_extend_fn",
           "init_serve_state", "make_probe_fn"]


def prepare_serving_params(cfg: ArchConfig, params,
                           par: ParallelCtx | None = None, *,
                           golden: bool = False):
    """Quantize-once weight preparation for DS-CIM serving.

    No-op for 'off'/'float' specs.  Otherwise every DS-CIM-eligible matrix
    (MLP, MoE shared expert, LM head — plus attention projections for
    '+attn' modes) is converted to a window-packed int8
    ``QuantizedLinearWeight`` with the serving layer's ``group_k``, matching
    the on-the-fly quantization bit for bit under f32 compute; under bf16
    compute the per-call path quantizes cast weights, prepare-once the f32
    originals (core/qweights.py).

    The MoE shared expert is prepared under a mesh too: its resident int8
    planes replicate across the mesh (launch/sharding.py) and the shard_map
    MoE body computes it locally, bit-identically to single-device serving
    (models/lm.py ``_moe_apply``) — the former float-only guard is gone.

    ``golden=True`` returns ``(prepared, golden_blob)`` where the blob is
    the host-side bit-exact copy + digest vector of every prepared plane
    (core/qweights.golden_weight_copy) — the integrity layer's repair
    source of truth, taken here because this is the one moment the planes
    are known-good by construction.  'off'/'float' specs have no prepared
    planes; their blob is ``None``."""
    from repro.core.qweights import (prepare_dscim_params, split_dscim_mode,
                                     golden_weight_copy)
    spec = getattr(cfg, "dscim", "off")
    if split_dscim_mode(spec)[0] in ("off", "float"):
        return (params, None) if golden else params
    from repro.models.lm import _linear_for
    lin = _linear_for(spec)
    prepared = prepare_dscim_params(params, cfg,
                                    group_k=lin.group_k if lin else 128)
    if golden:
        return prepared, golden_weight_copy(prepared)
    return prepared

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def make_train_step(cfg: ArchConfig, par: ParallelCtx | None,
                    opt: AdamW):
    model = get_model(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = model.forward(p, cfg, batch, par)
            return lm_loss(logits, batch["labels"]) + AUX_WEIGHT * aux

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, gnorm = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_eval_step(cfg: ArchConfig, par: ParallelCtx | None = None):
    model = get_model(cfg)

    def eval_step(params, batch):
        logits, _ = model.forward(params, cfg, batch, par)
        return lm_loss(logits, batch["labels"])

    return eval_step


def make_prefill_step(cfg: ArchConfig, par: ParallelCtx | None,
                      capacity: int | None = None):
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, cfg, batch, par, capacity=capacity)

    return prefill_step


def make_decode_step(cfg: ArchConfig, par: ParallelCtx | None,
                     greedy: bool = True, return_logits: bool = False):
    """One greedy decode step.  ``return_logits``: also return the step's
    logits — the host-loop logit-trace driver (launch/serve.py) rides the
    same step function instead of re-implementing it."""
    model = get_model(cfg)

    def decode_step(params, batch, cache):
        logits, cache = model.decode(params, cfg, batch, cache, par)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if return_logits:
            return token, logits, cache
        return token, cache

    return decode_step


def _make_sampler(sample: str):
    """Decode-rule factory: 'greedy' -> None (argmax, no RNG);
    'temp:<t>' -> temperature sampling; 'topk:<k>[:<t>]' -> top-k with
    optional temperature; 'topp:<p>[:<t>]' -> nucleus sampling (keep the
    smallest prefix of the temperature-scaled distribution with cumulative
    probability >= p — 'topp:1.0:<t>' is exactly 'temp:<t>').  The
    returned callable draws (key, logits) -> (B,) int32 inside the jitted
    loop."""
    if sample == "greedy":
        return None
    parts = sample.split(":")
    k = p = None
    if parts[0] == "temp" and len(parts) == 2:
        t = float(parts[1])
    elif parts[0] == "topk" and len(parts) in (2, 3):
        k = int(parts[1])
        t = float(parts[2]) if len(parts) == 3 else 1.0
    elif parts[0] == "topp" and len(parts) in (2, 3):
        p = float(parts[1])
        t = float(parts[2]) if len(parts) == 3 else 1.0
        if not 0.0 < p <= 1.0:
            raise ValueError(f"top-p must be in (0, 1], got {p}")
    else:
        raise ValueError(f"bad sample spec {sample!r}; want 'greedy', "
                         "'temp:<t>', 'topk:<k>[:<t>]' or 'topp:<p>[:<t>]'")
    if t <= 0:
        raise ValueError(f"temperature must be > 0, got {t}")

    def draw(key, logits):
        lg = logits.astype(jnp.float32) / t
        if k is not None:
            kth = jax.lax.top_k(lg, k)[0][..., -1:]
            lg = jnp.where(lg >= kth, lg, -jnp.inf)
        if p is not None:
            # nucleus: sort descending, keep tokens whose *exclusive*
            # cumulative probability is < p (the top token always stays),
            # i.e. the smallest set with inclusive cumsum >= p
            srt = -jnp.sort(-lg, axis=-1)
            probs = jax.nn.softmax(srt, axis=-1)
            excl = jnp.cumsum(probs, axis=-1) - probs
            nkeep = jnp.sum(excl < p, axis=-1, keepdims=True)
            kth = jnp.take_along_axis(srt, nkeep - 1, axis=-1)
            lg = jnp.where(lg >= kth, lg, -jnp.inf)
        # degenerate-row guard (ISSUE 6): a row whose masked logits hold a
        # NaN, a +inf, or no finite entry at all would make categorical's
        # gumbel-argmax return an arbitrary (or NaN-poisoned) id; fall
        # back to greedy argmax over the NaN-cleaned *original* logits for
        # that row (argmax is scale-invariant, so temperature is moot).
        # Healthy rows see bit-identical draws: their lg is untouched.
        bad = jnp.isnan(lg).any(-1) | jnp.isposinf(lg).any(-1) \
            | ~jnp.isfinite(lg).any(-1)
        clean = jnp.where(jnp.isnan(logits), -jnp.inf,
                          logits.astype(jnp.float32))
        greedy = jnp.argmax(clean, axis=-1).astype(jnp.int32)
        safe = jnp.where(bad[..., None], 0.0, lg)
        drawn = jax.random.categorical(key, safe, axis=-1).astype(jnp.int32)
        return jnp.where(bad, greedy, drawn)

    return draw


def _next_fn(sampler):
    """(logits, key) -> (token, key): greedy argmax, or one split + draw
    per step — the identical split sequence in the fixed-length scan and
    the EOS while_loop keeps the two drivers' draws bit-identical."""
    if sampler is None:
        return lambda logits, key: (
            jnp.argmax(logits, axis=-1).astype(jnp.int32), key)

    def nxt(logits, key):
        key, sub = jax.random.split(key)
        return sampler(sub, logits), key

    return nxt


_SPEC_L = {"dscim1": 256, "dscim2": 64}   # the paper's two operating points


def _parse_spec(spec: str | None):
    """Self-speculative decoding spec: '<variant>:<k>' (e.g. 'dscim2:4')
    -> (draft_variant, k).  k = 0 (or None/'') disables speculation —
    the builders fall through to the plain drivers, so 'dscim2:0' is the
    plain path, not a degenerate window."""
    if not spec:
        return None
    parts = spec.split(":")
    if len(parts) != 2 or parts[0] not in _SPEC_L:
        raise ValueError(f"bad spec {spec!r}; want 'dscim1:<k>' or "
                         "'dscim2:<k>', e.g. 'dscim2:4'")
    try:
        k = int(parts[1])
    except ValueError:
        raise ValueError(f"bad spec {spec!r}: draft depth {parts[1]!r} is "
                         "not an int") from None
    if k < 0:
        raise ValueError(f"spec draft depth must be >= 0, got {k}")
    return (parts[0], k) if k else None


def _draft_cfg(cfg: ArchConfig, variant: str) -> ArchConfig:
    """The drafter's config: same weights and architecture, the cheaper
    stochastic estimator.  Rewrites the serving dscim spec's variant and
    sample length (dscim2 -> L64, dscim1 -> L256), keeping mode[+attn] and
    calibration — the prepared ``QuantizedLinearWeight`` planes are shared
    by every estimator mode, so draft and verify serve the *same* resident
    weights (that is what makes this self-speculation).  'off'/'float'
    serving specs draft through themselves (degenerate self-draft: every
    greedy draft is accepted — useful as a plumbing check)."""
    import dataclasses

    from repro.core.qweights import split_dscim_mode
    spec = getattr(cfg, "dscim", "off")
    if spec == "off" or split_dscim_mode(spec)[0] in ("off", "float"):
        return cfg
    parts = spec.split(":")
    parts[1] = variant
    parts[2] = str(_SPEC_L[variant])
    return dataclasses.replace(cfg, dscim=":".join(parts))


def _check_spec(model, cfg: ArchConfig):
    if not hasattr(model, "decode_multi"):
        raise ValueError("speculative decoding needs a model family with a "
                         f"batched verify forward, not {cfg.family!r}")
    if cfg.stub_frontend:
        raise ValueError("speculative decoding needs token inputs; "
                         "stub-frontend configs are unsupported")


def _make_spec_window(model, cfg: ArchConfig, cfg_draft: ArchConfig, par,
                      nxt, k: int, eos: int, pad_id: int, pin: dict):
    """One self-speculative draft/verify window, fully device-resident.

    Drafts ``k`` tokens with the cheap estimator (greedy argmax — drafting
    consumes no RNG; only emissions draw, keeping the carried key chain
    aligned with the non-spec drivers), verifies the k+1-token window with
    one batched forward through the serving estimator
    (``models.lm.decode_multi``), then folds the standard accept rule over
    the window inside a ``lax.scan``: position t emits the token the
    *verifier* decides (argmax, or one RNG draw per emitting position), and
    the window continues past t only while the draft at t+1 equals the
    emitted token.  Greedy emission is therefore bitwise what target-only
    serving would emit; every window emits at least one token per live row
    (progress is unconditional), and position k's emission is the standard
    bonus token.

    Draft decodes write provisional KV at the window positions; the verify
    forward rewinds to the window start and overwrites every one of those
    writes before reading it, so the verifier sees a cache bitwise equal to
    non-spec serving — and ``kvcache.spec_rollback`` truncates back to the
    last accepted position after the fold.  Pages are never allocated or
    freed in here: callers size every slot's grant with +k headroom.

    Returns ``(tok', done', n_out', cache', key', em (B, k+1) int32,
    vm (B, k+1) bool, bad (B, k+1) bool, logits0 (B, Vp) f32)`` — ``em``
    holds the emitted token where ``vm`` is set (pad elsewhere), ``bad``
    flags emitted-from non-finite verifier logits, ``logits0`` is the
    verify logits at window position 0 (the accuracy-watchdog probe plane:
    same (token, cache) inputs the exact-mode probe decodes).
    """
    from repro.core import kvcache

    def window(params, tok, done, n_out, budget, cache, key):
        B = tok.shape[0]
        pos0 = cache["pos"]
        paged = "k_pages" in cache
        tails0 = (cache["k_tail"], cache["v_tail"]) if paged else None

        def dstep(carry, _):
            dtok, dcache = carry
            dlogits, dcache = model.decode(
                params, cfg_draft, {"token": dtok, "done": done, **pin},
                dcache, par)
            nd = jnp.argmax(dlogits, axis=-1).astype(jnp.int32)
            return (nd, dcache), nd

        (_, dcache), drafts = jax.lax.scan(dstep, (tok, cache), None,
                                           length=k)
        drafts = jnp.moveaxis(drafts, 0, 1)                    # (B, k)

        window_toks = jnp.concatenate([tok[:, None], drafts], axis=1)
        # rewind pos: the verify pass overwrites every draft write before
        # reading it.  Paged caches also restore the pre-window tail: a
        # draft that crossed a page boundary wrapped the tail buffer and
        # clobbered committed entries below pos0 % ps, which verify reads
        # for the window's first page (it only rewrites offsets >= pos0 %
        # ps); every later page starts at offset 0 and needs no restore.
        vcache = dict(dcache, pos=pos0)
        if paged:
            vcache["k_tail"], vcache["v_tail"] = tails0
        vlogits, vcache, win_kv = model.decode_multi(
            params, cfg, {"tokens": window_toks, "done": done, **pin},
            vcache, par)

        # the draft after each position (what must match to keep going);
        # -1 after the bonus position — never equal to a real token
        d_next = jnp.concatenate(
            [drafts, jnp.full((B, 1), -1, jnp.int32)], axis=1)

        def astep(carry, xs):
            acc, dn, nout, kkey, last = carry
            lg, dnx = xs
            cand, k2 = nxt(lg, kkey)
            emit = acc
            # consume the split only if some row emitted at this position
            # (greedy nxt returns the key untouched, so this is a no-op
            # there); in the lockstep case this is exactly one split per
            # emitted token — the non-spec chain
            any_e = jnp.any(emit)
            kkey = jax.tree.map(lambda n, o: jnp.where(any_e, n, o),
                                k2, kkey)
            tok_t = jnp.where(emit, cand, pad_id)
            nout2 = nout + jnp.where(emit, 1, 0)
            stop = (tok_t == eos) | (nout2 >= budget)
            dn2 = dn | (emit & stop)
            acc2 = emit & ~stop & (dnx >= 0) & (cand == dnx)
            last2 = jnp.where(emit, cand, last)
            return (acc2, dn2, nout2, kkey, last2), (tok_t, emit)

        (_, done2, n_out2, key2, tok2), (em, vm) = jax.lax.scan(
            astep, (~done, done, n_out, key, tok),
            (jnp.moveaxis(vlogits, 1, 0), jnp.moveaxis(d_next, 1, 0)))
        em = jnp.moveaxis(em, 0, 1)                            # (B, k+1)
        vm = jnp.moveaxis(vm, 0, 1)
        bad = vm & ~jnp.isfinite(vlogits).all(axis=-1)

        n_emit = jnp.sum(vm, axis=1).astype(jnp.int32)
        cache2 = kvcache.spec_rollback(vcache, pos0, pos0 + n_emit,
                                       tails0, win_kv)
        logits0 = vlogits[:, 0].astype(jnp.float32)
        return tok2, done2, n_out2, cache2, key2, em, vm, bad, logits0

    return window


def _check_kv(cfg: ArchConfig, kv: str):
    if kv not in ("float", "int8"):
        raise ValueError(f"kv must be 'float' or 'int8', got {kv!r}")
    if kv == "int8" and cfg.family not in ("dense", "moe"):
        raise ValueError("the paged int8 KV cache needs an attention-"
                         f"family model, not {cfg.family!r}")


def _paged_kernel_flag(paged_attn: str):
    """'auto' | 'kernel' | 'jnp' -> the static read-path bool the decode
    batch carries (None = follow cfg.dscim / REPRO_PAGED_ATTN, see
    layers/attention.py).  An explicit choice is part of every jitted
    builder's lru_cache key, so A/B-ing the two paths can never hand back
    a stale executable traced for the other one."""
    try:
        return {"auto": None, "kernel": True, "jnp": False}[paged_attn]
    except KeyError:
        raise ValueError(f"paged_attn must be 'auto', 'kernel' or 'jnp', "
                         f"got {paged_attn!r}") from None


@functools.lru_cache(maxsize=16)
def make_generate_fn(cfg: ArchConfig, par: ParallelCtx | None = None,
                     n_tokens: int = 16, *, trace_logits: bool = False,
                     jit: bool = True, eos_id: int | None = None,
                     sample: str = "greedy", pad_id: int = 0,
                     kv: str = "float", page_size: int = 8,
                     paged_attn: str = "auto", spec: str | None = None):
    """Device-resident generation: prefill + up to (n_tokens-1) decode
    steps inside a single jit.

    The host dispatches exactly once per request; the KV cache lives in the
    loop carry (XLA reuses its buffers in place — no per-token host round
    trip, no per-token cache copy), and the generated tokens accumulate on
    device.  ``generate(params, batch)`` with ``batch = {"tokens": (B, S)
    int32}`` returns ``(tokens (B, n_tokens) int32, logits)`` where
    ``logits`` is the prefill last-token logits by default — the per-token
    logit trace is off the hot path and only materialized (stacked,
    (n_tokens, B, Vp)) under ``trace_logits=True`` (fixed-length scan only).

    ``eos_id``: switch the fixed-length ``lax.scan`` to a ``lax.while_loop``
    that exits as soon as every slot has emitted ``eos_id`` (and/or reached
    its optional per-slot ``batch["max_new"]`` (B,) int32 budget, counted
    including the prefill token).  Finished slots are done-masked: their
    cache position stops advancing and their remaining tokens are pinned
    to ``pad_id`` — ragged completion with no dead-token decode work once
    the whole batch is finished.

    ``sample``: 'greedy' (default, bit-compatible with the PR 3 scan) or
    'temp:<t>' / 'topk:<k>[:<t>]' / 'topp:<p>[:<t>]' — the RNG key
    (``batch["rng"]``, a PRNGKey) rides the loop carry with one split per
    step.

    ``kv``: 'float' serves from the dense fixed-capacity cache; 'int8'
    from the block-paged per-head-quantized KV cache (core/kvcache.py,
    ~4x fewer resident decode cache bytes, dequant fused into the paged
    flash attention inner loop).  ``paged_attn``: the int8 read path —
    'kernel' (fused Pallas paged attention) or 'jnp' (gather reference)
    pin it and key this builder's cache; 'auto' (default) follows
    cfg.dscim ('kernel' modes -> kernel) with the trace-time
    ``REPRO_PAGED_ATTN`` env override.

    Under a mesh (``par`` given) the whole loop runs inside the one jit
    with the params' committed shardings — prepared DS-CIM weights route
    through the model-axis sharded fused MVM (core/dscim_layer.py) with no
    per-token host sync.  The builder is cached, so repeated ``serve_batch``
    calls with the same options reuse the compiled executable.

    ``spec``: ``'<variant>:<k>'`` (e.g. ``'dscim2:4'``) turns on
    self-speculative decoding — draft k tokens per window with the cheaper
    estimator (``_draft_cfg``), verify them in one batched forward through
    the serving estimator, accept by the standard rule
    (``_make_spec_window``).  The driver becomes a window-granular
    ``lax.while_loop`` (accept/reject never round-trips to the host);
    greedy emission is bitwise-identical to the non-spec drivers, sampled
    emission replays the carried key chain.  The KV allocation gains +k
    headroom for in-flight draft positions.  Returns a third element,
    ``{"windows": (B,), "emitted": (B,)}`` — per-row verify-window
    participation and emitted-token counts, the
    accepted-tokens-per-verify numerator/denominator serve_bench reports.
    """
    model = get_model(cfg)
    nxt = _next_fn(_make_sampler(sample))
    _check_kv(cfg, kv)
    sp = _parse_spec(spec)
    k_spec = sp[1] if sp else 0
    if sp:
        _check_spec(model, cfg)
    pk = _paged_kernel_flag(paged_attn)
    # static read-path pin, merged into the decode batches built inside
    # the jitted loop (absent under 'auto' — plain python values in a
    # dict literal constructed during tracing, never traced operands)
    pin = {} if pk is None else {"paged_kernel": pk}
    if trace_logits and eos_id is not None:
        raise ValueError("trace_logits is a fixed-length-scan feature; the "
                         "EOS early-exit variant keeps logits off the path")
    if trace_logits and sp:
        raise ValueError("trace_logits is a fixed-length-scan feature; "
                         "speculative windows keep logits off the path")

    def _prefill(params, batch):
        B, S = batch["tokens"].shape
        if kv == "float":
            return model.prefill(params, cfg, {"tokens": batch["tokens"]},
                                 par, capacity=S + n_tokens + k_spec)
        from repro.core.kvcache import n_pages_for, paged_from_dense
        logits0, dense = model.prefill(params, cfg,
                                       {"tokens": batch["tokens"]}, par)
        mp = n_pages_for(S + n_tokens + k_spec, page_size)
        return logits0, paged_from_dense(dense["k"], dense["v"], page_size,
                                         n_pages=B * mp, max_pages=mp)

    def generate(params, batch):
        B = batch["tokens"].shape[0]
        logits0, cache = _prefill(params, batch)
        key = batch.get("rng", jax.random.PRNGKey(0))
        tok0, key = nxt(logits0, key)

        if sp is not None:
            # self-speculative window driver: while_loop over draft/verify
            # windows, per-row output cursors (rows desync by acceptance)
            variant, kd = sp
            window = _make_spec_window(model, cfg, _draft_cfg(cfg, variant),
                                       par, nxt, kd,
                                       -1 if eos_id is None else eos_id,
                                       pad_id, pin)
            budget = jnp.full((B,), n_tokens, jnp.int32)
            if "max_new" in batch:
                budget = jnp.minimum(budget, batch["max_new"])
            done0 = (tok0 == (-1 if eos_id is None else eos_id)) \
                | (budget <= 1)
            if kv == "float":      # per-row positions from the first window
                cache = dict(cache,
                             pos=jnp.full((B,), cache["pos"], jnp.int32))
            toks0 = jnp.full((B, n_tokens), pad_id,
                             jnp.int32).at[:, 0].set(tok0)
            cnt0 = jnp.ones((B,), jnp.int32)          # emitted (incl. tok0)
            wn0 = jnp.zeros((B,), jnp.int32)          # windows participated

            def cond(c):
                w, _, done = c[0], c[1], c[2]
                return (w < n_tokens) & ~jnp.all(done)

            def body(c):
                w, tok, done, toks, cnt, wn, cache, key = c
                wn = wn + jnp.where(done, 0, 1)
                tok, ndone, cnt2, cache, key, em, vm, _, _ = window(
                    params, tok, done, cnt, budget, cache, key)
                rows = jnp.arange(B)[:, None]
                idx = cnt[:, None] + jnp.arange(kd + 1,
                                                dtype=jnp.int32)[None, :]
                idx = jnp.where(vm, idx, n_tokens)    # drop non-emissions
                toks = toks.at[rows, idx].set(em, mode="drop")
                return w + 1, tok, ndone, toks, cnt2, wn, cache, key

            _, _, _, toks, cnt, wn, _, _ = jax.lax.while_loop(
                cond, body,
                (jnp.int32(1), tok0, done0, toks0, cnt0, wn0, cache, key))
            return toks, logits0, {"windows": wn, "emitted": cnt}

        if eos_id is None:
            # fixed-length scan (the PR 3 path)
            def step(carry, _):
                tok, cache, key = carry
                logits, cache = model.decode(params, cfg,
                                             {"token": tok, **pin},
                                             cache, par)
                tok, key = nxt(logits, key)
                return (tok, cache, key), ((tok, logits) if trace_logits
                                           else tok)

            (_, cache, _), ys = jax.lax.scan(step, (tok0, cache, key), None,
                                             length=n_tokens - 1)
            toks = ys[0] if trace_logits else ys
            tokens = jnp.concatenate(
                [tok0[:, None], jnp.moveaxis(toks, 0, 1)], axis=1)
            if trace_logits:
                return tokens, jnp.concatenate([logits0[None], ys[1]],
                                               axis=0)
            return tokens, logits0

        # EOS early-exit while_loop: stop the moment the whole batch is
        # done; per-slot done-masking gives ragged completion inside it
        done0 = tok0 == eos_id
        if "max_new" in batch:
            done0 = done0 | (batch["max_new"] <= 1)
        if kv == "float":          # ragged completion needs per-slot pos
            cache = dict(cache,
                         pos=jnp.full((B,), cache["pos"], jnp.int32))
        toks0 = jnp.full((B, n_tokens), pad_id, jnp.int32).at[:, 0].set(tok0)

        def cond(c):
            i, _, done, _, _, _ = c
            return (i < n_tokens) & ~jnp.all(done)

        def body(c):
            i, tok, done, toks, cache, key = c
            logits, cache = model.decode(
                params, cfg, {"token": tok, "done": done, **pin}, cache,
                par)
            new, key = nxt(logits, key)
            new = jnp.where(done, pad_id, new)
            ndone = done | (new == eos_id)
            if "max_new" in batch:
                ndone = ndone | (i + 1 >= batch["max_new"])
            toks = jax.lax.dynamic_update_slice(toks, new[:, None], (0, i))
            return i + 1, new, ndone, toks, cache, key

        _, _, _, toks, _, _ = jax.lax.while_loop(
            cond, body, (jnp.int32(1), tok0, done0, toks0, cache, key))
        return toks, logits0

    return jax.jit(generate) if jit else generate


# ---------------------------------------------------------------------------
# continuous batching: jitted admit / segment halves of the scheduler
# ---------------------------------------------------------------------------

def init_serve_state(cfg: ArchConfig, slots: int, capacity: int, *,
                     kv: str = "float", page_size: int = 8,
                     n_pages: int | None = None, seed: int = 0,
                     integrity: bool = False):
    """Idle scheduler state: every slot free (done), empty KV cache of the
    requested layout, shared PRNG key.  ``capacity`` is the per-slot token
    budget (prompt + generated); for ``kv='int8'`` the page pool defaults
    to slots x pages-per-sequence but can be sized independently
    (``n_pages``) — capacity is a pool knob, not slots x max_len.

    ``integrity=True`` (int8 only) adds the per-page checksum plane to the
    cache; every jitted builder branches on the cache *structure* at trace
    time, so the flag changes no builder cache keys."""
    _check_kv(cfg, kv)
    B = slots
    if kv == "float":
        if integrity:
            raise ValueError("integrity checksums need the int8 paged "
                             "cache (kv='int8'); the float dense cache "
                             "is rewritten in place every step")
        cdt = jnp.dtype(cfg.cache_dtype)
        cache = {"k": jnp.zeros((cfg.n_layers, B, capacity, cfg.n_kv,
                                 cfg.head_dim), cdt),
                 "v": jnp.zeros((cfg.n_layers, B, capacity, cfg.n_kv,
                                 cfg.head_dim), cdt),
                 "pos": jnp.zeros((B,), jnp.int32)}
    else:
        from repro.core.kvcache import init_paged_cache, n_pages_for
        mp = n_pages_for(capacity, page_size)
        cache = init_paged_cache(cfg.n_layers, B,
                                 B * mp if n_pages is None else n_pages,
                                 page_size, mp, cfg.n_kv, cfg.head_dim,
                                 integrity=integrity)
    return {"tok": jnp.zeros((B,), jnp.int32),
            "done": jnp.ones((B,), bool),
            "n_out": jnp.zeros((B,), jnp.int32),
            "max_new": jnp.ones((B,), jnp.int32),
            "cache": cache,
            "rng": jax.random.PRNGKey(seed)}


@functools.lru_cache(maxsize=16)
def make_admit_fn(cfg: ArchConfig, par: ParallelCtx | None = None, *,
                  eos_id: int | None = None, sample: str = "greedy",
                  jit: bool = True):
    """One jitted request admission: prefill a (1, S) prompt, write its KV
    into free slot ``slot`` of the live cache (dense row overwrite, or
    host-allocated physical pages for the paged layout — the cache layout
    is picked up from the state structure), seed the slot's first token /
    budget / done flag.  Runs between segments; carries persist."""
    model = get_model(cfg)
    nxt = _next_fn(_make_sampler(sample))
    eos = -1 if eos_id is None else eos_id

    def admit(params, state, prompt, slot, page_ids, max_new):
        from repro.core import kvcache
        logits0, dense = model.prefill(params, cfg, {"tokens": prompt}, par)
        tok0, key = nxt(logits0, state["rng"])
        tok0 = tok0[0]
        cache = state["cache"]
        if "k_pages" in cache:
            cache = kvcache.admit_request(cache, dense["k"], dense["v"],
                                          slot, page_ids)
        else:
            cache = kvcache.admit_dense(cache, dense["k"], dense["v"], slot)
        done0 = (tok0 == eos) | (max_new <= 1)
        return dict(state,
                    tok=state["tok"].at[slot].set(tok0),
                    done=state["done"].at[slot].set(done0),
                    n_out=state["n_out"].at[slot].set(1),
                    max_new=state["max_new"].at[slot].set(max_new),
                    cache=cache, rng=key), tok0

    # the state (KV cache included) is donated: admissions between
    # segments update the pool in place instead of copying it
    return jax.jit(admit, donate_argnums=(1,)) if jit else admit


@functools.lru_cache(maxsize=16)
def make_segment_fn(cfg: ArchConfig, par: ParallelCtx | None = None,
                    seg_len: int = 4, *, eos_id: int | None = None,
                    sample: str = "greedy", pad_id: int = 0,
                    jit: bool = True, paged_attn: str = "auto",
                    spec: str | None = None):
    """One jitted continuous-batching segment: a fixed-size ``lax.scan`` of
    ``seg_len`` done-masked decode steps over the whole slot batch.  Slots
    finish on EOS or their per-slot budget and stop advancing their cache
    position; the scheduler admits new requests into freed slots *between*
    segments.  Returns (state', toks (seg_len, B) int32, live (seg_len, B)
    bool, aux) where ``live[s, b]`` marks that slot b did useful work at
    step s — the occupancy/live-tok-s accounting the serve report uses.

    ``aux`` carries the fault-tolerant scheduler's monitoring planes
    (runtime/serving.py), computed inside the same scan so the hot path
    gains no extra dispatches: ``aux["bad"]`` (seg_len, B) bool flags
    steps whose logits went NaN/Inf (corrupted KV pages, a poisoned
    estimator), and ``aux["logits0"]`` (B, Vp) f32 is the *first* step's
    logits — the serving side of the accuracy-watchdog probe, which
    decodes the same (token, cache) inputs through the exact reference
    (``make_probe_fn``) and compares.  Both stay as unfetched device
    buffers unless the scheduler is monitoring.

    ``spec`` ('<variant>:<k>'): each of the ``seg_len`` scan steps becomes
    a self-speculative draft/verify *window* (``_make_spec_window``) —
    still one host dispatch per segment; accept/reject lives in the scan
    carry.  Outputs stay step-shaped: toks/live/``aux["bad"]`` come back
    as (seg_len * (k+1), B) with window emissions laid out chronologically
    and non-emitted positions dead (``live`` False, token ``pad_id``) —
    the host harvest loop is unchanged, it just sees more rows, and the
    deadline ledger counts all seg_len * (k+1) attempted verifier
    positions.  ``aux["logits0"]`` stays the segment's first (token,
    cache) decode — under spec that is the first window's verify logits
    at position 0, i.e. still the *verifier* estimator on exactly the
    inputs the exact-mode probe decodes."""
    model = get_model(cfg)
    nxt = _next_fn(_make_sampler(sample))
    eos = -1 if eos_id is None else eos_id
    pin = {} if _paged_kernel_flag(paged_attn) is None \
        else {"paged_kernel": _paged_kernel_flag(paged_attn)}
    sp = _parse_spec(spec)
    if sp:
        _check_spec(model, cfg)
        variant, kd = sp
        window = _make_spec_window(model, cfg, _draft_cfg(cfg, variant),
                                   par, nxt, kd, eos, pad_id, pin)

        def segment(params, state):
            def step(carry, _):
                tok, done, n_out, max_new, cache, key, i, lg0 = carry
                tok, done, n_out, cache, key, em, vm, bad, l0 = window(
                    params, tok, done, n_out, max_new, cache, key)
                lg0 = jnp.where(i == 0, l0, lg0)
                return (tok, done, n_out, max_new, cache, key, i + 1,
                        lg0), (em, vm, bad)

            B = state["tok"].shape[0]
            lg0_init = jnp.zeros((B, cfg.vocab_padded), jnp.float32)
            carry = (state["tok"], state["done"], state["n_out"],
                     state["max_new"], state["cache"], state["rng"],
                     jnp.int32(0), lg0_init)
            (tok, done, n_out, max_new, cache, key, _, lg0), \
                (ems, vms, bads) = \
                jax.lax.scan(step, carry, None, length=seg_len)
            if "page_sum" in state["cache"]:        # trace-time structure
                from repro.core.kvcache import refresh_page_checksums
                # draft windows flush up to k positions past the committed
                # pos; every such page is re-digested from live content
                cache = refresh_page_checksums(
                    cache, state["cache"]["pos"], cache["pos"] + kd,
                    seg_len * (kd + 1) + kd)

            def rows(x):     # (seg_len, B, k+1) -> (seg_len * (k+1), B)
                return jnp.moveaxis(x, 2, 1).reshape(seg_len * (kd + 1), B)

            return dict(state, tok=tok, done=done, n_out=n_out,
                        max_new=max_new, cache=cache, rng=key), \
                rows(ems), rows(vms), {"bad": rows(bads), "logits0": lg0}

        return jax.jit(segment, donate_argnums=(1,)) if jit else segment

    def segment(params, state):
        def step(carry, _):
            tok, done, n_out, max_new, cache, key, i, lg0 = carry
            live = ~done
            logits, cache = model.decode(
                params, cfg, {"token": tok, "done": done, **pin}, cache,
                par)
            lg0 = jnp.where(i == 0, logits.astype(jnp.float32), lg0)
            bad = live & ~jnp.isfinite(logits).all(axis=-1)
            new, key = nxt(logits, key)
            new = jnp.where(done, pad_id, new)
            n_out = n_out + jnp.where(done, 0, 1)
            ndone = done | (new == eos) | (n_out >= max_new)
            return (new, ndone, n_out, max_new, cache, key, i + 1, lg0), \
                (new, live, bad)

        B = state["tok"].shape[0]
        lg0_init = jnp.zeros((B, cfg.vocab_padded), jnp.float32)
        carry = (state["tok"], state["done"], state["n_out"],
                 state["max_new"], state["cache"], state["rng"],
                 jnp.int32(0), lg0_init)
        (tok, done, n_out, max_new, cache, key, _, lg0), \
            (toks, lives, bads) = \
            jax.lax.scan(step, carry, None, length=seg_len)
        if "page_sum" in state["cache"]:            # trace-time structure
            from repro.core.kvcache import refresh_page_checksums
            cache = refresh_page_checksums(
                cache, state["cache"]["pos"], cache["pos"], seg_len)
        return dict(state, tok=tok, done=done, n_out=n_out, max_new=max_new,
                    cache=cache, rng=key), toks, lives, \
            {"bad": bads, "logits0": lg0}

    # donate the carried state so each segment reuses the KV cache
    # buffers in place (the host loop's donate_argnums=(2,) analogue)
    return jax.jit(segment, donate_argnums=(1,)) if jit else segment


@functools.lru_cache(maxsize=16)
def make_extend_fn(cfg: ArchConfig, par: ParallelCtx | None = None,
                   chunk_len: int = 16, *, eos_id: int | None = None,
                   sample: str = "greedy", paged_attn: str = "auto",
                   trace_logits: bool = False, jit: bool = True):
    """One jitted chunked-prefill step for the serving router
    (runtime/router.py): feed ``chunk_len`` prompt tokens of ONE slot
    through the batched verify forward (``models.lm.decode_multi``) while
    every other slot is done-masked (frozen position, writes suppressed —
    layers/attention.py), then roll the window back to the chunk's real
    length with the speculative write-then-rollback discipline
    (``core/kvcache.spec_rollback``).  A prompt of arbitrary length S is
    admitted as ceil(S / chunk_len) extend calls against ONE compiled
    program — between calls the router keeps serving decode segments, so
    a long admission never stalls live slots.

    Position semantics: the slot's KV at positions ``pos .. pos+n_real-1``
    after the call is bitwise what ``n_real`` successive single-token
    teacher-forced ``decode`` steps would have written (the decode_multi
    exact-replay guarantee) — chunked prefill is *sequential-decode*
    equivalent, not bitwise-equal to the batched full-prompt prefill
    (XLA reduces the S-position attention in a different order), which is
    why the router's bucketed one-shot path exists for common lengths.
    The final chunk may be padded up to ``chunk_len``: pad positions sit
    causally after every real one, their KV writes are rolled back, and
    the page a padded flush may have garbage-quantized sits at logical
    index >= the committed position, where the tail overlay masks it
    until a later whole-page flush rewrites it (the spec-window
    argument).

    ``extend(params, state, toks (1, chunk_len) int32, slot, n_real,
    emit, max_new) -> (state', tok0)``: writes the chunk's KV for
    ``slot``; under ``emit`` (the last chunk) also samples the first
    output token from the final real position's logits — one ``nxt``
    call against the carried key, exactly like ``make_admit_fn`` — and
    arms the slot (tok/done/n_out=1/max_new).  Non-emitting calls leave
    the slot done-masked so interleaved segments skip it.  The state is
    donated; the slot's page-table row must already hold its granted
    pages (the router writes it host-side at begin-admit).

    ``trace_logits=True`` compiles a separate program returning a third
    element — the slot's full-chunk logits ((chunk_len, Vp) f32, pad
    positions included) — the prefix-cache bitwise-parity tests compare
    these traces hit-vs-cold; the serving paths never pay for them."""
    from repro.core import kvcache
    model = get_model(cfg)
    _check_spec(model, cfg)
    nxt = _next_fn(_make_sampler(sample))
    eos = -1 if eos_id is None else eos_id
    pk = _paged_kernel_flag(paged_attn)
    pin = {} if pk is None else {"paged_kernel": pk}

    def extend(params, state, toks, slot, n_real, emit, max_new):
        cache = state["cache"]
        B = state["tok"].shape[0]
        rows = jnp.arange(B, dtype=jnp.int32)
        is_t = rows == slot
        tokens = jnp.zeros((B, chunk_len), jnp.int32).at[slot].set(toks[0])
        pos0 = cache["pos"]
        paged = "k_pages" in cache
        tails0 = (cache["k_tail"], cache["v_tail"]) if paged else None
        logits, vcache, win_kv = model.decode_multi(
            params, cfg, {"tokens": tokens, "done": ~is_t, **pin},
            cache, par)
        new_pos = pos0 + jnp.where(is_t, n_real, 0)
        cache2 = kvcache.spec_rollback(vcache, pos0, new_pos, tails0,
                                       win_kv)
        if paged and "page_sum" in cache:
            # a chunk (pad positions included) may have flushed pages up
            # to chunk_len past the slot's entry position — re-digest them
            cache2 = kvcache.refresh_page_checksums(
                cache2, pos0, pos0 + jnp.where(is_t, chunk_len, 0),
                chunk_len)
        # emission: sample the first output token from the last *real*
        # position's logits — the chunked-path analogue of admit's
        # prefill-logits draw; the key is consumed only when emitting
        # (and never under greedy), keeping the carried chain aligned
        lg = jax.lax.dynamic_index_in_dim(logits[slot], n_real - 1,
                                          keepdims=False)
        tok0, key2 = nxt(lg[None], state["rng"])
        tok0 = tok0[0]
        key = jax.tree.map(lambda n, o: jnp.where(emit, n, o),
                           key2, state["rng"])
        done0 = jnp.where(emit, (tok0 == eos) | (max_new <= 1), True)
        old = state["tok"][slot]
        state2 = dict(
            state, cache=cache2,
            tok=state["tok"].at[slot].set(jnp.where(emit, tok0, old)),
            done=state["done"].at[slot].set(done0),
            n_out=state["n_out"].at[slot].set(
                jnp.where(emit, 1, state["n_out"][slot])),
            max_new=state["max_new"].at[slot].set(
                jnp.where(emit, max_new, state["max_new"][slot])),
            rng=key)
        if trace_logits:
            return state2, tok0, logits[slot].astype(jnp.float32)
        return state2, tok0

    return jax.jit(extend, donate_argnums=(1,)) if jit else extend


@functools.lru_cache(maxsize=16)
def make_probe_fn(cfg_ref: ArchConfig, par: ParallelCtx | None = None, *,
                  jit: bool = True):
    """The exact-reference half of the accuracy-watchdog probe: one
    non-donating decode of the serve state's (token, cache) inputs under
    ``cfg_ref`` — normally the serving spec's exact-mode, fault-free
    counterpart (``dscim='exact:...'``, ``dscim_fault=''``).

    The exact backend accepts the same prepared ``QuantizedLinearWeight``
    planes the stochastic serving path uses (core/dscim_layer.py), so the
    probe needs no second parameter copy and isolates exactly the
    estimator's contribution: same int8 weights, same int8 KV cache, same
    token — only the MVM estimator differs.  The scheduler compares the
    returned (B, Vp) logits against the segment's ``aux["logits0"]``
    (same inputs, serving estimator) via ``AccuracyWatchdog.check``.

    The decoded cache is discarded (functional decode — the pool pages
    are never written), so probing does not perturb serving state."""
    model = get_model(cfg_ref)

    def probe(params, state):
        logits, _ = model.decode(
            params, cfg_ref, {"token": state["tok"], "done": state["done"]},
            state["cache"], par)
        return logits

    return jax.jit(probe) if jit else probe
