"""Step-function builders shared by train.py, serve.py and dryrun.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import get_model
from repro.models.lm import lm_loss
from repro.optim.adamw import AdamW
from repro.parallel import ParallelCtx

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "make_eval_step"]

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def make_train_step(cfg: ArchConfig, par: ParallelCtx | None,
                    opt: AdamW):
    model = get_model(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = model.forward(p, cfg, batch, par)
            return lm_loss(logits, batch["labels"]) + AUX_WEIGHT * aux

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, gnorm = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_eval_step(cfg: ArchConfig, par: ParallelCtx | None = None):
    model = get_model(cfg)

    def eval_step(params, batch):
        logits, _ = model.forward(params, cfg, batch, par)
        return lm_loss(logits, batch["labels"])

    return eval_step


def make_prefill_step(cfg: ArchConfig, par: ParallelCtx | None,
                      capacity: int | None = None):
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, cfg, batch, par, capacity=capacity)

    return prefill_step


def make_decode_step(cfg: ArchConfig, par: ParallelCtx | None,
                     greedy: bool = True):
    model = get_model(cfg)

    def decode_step(params, batch, cache):
        logits, cache = model.decode(params, cfg, batch, cache, par)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return token, cache

    return decode_step
