"""Step-function builders shared by train.py, serve.py and dryrun.py —
plus ``prepare_serving_params``, the quantize-once entry of the DS-CIM
serve path (convert every eligible weight matrix to a resident int8
``QuantizedLinearWeight`` before jitting the prefill/decode steps, so no
weight quantization appears in the decode-step HLO)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import get_model
from repro.models.lm import lm_loss
from repro.optim.adamw import AdamW
from repro.parallel import ParallelCtx

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "make_eval_step", "prepare_serving_params"]


def prepare_serving_params(cfg: ArchConfig, params,
                           par: ParallelCtx | None = None):
    """Quantize-once weight preparation for DS-CIM serving.

    No-op for 'off'/'float' specs.  Otherwise every DS-CIM-eligible matrix
    (MLP, MoE shared expert, LM head — plus attention projections for
    '+attn' modes) is converted to a window-packed int8
    ``QuantizedLinearWeight`` with the serving layer's ``group_k``, matching
    the on-the-fly quantization bit for bit under f32 compute; under bf16
    compute the per-call path quantizes cast weights, prepare-once the f32
    originals (core/qweights.py).

    With a mesh (``par`` given) the MoE shared expert stays float — its FSDP
    gather path needs float leaves (models/lm.py ``_moe_apply``); it still
    runs DS-CIM via on-the-fly quantization there."""
    from repro.core.qweights import prepare_dscim_params, split_dscim_mode
    spec = getattr(cfg, "dscim", "off")
    if split_dscim_mode(spec)[0] in ("off", "float"):
        return params
    from repro.models.lm import _linear_for
    lin = _linear_for(spec)
    return prepare_dscim_params(params, cfg,
                                group_k=lin.group_k if lin else 128,
                                include_moe_shared=par is None)

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def make_train_step(cfg: ArchConfig, par: ParallelCtx | None,
                    opt: AdamW):
    model = get_model(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = model.forward(p, cfg, batch, par)
            return lm_loss(logits, batch["labels"]) + AUX_WEIGHT * aux

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, gnorm = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_eval_step(cfg: ArchConfig, par: ParallelCtx | None = None):
    model = get_model(cfg)

    def eval_step(params, batch):
        logits, _ = model.forward(params, cfg, batch, par)
        return lm_loss(logits, batch["labels"])

    return eval_step


def make_prefill_step(cfg: ArchConfig, par: ParallelCtx | None,
                      capacity: int | None = None):
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, cfg, batch, par, capacity=capacity)

    return prefill_step


def make_decode_step(cfg: ArchConfig, par: ParallelCtx | None,
                     greedy: bool = True):
    model = get_model(cfg)

    def decode_step(params, batch, cache):
        logits, cache = model.decode(params, cfg, batch, cache, par)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return token, cache

    return decode_step
