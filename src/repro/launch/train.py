"""Training driver: data pipeline -> jit'd train step -> checkpoints,
with watchdog straggler detection, failover restart, elastic mesh resume.

Runs anywhere from 1 CPU device (examples/tests; --mesh off) to the fake
512-device production mesh (machinery tests) — the same code path a real
TPU deployment uses, minus only the hardware.

Example (tiny, CPU):
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_arch
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import SyntheticLM
from repro.launch.mesh import make_parallel_ctx
from repro.launch.sharding import (batch_specs, opt_state_specs, param_specs,
                                   to_shardings)
from repro.launch.steps import make_train_step
from repro.models import get_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.elastic import mesh_from_env
from repro.runtime.failover import (FailureInjector, run_with_failover,
                                    SimulatedHardwareFailure)
from repro.runtime.watchdog import StepHang, Watchdog

__all__ = ["TrainLoop", "main"]


class TrainLoop:
    def __init__(self, cfg, *, steps: int, batch: int, seq: int,
                 ckpt_dir: str | None = None, lr: float = 3e-4,
                 mesh=None, ckpt_every: int = 20, seed: int = 0,
                 fail_at: tuple = (), log=print):
        self.cfg = cfg
        self.steps, self.batch, self.seq = steps, batch, seq
        self.log = log
        self.model = get_model(cfg)
        self.opt = AdamW(lr=cosine_schedule(lr, warmup=max(steps // 20, 5),
                                            total=steps))
        self.par = make_parallel_ctx(mesh) if mesh is not None else None
        self.ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.injector = FailureInjector(fail_at=fail_at)
        self.data = SyntheticLM(cfg.vocab, seed=seed)
        self.seed = seed
        self.history: list[dict] = []

        step_fn = make_train_step(cfg, self.par, self.opt)
        if self.par is not None:
            key_s = jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
            p_struct = jax.eval_shape(
                lambda k: self.model.init_params(cfg, k), key_s)
            pspecs = param_specs(cfg, self.par, p_struct)
            mesh_ = self.par.mesh
            self._pshard = to_shardings(mesh_, pspecs)
            self._oshard = to_shardings(mesh_, opt_state_specs(pspecs))
            _, b_struct = cfg.input_specs("train_4k")
            self.step_fn = jax.jit(
                step_fn,
                in_shardings=(self._pshard, self._oshard, None),
                out_shardings=(self._pshard, self._oshard, None),
                donate_argnums=(0, 1))
        else:
            self._pshard = self._oshard = None
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    # -- state management -------------------------------------------------------
    def init_state(self):
        params = self.model.init_params(self.cfg, jax.random.PRNGKey(self.seed))
        if self._pshard is not None:
            params = jax.device_put(params, self._pshard)
        return {"params": params, "opt": self.opt.init(params), "step": 0}

    def restore_or_init(self):
        if self.ckpt is not None:
            params_struct = jax.eval_shape(
                lambda: self.model.init_params(self.cfg,
                                               jax.random.PRNGKey(self.seed)))
            opt_struct = jax.eval_shape(
                lambda: self.opt.init(params_struct))
            got = self.ckpt.restore_latest(
                {"params": params_struct, "opt": opt_struct},
                {"params": self._pshard, "opt": self._oshard}
                if self._pshard is not None else None)
            if got is not None:
                step, tree, _ = got
                self.log(f"[train] resumed from step {step}")
                return {"params": tree["params"], "opt": tree["opt"],
                        "step": step}
        return self.init_state()

    # -- main loop ----------------------------------------------------------------
    def _run(self, state):
        wd = Watchdog(hang_timeout=600.0,
                      on_straggler=lambda info: self.log(
                          f"[watchdog] straggler: {info}"))
        pipe = DataPipeline(self.data, self.batch, self.seq,
                            start_step=state["step"])
        params, opt_state = state["params"], state["opt"]
        try:
            for step in range(state["step"], self.steps):
                batch_np = next(pipe)
                self.injector.maybe_fail(step)
                batch = {"tokens": batch_np["tokens"],
                         "labels": batch_np["labels"]}
                with wd.step():
                    t0 = time.time()
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch)
                    loss = float(metrics["loss"])
                    dt = time.time() - t0
                self.history.append({"step": step, "loss": loss,
                                     "time": dt})
                if step % 10 == 0 or step == self.steps - 1:
                    self.log(f"[train] step {step:5d} loss {loss:.4f} "
                             f"({dt*1e3:.0f} ms)")
                if self.ckpt and (step + 1) % self.ckpt_every == 0:
                    self.ckpt.save(step + 1,
                                   {"params": params, "opt": opt_state},
                                   extras={"loss": loss})
            if self.ckpt:
                self.ckpt.save(self.steps,
                               {"params": params, "opt": opt_state},
                               blocking=True)
            return {"params": params, "opt": opt_state, "step": self.steps}
        finally:
            pipe.close()
            wd.close()

    def run(self):
        state, restarts = run_with_failover(
            self._run, restore_fn=self.restore_or_init,
            recoverable=(SimulatedHardwareFailure, StepHang),
            log=self.log)
        if restarts:
            self.log(f"[train] completed after {restarts} failover restart(s)")
        return state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--mesh", default="off",
                    help="off | pod16x16 | pod2x16x16 | dNxM")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = None
    if args.mesh != "off":
        import os
        os.environ["REPRO_MESH"] = args.mesh
        mesh = mesh_from_env()
    loop = TrainLoop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt, lr=args.lr, mesh=mesh,
                     fail_at=tuple(args.fail_at))
    loop.run()
    losses = [h["loss"] for h in loop.history]
    print(f"[train] first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.4f}")
    if args.metrics_out:
        json.dump(loop.history, open(args.metrics_out, "w"))
    return loop


if __name__ == "__main__":
    main()
