"""GQA attention with flash-style chunked softmax (pure JAX) + KV-cache decode.

Production posture: the prefill/train path never materializes (S, S) scores;
it scans q-chunks and kv-chunks with an online-softmax accumulator (running
max / running sum), so activation memory is O(S * chunk) — this is what makes
the 32k-prefill cells compile with sane per-device memory.  Causality is
enforced by masking (the masked-out upper-triangle blocks still burn MXU
FLOPs in the baseline; EXPERIMENTS.md §Perf hillclimbs this).

DS-CIM scope: the q/k/v/o projections stay on the exact path by default
(DESIGN.md §6 — the MLP matmuls and LM head dominate).  A ``linear``
operator (DSCIMLinear) can be passed to route the projections through the
macro too — the opt-in '<mode>+attn' dscim spec (models/lm.py) — in which
case the projection weights may also be prepared ``QuantizedLinearWeight``
pytrees (core/qweights.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qweights import QuantizedLinearWeight

from .norms import qk_norm
from .rope import apply_rope, rope_angles

__all__ = ["init_attention", "attention", "decode_attention",
           "decode_attention_multi", "decode_attention_paged",
           "decode_attention_paged_multi", "AttnParams"]

NEG_INF = -1e30


def _mm(x, w, linear, salt):
    """Projection matmul: exact by default, DS-CIM when ``linear`` given."""
    if linear is None:
        if isinstance(w, QuantizedLinearWeight):
            raise TypeError("prepared attention weights need a DS-CIM "
                            "`linear` operator (the '+attn' dscim mode)")
        return x @ w
    return linear(x, w, salt=salt).astype(x.dtype)


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qk_norm_flag: bool = False, dtype=jnp.float32,
                   pad_to: int = 0):
    """``pad_to``: pad the q-head count (e.g. 36 -> 48) so heads shard
    cleanly over the TP axis.  Pad heads have zero wo rows, so the function
    computed is *exactly* unchanged (§Perf cell B iter-2); without the pad,
    GSPMD partial-shards the head dim and all-reduces attention internals."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    hp = max(n_heads, pad_to or n_heads)
    s = d_model ** -0.5
    wq = jax.random.normal(kq, (d_model, hp * head_dim), dtype) * s
    wo = jax.random.normal(ko, (hp * head_dim, d_model), dtype) \
        * (n_heads * head_dim) ** -0.5
    if hp > n_heads:
        live = n_heads * head_dim
        wq = wq.at[:, live:].set(0.0)
        wo = wo.at[live:, :].set(0.0)
    p = {
        "wq": wq,
        "wk": jax.random.normal(kk, (d_model, n_kv * head_dim), dtype) * s,
        "wv": jax.random.normal(kv, (d_model, n_kv * head_dim), dtype) * s,
        "wo": wo,
    }
    if qk_norm_flag:
        p["q_norm"] = {"scale": jnp.ones((head_dim,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((head_dim,), jnp.float32)}
    return p


def _qkv(params, x, n_heads, n_kv, head_dim, positions, rope_theta,
         use_qk_norm, linear=None, salt=None):
    B, S, _ = x.shape
    n_heads = params["wq"].shape[1] // head_dim   # includes TP head padding
    s = (lambda i: None) if salt is None else (lambda i: salt + i)
    q = _mm(x, params["wq"], linear, s(4)).reshape(B, S, n_heads, head_dim)
    k = _mm(x, params["wk"], linear, s(5)).reshape(B, S, n_kv, head_dim)
    v = _mm(x, params["wv"], linear, s(6)).reshape(B, S, n_kv, head_dim)
    if use_qk_norm:
        q = qk_norm(q, params.get("q_norm"))
        k = qk_norm(k, params.get("k_norm"))
    cos, sin = rope_angles(positions, head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _flash(q, k, v, q_pos, kv_pos, q_chunk: int, kv_chunk: int, n_rep: int):
    """Online-softmax attention. q (B,S,H,D); k/v (B,T,Hkv,D); GQA grouped.

    §Perf cell B: (a) kv heads are never materialized n_rep-fold — q is
    reshaped to (Hkv, n_rep) groups and contracted against kv directly;
    (b) the QK/AV einsums run in bf16 with f32 accumulation (MXU path) —
    the running max/sum statistics stay f32.

    q_pos (S,), kv_pos (T,): absolute positions for causal masking (kv_pos
    may include cache prefix).  Returns (B,S,H,D).
    """
    B, S, H, D = q.shape
    T = k.shape[1]
    G = k.shape[2]                        # kv heads
    scale = D ** -0.5
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    nq, nk = S // q_chunk, T // kv_chunk
    assert S % q_chunk == 0 and T % kv_chunk == 0, (S, T, q_chunk, kv_chunk)

    qc = q.reshape(B, nq, q_chunk, G, n_rep, D).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, G, n_rep, cq, D)
    kc = k.reshape(B, nk, kv_chunk, G, D).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nk, kv_chunk, G, D).transpose(1, 0, 3, 2, 4)
    qp = q_pos.reshape(nq, q_chunk)
    kp = kv_pos.reshape(nk, kv_chunk)
    bf = jnp.bfloat16

    causal_dense = (S == T)   # train/prefill: q and kv cover the same span

    def _block(qi, qpi, kcj, vcj, kpj, acc, m, l):
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qi.astype(bf), kcj.astype(bf),
                       preferred_element_type=jnp.float32) * scale
        mask = qpi[None, None, None, :, None] >= kpj[None, None, None,
                                                     None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgrqk,bgkd->bgrqd", p.astype(bf), vcj.astype(bf),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    if causal_dense and q_chunk == kv_chunk and nq == nk:
        # §Perf cell B iter-3: scan only the nq(nq+1)/2 lower-triangle
        # (q-chunk, kv-chunk) pairs — the upper triangle is fully masked
        # and would burn MXU flops + HBM bytes for nothing.
        import numpy as _np
        ii, jj = _np.tril_indices(nq)
        pairs = (jnp.asarray(ii, jnp.int32), jnp.asarray(jj, jnp.int32))

        def body(carry, ij):
            acc_all, m_all, l_all = carry
            i, j = ij
            qi = jax.lax.dynamic_index_in_dim(qc, i, 0, keepdims=False)
            qpi = jax.lax.dynamic_index_in_dim(qp, i, 0, keepdims=False)
            kcj = jax.lax.dynamic_index_in_dim(kc, j, 0, keepdims=False)
            vcj = jax.lax.dynamic_index_in_dim(vc, j, 0, keepdims=False)
            kpj = jax.lax.dynamic_index_in_dim(kp, j, 0, keepdims=False)
            acc = jax.lax.dynamic_index_in_dim(acc_all, i, 0, keepdims=False)
            m = jax.lax.dynamic_index_in_dim(m_all, i, 0, keepdims=False)
            l = jax.lax.dynamic_index_in_dim(l_all, i, 0, keepdims=False)
            acc, m, l = _block(qi, qpi, kcj, vcj, kpj, acc, m, l)
            acc_all = jax.lax.dynamic_update_index_in_dim(acc_all, acc, i, 0)
            m_all = jax.lax.dynamic_update_index_in_dim(m_all, m, i, 0)
            l_all = jax.lax.dynamic_update_index_in_dim(l_all, l, i, 0)
            return (acc_all, m_all, l_all), None

        acc0 = jnp.zeros((nq, B, G, n_rep, q_chunk, D), jnp.float32)
        m0 = jnp.full((nq, B, G, n_rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((nq, B, G, n_rep, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), pairs)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
    else:
        def per_q(qi, qpi):
            acc0 = jnp.zeros((B, G, n_rep, q_chunk, D), jnp.float32)
            m0 = jnp.full((B, G, n_rep, q_chunk), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, G, n_rep, q_chunk), jnp.float32)

            def inner(carry, kj):
                kcj, vcj, kpj = kj
                return _block(qi, qpi, kcj, vcj, kpj, *carry), None

            (acc, m, l), _ = jax.lax.scan(inner, (acc0, m0, l0),
                                          (kc, vc, kp))
            return acc / jnp.maximum(l, 1e-30)[..., None]

        out = jax.lax.map(lambda args: per_q(*args), (qc, qp))
    # (nq, B, G, n_rep, cq, D) -> (B, nq, cq, G, n_rep, D) -> (B, S, H, D)
    return out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, D).astype(q.dtype)


def attention(params, x, cfg, positions=None, q_chunk: int = 512,
              kv_chunk: int = 1024, return_kv: bool = False,
              linear=None, salt=None):
    """Full-sequence (train / prefill) GQA attention block.

    cfg needs: n_heads, n_kv, head_dim, rope_theta, qk_norm.
    Returns (out, (k, v)) where k/v are the cacheable projections.
    ``linear``/``salt``: optional DS-CIM operator for the projections
    (sites 4..7 of the per-layer salt space; mlp owns 0..2).
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = _qkv(params, x, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                   positions, cfg.rope_theta, cfg.qk_norm, linear, salt)
    n_rep = q.shape[2] // cfg.n_kv
    pos1 = positions[0]
    kv_chunk = q_chunk  # square blocks enable the causal pair-scan path
    out = _flash(q, k, v, pos1, pos1, q_chunk, kv_chunk, n_rep)
    out = _mm(out.reshape(B, S, -1), params["wo"], linear,
              None if salt is None else salt + 7)
    return (out, (k, v)) if return_kv else (out, None)


def decode_attention(params, x, cache_k, cache_v, pos, cfg,
                     linear=None, salt=None):
    """Single-token decode against a fixed-capacity KV cache.

    x (B,1,D); cache_k/v (B, T, n_kv, head_dim) with valid prefix length
    ``pos``: a scalar (all rows in lockstep — the PR 3 fixed-length path,
    bit-compatible) or a per-slot (B,) vector for ragged completion /
    continuous batching (each row writes and masks at its own position;
    a finished row whose pos stops advancing benignly rewrites its own
    head entry — it is dead until re-admission overwrites the whole row).
    Returns (out (B,1,D), new_k, new_v).
    """
    B, _, _ = x.shape
    T = cache_k.shape[1]
    ragged = getattr(pos, "ndim", 0) == 1
    positions = (pos[:, None].astype(jnp.int32) if ragged
                 else jnp.full((B, 1), pos, jnp.int32))
    q, k, v = _qkv(params, x, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                   positions, cfg.rope_theta, cfg.qk_norm, linear, salt)
    if ragged:
        def upd(c, kk, p):
            return jax.lax.dynamic_update_slice_in_dim(c, kk, p, axis=0)
        new_k = jax.vmap(upd)(cache_k, k.astype(cache_k.dtype), pos)
        new_v = jax.vmap(upd)(cache_v, v.astype(cache_v.dtype), pos)
        mask = jnp.arange(T)[None, None, None, :] <= pos[:, None, None, None]
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), pos, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), pos, axis=1)
        mask = jnp.arange(T)[None, None, None, :] <= pos
    n_rep = q.shape[2] // cfg.n_kv
    kr = jnp.repeat(new_k, n_rep, axis=2)            # (B,T,H,D)
    vr = jnp.repeat(new_v, n_rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * cfg.head_dim ** -0.5
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    out = _mm(out.reshape(B, 1, -1).astype(x.dtype), params["wo"], linear,
              None if salt is None else salt + 7)
    return out, new_k, new_v


def decode_attention_multi(params, x, cache_k, cache_v, pos, cfg,
                           linear=None, salt=None, done=None):
    """Speculative-verify decode: score T consecutive tokens per row in one
    call against a fixed-capacity KV cache.

    The projections (q/k/v/wo) batch over the window — that is the whole
    speedup — while the cache write / mask / softmax run as a per-position
    ``lax.scan`` that replays the exact op sequence of ``decode_attention``
    (write position t, mask ``tj <= pos + t``, (B,1,..)-shaped einsums), so
    position t here is bitwise-identical to t successive single-token
    decodes with the same weights.  (DS-CIM ``statistical``/``paper_inject``
    estimators draw shape-keyed noise and are excluded from that guarantee;
    ``exact``/``lut``/``bitmatmul``/``kernel`` and the plain float path
    batch bitwise-cleanly.)

    x (B, T, D); pos (B,) valid prefix lengths.  ``done`` rows freeze their
    positions (write/mask at ``pos``, like the single-token ragged path) so
    a finished slot only benignly rewrites its own head entry.
    Returns (out (B, T, D), new_k, new_v).
    """
    B, T, _ = x.shape
    Tc = cache_k.shape[1]
    step = jnp.ones((B,), jnp.int32) if done is None \
        else jnp.where(done, 0, 1).astype(jnp.int32)
    offs = jnp.arange(T, dtype=jnp.int32)
    positions = pos[:, None].astype(jnp.int32) + step[:, None] * offs[None, :]
    q, k, v = _qkv(params, x, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                   positions, cfg.rope_theta, cfg.qk_norm, linear, salt)
    n_rep = q.shape[2] // cfg.n_kv

    def upd(c, kk, p):
        return jax.lax.dynamic_update_slice_in_dim(c, kk[None], p, axis=0)

    def pstep(carry, xs):
        ck, cv = carry
        qt, kt, vt, t = xs                                # (B,H,D)/(B,KV,D)
        pt = pos + step * t                               # (B,)
        nk = jax.vmap(upd)(ck, kt.astype(ck.dtype), pt)
        nv = jax.vmap(upd)(cv, vt.astype(cv.dtype), pt)
        mask = jnp.arange(Tc)[None, None, None, :] <= pt[:, None, None, None]
        kr = jnp.repeat(nk, n_rep, axis=2)
        vr = jnp.repeat(nv, n_rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qt[:, None].astype(jnp.float32),
                       kr.astype(jnp.float32)) * cfg.head_dim ** -0.5
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ot = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
        return (nk, nv), ot[:, 0]                         # (B,H,D)

    (new_k, new_v), outs = jax.lax.scan(
        pstep, (cache_k, cache_v),
        (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
         jnp.moveaxis(v, 1, 0), offs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, T, -1).astype(x.dtype)
    out = _mm(out, params["wo"], linear,
              None if salt is None else salt + 7)
    return out, new_k, new_v


def _paged_read_jnp(qf, view, k_tail, v_tail):
    """The jnp reference read path: flash-style online softmax over logical
    pages as a ``lax.scan``, gathering each physical int8 page and fusing
    its per-kv-head dequant into the inner loop — the full-precision cache
    is never materialized (though on TPU the gathered page and its f32
    copy still stage through HBM, which is what the Pallas kernel path
    removes).  qf (B, KV, n_rep, HD) f32; returns (B, KV, n_rep, HD) f32.
    """
    B, KV, n_rep, HD = qf.shape
    pos = view["pos"]
    page_table = view["page_table"]
    k_pages, v_pages = view["k_pages"], view["v_pages"]
    k_scale, v_scale = view["k_scale"], view["v_scale"]
    ps = k_pages.shape[1]
    MP = page_table.shape[1]
    scale_qk = HD ** -0.5
    tail_page = pos // ps

    def page_step(carry, j):
        m, l, acc = carry
        phys = page_table[:, j]                           # (B,)
        kj = k_pages[phys].astype(jnp.float32) \
            * k_scale[phys][:, None, :, None]             # (B,ps,KV,HD)
        vj = v_pages[phys].astype(jnp.float32) \
            * v_scale[phys][:, None, :, None]
        is_tail = (j == tail_page)[:, None, None, None]
        kj = jnp.where(is_tail, k_tail.astype(jnp.float32), kj)
        vj = jnp.where(is_tail, v_tail.astype(jnp.float32), vj)
        tj = j * ps + jnp.arange(ps, dtype=jnp.int32)     # token indices
        valid = tj[None, :] <= pos[:, None]               # (B,ps)
        s = jnp.einsum("bgrd,bpgd->bgrp", qf, kj) * scale_qk
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bgrp,bpgd->bgrd", p, vj)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, KV, n_rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, n_rep), jnp.float32)
    acc0 = jnp.zeros((B, KV, n_rep, HD), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(page_step, (m0, l0, acc0),
                                  jnp.arange(MP, dtype=jnp.int32))
    return acc / jnp.maximum(l, 1e-30)[..., None]         # (B,KV,R,HD)


def _paged_read_kernel(qf, view, k_tail, v_tail, par):
    """The fused Pallas read path (kernels/paged_attention.py): one launch
    walking the page table per (slot, kv-head-group) grid cell, int8 pages
    streamed into VMEM by scalar-prefetch index maps, dequant + bf16 tail
    overlay fused into the in-VMEM online softmax.  Under a mesh the call
    wraps in shard_map (batch over DP, pool gathered — Pallas cannot be
    GSPMD-partitioned).  Tile knobs come from the autotune cache under
    ``REPRO_DSCIM_TUNE`` (checked-in winners for the serving shapes)."""
    import os

    from repro.kernels.paged_attention import (paged_attention_decode,
                                               paged_attention_decode_sharded)
    tune = os.environ.get("REPRO_DSCIM_TUNE", "") not in ("", "0")
    args = (qf, view["k_pages"], view["v_pages"], view["k_scale"],
            view["v_scale"], k_tail, v_tail, view["page_table"], view["pos"])
    if par is not None:
        return paged_attention_decode_sharded(
            *args, mesh=par.mesh, dp_axes=par.dp_axes, tune=tune)
    return paged_attention_decode(*args, tune=tune)


def decode_attention_paged(params, x, view, cfg, linear=None, salt=None,
                           done=None, par=None, use_kernel=None):
    """Single-token decode against one layer of the int8 paged KV cache
    (core/kvcache.py): flash-style online softmax over logical pages with
    the int8->f32 dequant fused into the inner loop — the full-precision
    cache is never materialized.

    Two read paths compute the page walk: the fused Pallas kernel and the
    jnp gather scan (the reference).  ``use_kernel`` selects explicitly
    (the serve stack threads it from ``paged_attn='kernel'|'jnp'``, which
    keys the jitted-builder caches); ``None`` falls back to
    ``kernels.paged_attention.use_paged_kernel(cfg.dscim)`` — kernel for
    the 'kernel' serving mode, jnp everywhere else, with the
    ``REPRO_PAGED_ATTN`` env knob (read at trace time) forcing either.
    Both walk pages in the same order with f32 statistics, so they agree
    to float-accumulation tolerance (~1e-8 end-to-end logit RMSE in
    interpret mode — tests/test_paged_kernel.py asserts <=1e-5).

    ``view`` (one layer's slice of the paged cache dict):
      k_pages/v_pages (P, ps, KV, HD) int8, k_scale/v_scale (P, KV) f32,
      k_tail/v_tail (B, ps, KV, HD), page_table (B, MP) int32, pos (B,).
    ``done`` (B,) bool: finished slots neither advance nor flush — a dead
    slot must not scatter into pool pages its allocator may already have
    re-granted to a live request.  (The read needs no done mask of its
    own: a finished slot's ``pos`` is frozen, so the in-loop ragged mask
    already covers it.)
    ``par``: ParallelCtx when serving under a mesh — the kernel path must
    run inside shard_map there; the jnp path partitions under GSPMD and
    ignores it.

    Returns (out (B,1,D), (k_pages, v_pages, k_scale, v_scale, k_tail,
    v_tail)) — pos advances at the model level, shared by all layers.
    """
    from repro.core.kvcache import quantize_page
    from repro.kernels.paged_attention import use_paged_kernel

    B = x.shape[0]
    pos = view["pos"]
    page_table = view["page_table"]
    k_pages, v_pages = view["k_pages"], view["v_pages"]
    k_scale, v_scale = view["k_scale"], view["v_scale"]
    n_pages, ps, KV, HD = k_pages.shape
    positions = pos[:, None].astype(jnp.int32)
    q, k, v = _qkv(params, x, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                   positions, cfg.rope_theta, cfg.qk_norm, linear, salt)

    # 1. the new token lands in the slot's tail page at offset pos % ps
    #    (bf16 — recent tokens attend at full precision until the page
    #    fills and is quantized exactly once)
    off = pos % ps

    def _tail_write(tail, val):
        def upd(t, vv, o):
            return jax.lax.dynamic_update_slice_in_dim(t, vv[None], o, 0)
        new = jax.vmap(upd)(tail, val[:, 0].astype(tail.dtype), off)
        if done is None:
            return new
        return jnp.where(done[:, None, None, None], tail, new)

    k_tail = _tail_write(view["k_tail"], k)
    v_tail = _tail_write(view["v_tail"], v)

    # 2. the page walk: online softmax with in-loop dequant + tail overlay
    n_rep = q.shape[2] // KV
    # _qkv lays heads out kv-major: head h = (g, r) with g = h // n_rep,
    # matching jnp.repeat(k, n_rep, axis=2) on the dense path
    qf = q[:, 0].astype(jnp.float32).reshape(B, KV, n_rep, HD)
    if use_kernel is None:
        use_kernel = use_paged_kernel(getattr(cfg, "dscim", "off"))
    if use_kernel:
        out = _paged_read_kernel(qf, view, k_tail, v_tail, par)
    else:
        out = _paged_read_jnp(qf, view, k_tail, v_tail)
    out = out.reshape(B, 1, -1).astype(x.dtype)

    # 3. flush: a tail page that just filled is quantized (fresh per-head
    #    absmax scales) and scattered to its physical page; slots that are
    #    not flushing (or are done) scatter to an out-of-bounds sentinel
    #    which mode="drop" discards — no read-modify-write, no collisions
    full = (pos + 1) % ps == 0
    if done is not None:
        full = full & ~done
    tail_page = pos // ps
    phys_t = jnp.take_along_axis(page_table, tail_page[:, None], 1)[:, 0]
    idx = jnp.where(full, phys_t, n_pages)
    qk_, sk_ = quantize_page(k_tail)
    qv_, sv_ = quantize_page(v_tail)
    k_pages = k_pages.at[idx].set(qk_, mode="drop")
    v_pages = v_pages.at[idx].set(qv_, mode="drop")
    k_scale = k_scale.at[idx].set(sk_, mode="drop")
    v_scale = v_scale.at[idx].set(sv_, mode="drop")

    out = _mm(out, params["wo"], linear,
              None if salt is None else salt + 7)
    return out, (k_pages, v_pages, k_scale, v_scale, k_tail, v_tail)


def decode_attention_paged_multi(params, x, view, cfg, linear=None, salt=None,
                                 done=None, par=None, use_kernel=None):
    """Speculative-verify decode against one layer of the int8 paged cache:
    score T consecutive tokens per row in one call.

    Projections batch over the window; the tail-write / page-walk / flush
    sequence runs per position inside a ``lax.scan``, replaying
    ``decode_attention_paged`` exactly (write tail at ``pt % ps``, read with
    the frozen-``pt`` ragged mask — which is how the kernel's masking covers
    in-flight draft positions — then quantize-once flush when ``pt`` fills a
    page), so position t is bitwise-identical to t successive single-token
    decodes.  ``done`` rows freeze ``pt`` and suppress writes + flushes,
    exactly like the single-token path.

    Also returns the window's K/V projections in tail dtype — the
    speculative rollback (core/kvcache.spec_rollback) needs them to rebuild
    the committed tail when a rejected window crossed a page boundary.

    Returns (out (B, T, D),
             (k_pages, v_pages, k_scale, v_scale, k_tail, v_tail),
             (win_k, win_v))  with win_k/win_v (B, T, KV, HD) tail-dtype.
    """
    from repro.core.kvcache import quantize_page
    from repro.kernels.paged_attention import use_paged_kernel

    B, T, _ = x.shape
    pos = view["pos"]
    page_table = view["page_table"]
    n_pages, ps, KV, HD = view["k_pages"].shape
    step = jnp.ones((B,), jnp.int32) if done is None \
        else jnp.where(done, 0, 1).astype(jnp.int32)
    offs = jnp.arange(T, dtype=jnp.int32)
    positions = pos[:, None].astype(jnp.int32) + step[:, None] * offs[None, :]
    q, k, v = _qkv(params, x, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                   positions, cfg.rope_theta, cfg.qk_norm, linear, salt)
    n_rep = q.shape[2] // KV
    if use_kernel is None:
        use_kernel = use_paged_kernel(getattr(cfg, "dscim", "off"))
    win_k = k.astype(view["k_tail"].dtype)                # (B,T,KV,HD)
    win_v = v.astype(view["v_tail"].dtype)

    def upd(t, vv, o):
        return jax.lax.dynamic_update_slice_in_dim(t, vv[None], o, 0)

    def pstep(carry, xs):
        k_pages, v_pages, k_scale, v_scale, k_tail, v_tail = carry
        qt, kt, vt, t = xs                                # (B,KV,..,HD)
        pt = pos + step * t
        off = pt % ps
        nkt = jax.vmap(upd)(k_tail, kt, off)
        nvt = jax.vmap(upd)(v_tail, vt, off)
        if done is not None:
            nkt = jnp.where(done[:, None, None, None], k_tail, nkt)
            nvt = jnp.where(done[:, None, None, None], v_tail, nvt)
        viewt = {"k_pages": k_pages, "v_pages": v_pages,
                 "k_scale": k_scale, "v_scale": v_scale,
                 "page_table": page_table, "pos": pt}
        qf = qt.astype(jnp.float32).reshape(B, KV, n_rep, HD)
        if use_kernel:
            ot = _paged_read_kernel(qf, viewt, nkt, nvt, par)
        else:
            ot = _paged_read_jnp(qf, viewt, nkt, nvt)
        full = (pt + 1) % ps == 0
        if done is not None:
            full = full & ~done
        tail_page = pt // ps
        phys_t = jnp.take_along_axis(page_table, tail_page[:, None], 1)[:, 0]
        idx = jnp.where(full, phys_t, n_pages)
        qk_, sk_ = quantize_page(nkt)
        qv_, sv_ = quantize_page(nvt)
        k_pages = k_pages.at[idx].set(qk_, mode="drop")
        v_pages = v_pages.at[idx].set(qv_, mode="drop")
        k_scale = k_scale.at[idx].set(sk_, mode="drop")
        v_scale = v_scale.at[idx].set(sv_, mode="drop")
        return (k_pages, v_pages, k_scale, v_scale, nkt, nvt), ot

    carry0 = (view["k_pages"], view["v_pages"], view["k_scale"],
              view["v_scale"], view["k_tail"], view["v_tail"])
    planes, outs = jax.lax.scan(
        pstep, carry0,
        (jnp.moveaxis(q, 1, 0), jnp.moveaxis(win_k, 1, 0),
         jnp.moveaxis(win_v, 1, 0), offs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, T, -1).astype(x.dtype)
    out = _mm(out, params["wo"], linear,
              None if salt is None else salt + 7)
    return out, planes, (win_k, win_v)


AttnParams = dict
