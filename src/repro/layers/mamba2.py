"""Mamba2 (SSD) block with scalar-per-head decay, chunked scan, O(1) decode.

Recurrence per head (state h in R^{P x N}, P = head dim, N = ssm state):

    h_t = exp(dt_t * A) h_{t-1} + (dt_t x_t) B_t^T
    y_t = h_t C_t + D x_t

Chunked evaluation uses the scalar pairwise decay ratio (B,H,C,C) — cheap,
no per-channel blowup.  A depthwise causal conv (kernel 4) precedes x/B/C as
in the reference implementation; decode carries (conv tail, h) state.
Used by the zamba2-7b hybrid config (ssm_state=64).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .norms import rmsnorm

__all__ = ["init_mamba2", "mamba2_block", "init_mamba2_state"]

CONV_K = 4


def init_mamba2(key, d_model: int, head_dim: int = 64, ssm_state: int = 64,
                expand: int = 1, dtype=jnp.float32):
    d_in = expand * d_model
    H = d_in // head_dim
    ks = jax.random.split(key, 8)
    s = d_model ** -0.5
    n = lambda k, shp, sc=s: jax.random.normal(k, shp, dtype) * sc
    return {
        "wz": n(ks[0], (d_model, d_in)),
        "wx": n(ks[1], (d_model, d_in)),
        "wB": n(ks[2], (d_model, ssm_state)),
        "wC": n(ks[3], (d_model, ssm_state)),
        "wdt": n(ks[4], (d_model, H)),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.zeros((H,), dtype),              # A = -exp(A_log)
        "D": jnp.ones((H,), dtype),
        "conv": jax.random.normal(ks[5], (CONV_K, d_in + 2 * ssm_state),
                                  dtype) * 0.2,
        "norm": {"scale": jnp.ones((d_in,), jnp.float32)},
        "wo": n(ks[6], (d_in, d_model), d_in ** -0.5),
    }


def init_mamba2_state(batch: int, d_model: int, head_dim: int = 64,
                      ssm_state: int = 64, expand: int = 1,
                      dtype=jnp.float32):
    d_in = expand * d_model
    H = d_in // head_dim
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, d_in + 2 * ssm_state), dtype),
        "h": jnp.zeros((batch, H, head_dim, ssm_state), jnp.float32),
    }


def _causal_conv(xbc, weight, tail):
    """Depthwise causal conv over time. xbc (B,S,Dc), weight (K,Dc),
    tail (B,K-1,Dc) carries the previous tokens."""
    full = jnp.concatenate([tail, xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1]] * weight[i]
              for i in range(CONV_K))
    return jax.nn.silu(out), full[:, -(CONV_K - 1):]


def _ssd_chunk(h0, inp):
    """One chunk. h0 (B,H,P,N); x (B,C,H,P), Bm/Cm (B,C,N), lw (B,C,H)."""
    x, Bm, Cm, lw, dt = inp
    cum = jnp.cumsum(lw, axis=1)                          # (B,C,H)
    # intra: scores[t,s] = exp(cum[t]-cum[s]) * (C_t . B_s) * dt_s  (s<=t)
    ratio = jnp.exp(jnp.clip(cum[:, :, None] - cum[:, None, :], -60.0, 0.0))
    C = x.shape[1]
    tri = jnp.tril(jnp.ones((C, C), bool))[None, :, :, None]
    cb = jnp.einsum("btn,bsn->bts", Cm, Bm)               # (B,C,C)
    scores = jnp.where(tri, ratio * cb[..., None], 0.0)   # (B,C,C,H)
    scores = scores * dt[:, None, :, :]                   # fold dt_s
    y = jnp.einsum("btsh,bshp->bthp", scores, x)
    # inter: y_t += exp(cum[t]) * C_t h0^T
    y = y + jnp.exp(cum)[..., None] * jnp.einsum(
        "btn,bhpn->bthp", Cm, h0)
    # chunk-end state
    kscale = jnp.exp(jnp.clip(cum[:, -1:, :] - cum, -60.0, 0.0)) \
        * dt                                              # (B,C,H)
    h1 = h0 * jnp.exp(cum[:, -1])[..., None, None] \
        + jnp.einsum("bch,bchp,bcn->bhpn", kscale, x, Bm)
    return h1, y


def mamba2_block(params, x, state=None, head_dim: int = 64, chunk: int = 64,
                 shard_fn=None):
    """x (B,S,D) -> (out (B,S,D), new_state). ssm_state derived from wB.

    ``shard_fn`` pins the sharding of the (nc,B,c,...) chunk streams (same
    GSPMD loop-state replication fix as rwkv6, §Perf cell A); the chunk
    body is rematerialized in the backward pass."""
    B, S, D = x.shape
    d_in = params["wx"].shape[1]
    ssm_state = params["wB"].shape[1]
    H = d_in // head_dim
    if state is None:
        state = init_mamba2_state(B, D, head_dim, ssm_state,
                                  d_in // D, x.dtype)
    z = x @ params["wz"]
    xb = x @ params["wx"]
    Bm = x @ params["wB"]
    Cm = x @ params["wC"]
    xbc, conv_tail = _causal_conv(
        jnp.concatenate([xb, Bm, Cm], axis=-1), params["conv"],
        state["conv"])
    xb, Bm, Cm = jnp.split(xbc, [d_in, d_in + ssm_state], axis=-1)
    dt = jax.nn.softplus((x @ params["wdt"]) + params["dt_bias"])
    dt = dt.astype(jnp.float32)                           # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))     # (H,)
    lw = jnp.clip(dt * A[None, None, :], -30.0, -1e-6)    # log decay

    c = min(chunk, S)
    assert S % c == 0, (S, c)
    nc = S // c
    xh = xb.reshape(B, S, H, head_dim).astype(jnp.float32)
    sf = shard_fn or (lambda t: t)
    rs = lambda t: sf(t.reshape(B, nc, c, *t.shape[2:]).transpose(
        1, 0, 2, *range(3, t.ndim + 1)))
    h0 = state["h"]
    body = lambda h, i: _ssd_chunk(h, i)
    if S > c:  # remat chunk internals in the backward pass
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    hN, ys = jax.lax.scan(
        body,
        h0, (rs(xh), rs(Bm.astype(jnp.float32)), rs(Cm.astype(jnp.float32)),
             rs(lw), rs(dt)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, head_dim)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"]).astype(x.dtype)
    out = y @ params["wo"]
    return out, {"conv": conv_tail, "h": hN}
