"""Feed-forward blocks: SwiGLU (llama/qwen family) and GELU (starcoder-ish),
with an optional DS-CIM serving path (DSCIMLinear swaps in for the matmuls
when a macro config is attached at serve time).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_mlp", "mlp"]


def init_mlp(key, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {"w_up": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
         "w_down": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out}
    if kind == "swiglu":
        p["w_gate"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    return p


def mlp(params, x, kind: str = "swiglu", linear=None):
    """linear: optional callable (x2d, w) -> y2d (e.g. DSCIMLinear)."""
    def mm(a, w):
        if linear is None:
            return a @ w
        # DSCIMLinear consumes (..., K) natively (the fused kernel maps
        # leading dims onto a batch grid axis — no flatten round-trip)
        return linear(a, w).astype(a.dtype)

    if kind == "swiglu":
        h = jax.nn.silu(mm(x, params["w_gate"])) * mm(x, params["w_up"])
    else:
        h = jax.nn.gelu(mm(x, params["w_up"]))
    return mm(h, params["w_down"])
