"""Feed-forward blocks: SwiGLU (llama/qwen family) and GELU (starcoder-ish),
with an optional DS-CIM serving path (DSCIMLinear swaps in for the matmuls
when a macro config is attached at serve time).

Weights may be plain float matrices or prepared ``QuantizedLinearWeight``
pytrees (core/qweights.py, serve startup quantize-once) — the latter require
a ``linear`` operator that understands them (DSCIMLinear does).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qweights import QuantizedLinearWeight

__all__ = ["init_mlp", "mlp"]


def init_mlp(key, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {"w_up": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
         "w_down": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out}
    if kind == "swiglu":
        p["w_gate"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    return p


def mlp(params, x, kind: str = "swiglu", linear=None, salt=None):
    """linear: optional callable (x2d, w) -> y2d (e.g. DSCIMLinear).
    ``salt``: static/traced int decorrelating the linear's fallback noise
    key across layers; the three matmul sites fold in offsets 0..2."""
    def mm(a, w, site):
        if linear is None:
            if isinstance(w, QuantizedLinearWeight):
                raise TypeError(
                    "prepared (QuantizedLinearWeight) params need a DS-CIM "
                    "`linear` operator — don't prepare for the float path")
            return a @ w
        # DSCIMLinear consumes (..., K) natively (the fused kernel maps
        # leading dims onto a batch grid axis — no flatten round-trip)
        s = None if salt is None else salt + site
        return linear(a, w, salt=s).astype(a.dtype)

    if kind == "swiglu":
        h = jax.nn.silu(mm(x, params["w_gate"], 0)) * mm(x, params["w_up"], 1)
    else:
        h = jax.nn.gelu(mm(x, params["w_up"], 1))
    return mm(h, params["w_down"], 2)
