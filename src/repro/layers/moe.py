"""Mixture-of-Experts with production expert parallelism.

Dispatch is *sort-based with fixed capacity* (the Megatron/MaxText dropping
implementation), not the GShard one-hot einsum — at 1M-token batches the
(tokens, E, capacity) dispatch tensor would be ~16 GB/device, while the
sort-based path is O(tokens·k) index arithmetic plus two `all_to_all`s.

Topology: inside the pjit'd layer, activations are replicated over the
'model' axis; the MoE block (a) splits the sequence across 'model' (so each
EP rank routes a distinct token slice), (b) scatters tokens into per-expert
capacity buffers, (c) `all_to_all`s them to the expert owners, (d) runs the
expert FFNs (experts are sharded over 'model'), (e) `all_to_all`s back and
combines, (f) `all_gather`s the sequence slices.  DeepSeek-MoE style shared
experts run densely on every token.

When ``ep_axis is None`` (single-device smoke tests) the same code runs with
ep=1 and no collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .mlp import init_mlp, mlp

__all__ = ["init_moe", "moe", "moe_local"]


def init_moe(key, d_model: int, d_ff: int, n_experts: int, top_k: int,
             n_shared: int = 0, dtype=jnp.float32):
    kr, ke, ks = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "router": jax.random.normal(kr, (d_model, n_experts), dtype) * s_in,
        "experts": {
            "w_gate": jax.random.normal(jax.random.fold_in(ke, 0),
                                        (n_experts, d_model, d_ff), dtype) * s_in,
            "w_up": jax.random.normal(jax.random.fold_in(ke, 1),
                                      (n_experts, d_model, d_ff), dtype) * s_in,
            "w_down": jax.random.normal(jax.random.fold_in(ke, 2),
                                        (n_experts, d_ff, d_model), dtype) * s_out,
        },
    }
    if n_shared:
        p["shared"] = init_mlp(ks, d_model, n_shared * d_ff, "swiglu", dtype)
    return p


def _expert_ffn(we, x):
    """x (E_loc, C', D) through per-expert SwiGLU FFNs."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, we["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", x, we["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, we["w_down"])


def _route(xs, router_w, top_k: int):
    """xs (T, D) -> (ids (T,k) int32, weights (T,k) f32, aux loss scalar)."""
    logits = (xs.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, ids = jax.lax.top_k(probs, top_k)
    weights = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * mean(frac_tokens_e * mean_prob_e)
    E = logits.shape[-1]
    frac = jnp.mean(jax.nn.one_hot(ids[:, 0], E), axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
    return ids, weights, aux


def moe(params, x, *, top_k: int, capacity_factor: float = 1.25,
        ep_axis: str | None = None, has_shared: bool = False,
        linear=None, salt=None):
    """x (B, S, D) -> (out (B, S, D), aux).  See module docstring.

    ``linear``/``salt``: optional DS-CIM operator for the *shared* expert's
    dense matmuls (it runs on every token — same hot-path class as the MLP
    block); the routed experts stay on the exact einsum path."""
    B, S, D = x.shape
    E = params["router"].shape[-1]
    # jax.lax.axis_size is newer-jax; psum(1, axis) is the portable idiom
    ep = (jax.lax.axis_size(ep_axis) if hasattr(jax.lax, "axis_size")
          else jax.lax.psum(1, ep_axis)) if ep_axis else 1
    assert E % ep == 0, (E, ep)

    split_seq = bool(ep_axis) and ep > 1 and S % ep == 0
    if split_seq:
        rank = jax.lax.axis_index(ep_axis)
        S_loc = S // ep
        xs = jax.lax.dynamic_slice_in_dim(x, rank * S_loc, S_loc, axis=1)
    else:
        # decode-style tiny S: every EP rank routes the same tokens; the
        # all_to_all still delivers each expert's buffer to its owner and
        # every rank reconstructs identical outputs (no gather needed).
        S_loc = S
        xs = x
    xt = xs.reshape(B * S_loc, D)
    T = B * S_loc

    ids, weights, aux = _route(xt, params["router"], top_k)

    # ---- sort-based capacity dispatch ----
    C = max(int(T * top_k / E * capacity_factor), top_k)
    flat_ids = ids.reshape(-1)                             # (T*k,)
    order = jnp.argsort(flat_ids)                          # stable
    sorted_ids = flat_ids[order]
    ones = jnp.ones_like(sorted_ids)
    # position within expert among the sorted sequence
    seg_pos = jnp.cumsum(ones) - 1
    starts = jnp.searchsorted(sorted_ids, jnp.arange(E), side="left")
    pos_in_e = seg_pos - starts[sorted_ids]
    keep = pos_in_e < C                                    # dropped beyond cap
    slot = jnp.where(keep, sorted_ids * C + pos_in_e, E * C)
    tok_idx = order // top_k
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[slot].set(
        xt[tok_idx], mode="drop")
    buf = buf[:-1].reshape(E, C, D)

    # ---- EP all_to_all: experts to owners ----
    if ep_axis and ep > 1:
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)               # (E_loc, ep*C, D)
    out_buf = _expert_ffn(params["experts"], buf)
    if ep_axis and ep > 1:
        out_buf = jax.lax.all_to_all(out_buf, ep_axis, split_axis=1,
                                     concat_axis=0, tiled=True)  # (E, C, D)

    # ---- combine ----
    flat_out = out_buf.reshape(E * C, D)
    gathered = jnp.where(keep[:, None],
                         flat_out[jnp.clip(slot, 0, E * C - 1)], 0.0)
    contrib = gathered * weights.reshape(-1)[order][:, None]
    out_t = jnp.zeros_like(xt).at[tok_idx].add(contrib)

    if has_shared:
        out_t = out_t + mlp(params["shared"], xt, "swiglu", linear=linear,
                            salt=salt)
    out = out_t.reshape(B, S_loc, D)

    if split_seq:
        out = jax.lax.all_gather(out, ep_axis, axis=1, tiled=True)  # (B,S,D)
    return out, aux


def moe_local(params, x, *, top_k: int, capacity_factor: float = 2.0,
              has_shared: bool = False, linear=None, salt=None):
    """Single-device convenience (smoke tests + single-device serving —
    the path that accepts prepared shared-expert weights)."""
    return moe(params, x, top_k=top_k, capacity_factor=capacity_factor,
               ep_axis=None, has_shared=has_shared, linear=linear, salt=salt)
