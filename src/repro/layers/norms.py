"""Normalization layers (functional, param-dict based).

Covers the assigned-arch zoo: parametric RMSNorm (qwen/llama-family),
non-parametric LayerNorm (OLMo-1B uses LN without scale/bias), per-head
qk-norm (qwen3).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rmsnorm", "layernorm", "init_rmsnorm", "qk_norm"]


def init_rmsnorm(dim: int, parametric: bool = True):
    return {"scale": jnp.ones((dim,), jnp.float32)} if parametric else {}


def rmsnorm(x, params=None, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * (jnp.mean(xf * xf, -1, keepdims=True) + eps) ** -0.5
    if params and "scale" in params:
        y = y * params["scale"]
    return y.astype(dt)


def layernorm(x, params=None, eps: float = 1e-5):
    """Non-parametric when params is empty (OLMo)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    if params and "scale" in params:
        y = y * params["scale"]
    if params and "bias" in params:
        y = y + params["bias"]
    return y.astype(dt)


def qk_norm(q, params=None, eps: float = 1e-6):
    """Per-head RMS norm over head_dim (qwen3-style)."""
    return rmsnorm(q, params, eps)
