"""Rotary position embeddings (half-rotation convention, llama-style)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rope_angles", "apply_rope"]


def rope_angles(positions, head_dim: int, theta: float = 10000.0):
    """positions (...,) int32 -> (cos, sin) each (..., head_dim/2) f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin (..., S, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)
