"""RWKV6 "Finch" block: data-dependent decay, ddlerp token shift, chunked WKV.

The WKV recurrence per head (state S in R^{dk x dv}):

    out_t = r_t^T S_{t-1} + (r_t . u . k_t) v_t^T
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T          (w_t in (0,1), per channel)

is evaluated chunk-parallel: within a chunk of C tokens the pairwise decay
ratio R[t,s,i] = exp(cum[t-1,i] - cum[s,i]) (s < t, always <= 1 so fp32-safe)
forms the intra-chunk attention-like score; the chunk state is carried by a
lax.scan.  O(S*C*dk) memory, O(1) decode state -> the long_500k serving cell
is a fixed-size-state decode for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .norms import rmsnorm

__all__ = ["init_rwkv6", "rwkv6_block", "rwkv6_decode", "init_rwkv6_state"]

LORA_R = 32
# Clip |log w| per step.  4.0 => per-step decay floor w >= e^-4 = 0.018
# (a channel's contribution is <3e-4 after two steps — numerically
# indistinguishable for realistic data) and allows chunk=16 under the
# two-sided fp32 bound chunk*DECAY_CLIP <= 80 (§Perf cell A iter-3).
DECAY_CLIP = 4.0


def init_rwkv6(key, d_model: int, d_ff: int, head_dim: int = 64,
               dtype=jnp.float32):
    H = d_model // head_dim
    ks = jax.random.split(key, 12)
    s = d_model ** -0.5
    n = lambda k, shp, sc=s: jax.random.normal(k, shp, dtype) * sc
    return {
        # time-mix (attention-analogue)
        "mu": 0.5 * jnp.ones((5, d_model), dtype),     # w,k,v,r,g base lerp
        "maa_w1": n(ks[0], (d_model, 5 * LORA_R)),
        "maa_w2": n(ks[1], (5, LORA_R, d_model), LORA_R ** -0.5),
        "decay_base": jnp.full((d_model,), -1.5, dtype),
        "decay_w1": n(ks[2], (d_model, LORA_R * 2)),
        "decay_w2": n(ks[3], (LORA_R * 2, d_model), (2 * LORA_R) ** -0.5),
        "wr": n(ks[4], (d_model, d_model)),
        "wk": n(ks[5], (d_model, d_model)),
        "wv": n(ks[6], (d_model, d_model)),
        "wg": n(ks[7], (d_model, d_model)),
        "wo": n(ks[8], (d_model, d_model)),
        "u": n(ks[9], (H, head_dim), 0.3),             # per-head bonus
        "ln_x": {"scale": jnp.ones((d_model,), jnp.float32)},
        "ln1": {"scale": jnp.ones((d_model,), jnp.float32)},
        "ln2": {"scale": jnp.ones((d_model,), jnp.float32)},
        # channel-mix (FFN-analogue)
        "mu_ffn": 0.5 * jnp.ones((2, d_model), dtype),
        "wk_ffn": n(ks[10], (d_model, d_ff)),
        "wv_ffn": n(ks[11], (d_ff, d_model), d_ff ** -0.5),
        "wr_ffn": n(ks[4], (d_model, d_model)),
    }


def init_rwkv6_state(batch: int, d_model: int, head_dim: int = 64,
                     dtype=jnp.float32):
    H = d_model // head_dim
    return {
        "x_att": jnp.zeros((batch, d_model), dtype),
        "x_ffn": jnp.zeros((batch, d_model), dtype),
        "wkv": jnp.zeros((batch, H, head_dim, head_dim), jnp.float32),
    }


def _ddlerp(p, x, sx):
    """Data-dependent token-shift mix for the 5 projections (B,S,D)->5x."""
    xxx = x + sx * p["mu"][0]  # base mix for the lora input (w-slot)
    lora = jnp.tanh(xxx @ p["maa_w1"]).reshape(*x.shape[:-1], 5, LORA_R)
    mix = jnp.einsum("bscr,crd->bscd", lora, p["maa_w2"]) + p["mu"]
    return x[..., None, :] + sx[..., None, :] * mix    # (B,S,5,D)


def _wkv_chunk(carry, inp, head_dim):
    """One chunk of the WKV scan. carry S (B,H,dk,dv).

    Two-sided bounded form (§Perf cell A iter-2): instead of materializing
    the (B,C,C,H,dk) per-channel decay-ratio tensor, write
        scores[t,s] = Σ_i (r_t e^{cum_{t-1}})_i (k_s e^{-cum_s})_i
    with chunk-local cumsums.  Exponents are bounded by DECAY_CLIP*C (<= 80
    for C<=10), and every in-mask product has exponent <= 0, so fp32 is safe
    and the result is exact — validated against the naive recurrence by
    tests.  Memory per chunk drops from C*dk to C per token.
    """
    S0 = carry
    r, k, v, lw, u = inp          # r/k/lw (B,C,H,dk), v (B,C,H,dv), u (H,dk)
    B, C, H, dk = r.shape
    cum = jnp.cumsum(lw, axis=1)                        # (B,C,H,dk), <= 0
    cum_prev = cum - lw                                  # cum[t-1]
    rA = r * jnp.exp(cum_prev)                           # factors <= 1
    kB = k * jnp.exp(-cum)                               # <= e^{|lw|C}
    # bf16 streams into the MXU einsums, f32 accumulation (iter-4)
    scores = jnp.einsum("bthi,bshi->bhts", rA.astype(jnp.bfloat16),
                        kB.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)  # (B,H,C,C)
    tri = jnp.tril(jnp.ones((C, C), bool), -1)[None, None]
    scores = jnp.where(tri, scores, 0.0)
    diag = jnp.einsum("bthi,hi,bthi->bth", r, u, k)      # bonus (s=t)
    out = jnp.einsum("bhts,bshj->bthj", scores.astype(jnp.bfloat16),
                     v.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32) \
        + diag[..., None] * v
    # inter-chunk: read S0 with decay-to-(t-1)
    out = out + jnp.einsum("bthi,bhij->bthj", rA, S0)
    # chunk-end state: S_C = diag(e^{cum_C}) S0 + Σ_s diag(e^{cum_C-cum_s}) k_s v_s^T
    kscale = jnp.exp(jnp.clip(cum[:, -1][:, None] - cum, -60.0, 0.0))
    S_new = S0 * jnp.exp(cum[:, -1])[..., None] \
        + jnp.einsum("bshi,bshj->bhij", k * kscale, v)
    return S_new, out


def wkv6(r, k, v, lw, u, state, chunk: int = 32, shard_fn=None,
         remat_chunk: bool = True):
    """Chunked WKV scan. r/k/lw (B,S,H,dk), v (B,S,H,dv), u (H,dk),
    state (B,H,dk,dv).  Returns (out (B,S,H,dv), new_state).

    ``shard_fn(t)`` pins the sharding of the chunked (nc,B,c,H,*) streams —
    without it GSPMD loses batch sharding through the nested while loop and
    replicates the loop state (measured 16x memory blow-up, §Perf cell A).
    ``remat_chunk`` recomputes chunk internals in the backward pass instead
    of stacking per-chunk residuals across all nc chunks."""
    B, S, H, dk = r.shape
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    assert c * DECAY_CLIP <= 80, (
        "two-sided chunk form needs chunk*DECAY_CLIP <= 80 for fp32", c)
    nc = S // c
    shard_fn = shard_fn or (lambda t: t)
    resh = lambda t: shard_fn(
        t.reshape(B, nc, c, H, -1).transpose(1, 0, 2, 3, 4))
    rs, ks, vs, lws = map(resh, (r, k, v, lw))

    def body(S0, xs):
        rr, kk, vv, ll = xs
        S1, out = _wkv_chunk(S0, (rr, kk, vv, ll, u), dk)
        return S1, out

    if remat_chunk:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    state, outs = jax.lax.scan(body, state, (rs, ks, vs, lws))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, -1)
    return out, state


def rwkv6_block(params, x_res, state=None, head_dim: int = 64,
                chunk: int = 32, shard_fn=None):
    """Full RWKV6 layer: x = x + time_mix(ln1(x)); x = x + channel_mix(ln2(x)).

    x_res (B,S,D) is the residual stream.  Returns (new_residual, new_state).
    """
    B, S, D = x_res.shape
    H = D // head_dim
    if state is None:
        state = init_rwkv6_state(B, D, head_dim, x_res.dtype)

    # ---- time mix ----
    x = rmsnorm(x_res, params["ln1"]).astype(x_res.dtype)
    prev = jnp.concatenate([state["x_att"][:, None].astype(x.dtype),
                            x[:, :-1]], axis=1)
    sx = prev - x
    mixed = _ddlerp(params, x, sx)                       # (B,S,5,D)
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(5)]
    ww = params["decay_base"] + jnp.tanh(xw @ params["decay_w1"]) \
        @ params["decay_w2"]
    lw = -jnp.exp(jnp.clip(ww.astype(jnp.float32), -20.0, 2.0))
    lw = jnp.clip(lw, -DECAY_CLIP, -1e-4)                # log-decay < 0
    r = (xr @ params["wr"]).reshape(B, S, H, head_dim)
    k = (xk @ params["wk"]).reshape(B, S, H, head_dim)
    v = (xv @ params["wv"]).reshape(B, S, H, head_dim)
    g = jax.nn.silu(xg @ params["wg"])
    out, wkv_state = wkv6(r.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32),
                          lw.reshape(B, S, H, head_dim),
                          params["u"].astype(jnp.float32),
                          state["wkv"], chunk, shard_fn=shard_fn)
    out = rmsnorm(out.reshape(B, S, D), params["ln_x"]).astype(x.dtype)
    att = (out * g) @ params["wo"]
    res = x_res + att

    # ---- channel mix ----
    h = rmsnorm(res, params["ln2"]).astype(res.dtype)
    prev_f = jnp.concatenate([state["x_ffn"][:, None].astype(h.dtype),
                              h[:, :-1]], axis=1)
    sxf = prev_f - h
    xk_f = h + sxf * params["mu_ffn"][0]
    xr_f = h + sxf * params["mu_ffn"][1]
    kf = jnp.square(jax.nn.relu(xk_f @ params["wk_ffn"]))
    ffn = jax.nn.sigmoid(xr_f @ params["wr_ffn"]) * (kf @ params["wv_ffn"])

    new_state = {"x_att": x[:, -1].astype(jnp.float32),
                 "x_ffn": h[:, -1].astype(jnp.float32),
                 "wkv": wkv_state}
    return (res + ffn).astype(x_res.dtype), new_state


def rwkv6_decode(params, x, state, head_dim: int = 64):
    """O(1) single-token step; x (B,1,D)."""
    return rwkv6_block(params, x, state, head_dim, chunk=1)
