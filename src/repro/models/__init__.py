from .registry import get_model  # noqa: F401
