"""Generic decoder-only transformer LM (dense + MoE families).

Covers: olmo-1b, qwen3-0.6b, starcoder2-7b, codeqwen1.5-7b (dense),
deepseek-moe-16b, granite-moe-1b-a400m (moe), musicgen-large, pixtral-12b
(stub-frontend decoder backbones).

Structure: scan-over-stacked-layers with full remat (HLO is O(1) in depth —
this is what keeps the 512-device AOT dry-runs fast), flash-style chunked
attention, functional KV-cache prefill/decode, optional MoE expert
parallelism via shard_map (see layers/moe.py), optional sequence-parallel
residual stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.qweights import QuantizedLinearWeight
from repro.layers.attention import (attention, decode_attention,
                                    decode_attention_multi,
                                    decode_attention_paged,
                                    decode_attention_paged_multi,
                                    init_attention)
from repro.layers.mlp import init_mlp, mlp
from repro.layers.moe import init_moe, moe, moe_local
from repro.layers.norms import init_rmsnorm, layernorm, rmsnorm
from repro.parallel import ParallelCtx, shard_map

__all__ = ["init_params", "forward", "prefill", "decode", "decode_multi",
           "cache_specs", "lm_loss"]


def _parse_dscim(dscim_spec: str):
    """'<mode>[+attn]:<variant>:<L>[:calib]' -> (mode, attn, variant, L,
    calib).  The '+attn' suffix opt-ins the attention projections (default
    scope is MLP matmuls + LM head, DESIGN.md §6)."""
    from repro.core.qweights import split_dscim_mode
    parts = dscim_spec.split(":")
    if len(parts) < 3:
        raise ValueError(f"bad dscim spec {dscim_spec!r}; want "
                         "'<mode>[+attn]:<variant>:<L>[:calib]', e.g. "
                         "'kernel:dscim1:256' or 'kernel+attn:dscim1:256'")
    mode, attn_suffix = split_dscim_mode(dscim_spec)
    calib = parts[3] if len(parts) > 3 else "paper"
    return mode, attn_suffix, parts[1], int(parts[2]), calib


def _parse_fault(fault: str):
    """'stuck:<stride>:<value>' -> (stride, value): every <stride>-th
    output column of a DS-CIM linear reads back the constant <value> —
    the trace-level model of stuck-at OR-accumulation columns in the CIM
    array (runtime/failover.py injects it via cfg.dscim_fault)."""
    parts = fault.split(":")
    if len(parts) != 3 or parts[0] != "stuck":
        raise ValueError(f"bad dscim_fault {fault!r}; want "
                         "'stuck:<stride>:<value>'")
    stride = int(parts[1])
    if stride < 1:
        raise ValueError(f"dscim_fault stride must be >= 1, got {stride}")
    return stride, float(parts[2])


@functools.lru_cache(maxsize=16)
def _linear_for(dscim_spec: str, par: ParallelCtx | None = None,
                fault: str = ""):
    """DS-CIM linear operator for cfg.dscim (see ``_parse_dscim``).

    Applied to the MLP matmuls, the MoE shared expert and the LM head (the
    dominant MVMs).  Returns None when 'off'.

    ``par``: under a mesh, the 'kernel' mode operator carries the mesh so
    prepared weights route through the sharded fused MVM
    (kernels/dscim_fused.py ``dscim_fused_mvm_sharded`` — a Pallas call
    must run inside shard_map on a multi-device mesh; N shards over the TP
    axis, the request batch over the DP axes, and the windows-stay-local
    decomposition is bit-identical to single-device).  The pure-jnp
    backends partition fine under GSPMD and ignore the mesh.

    ``fault`` (cfg.dscim_fault): 'stuck:<stride>:<value>' wraps the
    operator so every <stride>-th output column is stuck at <value> —
    the chaos-testing model of a hard macro fault.  The params are never
    touched, so an exact-mode probe on the *same* prepared weights stays a
    clean reference (runtime/serving.py's accuracy watchdog relies on
    this)."""
    if dscim_spec == "off":
        return None
    from repro.core.dscim_layer import make_linear
    mode, _, variant, length, calib = _parse_dscim(dscim_spec)
    mesh = par.mesh if (par is not None and mode == "kernel") else None
    axis = par.tp_axis if par is not None else "model"
    dp = par.dp_axes if (par is not None and mode == "kernel") else ()
    op = make_linear(variant, length, mode, calib, mesh=mesh,
                     shard_axis=axis, batch_axes=dp)
    if not fault:
        return op
    stride, value = _parse_fault(fault)

    def faulted(x, w, key=None, *, salt=None):
        y = op(x, w, key, salt=salt)
        stuck = (jnp.arange(y.shape[-1]) % stride) == 0
        return jnp.where(stuck, jnp.asarray(value, y.dtype), y)

    faulted.group_k = op.group_k   # prepare_serving_params reads this
    return faulted


@functools.lru_cache(maxsize=16)
def _attn_linear_for(dscim_spec: str, par: ParallelCtx | None = None,
                     fault: str = ""):
    """The attention-projection DS-CIM operator — non-None only for
    '<mode>+attn' specs."""
    if dscim_spec == "off" or not _parse_dscim(dscim_spec)[1]:
        return None
    return _linear_for(dscim_spec, par, fault)


def _norm(cfg: ArchConfig, x, params):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, params)
    return layernorm(x, params)  # layernorm / layernorm_np


def _init_norm(cfg: ArchConfig, dim):
    if cfg.norm == "layernorm_np":
        return {}
    return init_rmsnorm(dim, parametric=True)


def _init_block(cfg: ArchConfig, key):
    ka, km = jax.random.split(key)
    p = {
        "ln1": _init_norm(cfg, cfg.d_model),
        "ln2": _init_norm(cfg, cfg.d_model),
        "attn": init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv,
                               cfg.head_dim, cfg.qk_norm,
                               pad_to=cfg.head_pad_to),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(km, cfg.d_model, cfg.d_ff, cfg.moe_experts,
                            cfg.moe_topk, cfg.moe_shared)
    else:
        p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    return p


def init_params(cfg: ArchConfig, key):
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_block(cfg, k))(layer_keys)
    params = {"layers": layers, "final_norm": _init_norm(cfg, cfg.d_model)}
    if not cfg.stub_frontend:
        params["embed"] = jax.random.normal(
            ke, (cfg.vocab_padded, cfg.d_model), jnp.float32) * 0.02
    if not cfg.tie_embeddings or cfg.stub_frontend:
        params["lm_head"] = jax.random.normal(
            kh, (cfg.d_model, cfg.vocab_padded), jnp.float32) \
            * cfg.d_model ** -0.5
    return params


# ---------------------------------------------------------------------------
# MoE dispatch: shard_map under a mesh, local fallback otherwise
# ---------------------------------------------------------------------------

def _moe_apply(lp_moe, h, cfg: ArchConfig, par: ParallelCtx | None,
               salt=None):
    if par is None:
        out, aux = moe_local(lp_moe, h, top_k=cfg.moe_topk,
                             capacity_factor=cfg.moe_capacity,
                             has_shared=cfg.moe_shared > 0,
                             linear=_linear_for(
                                 cfg.dscim, fault=cfg.dscim_fault),
                             salt=salt)
        return out, aux
    # Shared expert under the mesh: a prepared (resident int8) shared expert
    # replicates across the mesh (launch/sharding.py keeps its planes
    # unsharded) and the shard_map body computes it locally — bit-identical
    # per token to single-device serving, no FSDP gather of int8 planes.
    # Float shared weights keep the FSDP-shard + gather path; either way the
    # gathered/replicated weights feed the same DS-CIM linear as the local
    # path (the operator must be the *local* one — no nested shard_map).
    shared_prepared = bool(cfg.moe_shared) and isinstance(
        lp_moe.get("shared", {}).get("w_gate"), QuantizedLinearWeight)
    fsdp = par.dp_axes[-1]
    tp = par.tp_axis
    dp = par.dp_axes
    especs = {"w_gate": P(tp, None, fsdp), "w_up": P(tp, None, fsdp),
              "w_down": P(tp, fsdp, None)}
    pspecs = {"router": P(None, None), "experts": especs}
    if cfg.moe_shared:
        if shared_prepared:
            from repro.core.qweights import qweight_replicated_specs
            pspecs["shared"] = {k: qweight_replicated_specs(v)
                                for k, v in lp_moe["shared"].items()}
        else:
            pspecs["shared"] = {"w_gate": P(None, fsdp),
                                "w_up": P(None, fsdp),
                                "w_down": P(fsdp, None)}

    def inner(lp, x, *s):
        # FSDP: gather the weight shards before use (explicit ZeRO-3)
        e = lp["experts"]
        e = {"w_gate": jax.lax.all_gather(e["w_gate"], fsdp, axis=2, tiled=True),
             "w_up": jax.lax.all_gather(e["w_up"], fsdp, axis=2, tiled=True),
             "w_down": jax.lax.all_gather(e["w_down"], fsdp, axis=1, tiled=True)}
        lp2 = dict(lp, experts=e)
        if cfg.moe_shared and not shared_prepared:
            sh = lp["shared"]
            lp2["shared"] = {
                "w_gate": jax.lax.all_gather(sh["w_gate"], fsdp, axis=1, tiled=True),
                "w_up": jax.lax.all_gather(sh["w_up"], fsdp, axis=1, tiled=True),
                "w_down": jax.lax.all_gather(sh["w_down"], fsdp, axis=0, tiled=True)}
        out, aux = moe(lp2, x, top_k=cfg.moe_topk, ep_axis=tp,
                       capacity_factor=cfg.moe_capacity,
                       has_shared=cfg.moe_shared > 0,
                       linear=_linear_for(cfg.dscim, fault=cfg.dscim_fault),
                       salt=s[0] if s else None)
        return out, jax.lax.pmean(aux, (*dp, tp))

    # the (possibly traced) salt rides as an explicit replicated operand —
    # shard_map bodies must not close over tracers
    operands = (lp_moe, h)
    in_specs = (pspecs, P(dp, None, None))
    if salt is not None:
        operands += (jnp.asarray(salt, jnp.int32),)
        in_specs += (P(),)
    return shard_map(
        inner, mesh=par.mesh, in_specs=in_specs,
        out_specs=(P(dp, None, None), P()),
    )(*operands)


# ---------------------------------------------------------------------------
# forward / prefill / decode
# ---------------------------------------------------------------------------

def _cast(tree, dtype):
    """Cast f32 leaves to the compute dtype.  Prepared weights pass through
    untouched — their int8 planes are the compute representation and their
    dequant scales must stay f32 for bit-exactness vs the float-weight path.
    """
    def f(a):
        if isinstance(a, QuantizedLinearWeight):
            return a
        return a.astype(dtype) if a.dtype == jnp.float32 else a
    return jax.tree.map(f, tree,
                        is_leaf=lambda a: isinstance(a, QuantizedLinearWeight))


def _constraint(x, cfg, par: ParallelCtx | None):
    if par is None:
        return x
    spec = (P(par.dp_axes, par.tp_axis, None) if par.sp
            else P(par.dp_axes, None, None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(par.mesh, spec))


def _embed_in(params, cfg: ArchConfig, batch, dt):
    if cfg.stub_frontend:
        x = batch["embeds"].astype(dt)
    else:
        x = params["embed"].astype(dt)[batch["tokens"]]
    return x


def _head(params, cfg: ArchConfig, x, par: ParallelCtx | None = None):
    lin = _linear_for(cfg.dscim, par, cfg.dscim_fault)
    head = params.get("lm_head")
    if isinstance(head, QuantizedLinearWeight):
        # prepare-once serve path: the head (incl. the tied-embedding head,
        # materialized from embed.T at prepare time) is resident int8
        return lin(x.astype(jnp.float32), head,
                   salt=8 * cfg.n_layers).astype(jnp.float32)
    if cfg.tie_embeddings and not cfg.stub_frontend:
        w = params["embed"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    if lin is not None:
        return lin(x.astype(jnp.float32), w.astype(jnp.float32),
                   salt=8 * cfg.n_layers).astype(jnp.float32)
    return (x @ w).astype(jnp.float32)


def _block_apply(cfg: ArchConfig, par, lp, x, positions, collect_kv: bool,
                 layer_idx=None):
    # per-layer salt space: mlp/shared-expert sites 0..2, attention 4..7,
    # head 8*n_layers — decorrelates the DS-CIM noise backends' fallback
    # keys across layers and matmul sites (dscim_layer.py docstring)
    salt = None if layer_idx is None else layer_idx * 8
    h_attn, kv = attention(lp["attn"], _norm(cfg, x, lp["ln1"]), cfg,
                           positions, cfg.q_chunk, cfg.kv_chunk,
                           return_kv=collect_kv,
                           linear=_attn_linear_for(cfg.dscim, par,
                                                   cfg.dscim_fault),
                           salt=salt)
    x = x + h_attn
    x = _constraint(x, cfg, par)
    hn = _norm(cfg, x, lp["ln2"])
    if cfg.family == "moe":
        h_ff, aux = _moe_apply(lp["moe"], hn, cfg, par, salt=salt)
    else:
        h_ff, aux = mlp(lp["mlp"], hn, cfg.mlp_kind,
                        linear=_linear_for(cfg.dscim, par,
                                           cfg.dscim_fault),
                        salt=salt), 0.0
    x = _constraint(x + h_ff, cfg, par)
    return x, aux, kv


def forward(params, cfg: ArchConfig, batch, par: ParallelCtx | None = None):
    """Training/scoring forward. Returns (logits f32, aux_loss)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = _embed_in(params, cfg, batch, dt)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(carry, xs):
        x, aux = carry
        lp, li = xs
        lp = _cast(lp, dt)
        x, aux_l, _ = _block_apply(cfg, par, lp, x, positions, False,
                                   layer_idx=li)
        return (x, aux + aux_l), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.float32(0.0)),
        (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    x = _norm(cfg, x, params["final_norm"])
    return _head(params, cfg, x, par), aux / cfg.n_layers


def prefill(params, cfg: ArchConfig, batch, par: ParallelCtx | None = None,
            capacity: int | None = None):
    """Forward + KV-cache construction. Returns (last-token logits, cache).

    ``capacity``: total cache length to allocate (>= prompt length) so decode
    steps have headroom; defaults to the prompt length (dry-run convention,
    where the decode cells allocate their own full-length cache specs)."""
    dt = jnp.dtype(cfg.compute_dtype)
    cdt = jnp.dtype(cfg.cache_dtype)
    x = _embed_in(params, cfg, batch, dt)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(x, xs):
        lp, li = xs
        lp = _cast(lp, dt)
        x, _, kv = _block_apply(cfg, par, lp, x, positions, True,
                                layer_idx=li)
        return x, (kv[0].astype(cdt), kv[1].astype(cdt))

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    if capacity is not None and capacity > S:
        pad = [(0, 0), (0, 0), (0, capacity - S), (0, 0), (0, 0)]
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    x = _norm(cfg, x[:, -1:], params["final_norm"])
    logits = _head(params, cfg, x, par)[:, 0]
    return logits, {"k": ks, "v": vs, "pos": jnp.int32(S)}


def _decode_embed(params, cfg: ArchConfig, batch, dt):
    if cfg.stub_frontend:
        return batch["embed"].astype(dt)              # (B,1,D)
    return params["embed"].astype(dt)[batch["token"]][:, None]


def _advance(pos, done):
    """Per-slot position advance: finished rows stop moving (ragged
    completion).  Scalar pos (lockstep PR 3 path) stays scalar."""
    if done is None:
        return pos + 1
    return pos + jnp.where(done, 0, 1).astype(jnp.int32)


def _decode_ff(cfg: ArchConfig, par, lp, x, h_attn, salt):
    """Post-attention half of one decode layer (residual + FF/MoE) —
    shared by the dense and paged decode bodies so the two cache layouts
    can't drift apart."""
    x = x + h_attn
    hn = _norm(cfg, x, lp["ln2"])
    if cfg.family == "moe":
        h_ff, _ = _moe_apply(lp["moe"], hn, cfg, par, salt=salt)
    else:
        h_ff = mlp(lp["mlp"], hn, cfg.mlp_kind,
                   linear=_linear_for(cfg.dscim, par, cfg.dscim_fault),
                   salt=salt)
    return x + h_ff


def decode(params, cfg: ArchConfig, batch, cache,
           par: ParallelCtx | None = None):
    """One-token decode against the cache. Returns (logits (B,Vp), cache).

    ``cache["pos"]`` may be a scalar (all rows in lockstep) or per-slot
    (B,) for ragged completion; ``batch["done"]`` (optional, (B,) bool)
    marks finished slots, which stop advancing their position.  A cache
    carrying ``k_pages`` is the int8 block-paged layout (core/kvcache.py)
    and routes through ``decode_attention_paged``."""
    if "k_pages" in cache:
        return _decode_paged(params, cfg, batch, cache, par)
    dt = jnp.dtype(cfg.compute_dtype)
    x = _decode_embed(params, cfg, batch, dt)
    pos = cache["pos"]
    done = batch.get("done")

    def body(x, xs):
        lp, ck, cv, li = xs
        lp = _cast(lp, dt)
        salt = li * 8
        h, nk, nv = decode_attention(lp["attn"], _norm(cfg, x, lp["ln1"]),
                                     ck, cv, pos, cfg,
                                     linear=_attn_linear_for(
                                         cfg.dscim, par, cfg.dscim_fault),
                                     salt=salt)
        return _decode_ff(cfg, par, lp, x, h, salt), (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    x = _norm(cfg, x, params["final_norm"])
    logits = _head(params, cfg, x)[:, 0]
    return logits, {"k": nk, "v": nv, "pos": _advance(pos, done)}


def _decode_paged(params, cfg: ArchConfig, batch, cache,
                  par: ParallelCtx | None = None):
    """One-token decode against the int8 block-paged KV cache: per-layer
    page pools ride the layer scan as xs (like the dense k/v planes); the
    page table and per-slot positions are layer-shared carry state.

    ``batch["paged_kernel"]`` (a static Python bool, set by the serving
    loop builders from their ``paged_attn`` option) pins the read path —
    Pallas kernel vs jnp gather; absent, the path follows cfg.dscim (see
    layers/attention.py ``decode_attention_paged``)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = _decode_embed(params, cfg, batch, dt)
    pos = cache["pos"]
    page_table = cache["page_table"]
    done = batch.get("done")
    use_kernel = batch.get("paged_kernel")

    def body(x, xs):
        lp, kp, vp, ks, vs, kt, vt, li = xs
        lp = _cast(lp, dt)
        salt = li * 8
        view = {"k_pages": kp, "v_pages": vp, "k_scale": ks, "v_scale": vs,
                "k_tail": kt, "v_tail": vt, "page_table": page_table,
                "pos": pos}
        h, planes = decode_attention_paged(
            lp["attn"], _norm(cfg, x, lp["ln1"]), view, cfg,
            linear=_attn_linear_for(cfg.dscim, par, cfg.dscim_fault),
            salt=salt, done=done,
            par=par, use_kernel=use_kernel)
        return _decode_ff(cfg, par, lp, x, h, salt), planes

    x, (kp, vp, ks, vs, kt, vt) = jax.lax.scan(
        body, x, (params["layers"], cache["k_pages"], cache["v_pages"],
                  cache["k_scale"], cache["v_scale"],
                  cache["k_tail"], cache["v_tail"],
                  jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    x = _norm(cfg, x, params["final_norm"])
    logits = _head(params, cfg, x)[:, 0]
    # dict(cache, ...) rebuild: bookkeeping planes that ride the cache but
    # are not rewritten per step (the integrity ``page_sum`` digests) must
    # pass through, not be dropped by an explicit-key reconstruction
    return logits, dict(cache, k_pages=kp, v_pages=vp, k_scale=ks,
                        v_scale=vs, k_tail=kt, v_tail=vt,
                        page_table=page_table, pos=_advance(pos, done))


def decode_multi(params, cfg: ArchConfig, batch, cache,
                 par: ParallelCtx | None = None):
    """Speculative-verify decode: score T consecutive tokens per row in one
    forward — the verifier half of self-speculative decoding
    (launch/steps.py).  ``batch["tokens"]`` (B, T) int32; ``cache["pos"]``
    per-slot (B,) (a scalar is broadcast).  Position t of the returned
    logits is bitwise what ``decode`` would produce after feeding tokens
    0..t-1 (same weights, same salts, same ``_head`` path — see
    layers/attention.py ``decode_attention_multi`` for the exact-replay
    argument and the statistical/paper_inject carve-out).

    Returns (logits (B, T, Vp) f32, cache, win_kv) where win_kv is
    (win_k, win_v) (n_layers, B, T, KV, HD) tail-dtype window projections
    for the paged layout (``core/kvcache.spec_rollback`` consumes them) and
    None for the dense layout (dense rollback is position truncation only).
    """
    if cfg.stub_frontend:
        raise ValueError("decode_multi (speculative verify) needs token "
                         "inputs; stub-frontend configs are unsupported")
    dt = jnp.dtype(cfg.compute_dtype)
    B, T = batch["tokens"].shape
    x = params["embed"].astype(dt)[batch["tokens"]]       # (B,T,D)
    pos = cache["pos"]
    if getattr(pos, "ndim", 0) == 0:
        pos = jnp.full((B,), pos, jnp.int32)
    done = batch.get("done")
    adv = T if done is None else jnp.where(done, 0, T).astype(jnp.int32)
    lin = _attn_linear_for(cfg.dscim, par, cfg.dscim_fault)

    if "k_pages" in cache:
        page_table = cache["page_table"]
        use_kernel = batch.get("paged_kernel")

        def pbody(x, xs):
            lp, kp, vp, ks, vs, kt, vt, li = xs
            lp = _cast(lp, dt)
            salt = li * 8
            view = {"k_pages": kp, "v_pages": vp, "k_scale": ks,
                    "v_scale": vs, "k_tail": kt, "v_tail": vt,
                    "page_table": page_table, "pos": pos}
            h, planes, wkv = decode_attention_paged_multi(
                lp["attn"], _norm(cfg, x, lp["ln1"]), view, cfg,
                linear=lin, salt=salt, done=done,
                par=par, use_kernel=use_kernel)
            return _decode_ff(cfg, par, lp, x, h, salt), planes + wkv

        x, (kp, vp, ks, vs, kt, vt, wk, wv) = jax.lax.scan(
            pbody, x, (params["layers"], cache["k_pages"], cache["v_pages"],
                       cache["k_scale"], cache["v_scale"],
                       cache["k_tail"], cache["v_tail"],
                       jnp.arange(cfg.n_layers, dtype=jnp.int32)))
        # pass-through rebuild so the integrity digest plane (if present)
        # survives the verify forward
        new_cache = dict(cache, k_pages=kp, v_pages=vp, k_scale=ks,
                         v_scale=vs, k_tail=kt, v_tail=vt,
                         page_table=page_table, pos=pos + adv)
        win_kv = (wk, wv)
    else:
        def body(x, xs):
            lp, ck, cv, li = xs
            lp = _cast(lp, dt)
            salt = li * 8
            h, nk, nv = decode_attention_multi(
                lp["attn"], _norm(cfg, x, lp["ln1"]), ck, cv, pos, cfg,
                linear=lin, salt=salt, done=done)
            return _decode_ff(cfg, par, lp, x, h, salt), (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      jnp.arange(cfg.n_layers, dtype=jnp.int32)))
        new_cache = {"k": nk, "v": nv, "pos": pos + adv}
        win_kv = None
    x = _norm(cfg, x, params["final_norm"])
    logits = _head(params, cfg, x)                        # (B,T,Vp)
    return logits, new_cache, win_kv


def cache_specs(cfg: ArchConfig, batch: int, seq: int):
    cdt = jnp.dtype(cfg.cache_dtype)
    f = jax.ShapeDtypeStruct
    return {
        "k": f((cfg.n_layers, batch, seq, cfg.n_kv, cfg.head_dim), cdt),
        "v": f((cfg.n_layers, batch, seq, cfg.n_kv, cfg.head_dim), cdt),
        "pos": f((), jnp.int32),
    }


def lm_loss(logits, labels, mask=None):
    """Token-mean cross-entropy; logits (B,S,Vp) f32, labels (B,S) int32."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
