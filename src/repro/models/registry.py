"""Family -> model-module registry (uniform API: init_params/forward/
prefill/decode/cache_specs)."""
from __future__ import annotations

from repro.configs.base import ArchConfig

from . import lm, rwkv, zamba

__all__ = ["get_model"]

_FAMILIES = {
    "dense": lm,
    "moe": lm,
    "ssm": rwkv,
    "hybrid": zamba,
}


def get_model(cfg: ArchConfig):
    return _FAMILIES[cfg.family]
