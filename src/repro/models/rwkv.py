"""RWKV6 "Finch" LM (rwkv6-7b): attention-free, O(1)-state decode.

Same public API as models/lm.py; the "cache" is the per-layer recurrent
state (token-shift tails + WKV matrices), whose size is independent of
sequence length — which is exactly why this family runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.layers.norms import init_rmsnorm, rmsnorm
from repro.layers.rwkv6 import init_rwkv6, init_rwkv6_state, rwkv6_block
from repro.parallel import ParallelCtx

__all__ = ["init_params", "forward", "prefill", "decode", "cache_specs",
           "lm_loss"]

from .lm import lm_loss  # shared loss


def init_params(cfg: ArchConfig, key):
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(
        lambda k: init_rwkv6(k, cfg.d_model, cfg.d_ff, cfg.ssm_head_dim)
    )(layer_keys)
    return {
        "embed": jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model),
                                   jnp.float32) * 0.02,
        "layers": layers,
        "ln_in": init_rmsnorm(cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
        "lm_head": jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded),
                                     jnp.float32) * cfg.d_model ** -0.5,
    }


def _stack_states(cfg: ArchConfig, batch: int, dt):
    one = init_rwkv6_state(batch, cfg.d_model, cfg.ssm_head_dim, dt)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one)


def _run(params, cfg: ArchConfig, x, states, par):
    dt = x.dtype
    shard_fn = None
    if par is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        def shard_fn(t):  # (nc, B, c, H, dk) chunk streams: pin DP + TP
            bspec = par.dp_axes if t.shape[1] % par.dp_size == 0 else None
            hspec = (par.tp_axis
                     if t.shape[3] % par.mesh.shape[par.tp_axis] == 0
                     else None)
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(par.mesh, P(None, bspec, None, hspec, None)))

    def body(x, xs):
        lp, st = xs
        lp = jax.tree.map(lambda a: a.astype(dt)
                          if a.dtype == jnp.float32 else a, lp)
        x, new_st = rwkv6_block(lp, x, st, cfg.ssm_head_dim, cfg.scan_chunk,
                                shard_fn=shard_fn)
        if par is not None and par.sp:
            # Megatron-SP-style: shard the saved residual stream over TP so
            # the layer-scan remat stash is 1/tp_size per device
            from jax.sharding import NamedSharding, PartitionSpec as P
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(par.mesh,
                                 P(par.dp_axes, None, par.tp_axis)))
        return x, new_st

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, new_states = jax.lax.scan(body, x, (params["layers"], states))
    return x, new_states


def forward(params, cfg: ArchConfig, batch, par: ParallelCtx | None = None):
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(dt)[batch["tokens"]]
    x = rmsnorm(x, params["ln_in"]).astype(dt)
    states = _stack_states(cfg, x.shape[0], jnp.float32)
    x, _ = _run(params, cfg, x, states, par)
    x = rmsnorm(x, params["final_norm"])
    return (x @ params["lm_head"].astype(dt)).astype(jnp.float32), 0.0


def prefill(params, cfg: ArchConfig, batch, par: ParallelCtx | None = None,
            capacity: int | None = None):
    # capacity is a no-op: the recurrent state is sequence-length-free
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(dt)[batch["tokens"]]
    x = rmsnorm(x, params["ln_in"]).astype(dt)
    states = _stack_states(cfg, x.shape[0], jnp.float32)
    x, states = _run(params, cfg, x, states, par)
    x = rmsnorm(x[:, -1:], params["final_norm"])
    logits = (x @ params["lm_head"].astype(dt)).astype(jnp.float32)[:, 0]
    return logits, {"states": states, "pos": jnp.int32(batch["tokens"].shape[1])}


def decode(params, cfg: ArchConfig, batch, cache,
           par: ParallelCtx | None = None):
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(dt)[batch["token"]][:, None]
    x = rmsnorm(x, params["ln_in"]).astype(dt)
    x, states = _run(params, cfg, x, cache["states"], par)
    x = rmsnorm(x, params["final_norm"])
    logits = (x @ params["lm_head"].astype(dt)).astype(jnp.float32)[:, 0]
    return logits, {"states": states, "pos": cache["pos"] + 1}


def cache_specs(cfg: ArchConfig, batch: int, seq: int):
    """State size is seq-independent (the whole point of this family)."""
    H = cfg.d_model // cfg.ssm_head_dim
    f = jax.ShapeDtypeStruct
    L = cfg.n_layers
    return {
        "states": {
            "x_att": f((L, batch, cfg.d_model), jnp.float32),
            "x_ffn": f((L, batch, cfg.d_model), jnp.float32),
            "wkv": f((L, batch, H, cfg.ssm_head_dim, cfg.ssm_head_dim),
                     jnp.float32),
        },
        "pos": f((), jnp.int32),
    }
