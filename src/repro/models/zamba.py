"""Zamba2-7b hybrid LM: Mamba2 (SSD) backbone + one *shared* GQA attention
block applied once per scanned super-block (weight sharing as in the paper:
"Mamba2 + shared attn blocks").

81 layers are organized as n_blocks = n_layers // mamba_per_block scanned
super-blocks, each = [mamba2 x mamba_per_block ; shared_attention].  The
shared attention params are closure-captured (NOT scanned), so one weight
set serves every application — faithful to Zamba2's parameter sharing.

Cache = per-layer mamba states (stacked) + per-application KV cache for the
shared attention (n_blocks applications).  Sub-quadratic: runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.layers.attention import (attention, decode_attention,
                                    init_attention)
from repro.layers.mamba2 import (init_mamba2, init_mamba2_state,
                                 mamba2_block)
from repro.layers.norms import init_rmsnorm, rmsnorm
from repro.parallel import ParallelCtx

from .lm import lm_loss  # noqa: F401  (shared loss)

__all__ = ["init_params", "forward", "prefill", "decode", "cache_specs",
           "lm_loss"]


def _n_blocks(cfg: ArchConfig) -> int:
    assert cfg.n_layers % cfg.mamba_per_block == 0, (
        cfg.n_layers, cfg.mamba_per_block)
    return cfg.n_layers // cfg.mamba_per_block


def init_params(cfg: ArchConfig, key):
    ke, km, ka, kh = jax.random.split(key, 4)
    nb, mpb = _n_blocks(cfg), cfg.mamba_per_block
    mkeys = jax.random.split(km, nb * mpb).reshape(nb, mpb, 2)

    def init_one(k):
        return {"mamba": init_mamba2(k, cfg.d_model, cfg.ssm_head_dim,
                                     cfg.ssm_state),
                "ln": init_rmsnorm(cfg.d_model)}

    layers = jax.vmap(jax.vmap(init_one))(mkeys)
    return {
        "embed": jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model),
                                   jnp.float32) * 0.02,
        "blocks": layers,                      # (nb, mpb, ...)
        "shared_attn": init_attention(ka, cfg.d_model, cfg.n_heads,
                                      cfg.n_kv, cfg.head_dim, cfg.qk_norm),
        "shared_ln": init_rmsnorm(cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
        "lm_head": jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded),
                                     jnp.float32) * cfg.d_model ** -0.5,
    }


def _mamba_states(cfg: ArchConfig, batch: int):
    nb, mpb = _n_blocks(cfg), cfg.mamba_per_block
    one = init_mamba2_state(batch, cfg.d_model, cfg.ssm_head_dim,
                            cfg.ssm_state)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (nb, mpb, *a.shape)), one)


def _cast(tree, dt):
    return jax.tree.map(lambda a: a.astype(dt) if a.dtype == jnp.float32
                        else a, tree)


def _run_full(params, cfg: ArchConfig, x, states, par, collect_kv: bool):
    """Full-sequence pass (train / prefill). Returns (x, states, kv_stack)."""
    dt = x.dtype
    shared = _cast(params["shared_attn"], dt)
    shared_ln = params["shared_ln"]
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    shard_fn = None
    if par is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        def shard_fn(t):  # (nc, B, c, ...) SSD chunk streams
            bspec = par.dp_axes if t.shape[1] % par.dp_size == 0 else None
            spec = [None, bspec] + [None] * (t.ndim - 2)
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(par.mesh, P(*spec)))

    def block_body(x, xs):
        blk, st = xs
        blk = _cast(blk, dt)

        def mamba_body(x, xs2):
            lp, st2 = xs2
            h, nst = mamba2_block(lp["mamba"], rmsnorm(x, lp["ln"]).astype(dt),
                                  st2, cfg.ssm_head_dim, cfg.scan_chunk,
                                  shard_fn=shard_fn)
            return x + h, nst
        x, nst = jax.lax.scan(mamba_body, x, (blk, st))
        h, kv = attention(shared, rmsnorm(x, shared_ln).astype(dt), cfg,
                          positions, cfg.q_chunk, cfg.kv_chunk,
                          return_kv=collect_kv)
        x = x + h
        kv_out = ((kv[0].astype(jnp.dtype(cfg.cache_dtype)),
                   kv[1].astype(jnp.dtype(cfg.cache_dtype)))
                  if collect_kv else 0)
        return x, (nst, kv_out)

    if cfg.remat:
        block_body = jax.checkpoint(
            block_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (new_states, kvs) = jax.lax.scan(block_body, x,
                                        (params["blocks"], states))
    return x, new_states, kvs


def forward(params, cfg: ArchConfig, batch, par: ParallelCtx | None = None):
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(dt)[batch["tokens"]]
    states = _mamba_states(cfg, x.shape[0])
    x, _, _ = _run_full(params, cfg, x, states, par, False)
    x = rmsnorm(x, params["final_norm"])
    return (x @ params["lm_head"].astype(dt)).astype(jnp.float32), 0.0


def prefill(params, cfg: ArchConfig, batch, par: ParallelCtx | None = None,
            capacity: int | None = None):
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(dt)[batch["tokens"]]
    S = batch["tokens"].shape[1]
    states = _mamba_states(cfg, x.shape[0])
    x, states, kvs = _run_full(params, cfg, x, states, par, True)
    ks, vs = kvs
    if capacity is not None and capacity > S:
        pad = [(0, 0), (0, 0), (0, capacity - S), (0, 0), (0, 0)]
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    x = rmsnorm(x[:, -1:], params["final_norm"])
    logits = (x @ params["lm_head"].astype(dt)).astype(jnp.float32)[:, 0]
    return logits, {"mamba": states, "k": ks, "v": vs,
                    "pos": jnp.int32(S)}


def decode(params, cfg: ArchConfig, batch, cache,
           par: ParallelCtx | None = None):
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(dt)[batch["token"]][:, None]
    pos = cache["pos"]
    shared = _cast(params["shared_attn"], dt)

    def block_body(x, xs):
        blk, st, ck, cv = xs
        blk = _cast(blk, dt)

        def mamba_body(x, xs2):
            lp, st2 = xs2
            h, nst = mamba2_block(lp["mamba"], rmsnorm(x, lp["ln"]).astype(dt),
                                  st2, cfg.ssm_head_dim, chunk=1)
            return x + h, nst
        x, nst = jax.lax.scan(mamba_body, x, (blk, st))
        h, nk, nv = decode_attention(shared,
                                     rmsnorm(x, params["shared_ln"]).astype(dt),
                                     ck, cv, pos, cfg)
        return x + h, (nst, nk, nv)

    x, (nst, nk, nv) = jax.lax.scan(
        block_body, x, (params["blocks"], cache["mamba"], cache["k"],
                        cache["v"]))
    x = rmsnorm(x, params["final_norm"])
    logits = (x @ params["lm_head"].astype(dt)).astype(jnp.float32)[:, 0]
    return logits, {"mamba": nst, "k": nk, "v": nv, "pos": pos + 1}


def cache_specs(cfg: ArchConfig, batch: int, seq: int):
    from repro.layers.mamba2 import CONV_K
    nb, mpb = _n_blocks(cfg), cfg.mamba_per_block
    d_in = cfg.d_model
    H = d_in // cfg.ssm_head_dim
    cdt = jnp.dtype(cfg.cache_dtype)
    f = jax.ShapeDtypeStruct
    return {
        "mamba": {
            "conv": f((nb, mpb, batch, CONV_K - 1, d_in + 2 * cfg.ssm_state),
                      jnp.float32),
            "h": f((nb, mpb, batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                   jnp.float32),
        },
        "k": f((nb, batch, seq, cfg.n_kv, cfg.head_dim), cdt),
        "v": f((nb, batch, seq, cfg.n_kv, cfg.head_dim), cdt),
        "pos": f((), jnp.int32),
    }
