from .adamw import AdamW, cosine_schedule, global_norm  # noqa: F401
