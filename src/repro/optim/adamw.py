"""AdamW with fp32 master weights, global-norm clipping, LR schedules.

Self-contained (no optax in the container).  State mirrors the param tree
(same shapes → same PartitionSpecs), so optimizer state shards exactly like
FSDP/TP params with zero extra plumbing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "cosine_schedule", "linear_warmup", "global_norm"]


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def linear_warmup(base_lr: float, warmup: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        return base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, state, step=None):
        count = state["count"] + 1
        step = count if step is None else step
        lr = self.lr(step) if callable(self.lr) else self.lr

        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        b1, b2 = self.b1, self.b2
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            step_ = lr * (mh / (jnp.sqrt(vh) + self.eps)
                          + self.weight_decay * p.astype(jnp.float32))
            return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

        flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm
