"""Gradient compression for the slow cross-pod axis: int8 quantized
all-reduce with per-leaf error feedback (1-bit-Adam-family trick).

Usage (inside a shard_map over the 'pod' axis, or via compress_tree around
jax.lax.psum):  q, s = compress(g + err); g_hat = decompress(psum(q), s*?);
err = g - g_hat.  Error feedback keeps the quantization bias from
accumulating across steps — convergence property is covered by
tests/test_optim.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress", "decompress", "compressed_psum_tree", "init_error"]


def compress(g):
    """Symmetric per-tensor int8. Returns (q int8, scale f32 scalar)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_tree(grads, err, axis: str):
    """All-reduce ``grads`` over ``axis`` with int8 compression + error
    feedback state ``err``.  Returns (mean-reduced grads, new err).

    The int8 payloads are summed exactly (int32 accumulate in f32 carrier is
    exact for |sum| < 2^24, i.e. up to 131k pods), then rescaled by the
    max participant scale (scales are psum-maxed).
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = compress(g32)
        s_max = jax.lax.pmax(s, axis)
        # requantize against the shared scale so payloads are summable
        q2 = jnp.clip(jnp.round(g32 / s_max), -127, 127)
        total = jax.lax.psum(q2, axis)
        g_hat_local = q2 * s_max
        new_e = g32 - g_hat_local
        return (total * s_max / n).astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_g, new_e
