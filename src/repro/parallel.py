"""Parallelism context threaded through model code.

Carries the mesh + axis-name conventions so layers can issue explicit
collectives (MoE all_to_all, FSDP all-gathers) where GSPMD propagation is
not the right tool.  ``None`` everywhere means single-device (smoke tests).
"""
from __future__ import annotations

import dataclasses

import jax

__all__ = ["ParallelCtx"]


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: jax.sharding.Mesh
    dp_axes: tuple = ("data",)   # ('pod','data') on the multi-pod mesh
    tp_axis: str = "model"
    sp: bool = False             # sequence-parallel residual stream (opt-in)

    @property
    def batch_spec(self):
        from jax.sharding import PartitionSpec as P
        return P(self.dp_axes)

    @property
    def dp_size(self) -> int:
        return int(
            __import__("numpy").prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]
