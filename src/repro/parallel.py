"""Parallelism context threaded through model code.

Carries the mesh + axis-name conventions so layers can issue explicit
collectives (MoE all_to_all, FSDP all-gathers) where GSPMD propagation is
not the right tool.  ``None`` everywhere means single-device (smoke tests).
"""
from __future__ import annotations

import dataclasses

import jax

__all__ = ["ParallelCtx", "shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable shard_map: new jax exposes ``jax.shard_map`` with
    ``check_vma``; 0.4.x has ``jax.experimental.shard_map`` with
    ``check_rep``.  All repo call sites go through this wrapper."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: jax.sharding.Mesh
    dp_axes: tuple = ("data",)   # ('pod','data') on the multi-pod mesh
    tp_axis: str = "model"
    sp: bool = False             # sequence-parallel residual stream (opt-in)

    @property
    def batch_spec(self):
        from jax.sharding import PartitionSpec as P
        return P(self.dp_axes)

    @property
    def dp_size(self) -> int:
        return int(
            __import__("numpy").prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]
