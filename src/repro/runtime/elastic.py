"""Elastic mesh resolution: the relaunch environment declares the world.

REPRO_MESH=pod2x16x16 | pod16x16 | dxM (debug) controls the mesh a restart
builds; checkpoints reshard on restore, so scaling the pod count between
runs (node failures, capacity changes) requires no checkpoint surgery.

Pod specs degrade gracefully (ISSUE 6): a host without the pod's device
count (every CI runner, every laptop) gets the largest supported debug
mesh — all visible devices on the 'model' axis — with a warning, instead
of an unconditional raise.  Explicit debug specs (``dxM``) still raise
when oversubscribed: the operator asked for that exact shape.
"""
from __future__ import annotations

import math
import os
import warnings

import jax

from repro.launch.mesh import make_mesh

__all__ = ["mesh_from_env"]

_POD_SPECS = {
    "pod16x16": ((16, 16), ("data", "model")),
    "pod2x16x16": ((2, 16, 16), ("pod", "data", "model")),
}


def mesh_from_env(default: str = "pod16x16"):
    spec = os.environ.get("REPRO_MESH", default)
    if spec in _POD_SPECS:
        dims, names = _POD_SPECS[spec]
        have = jax.device_count()
        if math.prod(dims) > have:
            warnings.warn(
                f"REPRO_MESH={spec} wants {math.prod(dims)} devices but "
                f"only {have} are visible; degrading to the largest "
                f"supported debug mesh d1x{have} (data=1, model={have})",
                RuntimeWarning, stacklevel=2)
            return make_mesh((1, have), ("data", "model"))
        return make_mesh(dims, names)
    if spec.startswith("d"):                       # e.g. d2x2 for tests
        dims = tuple(int(x) for x in spec[1:].split("x"))
        names = ("data", "model")[:len(dims)]
        return make_mesh(dims, names)
    raise ValueError(f"unknown REPRO_MESH={spec!r}")
