"""Elastic mesh resolution: the relaunch environment declares the world.

REPRO_MESH=pod2x16x16 | pod16x16 | dxM (debug) controls the mesh a restart
builds; checkpoints reshard on restore, so scaling the pod count between
runs (node failures, capacity changes) requires no checkpoint surgery.
"""
from __future__ import annotations

import os

from repro.launch.mesh import make_mesh

__all__ = ["mesh_from_env"]


def mesh_from_env(default: str = "pod16x16"):
    spec = os.environ.get("REPRO_MESH", default)
    if spec == "pod16x16":
        return make_mesh((16, 16), ("data", "model"))
    if spec == "pod2x16x16":
        return make_mesh((2, 16, 16), ("pod", "data", "model"))
    if spec.startswith("d"):                       # e.g. d2x2 for tests
        dims = tuple(int(x) for x in spec[1:].split("x"))
        names = ("data", "model")[:len(dims)]
        return make_mesh(dims, names)
    raise ValueError(f"unknown REPRO_MESH={spec!r}")
