"""Checkpoint-restart failover loop + failure injection for testing.

``run_with_failover`` wraps a training function: on a recoverable failure
(injected hardware fault, watchdog hang, preemption signal) it restores the
latest checkpoint and continues, up to ``max_restarts``.  On a real cluster
the restart re-enters through the launcher with a possibly *different* mesh
(elastic) — covered by checkpointer reshard-on-restore.

ISSUE 6 generalizes ``FailureInjector`` from train steps to serving
faults, so the fault-tolerant continuous-batching scheduler
(runtime/serving.py) can be chaos-tested with the same deterministic
injector the training loop uses:

* ``fail_at`` — segment-level simulated device loss: ``maybe_fail(seg)``
  raises ``SimulatedHardwareFailure`` at a segment boundary; the serve
  loop's ``run_with_failover`` wrapper restores the latest serve-state
  snapshot and replays the segment bit-identically.
* ``page_flips`` — int8 page-pool bit flips (SEU model): at a given
  segment, XOR a bit pattern into one element of a slot's share of the
  paged KV cache (int8 page planes, f32 dequant scales, or the bf16
  tail).  Flips address *logical* state (slot + plane + element), so the
  affected request is determinate even though physical page ids depend on
  allocator history.  Each flip fires once (``fired``) — a transient
  upset, not a persistent fault — so a post-flip snapshot replay does not
  re-corrupt.
* ``macro_fault_at`` — a persistent stuck-at fault in the DS-CIM macro:
  from that segment on, ``serving_fault(seg)`` returns a non-empty
  ``cfg.dscim_fault`` spec (models/lm.py ``_parse_fault``) and the serve
  loop rebuilds its jitted segment/admit functions against the faulted
  config.  Persistent by construction (re-applied deterministically on
  replay), unlike the one-shot flips.
"""
from __future__ import annotations

import dataclasses

__all__ = ["SimulatedHardwareFailure", "IntegrityReplay", "FailureInjector",
           "run_with_failover", "flip_bits"]


class SimulatedHardwareFailure(RuntimeError):
    pass


class IntegrityReplay(RuntimeError):
    """Raised by the integrity layer (runtime/integrity.py) when a
    corrupted weight plane was repaired *after* poisoned segments already
    ran: the repair itself is surgical, but tokens decoded against the
    corrupted plane must be discarded — recoverable via the same
    snapshot-restore replay path as a device loss (the restored snapshot
    replays against the now-repaired weights, bit-clean)."""


def flip_bits(arr, index: tuple, mask: int):
    """XOR ``mask`` into one element of a jnp array — int dtypes directly,
    float dtypes through a same-width bitcast (so a flip can hit a f32
    scale's exponent, the classic NaN/Inf-producing upset).

    ``mask`` must fit the element's bit width: a too-wide mask (say
    ``0x7f000000`` aimed at a f32 plane but landing on int8) would
    silently truncate or overflow the dtype cast, and the injector's
    coverage claim ("this flip hit that plane") would be a lie."""
    import jax
    import jax.numpy as jnp
    width = jnp.dtype(arr.dtype).itemsize * 8
    if not 0 < int(mask) < (1 << width):
        raise ValueError(
            f"flip_bits: mask {mask:#x} does not fit a {width}-bit "
            f"{jnp.dtype(arr.dtype).name} element")
    if jnp.issubdtype(arr.dtype, jnp.floating):
        bits = {2: jnp.uint16, 4: jnp.uint32}[arr.dtype.itemsize]
        as_int = jax.lax.bitcast_convert_type(arr, bits)
        # typed mask: a bare python int >= 2**31 (an f32 sign-bit flip)
        # would overflow jnp's weak int32 promotion in the XOR
        as_int = as_int.at[index].set(as_int[index] ^ jnp.asarray(mask, bits))
        return jax.lax.bitcast_convert_type(as_int, arr.dtype)
    umask = jnp.asarray(mask, jnp.uint32).astype(arr.dtype)
    return arr.at[index].set(arr[index] ^ umask)


@dataclasses.dataclass
class FailureInjector:
    """Deterministically inject faults at given step/segment numbers.

    ``page_flips``: {segment: ((slot, plane, index, mask), ...)} — plane
    is one of 'k_pages'/'v_pages' (index (layer, page_ord, tok, kv, hd)),
    'k_scale'/'v_scale' (index (layer, page_ord, kv)), or
    'k_tail'/'v_tail' (index (layer, tok, kv, hd)); ``page_ord`` is the
    ordinal within the slot's granted pages, translated to a physical id
    by ``corrupt_cache`` via the scheduler's slot_pages map.
    ``macro_fault_at``/``macro_fault``: arm ``cfg.dscim_fault`` from that
    segment on (persistent — see module docstring).

    ``weight_flips`` (ISSUE 9): {segment: ((path, 'q'|'scale', offset,
    mask), ...)} — bit upsets in *prepared weight planes*
    (core/qweights.QuantizedLinearWeight).  ``path`` is the plane's
    flattened path string (``path_str``), ``offset`` a flat element
    offset (taken mod the plane's size, so ``sampled`` needs no shape
    knowledge).  One-shot like page flips: a snapshot replay after the
    repair does not re-corrupt."""
    fail_at: tuple = ()
    page_flips: dict = dataclasses.field(default_factory=dict)
    weight_flips: dict = dataclasses.field(default_factory=dict)
    macro_fault_at: int | None = None
    macro_fault: str = "stuck:5:24.0"
    fired: set = dataclasses.field(default_factory=set)

    @classmethod
    def sampled(cls, seed: int, *, segments: int = 64, slots: int = 4,
                n_layers: int = 2, page_size: int = 8, n_kv: int = 1,
                head_dim: int = 8, device_losses: int = 1, flips: int = 2,
                macro_fault: str | None = None,
                weight_paths: tuple = (),
                weight_flip_count: int = 0) -> "FailureInjector":
        """A randomized-but-reproducible fault schedule over ``segments``
        serve segments: ``device_losses`` segment-level device losses,
        ``flips`` page-pool bit upsets at random (slot, plane, element)
        addresses, and optionally a persistent stuck-at macro fault armed
        mid-run.  Everything derives from ``seed`` via one
        ``np.random.default_rng`` stream, so a chaos-drill or load-test
        failure reproduces exactly from the logged seed (the
        ``--chaos-seed`` contract) — same schedule, same addresses."""
        import numpy as np
        rng = np.random.default_rng(seed)
        hi = max(segments, 2)
        fail_at = tuple(sorted(rng.choice(
            np.arange(1, hi), size=min(device_losses, hi - 1),
            replace=False).tolist()))
        planes = ("k_pages", "v_pages", "k_scale", "v_scale",
                  "k_tail", "v_tail")
        page_flips: dict = {}
        for _ in range(flips):
            seg = int(rng.integers(1, hi))
            slot = int(rng.integers(0, slots))
            plane = planes[int(rng.integers(0, len(planes)))]
            layer = int(rng.integers(0, n_layers))
            if plane.endswith("_scale"):
                index = (layer, 0, int(rng.integers(0, n_kv)))
                mask = 1 << int(rng.integers(20, 31))      # f32 high bits
            elif plane.endswith("_tail"):
                index = (layer, int(rng.integers(0, page_size)),
                         int(rng.integers(0, n_kv)),
                         int(rng.integers(0, head_dim)))
                mask = 1 << int(rng.integers(8, 15))       # bf16 high bits
            else:
                index = (layer, 0, int(rng.integers(0, page_size)),
                         int(rng.integers(0, n_kv)),
                         int(rng.integers(0, head_dim)))
                mask = 1 << int(rng.integers(0, 8))        # int8 any bit
            page_flips.setdefault(seg, ())
            page_flips[seg] = page_flips[seg] + ((slot, plane, index, mask),)
        weight_flips: dict = {}
        if weight_flip_count and weight_paths:
            for _ in range(weight_flip_count):
                seg = int(rng.integers(1, hi))
                path = weight_paths[int(rng.integers(0, len(weight_paths)))]
                which = ("q", "scale")[int(rng.integers(0, 2))]
                offset = int(rng.integers(0, 1 << 30))   # taken mod size
                mask = (1 << int(rng.integers(0, 8)) if which == "q"
                        else 1 << int(rng.integers(20, 31)))
                weight_flips.setdefault(seg, ())
                weight_flips[seg] = weight_flips[seg] \
                    + ((path, which, offset, mask),)
        macro_at = None
        if macro_fault:
            macro_at = int(rng.integers(1, hi))
        return cls(fail_at=fail_at, page_flips=page_flips,
                   weight_flips=weight_flips,
                   macro_fault_at=macro_at,
                   macro_fault=macro_fault or "stuck:5:24.0")

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedHardwareFailure(f"injected fault at step {step}")

    def serving_fault(self, segment: int) -> str:
        """cfg.dscim_fault spec in force at this segment ('' = healthy)."""
        if self.macro_fault_at is not None and segment >= self.macro_fault_at:
            return self.macro_fault
        return ""

    def corrupt_cache(self, segment: int, cache, slot_pages):
        """Apply this segment's due page-pool bit flips to a paged KV
        cache (once each — transient upsets).  ``slot_pages``: the
        scheduler's slot -> granted physical page ids map.  Returns
        (cache', affected slot ids)."""
        affected = []
        for flip in self.page_flips.get(segment, ()):
            key = ("flip", segment, flip)
            if key in self.fired:
                continue
            slot, plane, index, mask = flip
            if slot_pages[slot] is None:
                continue            # slot idle this segment: nothing to hit
            self.fired.add(key)
            if plane.endswith("_tail"):
                layer, *rest = index
                full = (layer, slot, *rest)
            else:
                layer, page_ord, *rest = index
                full = (layer, int(slot_pages[slot][page_ord]), *rest)
            cache = dict(cache,
                         **{plane: flip_bits(cache[plane], full, mask)})
            affected.append(slot)
        return cache, affected

    def corrupt_weights(self, segment: int, params):
        """Apply this segment's due prepared-weight plane flips (once
        each).  Returns (params', [(path, which), ...] hit).  The flat
        offset is unraveled against the live plane's shape, so one
        sampled schedule works across models."""
        import numpy as np
        from repro.core.qweights import QuantizedLinearWeight, path_str
        import jax
        hit = []
        for flip in self.weight_flips.get(segment, ()):
            key = ("wflip", segment, flip)
            if key in self.fired:
                continue
            self.fired.add(key)
            path, which, offset, mask = flip
            touched = []

            def corrupt(p, leaf, _path=path, _which=which,
                        _offset=offset, _mask=mask):
                if not (isinstance(leaf, QuantizedLinearWeight)
                        and path_str(p) == _path):
                    return leaf
                arr = getattr(leaf, _which)
                idx = np.unravel_index(_offset % arr.size, arr.shape)
                arr = flip_bits(arr, idx, _mask)
                touched.append(_path)
                return QuantizedLinearWeight(
                    arr if _which == "q" else leaf.q,
                    arr if _which == "scale" else leaf.scale,
                    leaf.k_orig, leaf.group_k)

            params = jax.tree_util.tree_map_with_path(
                corrupt, params,
                is_leaf=lambda x: isinstance(x, QuantizedLinearWeight))
            if touched:
                hit.append((path, which))
        return params, hit


def run_with_failover(train_fn, *, restore_fn, max_restarts: int = 3,
                      recoverable=(SimulatedHardwareFailure,), log=print):
    """train_fn(start_state) -> final_state; restore_fn() -> start_state.

    Returns (final_state, n_restarts)."""
    restarts = 0
    while True:
        state = restore_fn()
        try:
            return train_fn(state), restarts
        except recoverable as e:
            restarts += 1
            log(f"[failover] {type(e).__name__}: {e}; "
                f"restart {restarts}/{max_restarts}")
            if restarts > max_restarts:
                raise
