"""Checkpoint-restart failover loop + failure injection for testing.

``run_with_failover`` wraps a training function: on a recoverable failure
(injected hardware fault, watchdog hang, preemption signal) it restores the
latest checkpoint and continues, up to ``max_restarts``.  On a real cluster
the restart re-enters through the launcher with a possibly *different* mesh
(elastic) — covered by checkpointer reshard-on-restore.
"""
from __future__ import annotations

import dataclasses

__all__ = ["SimulatedHardwareFailure", "FailureInjector", "run_with_failover"]


class SimulatedHardwareFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at given step numbers (tests/examples)."""
    fail_at: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedHardwareFailure(f"injected fault at step {step}")


def run_with_failover(train_fn, *, restore_fn, max_restarts: int = 3,
                      recoverable=(SimulatedHardwareFailure,), log=print):
    """train_fn(start_state) -> final_state; restore_fn() -> start_state.

    Returns (final_state, n_restarts)."""
    restarts = 0
    while True:
        state = restore_fn()
        try:
            return train_fn(state), restarts
        except recoverable as e:
            restarts += 1
            log(f"[failover] {type(e).__name__}: {e}; "
                f"restart {restarts}/{max_restarts}")
            if restarts > max_restarts:
                raise
