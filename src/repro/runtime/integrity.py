"""Deterministic silent-data-corruption detection + targeted repair
(ISSUE 9 tentpole).

The serving stack's pre-existing fault handling is *statistical*: the
accuracy watchdog notices a bit flip only if it drags the probe's logit
RMSE past the ErrorModel threshold, and ``stats['corrupted_requests']``
is honest only because the chaos injector says which slots it hit.  This
module makes int8 state corruption *deterministically* detectable and
*surgically* repairable:

* **KV page pool** — every physical page carries a uint32 digest over its
  int8 k/v planes and bitcast f32 scales (``core/kvcache.page_checksums``),
  stored in the cache's device-resident ``page_sum`` plane and kept
  current by the jitted write paths.  ``check_pages`` re-digests the live
  pool in one compiled sweep and attributes any mismatch to an exact
  (layer, physical page) coordinate.
* **Prepared weights** — every ``QuantizedLinearWeight`` plane (int8 q,
  f32 scale) is digested once at ``prepare_serving_params(...,
  golden=True)`` alongside a host-side bit-exact golden copy.
  ``check_weights`` re-digests the live planes in one compiled sweep;
  a mismatch names the exact (path, 'q'|'scale') plane, and
  ``repair_weights`` re-installs the golden bytes — bit-identical to the
  freshly prepared model, no requantization.

What this deliberately does NOT cover: raw float leaves (norms, the
embedding table) and transient activations — those stay the watchdog's
statistical territory (docs/serving.md "Fault model & integrity
contract").

Cadence (the scheduler's ``integrity`` option):

* ``'off'``    — period 0, no digest plane, today's behavior bit-for-bit;
* ``'verify'`` — period 1, check every segment boundary (detection
  latency <= 1 segment);
* ``'scrub:<n>'`` — check every n-th boundary (background scrubbing —
  cheaper, detection latency <= n segments).

Counters live on the engine, not in the scheduler's host dict, so a
snapshot-restore replay does not erase the record of what was detected.
"""
from __future__ import annotations

import time

import numpy as np

__all__ = ["parse_integrity", "IntegrityEngine"]


def _page_bad(cache, live_mask):
    from repro.core.kvcache import page_checksums, CHECKSUM_KEY
    cur = page_checksums(cache["k_pages"], cache["v_pages"],
                         cache["k_scale"], cache["v_scale"])
    return (cur != cache[CHECKSUM_KEY]) & live_mask[None, :]


_SWEEPS: dict = {}


def _sweeps():
    """Module-level jitted sweep functions, shared across engines.

    An engine is built per serve call; per-instance ``jax.jit`` wrappers
    would retrace both sweeps on every call, which at smoke shapes costs
    more than the sweeps themselves."""
    if not _SWEEPS:
        import jax
        from repro.core.qweights import weight_plane_digests
        _SWEEPS["weights"] = jax.jit(weight_plane_digests)
        _SWEEPS["pages"] = jax.jit(_page_bad)
    return _SWEEPS


def parse_integrity(spec: str | None) -> int:
    """'off'|'verify'|'scrub:<n>' -> check period in segments (0 = off)."""
    if spec is None or spec == "off":
        return 0
    if spec == "verify":
        return 1
    if spec.startswith("scrub:"):
        try:
            n = int(spec.split(":", 1)[1])
        except ValueError:
            n = 0
        if n >= 1:
            return n
    raise ValueError(f"integrity spec {spec!r}: expected 'off', 'verify' "
                     f"or 'scrub:<n>' with n >= 1")


class IntegrityEngine:
    """Segment-boundary verifier/scrubber + repair bookkeeping.

    ``golden`` is the blob from ``prepare_serving_params(...,
    golden=True)`` (None when the model has no prepared planes — weight
    checks then trivially pass).  The engine owns the jitted sweep
    functions, the reference digest vector, the detection ledger, and the
    counters surfaced through serve stats and ``Router /stats``."""

    def __init__(self, golden, *, period: int):
        self.period = int(period)
        self.golden = golden
        self.index = list(golden["index"]) if golden else []
        self.ref_digests = (np.asarray(golden["digests"]) if golden
                            else np.zeros((0,), np.uint32))
        sweeps = _sweeps()
        self._weight_sweep = sweeps["weights"]
        self._page_sweep = sweeps["pages"]
        self.detections: list = []
        self.counters = {"checks": 0,
                         "pages_verified": 0,
                         "weight_planes_verified": 0,
                         "page_mismatches": 0,
                         "weight_mismatches": 0,
                         "page_repairs": 0,
                         "weight_repairs": 0,
                         "replays": 0,
                         "scrub_time_s": 0.0}

    def due(self, segment: int) -> bool:
        return self.period > 0 and segment % self.period == 0

    # -- detection ----------------------------------------------------------
    def check_pages(self, cache, live_mask) -> list:
        """Re-digest the pool, compare against the stored plane, return
        the mismatching (layer, physical_page) coordinates.  ``live_mask``
        (n_pages,) bool marks pages that are granted AND completely
        flushed — only those have digests under warranty (freed or
        tail-resident pages hold stale sums by design)."""
        t0 = time.perf_counter()
        mask = np.asarray(live_mask, bool)
        bad = np.asarray(self._page_sweep(cache, mask))
        self.counters["checks"] += 1
        self.counters["pages_verified"] += int(mask.sum()) * bad.shape[0]
        self.counters["scrub_time_s"] += time.perf_counter() - t0
        coords = [tuple(int(v) for v in c) for c in np.argwhere(bad)]
        if coords:
            self.counters["page_mismatches"] += len(coords)
            self.detections.append({"kind": "page", "coords": coords})
        return coords

    def check_weights(self, params) -> list:
        """Re-digest every prepared plane, return the mismatching
        (path, 'q'|'scale') pairs."""
        if not self.index:
            return []
        t0 = time.perf_counter()
        cur = np.asarray(self._weight_sweep(params))
        self.counters["weight_planes_verified"] += len(self.index)
        self.counters["scrub_time_s"] += time.perf_counter() - t0
        bad = [self.index[i] for i in
               np.nonzero(cur != self.ref_digests)[0].tolist()]
        if bad:
            self.counters["weight_mismatches"] += len(bad)
            self.detections.append({"kind": "weight", "coords": list(bad)})
        return bad

    # -- repair -------------------------------------------------------------
    def repair_weights(self, params, planes) -> "params":
        """Re-install the golden bytes for each corrupted plane.  The
        result digests clean by construction (asserted — a repair that
        doesn't verify would be a silent double fault)."""
        from repro.core.qweights import restore_weight_plane
        for path, which in planes:
            params = restore_weight_plane(params, path, which, self.golden)
            self.counters["weight_repairs"] += 1
        cur = np.asarray(self._weight_sweep(params))
        if (cur != self.ref_digests).any():
            raise RuntimeError("integrity: weight repair failed to verify")
        return params

    def note_page_repair(self, n: int = 1) -> None:
        self.counters["page_repairs"] += n

    def note_replay(self) -> None:
        self.counters["replays"] += 1

    def stats(self) -> dict:
        out = dict(self.counters)
        out["period"] = self.period
        out["scrub_time_s"] = round(out["scrub_time_s"], 6)
        return out
