"""Async serving router: the request-granular frontend above the
continuous-batching scheduler (ISSUE 8 tentpole).

``serve_continuous`` serves a *closed* queue of fixed-length prompts; a
service faces the opposite shape — streaming requests of arbitrary prompt
length arriving at arbitrary times, each wanting its tokens back as they
are produced and a definite answer when they are not.  ``Router`` is that
layer: an asyncio in-process frontend (launch/server.py wraps it in a
thin HTTP shim) that owns the same jitted scheduler halves the
fault-tolerant loop uses (launch/steps.py ``make_admit_fn`` /
``make_segment_fn`` / ``make_extend_fn`` / ``make_probe_fn``) and drives
them request-by-request instead of queue-at-once.  Greedy deterministic
serving is schedule-independent per request (the PR 4 continuous-vs-
one-shot bitwise property), so a request admitted through the router
emits bitwise the tokens ``serve_continuous`` would have given it — the
load-test acceptance criterion.

Admission paths (the PR 4 "length bucketing" follow-on):

* **bucketed one-shot** — a prompt whose length is one of ``buckets``
  prefills in one jitted ``admit`` call; each bucket length compiles
  once (the jit shape cache), so a handful of cached admit fns replace
  the single fixed prompt shape.  Bitwise-comparable to
  ``serve_continuous`` at the same prompt length.
* **chunked** — any other length feeds through ONE compiled
  ``make_extend_fn`` program, ``chunk_len`` prompt tokens per engine
  round, final partial chunk padded and rolled back (the speculative
  write-then-rollback discipline).  Decode segments for live slots run
  between chunks, so a 10k-token admission never stalls streaming
  requests.  Sequential-decode equivalent (teacher-forced ``decode``
  parity), not bitwise-equal to the batched full-prompt prefill.
* **prefix-cached** (``prefix_cache=True``, ISSUE 10) — every
  admission routes chunked at ``chunk_len == page_size`` through one
  compiled extend program; a prompt sharing a page-aligned prefix with
  an earlier request maps its leading page-table rows to the donor's
  physical int8 pages (``PrefixCache.acquire``) and feeds only from
  the first divergent page.  Because hit and miss run the identical
  program over identical bytes, a prefix hit is bitwise-identical to
  the same prompt served cold by this router.  ``cow_fork`` guards the
  write frontier; refcounts ride the allocator snapshot so failover
  replay preserves sharing.

Robustness surface (the headline):

* **Backpressure** — ``submit`` raises a typed ``Refused`` instead of
  queueing unboundedly: ``too_large`` (the request could never fit the
  page pool/capacity — permanent, a 413), ``queue`` (admission queue at
  ``max_queue`` — transient, a 429 with a throughput-derived
  ``retry_after`` hint), ``draining`` (shutdown in progress — a 503).
  Page-pool exhaustion for admissible requests is *queueing*, not
  refusal; the queue bound is where overload sheds.
* **Deadlines** — ``deadline_s`` anchors at submission (an end-to-end
  SLO: queue time counts), ``deadline_steps`` at admission (a
  deterministic decode-step budget).  Expiry cancels at the next round
  boundary with status ``deadline`` and the partial tokens already
  streamed stay valid.
* **Cancellation** — ``handle.cancel()`` (client disconnect) frees the
  slot and recycles its pages mid-stream at the next round; status
  ``cancelled``.
* **Failover** — the engine snapshots serve state every
  ``snapshot_every`` rounds (device pytree + host bookkeeping + page
  allocator, the PR 6 machinery); a recoverable fault
  (``FailureInjector`` device loss, watchdog hang) restores and replays
  bit-identically.  Tokens already pushed to a stream are never
  re-pushed: per-request ``sent`` cursors live *outside* the snapshot,
  and the replay regrows the same token list underneath them.
* **Quarantine -> degraded** — the accuracy watchdog (``monitor``)
  quarantines a drifting/NaN slot exactly as in runtime/serving.py, but
  the router re-serves the request down the degradation ladder
  *immediately* (it cannot wait for end-of-queue: there is none) and the
  stream signals ``('restart', None)`` before the re-served tokens;
  terminal status ``degraded`` — visible, definite, trustworthy output.
* **Drain** — ``close('drain')`` stops admission, refuses the
  still-queued (retryable elsewhere), finishes live requests;
  ``close('snapshot')`` parks live+queued state in a resumable blob
  (``Router(..., resume=blob)`` picks them back up and completes them)
  and ends their streams ``cancelled``.  Either way the page pool drains
  to zero live pages — the leak check the load test asserts.

Every request ends in exactly one of ``ok | deadline | refused |
cancelled | degraded`` (docs/serving.md maps these to scheduler statuses
and HTTP codes).

Event-loop note: the jitted calls block the loop for one segment at a
time (milliseconds at serving shapes).  The engine yields between rounds,
which is what keeps submissions/cancellations responsive — this is an
in-process router, not a multi-host load balancer.
"""
from __future__ import annotations

import asyncio
import copy
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvcache import (PageAllocator, PrefixCache, admission_pages,
                                cow_fork, n_pages_for)
from repro.launch.steps import (_parse_spec, init_serve_state, make_admit_fn,
                                make_extend_fn, make_probe_fn,
                                make_segment_fn)
from repro.runtime.failover import IntegrityReplay, SimulatedHardwareFailure
from repro.runtime.integrity import IntegrityEngine, parse_integrity
from repro.runtime.serving import exact_probe_spec, next_ladder_spec
from repro.runtime.watchdog import StepHang

__all__ = ["Router", "RequestHandle", "RouterResult", "Refused",
           "STATUS_OK", "STATUS_DEADLINE", "STATUS_REFUSED",
           "STATUS_CANCELLED", "STATUS_DEGRADED", "TERMINAL_STATUSES"]

STATUS_OK = "ok"
STATUS_DEADLINE = "deadline"
STATUS_REFUSED = "refused"
STATUS_CANCELLED = "cancelled"
STATUS_DEGRADED = "degraded"
TERMINAL_STATUSES = (STATUS_OK, STATUS_DEADLINE, STATUS_REFUSED,
                     STATUS_CANCELLED, STATUS_DEGRADED)

_RECOVERABLE = (SimulatedHardwareFailure, StepHang, IntegrityReplay)


class Refused(Exception):
    """Typed admission refusal (the 429/413/503 surface).

    ``reason``: 'queue' (transient overload — retry after ``retry_after``
    seconds), 'too_large' (permanent: the request cannot fit this
    router's capacity/page pool), 'draining' (shutdown in progress —
    retry against another replica)."""

    def __init__(self, reason: str, retry_after: float | None = None,
                 detail: str = ""):
        self.reason = reason
        self.retry_after = retry_after
        super().__init__(f"admission refused ({reason})"
                         + (f": {detail}" if detail else ""))


@dataclasses.dataclass
class RouterResult:
    status: str
    tokens: list


class _Request:
    """Host-side request record.  The snapshotable parts of a request's
    life (tokens, status, admission anchors) live in the engine's host
    dict keyed by rid; this object carries the *client-visible* half —
    the stream queue and its ``sent`` cursor — which deliberately stays
    OUT of failover snapshots so a bitwise replay never re-streams."""

    __slots__ = ("rid", "prompt", "max_new", "deadline_s", "deadline_steps",
                 "priority", "submit_t", "queue", "sent", "cancelled",
                 "restart_sent", "ended")

    def __init__(self, rid, prompt, max_new, deadline_s, deadline_steps,
                 priority, submit_t):
        self.rid = rid
        self.prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.max_new = int(max_new)
        self.deadline_s = deadline_s
        self.deadline_steps = deadline_steps
        self.priority = int(priority)
        self.submit_t = submit_t
        self.queue: asyncio.Queue = asyncio.Queue()
        self.sent = 0
        self.cancelled = False
        self.restart_sent = False
        self.ended = False

    def descriptor(self) -> dict:
        """Plain-data re-submission record for ``close('snapshot')``."""
        return {"rid": self.rid, "prompt": self.prompt.tolist(),
                "max_new": self.max_new, "deadline_s": self.deadline_s,
                "deadline_steps": self.deadline_steps,
                "priority": self.priority}


class RequestHandle:
    """Client handle: an event stream plus cancellation.

    ``events()`` yields ``('token', id)`` per streamed token,
    ``('restart', None)`` when a quarantined request is re-served down
    the degradation ladder (previously streamed tokens are void), and a
    final ``('end', status)``.  ``result()`` folds that stream into a
    ``RouterResult``.  Consume one of the two — they share the queue."""

    def __init__(self, req: _Request):
        self._req = req

    @property
    def rid(self) -> int:
        return self._req.rid

    def cancel(self) -> None:
        """Client disconnect: the engine frees the slot and recycles its
        pages at the next round boundary (status ``cancelled``)."""
        self._req.cancelled = True

    async def events(self):
        while True:
            ev = await self._req.queue.get()
            yield ev
            if ev[0] == "end":
                return

    async def result(self) -> RouterResult:
        tokens: list = []
        async for kind, val in self.events():
            if kind == "token":
                tokens.append(int(val))
            elif kind == "restart":
                tokens.clear()
            else:
                return RouterResult(status=val, tokens=tokens)
        raise AssertionError("event stream ended without a terminal status")


class Router:
    """Asyncio serving frontend over the continuous-batching scheduler.

    ``params`` are placed/prepared once at construction (the
    launch/serve.py ``_place`` rules).  ``buckets`` lists the one-shot
    prefill lengths (each compiles one admit fn); any other prompt
    length <= ``max_prompt`` takes the chunked path.  ``max_new_cap``
    bounds per-request budgets (page grants are sized from it).
    ``monitor``/``injector``/``snapshot_every`` are the PR 6 knobs with
    identical semantics; ``spec`` enables self-speculative decode
    segments (PR 7).  ``prefix_cache`` turns on page-aligned prefix
    sharing (int8 KV only; forces every admission chunked at
    ``chunk_len == page_size`` so hits stay bitwise-identical to cold).
    Call ``await start()`` before ``submit``."""

    def __init__(self, cfg, params, *, slots: int = 4, seg_len: int = 4,
                 kv: str = "int8", page_size: int = 8,
                 n_pages: int | None = None,
                 buckets: tuple = (8, 16, 32), chunk_len: int = 16,
                 max_prompt: int = 256, max_new_cap: int = 64,
                 max_queue: int = 64, eos_id: int | None = -1,
                 sample: str = "greedy", paged_attn: str = "auto",
                 spec: str | None = None, par=None, prepare: bool = True,
                 rng_seed: int = 0, monitor=None, injector=None,
                 snapshot_every: int = 0, max_replays: int = 3,
                 integrity: str = "off", prefix_cache: bool = False,
                 resume: dict | None = None, log=print):
        from repro.launch.serve import _place   # lazy: serve.py imports us
        self.cfg = cfg
        self.params = _place(cfg, params, par, prepare)
        self.slots = slots
        self.seg_len = seg_len
        self.kv = kv
        self.page_size = page_size
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.chunk_len = int(chunk_len)
        self.prefix_cache = bool(prefix_cache)
        if self.prefix_cache:
            if kv != "int8":
                raise ValueError("prefix caching shares int8 physical "
                                 "pages; pass kv='int8'")
            # every admission (hit or miss) runs the one compiled extend
            # program at chunk_len == page_size, page-aligned from the
            # first divergent page — the bitwise hit-vs-cold contract
            self.chunk_len = self.page_size
        self.max_prompt = int(max_prompt)
        self.max_new_cap = int(max_new_cap)
        self.max_queue = int(max_queue)
        self.eos_id = eos_id
        self.eos = -1 if eos_id is None else eos_id
        self.sample = sample
        self.paged_attn = paged_attn
        self.spec = spec
        self.par = par
        self.rng_seed = rng_seed
        self.monitor = monitor
        self.injector = injector
        self.snapshot_every = snapshot_every
        self.max_replays = max_replays
        self.log = log

        sp = _parse_spec(spec)
        k_spec = sp[1] if sp else 0
        # in-flight write overhang past the committed position: spec
        # windows write up to k draft positions, a padded final prefill
        # chunk up to chunk_len - 1 pad positions — grants must cover
        # whichever the request's path can incur (core/kvcache.py
        # admission_pages is the shared accounting rule)
        self.headroom_bucket = k_spec
        self.headroom_chunked = max(k_spec, self.chunk_len - 1)
        self.capacity = self.max_prompt + self.max_new_cap \
            + max(self.headroom_bucket, self.headroom_chunked)
        self.mp = n_pages_for(self.capacity, page_size)
        period = parse_integrity(integrity)
        if period > 0 and kv != "int8":
            raise ValueError("integrity checksums cover the int8 paged "
                             "cache; use kv='int8' or integrity='off'")
        self._integrity = None
        if period > 0:
            from repro.core.qweights import golden_weight_copy
            self._integrity = IntegrityEngine(
                golden_weight_copy(self.params), period=period)
        self._state = init_serve_state(cfg, slots, self.capacity, kv=kv,
                                       page_size=page_size, n_pages=n_pages,
                                       seed=rng_seed, integrity=period > 0)
        self._alloc = PageAllocator(self._state["cache"]["k_pages"].shape[1]) \
            if kv == "int8" else None
        self.n_pages = self._alloc.n_pages if self._alloc is not None else None
        self._no_pages = jnp.zeros((self.mp,), jnp.int32)
        self._prefix = PrefixCache(self._alloc, page_size) \
            if self.prefix_cache else None

        self._segment = make_segment_fn(cfg, par, seg_len, eos_id=eos_id,
                                        sample=sample, paged_attn=paged_attn,
                                        spec=spec)
        self._extend = make_extend_fn(cfg, par, self.chunk_len,
                                      eos_id=eos_id, sample=sample,
                                      paged_attn=paged_attn)
        self._probe = None
        if monitor is not None and monitor.rel_threshold is not None:
            if cfg.dscim in ("off", "float"):
                raise ValueError("drift probes need a dscim serving spec "
                                 "(see runtime/serving.py)")
            cfg_probe = dataclasses.replace(
                cfg, dscim=exact_probe_spec(cfg.dscim), dscim_fault="")
            self._probe = make_probe_fn(cfg_probe, par)
        self._k_spec = k_spec

        # host bookkeeping — everything the failover snapshot must carry
        self._host = {
            "slot_rid": [-1] * slots,       # rid per slot (-1 free)
            "slot_pages": [None] * slots,
            "slot_phase": ["idle"] * slots,  # idle | prefill | decode
            "slot_fed": [0] * slots,         # chunked-prefill cursor
            "waiting": [],                   # admission queue (rids)
            "out": {},                       # rid -> [token, ...]
            "status": {},                    # rid -> None | terminal
            "restarted": {},                 # rid -> bool (ladder re-serve)
            "admit_t": {},                   # rid -> wall admission anchor
            "admit_step": {},                # rid -> global_step at admission
            "segments": 0, "global_step": 0,
            "live_steps": 0, "total_steps": 0,
            "prefill_computed": 0, "prefill_total": 0,
            "counters": {"deadline_cancelled": 0, "cancelled": 0,
                         "quarantined": 0, "degraded": 0, "refused_queue": 0,
                         "refused_too_large": 0, "refused_draining": 0},
        }
        self._requests: dict = {}            # rid -> _Request (NOT snapshot)
        self._inbox: list = []               # submitted, not yet ingested
        self._next_rid = 0
        self._replays = 0
        self._snap = None
        self._vsnap = None       # last integrity-verified snapshot
        self._draining = False
        self._drain_mode = "drain"
        self._engine_task = None
        self._wake: asyncio.Event | None = None
        self._tok_s_ema = 0.0
        self._t_start = time.perf_counter()
        self._resume_handles: dict = {}
        self._snapshot_blob = None
        if resume is not None:
            self._restore_blob(resume)

    # ------------------------------------------------------------------
    # client API
    # ------------------------------------------------------------------

    async def start(self) -> None:
        """Spawn the engine task on the running loop."""
        if self._engine_task is not None:
            return
        self._wake = asyncio.Event()
        self._engine_task = asyncio.create_task(self._engine())

    def _need_pages(self, prompt_len: int, max_new: int,
                    chunked: bool) -> int:
        head = self.headroom_chunked if chunked else self.headroom_bucket
        return admission_pages(prompt_len, max_new, self.page_size, head)

    def _queue_depth(self) -> int:
        return len(self._inbox) + len(self._host["waiting"])

    def _retry_after(self) -> float:
        """Throughput-derived backoff hint: the queued + live token debt
        over the recent useful tok/s (floored so a cold router still
        hints something finite)."""
        debt = 0
        for rid in self._host["waiting"]:
            rq = self._requests[rid]
            debt += len(rq.prompt) + rq.max_new
        for rid, rq in ((i, self._requests[i]) for i in self._inbox):
            debt += len(rq.prompt) + rq.max_new
        for b in range(self.slots):
            rid = self._host["slot_rid"][b]
            if rid >= 0:
                debt += self._requests[rid].max_new
        return debt / max(self._tok_s_ema, 1.0)

    def submit(self, prompt, max_new: int, *, deadline_s: float | None = None,
               deadline_steps: int | None = None,
               priority: int = 0) -> RequestHandle:
        """Admit one streaming request, or raise ``Refused``.

        ``prompt``: 1-D int32 token ids (any length <= ``max_prompt``).
        ``max_new``: generation budget (<= ``max_new_cap``), counted like
        the scheduler's — including the first prefill-sampled token.
        ``deadline_s`` anchors at *this call* (queue time counts);
        ``deadline_steps`` at admission.  ``priority`` orders admission
        only (higher first; FIFO within a class) — the router never
        preempts a live slot."""
        if self._draining:
            self._host["counters"]["refused_draining"] += 1
            raise Refused("draining")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        S = len(prompt)
        if S < 1 or S > self.max_prompt or max_new < 1 \
                or max_new > self.max_new_cap:
            self._host["counters"]["refused_too_large"] += 1
            raise Refused("too_large", detail=(
                f"prompt {S} tokens / budget {max_new} vs max_prompt "
                f"{self.max_prompt} / max_new_cap {self.max_new_cap}"))
        chunked = self.prefix_cache or S not in self.buckets
        if self.n_pages is not None \
                and self._need_pages(S, max_new, chunked) > self.n_pages:
            self._host["counters"]["refused_too_large"] += 1
            raise Refused("too_large", detail=(
                f"{self._need_pages(S, max_new, chunked)} pages needed, "
                f"pool holds {self.n_pages}"))
        if self._queue_depth() >= self.max_queue:
            self._host["counters"]["refused_queue"] += 1
            raise Refused("queue", retry_after=self._retry_after(),
                          detail=f"admission queue at {self.max_queue}")
        rid = self._next_rid
        self._next_rid += 1
        req = _Request(rid, prompt, max_new, deadline_s, deadline_steps,
                       priority, time.perf_counter())
        self._requests[rid] = req
        self._inbox.append(rid)
        if self._wake is not None:
            self._wake.set()
        return RequestHandle(req)

    async def close(self, mode: str = "drain") -> dict | None:
        """Graceful shutdown.  ``'drain'``: stop admission, serve live
        requests to completion, end still-queued streams ``refused``
        (retryable elsewhere).  ``'snapshot'``: stop admission, park live
        + queued request state in a resumable blob (returned; feed it to
        ``Router(..., resume=blob)``) and end their streams
        ``cancelled``.  Either way every granted page is freed."""
        if mode not in ("drain", "snapshot"):
            raise ValueError(f"close mode must be 'drain' or 'snapshot', "
                             f"got {mode!r}")
        self._draining = True
        self._drain_mode = mode
        if self._wake is not None:
            self._wake.set()
        if self._engine_task is not None:
            await self._engine_task
            self._engine_task = None
        return self._snapshot_blob

    def resume_handles(self) -> dict:
        """rid -> RequestHandle for requests revived from a resume blob
        (their streams start over from token 0 — the pre-snapshot client
        connections are gone)."""
        return dict(self._resume_handles)

    def stats(self) -> dict:
        h = self._host
        dt = time.perf_counter() - self._t_start
        useful = sum(len(o) for o in h["out"].values())
        done = [s for s in h["status"].values() if s is not None]
        return {
            "submitted": self._next_rid,
            "completed": len(done),
            "statuses": {s: done.count(s) for s in TERMINAL_STATUSES
                         if done.count(s)},
            "refusals": {k[8:]: v for k, v in h["counters"].items()
                         if k.startswith("refused_")},
            "counters": dict(h["counters"]),
            "segments": h["segments"],
            "replays": self._replays,
            "useful_tokens": useful,
            "tok_s": useful / max(dt, 1e-9),
            "occupancy": h["live_steps"] / max(h["total_steps"], 1),
            "pages": self._alloc.stats() if self._alloc is not None else None,
            "prefix": (dict(self._prefix.stats(),
                            prefill_positions_computed=h["prefill_computed"],
                            prefill_positions_total=h["prefill_total"])
                       if self._prefix is not None else None),
            "queue_depth": self._queue_depth(),
            "integrity": (dict(self._integrity.stats(),
                               detections=self._integrity.detections)
                          if self._integrity is not None else None),
        }

    # ------------------------------------------------------------------
    # failover snapshot/restore
    # ------------------------------------------------------------------

    def _take_snapshot(self) -> dict:
        return {"state": jax.device_get(self._state),
                "host": copy.deepcopy(self._host),
                "alloc": self._alloc.snapshot()
                if self._alloc is not None else None,
                "prefix": self._prefix.snapshot()
                if self._prefix is not None else None}

    def _restore_blob(self, blob: dict) -> None:
        self._state = jax.device_put(blob["state"])
        self._host = copy.deepcopy(blob["host"])
        self._host.setdefault("prefill_computed", 0)
        self._host.setdefault("prefill_total", 0)
        if blob["alloc"] is not None:
            self._alloc = PageAllocator.from_snapshot(blob["alloc"])
        if blob.get("prefix") is not None:
            self._prefix = PrefixCache.from_snapshot(blob["prefix"],
                                                     self._alloc)
        elif self._prefix is not None:
            # prefix router resumed from a pre-prefix blob: start a
            # fresh index bound to the restored allocator
            self._prefix = PrefixCache(self._alloc, self.page_size)
        # arrivals ingested after the snapshot was taken vanish from the
        # restored host — re-ingest anything the snapshot doesn't know
        for rid in sorted(self._requests):
            if rid not in self._host["status"] and rid not in self._inbox:
                self._inbox.append(rid)
        # resumed-from-disk blobs carry request descriptors
        for d in blob.get("requests", ()):
            rid = int(d["rid"])
            if rid in self._requests:
                continue
            req = _Request(rid, d["prompt"], d["max_new"], d["deadline_s"],
                           d["deadline_steps"], d["priority"],
                           time.perf_counter())
            self._requests[rid] = req
            self._resume_handles[rid] = RequestHandle(req)
            self._next_rid = max(self._next_rid, rid + 1)

    # ------------------------------------------------------------------
    # the engine
    # ------------------------------------------------------------------

    def _finish(self, rid: int, status: str) -> None:
        if self._host["status"].get(rid) is None:
            self._host["status"][rid] = status

    def _free_slot(self, b: int) -> None:
        h = self._host
        if self._alloc is not None and h["slot_pages"][b] is not None:
            self._alloc.free(h["slot_pages"][b])
            h["slot_pages"][b] = None
        h["slot_rid"][b] = -1
        h["slot_phase"][b] = "idle"
        h["slot_fed"][b] = 0

    def _release(self, rid: int, status: str) -> None:
        """Terminal-status a request wherever it currently lives."""
        h = self._host
        self._finish(rid, status)
        if rid in h["waiting"]:
            h["waiting"].remove(rid)
        for b in range(self.slots):
            if h["slot_rid"][b] == rid:
                self._free_slot(b)
                self._state = dict(
                    self._state,
                    done=self._state["done"].at[b].set(True))

    def _expired(self, rid: int, now: float) -> bool:
        h = self._host
        if h["status"].get(rid) is not None:
            return False
        req = self._requests[rid]
        if req.deadline_steps is not None and rid in h["admit_step"] \
                and h["global_step"] - h["admit_step"][rid] \
                >= int(req.deadline_steps):
            return True
        if req.deadline_s is not None and req.deadline_s > 0 \
                and now - req.submit_t >= float(req.deadline_s):
            return True
        return False

    def _ingest(self) -> None:
        """Move submitted requests into the admission queue, priority
        first (stable within a class — submission order)."""
        h = self._host
        if not self._inbox:
            return
        for rid in self._inbox:
            h["status"].setdefault(rid, None)
            h["out"].setdefault(rid, [])
            h["waiting"].append(rid)
        self._inbox.clear()
        h["waiting"].sort(key=lambda r: (-self._requests[r].priority, r))

    def _admit_waiting(self) -> None:
        """Fill free slots head-of-line from the admission queue (no
        skip-ahead: a big request at the head holds its place — admission
        order is the priority contract)."""
        h = self._host
        for b in range(self.slots):
            if h["slot_rid"][b] >= 0 or not h["waiting"]:
                continue
            rid = h["waiting"][0]
            req = self._requests[rid]
            S = len(req.prompt)
            chunked = self.prefix_cache or S not in self.buckets
            pages = self._no_pages
            d_shared = 0
            if self._alloc is not None:
                need = self._need_pages(S, req.max_new, chunked)
                shared: list = []
                if self._prefix is not None:
                    _n, shared = self._prefix.acquire(
                        req.prompt, (S - 1) // self.page_size)
                d_shared = len(shared)
                fresh = self._alloc.alloc(need - d_shared)
                if fresh is None:
                    if shared:             # return the borrowed refs
                        self._alloc.free(shared)
                    return                     # pool exhausted: wait
                ids = shared + fresh
                h["slot_pages"][b] = ids
                pages = jnp.asarray(ids + [ids[-1]] * (self.mp - need),
                                    jnp.int32)
            h["waiting"].pop(0)
            h["slot_rid"][b] = rid
            h["admit_t"][rid] = time.perf_counter()
            h["admit_step"][rid] = h["global_step"]
            if chunked:
                # begin-admit: point the slot's page-table row at its
                # grant and rewind its position past any shared prefix;
                # the slot stays done-masked until the final chunk emits
                cache = self._state["cache"]
                if self._prefix is not None and h["slot_pages"][b]:
                    # enforcement point: writes land only on private
                    # pages — a shared page at/after the write frontier
                    # would be forked here (fresh grants never are)
                    cache, ids, _nf = cow_fork(cache, self._alloc,
                                               h["slot_pages"][b],
                                               start_idx=d_shared)
                    h["slot_pages"][b] = ids
                    pages = jnp.asarray(
                        ids + [ids[-1]] * (self.mp - len(ids)), jnp.int32)
                fed0 = d_shared * self.page_size
                upd = {"pos": cache["pos"].at[b].set(fed0)}
                if "page_table" in cache:
                    upd["page_table"] = cache["page_table"].at[b].set(pages)
                self._state = dict(self._state, cache=dict(cache, **upd),
                                   done=self._state["done"].at[b].set(True))
                h["slot_phase"][b] = "prefill"
                h["slot_fed"][b] = fed0
                h["prefill_computed"] += S - fed0
                h["prefill_total"] += S
            else:
                admit = make_admit_fn(self._cfg_now, self.par,
                                      eos_id=self.eos_id, sample=self.sample)
                self._state, tok0 = admit(
                    self.params, self._state,
                    jnp.asarray(req.prompt[None]), jnp.int32(b), pages,
                    jnp.int32(req.max_new))
                h["out"][rid].append(int(tok0))
                h["slot_phase"][b] = "decode"

    def _feed_chunks(self) -> None:
        """One prompt chunk per prefilling slot per round — long
        admissions interleave with decode segments instead of stalling
        them."""
        h = self._host
        C = self.chunk_len
        cfg_now = self._cfg_now
        extend = self._extend if cfg_now is self.cfg else \
            make_extend_fn(cfg_now, self.par, C, eos_id=self.eos_id,
                           sample=self.sample, paged_attn=self.paged_attn)
        for b in range(self.slots):
            if h["slot_phase"][b] != "prefill":
                continue
            rid = h["slot_rid"][b]
            req = self._requests[rid]
            fed = h["slot_fed"][b]
            part = req.prompt[fed:fed + C]
            n_real = len(part)
            if n_real < C:
                part = np.pad(part, (0, C - n_real))
            emit = fed + n_real >= len(req.prompt)
            self._state, tok0 = extend(
                self.params, self._state, jnp.asarray(part[None]),
                jnp.int32(b), jnp.int32(n_real), jnp.bool_(emit),
                jnp.int32(req.max_new))
            h["slot_fed"][b] = fed + n_real
            if emit:
                h["out"][rid].append(int(tok0))
                h["slot_phase"][b] = "decode"
                if self._prefix is not None and h["slot_pages"][b]:
                    # every fully-flushed prompt page is now immutable
                    # (writes continue past pos) — index it for reuse
                    self._prefix.register(
                        req.prompt,
                        h["slot_pages"][b][:len(req.prompt)
                                           // self.page_size])

    def _ladder_reserve(self, rid: int) -> None:
        """Quarantined request: re-serve from the prompt down the
        degradation ladder (runtime/serving.py ``_escalate`` semantics,
        request-granular), replacing its discarded tokens.  Terminal
        status ``degraded`` — the client sees a restart event and a
        definite, verified output."""
        from repro.launch.serve import serve_batch
        h = self._host
        req = self._requests[rid]
        thresh = self.monitor.rel_threshold \
            if self.monitor is not None \
            and self.monitor.rel_threshold is not None else float("inf")
        level = self.cfg.dscim
        prompt = req.prompt[None]
        kw = dict(par=self.par, prepare=False, eos_id=self.eos,
                  max_new=[req.max_new], sample=self.sample, kv=self.kv,
                  page_size=self.page_size, rng_seed=self.rng_seed)
        while True:
            spec = next_ladder_spec(level) or level
            cfg_lvl = dataclasses.replace(self.cfg, dscim=spec,
                                          dscim_fault="")
            toks, lgs = serve_batch(cfg_lvl, self.params, prompt,
                                    req.max_new, **kw)
            terminal = next_ladder_spec(spec) is None
            ok = True
            if not terminal and np.isfinite(thresh):
                cfg_ex = dataclasses.replace(
                    self.cfg, dscim=exact_probe_spec(spec), dscim_fault="")
                _, lgs_ex = serve_batch(cfg_ex, self.params, prompt,
                                        req.max_new, **kw)
                s = np.asarray(lgs[0], np.float64).ravel()
                e = np.asarray(lgs_ex[0], np.float64).ravel()
                rms = max(float(np.sqrt(np.mean(e * e))), 1e-9)
                rel = float(np.sqrt(np.mean((s - e) ** 2))) / rms
                ok = np.isfinite(rel) and rel <= thresh
            self.log(f"[router] ladder: request {rid} {level} -> {spec} "
                     f"({'accepted' if ok else 'still drifting'})")
            if ok:
                row = np.asarray(toks[0])
                n_use = req.max_new
                hits = np.nonzero(row[:n_use] == self.eos)[0]
                if len(hits):
                    n_use = int(hits[0]) + 1
                h["out"][rid] = row[:n_use].tolist()
                h["status"][rid] = STATUS_DEGRADED
                h["counters"]["degraded"] += 1
                return
            level = spec

    @property
    def _cfg_now(self):
        """The serving config in force this segment — a persistent
        injected macro fault rewrites ``dscim_fault`` exactly like the
        fault-tolerant scheduler does."""
        fault = self.injector.serving_fault(self._host["segments"]) \
            if self.injector is not None else ""
        if not fault:
            return self.cfg
        return dataclasses.replace(self.cfg, dscim_fault=fault)

    def _round(self) -> bool:
        """One engine round: ingest/cancel/harvest/deadline/admit, one
        chunk per prefilling slot, one decode segment if anything is
        live.  Returns True if any request can still make progress."""
        h = self._host
        seg = h["segments"]
        if self._snap is not None and self.snapshot_every > 0 \
                and seg % self.snapshot_every == 0:
            self._snap = self._take_snapshot()
        if self.injector is not None:
            self.injector.maybe_fail(seg)

        self._ingest()
        now = time.perf_counter()
        for rid, req in self._requests.items():        # cancellations
            if req.cancelled and h["status"].get(rid) is None:
                self._release(rid, STATUS_CANCELLED)
                h["counters"]["cancelled"] += 1
        done_h = np.asarray(self._state["done"])
        for b in range(self.slots):                    # harvest finished
            rid = h["slot_rid"][b]
            if rid >= 0 and h["slot_phase"][b] == "decode" and done_h[b]:
                self._free_slot(b)
                self._finish(rid, STATUS_OK)
        for rid in list(h["status"]):                  # deadline sweep
            if self._expired(rid, now):
                self._release(rid, STATUS_DEADLINE)
                h["counters"]["deadline_cancelled"] += 1
        if self._draining:
            if self._drain_mode == "snapshot":
                return self._drain_snapshot()
            for rid in list(h["waiting"]):   # drain: shed the queue,
                self._release(rid, STATUS_REFUSED)     # retryable elsewhere
                h["counters"]["refused_draining"] += 1
        else:
            self._admit_waiting()
        self._feed_chunks()

        live_b = [b for b in range(self.slots)
                  if h["slot_rid"][b] >= 0 and h["slot_phase"][b] == "decode"]
        live0 = np.zeros((self.slots,), bool)
        if live_b:
            done_h = np.asarray(self._state["done"])
            for b in live_b:
                live0[b] = not done_h[b]
        if not live0.any():
            prefilling = any(p == "prefill" for p in h["slot_phase"])
            busy = bool(h["waiting"]) or bool(self._inbox) or prefilling \
                or any(r >= 0 for r in h["slot_rid"])
            return busy

        lg_exact = None
        if self._probe is not None and self.monitor.should_probe(seg):
            lg_exact = np.asarray(self._probe(self.params, self._state))
        corrupted: list = []
        if self.injector is not None and self._alloc is not None:
            cache2, hit = self.injector.corrupt_cache(
                seg, self._state["cache"], h["slot_pages"])
            if hit:
                self._state = dict(self._state, cache=cache2)
                corrupted = hit
        if self.injector is not None \
                and getattr(self.injector, "weight_flips", None):
            p2, whit = self.injector.corrupt_weights(seg, self.params)
            if whit:
                self.params = p2
        if self._integrity is not None and self._integrity.due(seg):
            bad_w = self._integrity.check_weights(self.params)
            if bad_w:
                self.params = self._integrity.repair_weights(self.params,
                                                             bad_w)
                self.log(f"[router] integrity: weight plane(s) {bad_w} "
                         "restored from golden copy")
            coords = []
            if self._alloc is not None:
                pos_h = np.asarray(self._state["cache"]["pos"])
                live_pages = np.zeros((self._alloc.n_pages,), bool)
                for b in range(self.slots):
                    ids = h["slot_pages"][b]
                    if ids is not None:
                        for p in ids[:int(pos_h[b]) // self.page_size]:
                            live_pages[int(p)] = True
                coords = self._integrity.check_pages(self._state["cache"],
                                                     live_pages)
                if coords:
                    self.log(f"[router] integrity: corrupted page(s) at "
                             f"(layer, page) {coords}")
            if bad_w or coords:
                # slot-scoped repair lives in the scheduler
                # (runtime/serving.py); the router takes the always-safe
                # path — restore the last *verified* snapshot and replay.
                # Repaired weights persist on self.params; transient
                # flips fire once, so the replay runs clean.
                self._integrity.note_replay()
                self._snap = self._vsnap
                raise IntegrityReplay(
                    f"weights {bad_w or 'clean'}, pages {coords or 'clean'}")
            self._vsnap = self._take_snapshot()
        cfg_now = self._cfg_now
        segment = self._segment if cfg_now is self.cfg else \
            make_segment_fn(cfg_now, self.par, self.seg_len,
                            eos_id=self.eos_id, sample=self.sample,
                            paged_attn=self.paged_attn, spec=self.spec)
        self._state, toks, lives, aux = segment(self.params, self._state)
        toks_h = np.asarray(toks)
        lives_h = np.asarray(lives)
        for s in range(toks_h.shape[0]):               # harvest tokens
            for b in range(self.slots):
                if lives_h[s, b] and h["slot_rid"][b] >= 0:
                    h["out"][h["slot_rid"][b]].append(int(toks_h[s, b]))
        if self.monitor is not None:
            bad = np.asarray(aux["bad"]).any(axis=0)
            trip = bad.copy()
            if lg_exact is not None:
                t2, _ = self.monitor.check(np.asarray(aux["logits0"]),
                                           lg_exact, live0)
                trip |= t2
            for b in np.nonzero(trip)[0]:
                rid = h["slot_rid"][int(b)]
                if rid < 0:
                    continue
                self._free_slot(int(b))
                self._state = dict(
                    self._state,
                    done=self._state["done"].at[int(b)].set(True))
                h["out"][rid] = []          # discard poisoned tokens
                h["restarted"][rid] = True
                h["counters"]["quarantined"] += 1
                self._ladder_reserve(rid)
        h["live_steps"] += int(lives_h.sum())
        h["total_steps"] += toks_h.shape[0] * self.slots
        h["segments"] += 1
        h["global_step"] += self.seg_len * (self._k_spec + 1)
        return True

    def _drain_snapshot(self) -> bool:
        """snapshot-mode close: park every live/prefilling request's
        descriptor + the full serve state, free all pages, end streams
        ``cancelled``."""
        h = self._host
        parked = [rid for rid in h["status"]
                  if h["status"][rid] is None]
        blob = self._take_snapshot()
        blob["requests"] = [self._requests[rid].descriptor()
                            for rid in parked]
        self._snapshot_blob = blob
        for rid in parked:
            self._release(rid, STATUS_CANCELLED)
        return False

    def _flush_streams(self) -> None:
        """Push newly harvested tokens / terminal statuses to client
        queues.  ``sent`` cursors are not snapshot state: a failover
        replay regrows ``out`` underneath them bit-identically, so
        nothing re-streams; a ladder re-serve flips ``restarted`` and
        restreams from zero behind an explicit restart event."""
        h = self._host
        for rid, req in self._requests.items():
            if req.ended:
                continue
            out = h["out"].get(rid)
            if out is None:
                continue
            if h["restarted"].get(rid) and not req.restart_sent:
                req.queue.put_nowait(("restart", None))
                req.restart_sent = True
                req.sent = 0
            while req.sent < len(out):
                req.queue.put_nowait(("token", out[req.sent]))
                req.sent += 1
            status = h["status"].get(rid)
            if status is not None and req.sent >= len(out):
                req.queue.put_nowait(("end", status))
                req.ended = True

    async def _engine(self) -> None:
        use_ft = self.injector is not None or self.snapshot_every > 0 \
            or self._integrity is not None
        if use_ft:
            # the initial state is integrity-verified by construction
            self._snap = self._take_snapshot()
            self._vsnap = self._snap
        emitted_before = 0
        t_last = time.perf_counter()
        while True:
            try:
                busy = self._round()
            except _RECOVERABLE as e:
                self._replays += 1
                self.log(f"[router] {type(e).__name__}: {e}; replay "
                         f"{self._replays}/{self.max_replays}")
                if self._snap is None or self._replays > self.max_replays:
                    # unrecoverable: every non-terminal request still
                    # gets a definite status
                    self._ingest()
                    for rid in list(self._host["status"]):
                        if self._host["status"][rid] is None:
                            self._release(rid, STATUS_CANCELLED)
                    self._flush_streams()
                    return
                self._restore_blob(self._snap)
                continue
            self._flush_streams()
            # throughput EMA for retry-after hints
            emitted = sum(len(o) for o in self._host["out"].values())
            now = time.perf_counter()
            if now - t_last > 1e-3:
                inst = (emitted - emitted_before) / (now - t_last)
                self._tok_s_ema = inst if self._tok_s_ema == 0.0 \
                    else 0.8 * self._tok_s_ema + 0.2 * inst
                emitted_before, t_last = emitted, now
            if self._draining and not busy and not self._inbox:
                self._flush_streams()
                return
            if busy or self._inbox:
                await asyncio.sleep(0)
            else:
                # idle: wait for a submission/cancel/close, waking
                # periodically so wall deadlines on queued work expire
                self._wake.clear()
                try:
                    await asyncio.wait_for(self._wake.wait(), timeout=0.05)
                except asyncio.TimeoutError:
                    pass
