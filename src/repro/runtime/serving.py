"""Fault-tolerant continuous-batching scheduler (ISSUE 6 tentpole).

``serve_continuous_ft`` is the robustness layer above the device-resident
continuous-batching loop (launch/serve.py ``serve_continuous`` delegates
here): the jitted segment/admit functions and all generation math are
unchanged, and with every fault-tolerance knob at its default the
scheduler is behaviourally identical to the PR-4 loop.  The additions are
host-side policies that act only *between* scan segments:

* **Deadlines** (``deadline_steps`` / ``deadline_s``): per-request decode-
  step and wall-clock budgets.  Step budgets count from serve start on
  the global step ledger (deterministic, replay-safe); wall budgets are
  anchored at each request's *admission* (PR 8 fix — measuring from
  serve start silently shrank late admissions' budgets), so a queued
  request never wall-expires and every admitted request gets its full
  ``deadline_s`` of service regardless of queue position.  An expired
  request is cancelled at the next segment boundary with status
  ``'deadline'`` and keeps its partial tokens; its slot and physical
  pages recycle immediately.  The admission anchor survives eviction
  round trips (the budget covers the request's whole lifetime, parked
  time included) and rides the host snapshot through failover replays.
* **Preemptive eviction + re-admission** (``priority``, int8 KV only):
  when the page pool cannot satisfy an admission, the scheduler may evict
  a live slot of *strictly lower* priority (strictness prevents same-
  priority livelock), lowest priority first, youngest (latest-admitted)
  on ties.  Eviction snapshots the victim's physical page contents
  bit-exactly (core/kvcache.py ``extract_slot_pages``) — not its tokens-
  so-far for a re-prefill, which would break bitwise continuity through
  float reduction-order changes — and queues it for re-admission
  (``insert_slot_pages``) as pages free up.  A re-admitted request
  resumes mid-stream bit-identically under greedy decoding.
* **Snapshot / restore + failover** (``snapshot_every``, ``injector``):
  every N segment boundaries the full serve state — device pytree
  (``jax.device_get``), host scheduler bookkeeping, page-allocator free
  list — is checkpointed host-side; ``run_with_failover``
  (runtime/failover.py) wraps the segment loop so a recoverable failure
  (injected device loss, watchdog ``StepHang``) restores the latest
  snapshot and replays from that boundary bit-identically.  The
  generalized ``FailureInjector`` drives chaos tests: segment-level
  device loss, transient int8 page-pool bit flips, persistent stuck-at
  DS-CIM macro faults (``cfg.dscim_fault``, models/lm.py).
* **Accuracy watchdog + degradation ladder** (``monitor``): every
  ``probe_every`` segments one extra *exact-mode* decode of the same
  (token, cache) inputs (launch/steps.py ``make_probe_fn``) is compared
  against the segment's first-step serving logits (``aux['logits0']`` —
  computed inside the scan, so the serving side costs nothing extra).
  A slot whose relative logit RMSE exceeds the ``AccuracyWatchdog``
  threshold — derived from the macro's ``ErrorModel`` moments — or whose
  logits go NaN/Inf (checked every segment via ``aux['bad']``) is
  *quarantined*: its poisoned tokens are discarded, its slot and pages
  recycle, and after the main loop the request is re-served from its
  prompt down the degradation ladder ``dscim2 -> dscim1 -> exact``
  (``next_ladder_spec``), each intermediate level verified against its
  exact-mode twin before acceptance.  Estimator faults are caught
  persistently; a transient finite KV corruption registers only when the
  flip lands in a probed segment (NaN corruption is caught regardless) —
  the documented probe-coverage limit.

Determinism & replay notes: a restored replay re-runs the segment
boundary loop on identical state, so greedy decoding replays bit-
identically; sampled decoding replays identically too (the PRNG key
rides the device carry inside the snapshot).  ``FailureInjector`` faults
are keyed by segment and fire once, so a replay neither re-raises the
device loss nor re-applies a transient flip.
"""
from __future__ import annotations

import contextlib
import copy
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvcache import (PageAllocator, PrefixCache, admission_pages,
                                cow_fork, extract_slot_pages,
                                insert_slot_pages, n_pages_for)
from repro.launch.steps import (_parse_spec, init_serve_state,
                                make_admit_fn, make_extend_fn,
                                make_probe_fn, make_segment_fn)
from repro.runtime.failover import (IntegrityReplay,
                                    SimulatedHardwareFailure,
                                    run_with_failover)
from repro.runtime.integrity import IntegrityEngine, parse_integrity
from repro.runtime.watchdog import AccuracyWatchdog, StepHang

__all__ = ["STATUS_OK", "STATUS_DEADLINE", "serve_continuous_ft",
           "next_ladder_spec", "exact_probe_spec", "watchdog_for_spec",
           "chaos_drill", "integrity_drill", "prefix_drill"]

STATUS_OK = "ok"
STATUS_DEADLINE = "deadline"


# --------------------------------------------------------------------------
# degradation-ladder spec algebra
# --------------------------------------------------------------------------

def exact_probe_spec(spec: str) -> str:
    """The exact-mode twin of a dscim serving spec: same variant/L/calib
    (so the same prepared int8 planes apply), same '+attn' scope, exact
    adder-tree MVMs.  'off'/'float' map to themselves."""
    if spec in ("off", "float"):
        return spec
    head, _, rest = spec.partition(":")
    base, plus, attn = head.partition("+")
    return "exact" + plus + attn + (":" + rest if rest else "")


def next_ladder_spec(spec: str) -> str | None:
    """One step down the degradation ladder, or None at the bottom.

    dscim2 (L=64, ~3.8% macro RMSE) -> dscim1:256 (~0.7%) -> the exact-
    mode twin; exact and float specs are terminal.  The mode (kernel/
    lut/...) and '+attn' scope are preserved on the dscim2 -> dscim1 hop
    so only the operating point changes."""
    if spec in ("off", "float"):
        return None
    head, _, rest = spec.partition(":")
    if head.partition("+")[0] == "exact":
        return None
    parts = rest.split(":") if rest else []
    if parts and parts[0] == "dscim2":
        parts[0] = "dscim1"
        if len(parts) > 1:
            parts[1] = "256"
        return head + ":" + ":".join(parts)
    return exact_probe_spec(spec)


def watchdog_for_spec(spec: str, *, margin: float = 3.0,
                      probe_every: int = 8) -> AccuracyWatchdog:
    """AccuracyWatchdog with a drift threshold derived from the serving
    spec's macro error moments (core/error_model.py
    ``relative_moment_bound``).  ``margin`` scales the bound into logit
    space, absorbing layer-to-logit error propagation; the default was
    pinned empirically (tests/test_serving_ft.py): healthy dscim2:64
    logit drift sits at ~2x the moment bound, a stuck-at macro fault at
    ~16x, so margin 3 splits them with headroom both ways."""
    from repro.core.dscim_layer import calibrated_config
    from repro.core.error_model import ErrorModel
    from repro.core.macro import DSCIMMacro
    from repro.models.lm import _parse_dscim
    _, _, variant, length, calib = _parse_dscim(spec)
    em = ErrorModel.from_macro(DSCIMMacro(calibrated_config(variant, length,
                                                            calib)))
    return AccuracyWatchdog.from_error_model(em, margin=margin,
                                             probe_every=probe_every)


# --------------------------------------------------------------------------
# the scheduler
# --------------------------------------------------------------------------

def _req_array(x, R, dtype, name):
    if x is None:
        return None
    arr = np.asarray(x, dtype)
    if arr.shape != (R,):
        raise ValueError(f"{name} must be shape ({R},), got {arr.shape}")
    return arr


def serve_continuous_ft(cfg, params, prompts: np.ndarray, n_tokens: int, *,
                        slots: int = 4, seg_len: int = 4, max_new=None,
                        eos_id: int | None = None, sample: str = "greedy",
                        kv: str = "float", page_size: int = 8,
                        n_pages: int | None = None, par=None,
                        rng_seed: int = 0, paged_attn: str = "auto",
                        deadline_steps=None, deadline_s=None, priority=None,
                        monitor: AccuracyWatchdog | None = None,
                        injector=None, snapshot_every: int = 0,
                        max_replays: int = 3, watchdog=None,
                        spec: str | None = None,
                        integrity: str = "off", prefix_cache=False,
                        log=print):
    """Fault-tolerant continuous batching over already-placed ``params``
    (launch/serve.py ``serve_continuous`` is the user-facing wrapper —
    argument semantics and the failure-mode contract are documented
    there).  Returns (outputs, stats).

    ``integrity`` ('off'|'verify'|'scrub:<n>', runtime/integrity.py):
    deterministic SDC detection + targeted repair at segment boundaries.
    Every n-th boundary the engine re-digests the live int8 page pool
    against the cache's ``page_sum`` plane and the prepared weight planes
    against their golden digests.  A corrupted weight plane is restored
    bit-exactly from the golden copy (plus a snapshot replay iff poisoned
    segments already ran); a corrupted KV page triggers *slot-scoped*
    repair — the owning slot alone is rewound to the last verified
    snapshot (``insert_slot_pages``) or re-served from its prompt, every
    other slot untouched.  'off' is bit-for-bit today's behavior (the
    digest plane is never created).

    ``prefix_cache`` (ISSUE 10, int8 KV only): ``True``/'on' admits
    every request through page-aligned chunked prefill (one compiled
    ``make_extend_fn`` program at ``chunk_len == page_size``) and shares
    physical pages across page-aligned prompt prefixes via the
    refcounted ``PrefixCache`` — a hit maps its leading page-table
    entries at the donor's pages and prefills only from the first
    divergent page.  Because hit and miss admissions run the *same*
    chunk programs on the same inputs (and shared pages hold exactly
    the bytes those programs would have produced), prefix-hit serving
    is bitwise-identical to cold serving.  'cold' runs the identical
    chunked admission path with lookup/registration disabled — the
    bitwise reference leg (``prefix_drill``).  ``False`` is today's
    one-shot bucketed admission, untouched."""
    prompts = np.asarray(prompts)
    R, S = prompts.shape
    budgets = np.full((R,), n_tokens, np.int32) if max_new is None \
        else np.asarray(max_new, np.int32)
    assert budgets.shape == (R,) and (budgets >= 1).all()
    dl_steps = _req_array(deadline_steps, R, np.int64, "deadline_steps")
    dl_secs = _req_array(deadline_s, R, np.float64, "deadline_s")
    prio = _req_array(priority, R, np.int64, "priority")
    if prio is not None and kv != "int8":
        raise ValueError("priority eviction preempts physical pages; it "
                         "needs the paged cache (kv='int8')")
    if monitor is not None and monitor.rel_threshold is not None \
            and cfg.dscim in ("off", "float"):
        raise ValueError("drift probes compare against the serving spec's "
                         "exact-mode twin; float serving has no estimator "
                         "to probe (pass rel_threshold=None for NaN-only "
                         "monitoring)")
    eos = -1 if eos_id is None else eos_id
    integrity_period = parse_integrity(integrity)
    if integrity_period > 0 and kv != "int8":
        raise ValueError("integrity checksums cover the int8 paged cache; "
                         "pass kv='int8' (the float dense cache is the "
                         "watchdog's statistical territory)")
    if prefix_cache is True:
        prefix_cache = "on"
    if prefix_cache not in (False, None, "", "off", "on", "cold"):
        raise ValueError(f"prefix_cache must be one of False/'on'/'cold', "
                         f"got {prefix_cache!r}")
    use_prefix = prefix_cache in ("on", "cold")
    if use_prefix and kv != "int8":
        raise ValueError("prefix caching shares int8 physical pages; "
                         "pass kv='int8'")
    # +headroom past prompt + budget: a speculative window may write k
    # draft positions past the committed pos before rollback, and a
    # chunked prefill (prefix mode) may write up to page_size - 1 pad
    # positions past the prompt — slot capacity and page grants cover
    # whichever the serving mode can incur.
    k_spec = _parse_spec(spec)[1] if _parse_spec(spec) else 0
    headroom = max(k_spec, page_size - 1) if use_prefix else k_spec
    capacity = S + int(budgets.max()) + headroom
    mp = n_pages_for(capacity, page_size)
    state0 = init_serve_state(cfg, slots, capacity, kv=kv,
                              page_size=page_size, n_pages=n_pages,
                              seed=rng_seed, integrity=integrity_period > 0)
    alloc0 = PageAllocator(state0["cache"]["k_pages"].shape[1]) \
        if kv == "int8" else None
    pfx0 = PrefixCache(alloc0, page_size) if use_prefix else None
    pfx_box = {"pfx": pfx0}
    engine = None
    if integrity_period > 0:
        from repro.core.qweights import golden_weight_copy
        engine = IntegrityEngine(golden_weight_copy(params),
                                 period=integrity_period)
    # weight repairs must outlive failover restarts, so the served params
    # live in a mutable holder rather than the closure binding
    pholder = {"params": params}
    host0 = {
        "slot_req": [-1] * slots, "slot_pages": [None] * slots,
        "slot_seq": [0] * slots,
        "out": [[] for _ in range(R)], "status": [None] * R,
        "admit_t": [None] * R,
        "next_req": 0, "seq": 0,
        "readmit": [], "evicted": {}, "quarantine": [], "corrupted": [],
        "evicted_ever": [], "reserve": [],
        "counters": {"evictions": 0, "readmissions": 0,
                     "deadline_cancelled": 0},
        "segments": 0, "global_step": 0,
        "live_steps": 0, "total_steps": 0,
        "prefill_computed": 0, "prefill_total": 0, "admit_lat": [],
    }
    probe = None
    if monitor is not None and monitor.rel_threshold is not None:
        cfg_probe = dataclasses.replace(
            cfg, dscim=exact_probe_spec(cfg.dscim), dscim_fault="")
        probe = make_probe_fn(cfg_probe, par)
    no_pages = jnp.zeros((mp,), jnp.int32)
    holder = None
    t0 = time.perf_counter()

    def _expired(host, r, now):
        if host["status"][r] is not None:
            return False
        if dl_steps is not None and dl_steps[r] >= 0 \
                and host["global_step"] >= int(dl_steps[r]):
            return True
        # wall budgets anchor at the request's admission, not serve start:
        # a late admission gets its full budget, and a still-queued
        # request (admit_t None) never wall-expires
        if dl_secs is not None and dl_secs[r] > 0 \
                and host["admit_t"][r] is not None \
                and now - host["admit_t"][r] >= float(dl_secs[r]):
            return True
        return False

    def _snap(state, host, alloc, pfx=None):
        return {"state": jax.device_get(state),
                "host": copy.deepcopy(host),
                "alloc": alloc.snapshot() if alloc is not None else None,
                "prefix": pfx.snapshot() if pfx is not None else None}

    def _loop(snap):
        if snap is None:
            state, host, alloc, pfx = state0, host0, alloc0, pfx0
        else:
            state = jax.device_put(snap["state"])
            host = copy.deepcopy(snap["host"])
            alloc = None if snap["alloc"] is None \
                else PageAllocator.from_snapshot(snap["alloc"])
            pfx = None if snap.get("prefix") is None \
                else PrefixCache.from_snapshot(snap["prefix"], alloc)
        pfx_box["pfx"] = pfx
        if watchdog is not None:
            watchdog.reset()
        # segments run since the last weight-digest sweep: a corrupted
        # plane found with this at 0 was caught before any decode used it
        # (pure repair); otherwise poisoned tokens exist and the repair
        # must be followed by a replay from the last verified snapshot
        segs_since_wcheck = 0

        def free_slot(b):
            if alloc is not None and host["slot_pages"][b] is not None:
                alloc.free(host["slot_pages"][b])
                host["slot_pages"][b] = None
            host["slot_req"][b] = -1

        def evict(b):
            nonlocal state
            r = host["slot_req"][b]
            blob = extract_slot_pages(state["cache"], b,
                                      host["slot_pages"][b])
            blob["tok"] = int(np.asarray(state["tok"])[b])
            blob["n_out"] = int(np.asarray(state["n_out"])[b])
            blob["seq"] = host["slot_seq"][b]
            host["evicted"][r] = blob
            host["readmit"].append(r)
            if r not in host["evicted_ever"]:
                host["evicted_ever"].append(r)
            free_slot(b)
            state = dict(state, done=state["done"].at[b].set(True))
            host["counters"]["evictions"] += 1

        def grant(need, want_prio):
            """Page grant for an admission, evicting strictly-lower-
            priority live slots (lowest priority, youngest on ties) if
            the pool is exhausted and priorities are in force."""
            ids = alloc.alloc(need)
            while ids is None and want_prio is not None:
                cands = [(int(prio[host["slot_req"][b]]),
                          -host["slot_seq"][b], b)
                         for b in range(slots) if host["slot_req"][b] >= 0
                         and int(prio[host["slot_req"][b]]) < want_prio]
                if not cands:
                    return None
                evict(min(cands)[2])
                ids = alloc.alloc(need)
            return ids

        def repair_pages(coords):
            """Slot-scoped KV repair: rewind each slot owning a corrupted
            physical page to the last *verified* snapshot (its pages
            digested clean there) via the eviction blob machinery, or —
            if the request wasn't live at that snapshot — re-serve it
            from its prompt (``host['reserve']``).  Every other slot's
            state is untouched, so under greedy decoding unaffected
            requests stay bitwise identical to a fault-free run."""
            nonlocal state
            vsnap = holder["verified"]
            vstate, vhost = vsnap["state"], vsnap["host"]
            owner = {}
            for b in range(slots):
                for p in (host["slot_pages"][b] or ()):
                    owner[int(p)] = b
            for b in sorted({owner[p] for _l, p in coords if p in owner}):
                r = host["slot_req"][b]
                b0 = vhost["slot_req"].index(r) \
                    if r in vhost["slot_req"] else -1
                if b0 >= 0:
                    blob = extract_slot_pages(vstate["cache"], b0,
                                              vhost["slot_pages"][b0])
                    cache = insert_slot_pages(state["cache"], b,
                                              host["slot_pages"][b], blob)
                    state = dict(
                        state, cache=cache,
                        tok=state["tok"].at[b].set(
                            int(vstate["tok"][b0])),
                        done=state["done"].at[b].set(
                            bool(vstate["done"][b0])),
                        n_out=state["n_out"].at[b].set(
                            int(vstate["n_out"][b0])),
                        max_new=state["max_new"].at[b].set(
                            int(vstate["max_new"][b0])))
                    host["out"][r] = list(vhost["out"][r])
                    log(f"[integrity] slot {b} (request {r}) rewound to "
                        f"verified snapshot (pos {blob['pos']})")
                else:
                    # admitted after the verified snapshot: restart from
                    # the prompt (greedy determinism -> identical tokens)
                    free_slot(b)
                    state = dict(state, done=state["done"].at[b].set(True))
                    host["out"][r] = []
                    host["reserve"].append(r)
                    log(f"[integrity] request {r} re-served from prompt "
                        "(corrupted page, no verified snapshot coverage)")
                engine.note_page_repair()

        def try_readmit(b):
            nonlocal state
            for r in list(host["readmit"]):
                blob = host["evicted"][r]
                need = blob["page_count"]
                ids = grant(need,
                            int(prio[r]) if prio is not None else None)
                if ids is None:
                    continue
                host["readmit"].remove(r)
                del host["evicted"][r]
                host["slot_pages"][b] = ids
                host["slot_req"][b] = r
                host["slot_seq"][b] = blob["seq"]   # keeps its seniority
                cache = insert_slot_pages(state["cache"], b, ids, blob)
                state = dict(
                    state, cache=cache,
                    tok=state["tok"].at[b].set(blob["tok"]),
                    done=state["done"].at[b].set(False),
                    n_out=state["n_out"].at[b].set(blob["n_out"]),
                    max_new=state["max_new"].at[b].set(int(budgets[r])))
                host["counters"]["readmissions"] += 1
                return True
            return False

        while True:
            seg = host["segments"]
            if holder is not None and snapshot_every > 0 \
                    and seg % snapshot_every == 0:
                holder["snap"] = _snap(state, host, alloc, pfx)
            if injector is not None:
                injector.maybe_fail(seg)
            fault_now = injector.serving_fault(seg) \
                if injector is not None else ""
            cfg_now = cfg if not fault_now else \
                dataclasses.replace(cfg, dscim_fault=fault_now)
            admit = make_admit_fn(cfg_now, par, eos_id=eos_id, sample=sample)
            extend = make_extend_fn(cfg_now, par, page_size, eos_id=eos_id,
                                    sample=sample, paged_attn=paged_attn) \
                if pfx is not None else None
            segment = make_segment_fn(cfg_now, par, seg_len, eos_id=eos_id,
                                      sample=sample, paged_attn=paged_attn,
                                      spec=spec)
            now = time.perf_counter()
            done_h = np.asarray(state["done"])
            for b in range(slots):                 # harvest finished slots
                r = host["slot_req"][b]
                if r >= 0 and done_h[b]:
                    free_slot(b)
                    host["status"][r] = STATUS_OK
            if dl_steps is not None or dl_secs is not None:
                for r in range(R):                 # deadline sweep
                    if not _expired(host, r, now):
                        continue
                    host["status"][r] = STATUS_DEADLINE
                    host["counters"]["deadline_cancelled"] += 1
                    if r in host["evicted"]:
                        del host["evicted"][r]
                        host["readmit"].remove(r)
                    if r in host["reserve"]:
                        host["reserve"].remove(r)
                    for b in range(slots):
                        if host["slot_req"][b] == r:
                            free_slot(b)
                            state = dict(
                                state,
                                done=state["done"].at[b].set(True))
            for b in range(slots):                 # admissions
                if host["slot_req"][b] >= 0:
                    continue
                if host["readmit"] and try_readmit(b):
                    continue
                # integrity re-serves (corrupted page, no snapshot
                # coverage) go first — they were admitted once already
                reserve = bool(host["reserve"])
                if reserve:
                    rq = host["reserve"][0]
                else:
                    while host["next_req"] < R \
                            and host["status"][host["next_req"]] is not None:
                        host["next_req"] += 1      # skip cancelled waiters
                    if host["next_req"] >= R:
                        continue
                    rq = host["next_req"]
                pages = no_pages
                ids = None
                d_shared = 0
                if alloc is not None:
                    need = admission_pages(S, int(budgets[rq]), page_size,
                                           headroom)
                    shared = []
                    if pfx is not None and prefix_cache == "on":
                        _ntok, shared = pfx.acquire(prompts[rq],
                                                    (S - 1) // page_size)
                    d_shared = len(shared)
                    fresh = grant(need - d_shared,
                                  int(prio[rq]) if prio is not None else None)
                    if fresh is None:              # pool exhausted: wait
                        if shared:
                            alloc.free(shared)     # release the refs we took
                        continue
                    ids = shared + fresh
                    host["slot_pages"][b] = ids
                    if pfx is None:
                        # pad to mp with a self-owned id (never read
                        # unmasked, never flushed — pos stays under the
                        # budget's pages)
                        pages = jnp.asarray(ids + [ids[-1]] * (mp - need),
                                            jnp.int32)
                if reserve:
                    host["reserve"].pop(0)
                else:
                    host["next_req"] = rq + 1
                if host["admit_t"][rq] is None:    # re-serves keep their
                    host["admit_t"][rq] = time.perf_counter()  # anchor
                if pfx is not None:
                    # prefix-mode admission: page-aligned chunked prefill
                    # through ONE compiled extend program for hits and
                    # misses alike — a hit feeds from the first divergent
                    # page, a miss from page 0.  Same programs + same
                    # inputs + shared pages holding exactly the bytes
                    # those programs produced on the donor => warm
                    # serving is bitwise-identical to cold serving.
                    t_adm = time.perf_counter()
                    fed = d_shared * page_size
                    cache = state["cache"]
                    # COW enforcement point: everything at or past the
                    # write frontier must be private before any scatter
                    # (a checked no-op here — sharing stops strictly
                    # below the frontier by construction)
                    cache, ids, _nf = cow_fork(cache, alloc, ids,
                                               start_idx=d_shared)
                    host["slot_pages"][b] = ids
                    row = jnp.asarray(ids + [ids[-1]] * (mp - len(ids)),
                                      jnp.int32)
                    cache = dict(
                        cache,
                        page_table=cache["page_table"].at[b].set(row),
                        pos=cache["pos"].at[b].set(fed))
                    state = dict(state, cache=cache,
                                 done=state["done"].at[b].set(True))
                    tok0 = None
                    while fed < S:
                        part = prompts[rq, fed:fed + page_size]
                        n_real = len(part)
                        if n_real < page_size:
                            part = np.pad(part, (0, page_size - n_real))
                        state, tok0 = extend(
                            pholder["params"], state,
                            jnp.asarray(part[None]), jnp.int32(b),
                            jnp.int32(n_real),
                            jnp.bool_(fed + n_real >= S),
                            jnp.int32(budgets[rq]))
                        fed += n_real
                    tok0 = int(tok0)               # sync: latency is real
                    if prefix_cache == "on":
                        pfx.register(prompts[rq], ids[:S // page_size])
                    host["prefill_computed"] += S - d_shared * page_size
                    host["prefill_total"] += S
                    host["admit_lat"].append(
                        (d_shared > 0, time.perf_counter() - t_adm))
                else:
                    state, tok0 = admit(pholder["params"], state,
                                        jnp.asarray(prompts[rq:rq + 1]),
                                        jnp.int32(b), pages,
                                        jnp.int32(budgets[rq]))
                host["out"][rq].append(int(tok0))
                host["slot_req"][b] = rq
                host["seq"] += 1
                host["slot_seq"][b] = host["seq"]
            if all(rr < 0 for rr in host["slot_req"]):
                waiting = any(host["status"][r] is None
                              for r in range(host["next_req"], R))
                if not waiting and not host["readmit"] \
                        and not host["reserve"]:
                    return state, host, alloc
                nr = host["next_req"]
                what = (f"request {nr} "
                        f"({admission_pages(S, int(budgets[nr]), page_size, headroom)} "
                        "pages needed") if nr < R else \
                    (f"evicted request {host['readmit'][0]} "
                     f"({host['evicted'][host['readmit'][0]]['page_count']}"
                     " pages needed")
                raise RuntimeError(f"page pool too small for {what}, "
                                   f"{alloc.free_pages} free)")
            if np.asarray(state["done"]).all():
                continue  # all finished at admission: harvest, don't step
            live0 = np.asarray([rr >= 0 for rr in host["slot_req"]]) \
                & ~np.asarray(state["done"])
            lg_exact = None
            if probe is not None and monitor.should_probe(seg) \
                    and live0.any():
                # fetch before the donating segment call consumes state
                lg_exact = np.asarray(probe(pholder["params"], state))
            if injector is not None and alloc is not None:
                cache2, hit = injector.corrupt_cache(seg, state["cache"],
                                                     host["slot_pages"])
                if hit:
                    state = dict(state, cache=cache2)
                    for b in hit:
                        rr = host["slot_req"][b]
                        if rr >= 0 and rr not in host["corrupted"]:
                            host["corrupted"].append(rr)
            if injector is not None \
                    and getattr(injector, "weight_flips", None):
                p2, whit = injector.corrupt_weights(seg, pholder["params"])
                if whit:
                    pholder["params"] = p2
            if engine is not None and engine.due(seg):
                # injected faults land *before* this check at the same
                # boundary, so a flip due the segment a check runs is
                # caught before any decode consumes it
                reprobe = False
                bad_w = engine.check_weights(pholder["params"])
                if bad_w:
                    pholder["params"] = engine.repair_weights(
                        pholder["params"], bad_w)
                    log(f"[integrity] weight plane(s) {bad_w} restored "
                        "from golden copy")
                    if segs_since_wcheck > 0:
                        # decodes ran against the corrupted plane: every
                        # slot's tokens since the last verified snapshot
                        # are suspect — discard and replay (bit-clean,
                        # the repaired planes equal the originals)
                        engine.note_replay()
                        holder["snap"] = holder["verified"]
                        raise IntegrityReplay(
                            f"weight plane(s) {bad_w} repaired after "
                            f"{segs_since_wcheck} unverified segment(s)")
                    reprobe = True
                segs_since_wcheck = 0
                if alloc is not None:
                    # digests are under warranty only for granted, fully
                    # flushed pages — build that mask host-side
                    pos_h = np.asarray(state["cache"]["pos"])
                    live_pages = np.zeros((alloc.n_pages,), bool)
                    for b in range(slots):
                        ids = host["slot_pages"][b]
                        if ids is not None:
                            for p in ids[:int(pos_h[b]) // page_size]:
                                live_pages[int(p)] = True
                    coords = engine.check_pages(state["cache"], live_pages)
                    if coords:
                        log(f"[integrity] corrupted page(s) at "
                            f"(layer, page) {coords}")
                        repair_pages(coords)
                        reprobe = True
                # everything digests clean now: this becomes the repair
                # restore point (regular snapshots may hold state later
                # poisoned by a not-yet-detected flip; this one cannot)
                holder["verified"] = _snap(state, host, alloc, pfx)
                if reprobe and lg_exact is not None:
                    # the pre-repair probe fetch no longer matches the
                    # repaired state — re-fetch so a surgical repair can
                    # never read as watchdog drift
                    lg_exact = np.asarray(probe(pholder["params"], state))
            ctx = watchdog.step() if watchdog is not None \
                else contextlib.nullcontext()
            with ctx:
                state, toks, lives, aux = segment(pholder["params"], state)
                toks_h = np.asarray(toks)
                lives_h = np.asarray(lives)
            # under spec the segment emits seg_len * (k + 1) chronological
            # rows per slot (accepted drafts + bonus; rejected rows have
            # lives False) — the harvest is row-count agnostic
            for s in range(toks_h.shape[0]):       # harvest tokens
                for b in range(slots):
                    if lives_h[s, b] and host["slot_req"][b] >= 0:
                        host["out"][host["slot_req"][b]].append(
                            int(toks_h[s, b]))
            if monitor is not None:
                bad = np.asarray(aux["bad"]).any(axis=0)
                trip = bad.copy()
                rels = np.zeros((slots,))
                reasons = np.where(bad, "nonfinite", "drift")
                if lg_exact is not None:
                    t2, rel = monitor.check(np.asarray(aux["logits0"]),
                                            lg_exact, live0)
                    rels = rel
                    trip |= t2
                for b in np.nonzero(trip)[0]:
                    rr = host["slot_req"][int(b)]
                    if rr < 0:
                        continue
                    free_slot(int(b))
                    state = dict(state,
                                 done=state["done"].at[int(b)].set(True))
                    host["out"][rr] = []           # discard poisoned tokens
                    host["quarantine"].append({
                        "request": rr, "slot": int(b), "segment": seg,
                        "reason": str(reasons[b]),
                        "rel": float(rels[b])
                        if np.isfinite(rels[b]) else float("inf")})
            host["live_steps"] += int(lives_h.sum())
            host["total_steps"] += toks_h.shape[0] * slots
            host["segments"] += 1
            segs_since_wcheck += 1
            # drafted-but-rejected verifier positions count toward the
            # deadline ledger: a spec segment attempts seg_len * (k + 1)
            # positions per slot regardless of the acceptance outcome
            host["global_step"] += seg_len * (k_spec + 1)

    use_ft = injector is not None or snapshot_every > 0 \
        or watchdog is not None or engine is not None
    if use_ft:
        snap0 = _snap(state0, host0, alloc0, pfx0)
        # the initial state is verified-clean by construction
        holder = {"snap": snap0, "verified": snap0}
        (state, host, alloc), replays = run_with_failover(
            _loop, restore_fn=lambda: holder["snap"],
            max_restarts=max_replays,
            recoverable=(SimulatedHardwareFailure, StepHang,
                         IntegrityReplay), log=log)
    else:
        state, host, alloc = _loop(None)
        replays = 0

    esc_records: list = []
    if any(host["status"][q["request"]] is None
           for q in host["quarantine"]):
        _escalate(cfg, pholder["params"], prompts, n_tokens, host, budgets,
                  eos_id=eos_id, sample=sample, kv=kv, page_size=page_size,
                  par=par, rng_seed=rng_seed, monitor=monitor,
                  records=esc_records, log=log)
    for r in range(R):
        if host["status"][r] is None:
            host["status"][r] = STATUS_OK

    dt = time.perf_counter() - t0
    useful = sum(len(o) for o in host["out"])
    stats = {
        "wall_s": dt,
        "tok_s": useful / dt,
        "occupancy": host["live_steps"] / max(host["total_steps"], 1),
        "live_slot_steps": host["live_steps"],
        "slot_steps": host["total_steps"],
        "segments": host["segments"],
        "requests": R,
        "useful_tokens": useful,
        "status": list(host["status"]),
        "replays": replays,
        "evictions": host["counters"]["evictions"],
        "readmissions": host["counters"]["readmissions"],
        "evicted_requests": list(host["evicted_ever"]),
        "deadline_cancelled": host["counters"]["deadline_cancelled"],
        "quarantined": sorted({q["request"] for q in host["quarantine"]}),
        "escalations": esc_records,
        "corrupted_requests": sorted(host["corrupted"]),
        "probes": monitor.n_probes if monitor is not None else 0,
        "probe_trips": monitor.n_trips if monitor is not None else 0,
        "stragglers": watchdog.n_stragglers if watchdog is not None else 0,
        "pages": alloc.stats() if alloc is not None else None,
        "integrity": (dict(engine.stats(), detections=engine.detections)
                      if engine is not None else None),
        "prefix": (dict(
            pfx_box["pfx"].stats(),
            prefill_positions_computed=host["prefill_computed"],
            prefill_positions_total=host["prefill_total"],
            admit_lat_hit=[t for hit, t in host["admit_lat"] if hit],
            admit_lat_miss=[t for hit, t in host["admit_lat"] if not hit])
            if pfx_box["pfx"] is not None else None),
    }
    return [np.asarray(o, np.int32) for o in host["out"]], stats


# --------------------------------------------------------------------------
# post-loop degradation-ladder escalation
# --------------------------------------------------------------------------

def _escalate(cfg, params, prompts, n_tokens, host, budgets, *, eos_id,
              sample, kv, page_size, par, rng_seed, monitor, records, log):
    """Re-serve quarantined requests from their prompts down the ladder.

    The serving batch is one jitted program — a single slot cannot run a
    different estimator mid-batch — so escalation restarts the request
    through ``serve_batch`` on a clean config (``dscim_fault=''``) at the
    next ladder level, grouped by level to share compilations.  Each
    intermediate level is verified against its exact-mode twin (prefill
    logit relative RMSE under the monitor threshold); rows still drifting
    escalate further.  The bottom (exact / float) level is accepted
    unconditionally — it *is* the reference."""
    from repro.launch.serve import serve_batch   # lazy: serve.py imports us
    thresh = monitor.rel_threshold \
        if monitor is not None and monitor.rel_threshold is not None \
        else float("inf")
    eos = -1 if eos_id is None else eos_id
    level, reason = {}, {}
    for q in host["quarantine"]:
        r = q["request"]
        if host["status"][r] is not None:      # e.g. deadline'd meanwhile
            continue
        level.setdefault(r, cfg.dscim)
        reason.setdefault(r, q["reason"])
    pending = sorted(level)
    while pending:
        groups: dict = {}
        for r in pending:
            nxt = next_ladder_spec(level[r]) or level[r]   # off: restart
            groups.setdefault(nxt, []).append(r)
        pending = []
        for spec, rows in sorted(groups.items()):
            cfg_lvl = dataclasses.replace(cfg, dscim=spec, dscim_fault="")
            kw = dict(par=par, prepare=False, eos_id=eos,
                      max_new=[int(budgets[r]) for r in rows],
                      sample=sample, kv=kv, page_size=page_size,
                      rng_seed=rng_seed)
            toks, lgs = serve_batch(cfg_lvl, params, prompts[rows],
                                    n_tokens, **kw)
            terminal = next_ladder_spec(spec) is None
            ok = np.ones(len(rows), bool)
            rel = np.zeros(len(rows))
            if not terminal and np.isfinite(thresh):
                cfg_ex = dataclasses.replace(
                    cfg, dscim=exact_probe_spec(spec), dscim_fault="")
                _, lgs_ex = serve_batch(cfg_ex, params, prompts[rows],
                                        n_tokens, **kw)
                s = np.asarray(lgs[0], np.float64).reshape(len(rows), -1)
                e = np.asarray(lgs_ex[0], np.float64).reshape(len(rows), -1)
                rms = np.sqrt(np.mean(e * e, axis=-1))
                rel = np.sqrt(np.mean((s - e) ** 2, axis=-1)) \
                    / np.maximum(rms, 1e-9)
                ok = np.isfinite(rel) & (rel <= thresh)
            for i, r in enumerate(rows):
                records.append({"request": r, "frm": level[r], "to": spec,
                                "reason": reason[r],
                                "accepted": bool(ok[i]),
                                "rel": float(rel[i])})
                log(f"[ladder] request {r}: {level[r]} -> {spec} "
                    f"({reason[r]}; rel {rel[i]:.2e}; "
                    f"{'accepted' if ok[i] else 'still drifting'})")
                if ok[i]:
                    row = np.asarray(toks[i])
                    n_use = int(budgets[r])
                    hits = np.nonzero(row[:n_use] == eos)[0]
                    if len(hits):
                        n_use = int(hits[0]) + 1
                    host["out"][r] = row[:n_use].tolist()
                    host["status"][r] = STATUS_OK
                else:
                    level[r] = spec
                    reason[r] = "drift"
                    pending.append(r)


# --------------------------------------------------------------------------
# chaos drill: the self-verifying end-to-end robustness exercise
# --------------------------------------------------------------------------

def chaos_drill(arch: str = "qwen3-0.6b", *, seed: int = 0,
                log=print) -> dict:
    """One scripted chaos scenario over the full fault-tolerant stack,
    asserting the ISSUE 6 acceptance contract end to end:

    under one injected segment-level device loss, page-pool bit flips
    (an f32 dequant-scale upset and an int8 page upset — both *silent*
    corruption on this RMSNorm'd model, tracked via
    ``corrupted_requests``; the NaN detection path is pinned separately
    in tests/test_serving_ft.py where Inf injection is deterministic), a
    persistent stuck-at DS-CIM macro fault, and a deadline expiry,
    ``serve_continuous`` completes every admitted request with a definite
    status; requests untouched by any fault finish bitwise-identical to
    the fault-free run; the accuracy watchdog trips on the injected
    macro fault and visibly escalates dscim2 -> dscim1; and exactly one
    failover replay absorbs the device loss.

    Deterministic by construction: greedy decoding (the shared PRNG key
    is never consumed), step-based deadlines, ``snapshot_every=1`` (the
    restore point is never older than a fired transient flip), eos=-1.
    Returns a report dict (the chaos bench rows and the CI smoke both
    consume it)."""
    from repro.configs import get_arch
    from repro.launch.serve import serve_continuous
    from repro.models import get_model
    from repro.runtime.failover import FailureInjector

    spec = "kernel:dscim2:64"
    cfg = dataclasses.replace(get_arch(arch).reduced(), dscim=spec)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    R, S, n = 6, 8, 8
    prompts = rng.integers(0, cfg.vocab, (R, S), dtype=np.int32)
    budgets = np.asarray([8, 6, 8, 5, 8, 6], np.int32)
    # request 3 gets a 4-decode-step budget: admitted in the first wave,
    # cancelled at the boundary after segment 2 with partial tokens
    deadlines = np.asarray([-1, -1, -1, 4, -1, -1], np.int64)
    knobs = dict(slots=3, seg_len=2, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=4)

    outs_ref, _ = serve_continuous(cfg, params, prompts, n, **knobs)

    monitor = watchdog_for_spec(spec, probe_every=1)
    injector = FailureInjector(
        fail_at=(3,),
        page_flips={
            # f32 dequant-scale exponent upset on slot 0 and an int8 page
            # upset on slot 1: both corrupt outputs silently (RMSNorm
            # squashes the magnitude excursion back into range) — the
            # contract is that they land in corrupted_requests, never in
            # a clean request's tokens
            1: ((0, "v_scale", (0, 0, 0), 0x7f000000),),
            2: ((1, "k_pages", (0, 0, 0, 0, 0), 0x41),),
        },
        macro_fault_at=6, macro_fault="stuck:3:40.0")
    outs, stats = serve_continuous(
        cfg, params, prompts, n, **knobs, deadline_steps=deadlines,
        monitor=monitor, injector=injector, snapshot_every=1,
        max_replays=2, log=log)

    # -- the acceptance contract ------------------------------------------
    assert all(s in (STATUS_OK, STATUS_DEADLINE) for s in stats["status"]), \
        f"indefinite request status: {stats['status']}"
    assert stats["replays"] == 1, \
        f"expected the device loss to cost exactly 1 replay: {stats}"
    assert stats["status"][3] == STATUS_DEADLINE \
        and len(outs[3]) < int(budgets[3]), \
        f"deadline request not cancelled: {stats['status']}"
    escalated = {e["request"] for e in stats["escalations"]}
    assert escalated, f"no ladder escalations recorded: {stats}"
    hops = {(e["frm"], e["to"]) for e in stats["escalations"]}
    assert any("dscim2" in frm and "dscim1" in to for frm, to in hops), \
        f"dscim2 -> dscim1 escalation not visible: {sorted(hops)}"
    assert stats["probe_trips"] >= 1, "watchdog never tripped"
    affected = (set(stats["corrupted_requests"]) | escalated
                | set(stats["quarantined"])
                | {r for r in range(R)
                   if stats["status"][r] == STATUS_DEADLINE})
    clean = sorted(set(range(R)) - affected)
    assert clean, "chaos scenario left no unaffected request to compare"
    for r in clean:
        np.testing.assert_array_equal(
            outs[r], outs_ref[r],
            err_msg=f"unaffected request {r} diverged from fault-free run")
    report = {
        "seed": seed,
        "requests": R, "clean": clean, "affected": sorted(affected),
        "replays": stats["replays"], "probes": stats["probes"],
        "probe_trips": stats["probe_trips"],
        "quarantined": stats["quarantined"],
        "escalations": len(stats["escalations"]),
        "deadline_cancelled": stats["deadline_cancelled"],
        "corrupted_requests": stats["corrupted_requests"],
        "statuses": stats["status"],
        "rel_threshold": monitor.rel_threshold,
    }
    log(f"[chaos] drill ok: {report}")
    return report


def integrity_drill(arch: str = "qwen3-0.6b", *, seed: int = 0,
                    log=print) -> dict:
    """The ISSUE 9 acceptance exercise: under injected page-pool *and*
    prepared-weight bit flips with ``integrity='scrub:2'``, every flip is
    detected at its exact coordinate within one scrub period, repaired
    requests finish ``'ok'``, **every** request (affected ones included —
    stronger than the chaos drill's unaffected-only contract) ends
    bitwise-identical to the fault-free run, and no repairable flip
    escalates the watchdog ladder.

    Two legs, both greedy / step-deterministic / ``snapshot_every=1``:

    * **leg 1** (watchdog armed, ``probe_every=1``): a weight q-plane
      upset at segment 0 (caught at the boundary-0 sweep before any
      decode consumed it — pure golden-copy repair, no replay), an f32
      dequant-scale upset at segment 1 repaired by rewinding the owner
      slot to the verified snapshot, and an int8 page upset at segment 3
      hitting a request admitted *after* that snapshot — repaired by
      re-serving it from its prompt.  Asserts zero replays, zero
      quarantines/escalations (a surgical repair must never read as
      watchdog drift), and exact (path, plane) / page-layer attribution.
    * **leg 2** (no watchdog): a scale-plane upset at segment 3, an
      *unchecked* boundary — segment 3 decodes against the corrupted
      plane, so the boundary-4 sweep must repair **and** discard the
      poisoned tokens via an ``IntegrityReplay`` from the last verified
      snapshot.  Asserts exactly one replay and, again, every output
      bitwise-identical.
    """
    from repro.configs import get_arch
    from repro.core.qweights import weight_plane_index
    from repro.launch.serve import serve_continuous
    from repro.launch.steps import prepare_serving_params
    from repro.models import get_model
    from repro.runtime.failover import FailureInjector

    spec = "kernel:dscim2:64"
    cfg = dataclasses.replace(get_arch(arch).reduced(), dscim=spec)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    R, S, n = 6, 8, 8
    prompts = rng.integers(0, cfg.vocab, (R, S), dtype=np.int32)
    budgets = np.asarray([8, 6, 8, 5, 8, 6], np.int32)
    knobs = dict(slots=3, seg_len=2, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=4)
    # the exact plane the weight flips target, discovered from a
    # throwaway prepare (deterministic — the scheduler's internal prepare
    # builds the same tree, so the path strings match)
    widx = weight_plane_index(prepare_serving_params(cfg, params))
    assert widx, "integrity drill needs a prepared (DS-CIM) model"
    wpath = next((p for p, w in widx if "w_up" in p and w == "q"),
                 widx[0][0])
    lay = 1 if cfg.n_layers > 1 else 0

    outs_ref, _ = serve_continuous(cfg, params, prompts, n, **knobs)

    # -- leg 1: detect + repair without replay, watchdog armed ------------
    monitor = watchdog_for_spec(spec, probe_every=1)
    inj1 = FailureInjector(
        page_flips={
            1: ((0, "v_scale", (0, 0, 0), 0x7f000000),),
            3: ((1, "k_pages", (lay, 0, 0, 0, 0), 0x41),),
        },
        weight_flips={0: ((wpath, "q", 2026, 0x10),)})
    outs1, st1 = serve_continuous(
        cfg, params, prompts, n, **knobs, monitor=monitor, injector=inj1,
        snapshot_every=1, max_replays=2, integrity="scrub:2", log=log)
    ig1 = st1["integrity"]
    assert ig1 is not None and ig1["period"] == 2, f"no integrity stats: {st1}"
    assert all(s == STATUS_OK for s in st1["status"]), \
        f"repaired requests must finish ok: {st1['status']}"
    assert st1["replays"] == 0 and ig1["replays"] == 0, \
        f"leg 1 faults are repairable without replay: {st1}"
    assert not st1["quarantined"] and not st1["escalations"], \
        f"a repairable flip escalated the ladder: {st1}"
    assert ig1["page_mismatches"] == 2 and ig1["page_repairs"] == 2, \
        f"both page flips must be detected and repaired: {ig1}"
    assert ig1["weight_mismatches"] == 1 and ig1["weight_repairs"] == 1, \
        f"the weight flip must be detected and repaired: {ig1}"
    wdet = [d for d in ig1["detections"] if d["kind"] == "weight"]
    pdet = [d for d in ig1["detections"] if d["kind"] == "page"]
    assert len(wdet) == 1 and wdet[0]["coords"] == [(wpath, "q")], \
        f"weight detection not attributed to the exact plane: {wdet}"
    assert [d["coords"][0][0] for d in pdet] == [0, lay] \
        and all(len(d["coords"]) == 1 for d in pdet), \
        f"page detections not attributed to the exact layers: {pdet}"
    assert set(st1["corrupted_requests"]) == {0, 3}, \
        f"unexpected corruption footprint: {st1['corrupted_requests']}"
    for r in range(R):
        np.testing.assert_array_equal(
            outs1[r], outs_ref[r],
            err_msg=f"request {r} diverged from the fault-free run (leg 1)")

    # -- leg 2: poisoned segments -> repair + bounded replay --------------
    inj2 = FailureInjector(
        weight_flips={3: ((wpath, "scale", 7, 1 << 23),)})
    outs2, st2 = serve_continuous(
        cfg, params, prompts, n, **knobs, injector=inj2,
        snapshot_every=1, max_replays=2, integrity="scrub:2", log=log)
    ig2 = st2["integrity"]
    assert st2["replays"] == 1 and ig2["replays"] == 1, \
        f"poisoned segments must cost exactly one replay: {st2}"
    assert ig2["weight_mismatches"] == 1 and ig2["weight_repairs"] == 1, \
        f"leg 2 weight flip not repaired: {ig2}"
    assert all(s == STATUS_OK for s in st2["status"]), \
        f"replayed requests must finish ok: {st2['status']}"
    for r in range(R):
        np.testing.assert_array_equal(
            outs2[r], outs_ref[r],
            err_msg=f"request {r} diverged from the fault-free run (leg 2)")

    report = {
        "seed": seed, "requests": R, "weight_plane": wpath,
        "scrub_period": 2,
        "leg1": {"page_repairs": ig1["page_repairs"],
                 "weight_repairs": ig1["weight_repairs"],
                 "replays": st1["replays"], "checks": ig1["checks"],
                 "pages_verified": ig1["pages_verified"],
                 "scrub_time_s": ig1["scrub_time_s"]},
        "leg2": {"weight_repairs": ig2["weight_repairs"],
                 "replays": st2["replays"], "checks": ig2["checks"]},
        "statuses": st1["status"],
    }
    log(f"[integrity] drill ok: {report}")
    return report


def prefix_drill(arch: str = "qwen3-0.6b", *, seed: int = 0,
                 log=print) -> dict:
    """The ISSUE 10 acceptance exercise: staggered admissions sharing a
    page-aligned system prompt, served warm (``prefix_cache='on'``) vs
    cold (``prefix_cache='cold'`` — the identical chunked admission path
    with lookup/registration disabled), must agree **bitwise** per
    request while the warm leg visibly dedupes pages and skips prefill:

    * every warm output equals its cold output token for token;
    * the warm leg records prefix hits and deduped pages (requests
      admitted after the first register-then-match its shared pages —
      sharers overlap live, so refcounts > 1 are exercised, and the
      last sharers release while the index retains);
    * prefill positions actually computed drop by the shared fraction
      (the prefill-FLOPs-removed measurement the bench rows report);
    * after drain the pool holds zero live pages (retained ref-0 pages
      are not live) and the retained set is non-empty — the index kept
      the prefix resident for future admissions.

    Deterministic by construction: greedy decoding, eos=-1, one
    compiled extend program for every admission.  Returns a report dict
    (the prefix bench rows and the CI smoke both consume it)."""
    from repro.configs import get_arch
    from repro.launch.serve import serve_continuous

    spec = "kernel:dscim2:64"
    cfg = dataclasses.replace(get_arch(arch).reduced(), dscim=spec)
    from repro.models import get_model
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    R, S, n, ps = 6, 16, 6, 4
    prompts = rng.integers(0, cfg.vocab, (R, S), dtype=np.int32)
    # requests 0..4 share a 12-token (3-page) system prompt; request 5
    # is fully distinct (a guaranteed miss among hits)
    prompts[1:5, :12] = prompts[0, :12]
    budgets = np.asarray([6, 5, 6, 4, 6, 5], np.int32)
    knobs = dict(slots=2, seg_len=2, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=ps)

    outs_cold, st_cold = serve_continuous(cfg, params, prompts, n, **knobs,
                                          prefix_cache="cold", log=log)
    outs_warm, st_warm = serve_continuous(cfg, params, prompts, n, **knobs,
                                          prefix_cache="on", log=log)

    # -- the acceptance contract ------------------------------------------
    for r in range(R):
        np.testing.assert_array_equal(
            outs_warm[r], outs_cold[r],
            err_msg=f"request {r}: prefix-hit serving diverged from cold")
    pw, pc = st_warm["prefix"], st_cold["prefix"]
    assert pc["hits"] == 0 and pc["pages_deduped"] == 0, \
        f"cold leg must not share: {pc}"
    assert pw["hits"] == 4, f"requests 1..4 must hit: {pw}"
    assert pw["pages_deduped"] == 4 * 3, \
        f"each hit shares 3 full pages: {pw}"
    assert pw["hit_tokens"] == 4 * 12, f"12 tokens per hit: {pw}"
    removed = 1.0 - pw["prefill_positions_computed"] \
        / max(pw["prefill_positions_total"], 1)
    assert removed > 0.4, \
        f"shared prefixes must remove >40% of prefill positions: {pw}"
    assert st_warm["pages"]["live_pages"] == 0 \
        and st_cold["pages"]["live_pages"] == 0, \
        "drained pools must hold zero live pages"
    assert st_warm["pages"]["retained_pages"] > 0, \
        f"the index must retain the shared prefix: {st_warm['pages']}"
    assert st_warm["pages"]["shares"] == pw["pages_deduped"], \
        f"every dedup is a share reference: {st_warm['pages']} vs {pw}"
    assert all(s == STATUS_OK for s in st_warm["status"]), \
        f"warm statuses: {st_warm['status']}"
    report = {
        "seed": seed, "requests": R,
        "hits": pw["hits"], "pages_deduped": pw["pages_deduped"],
        "hit_tokens": pw["hit_tokens"],
        "prefill_removed_frac": removed,
        "retained_pages": st_warm["pages"]["retained_pages"],
        "statuses": st_warm["status"],
    }
    log(f"[prefix] drill ok: {report}")
    return report
