"""Step watchdog: straggler detection + hang escalation.

At 1000+-node scale the common failure modes are (a) a host silently
slowing down (ECC retries, thermal throttle) and (b) a hung collective.
The watchdog tracks a robust step-time baseline (EMA + MAD) and

* flags *stragglers*: step time > straggler_factor x baseline  -> callback
  (production: report host to the scheduler for drain/requeue);
* raises on *hang*: no step completion within hang_timeout seconds, which
  the failover loop (runtime/failover.py) turns into checkpoint-restart.
"""
from __future__ import annotations

import threading
import time

__all__ = ["Watchdog", "StepHang"]


class StepHang(RuntimeError):
    pass


class Watchdog:
    def __init__(self, straggler_factor: float = 3.0,
                 hang_timeout: float = 300.0, on_straggler=None):
        self.factor = straggler_factor
        self.hang_timeout = hang_timeout
        self.on_straggler = on_straggler or (lambda info: None)
        self.ema = None
        self.n_stragglers = 0
        self._last_done = time.monotonic()
        self._armed = threading.Event()
        self._hang = threading.Event()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._monitor, daemon=True)
        self._thread.start()

    # -- hang monitoring (background thread) -----------------------------------
    def _monitor(self):
        while not self._stop.is_set():
            time.sleep(0.1)
            if self._armed.is_set() and \
                    time.monotonic() - self._last_done > self.hang_timeout:
                self._hang.set()
                self._armed.clear()

    # -- per-step API -----------------------------------------------------------
    def step(self):
        """Context manager wrapping one training step."""
        wd = self

        class _Ctx:
            def __enter__(self):
                if wd._hang.is_set():
                    raise StepHang("previous step exceeded hang_timeout")
                wd._armed.set()
                self.t0 = time.monotonic()
                return self

            def __exit__(self, et, ev, tb):
                wd._armed.clear()
                wd._last_done = time.monotonic()
                if et is not None:
                    return False
                dt = time.monotonic() - self.t0
                if wd.ema is None:
                    wd.ema = dt
                elif dt > wd.factor * wd.ema:
                    wd.n_stragglers += 1
                    wd.on_straggler({"step_time": dt, "baseline": wd.ema})
                else:
                    wd.ema = 0.9 * wd.ema + 0.1 * dt
                return False
        return _Ctx()

    def check_hang(self):
        if self._hang.is_set():
            raise StepHang("no step completed within hang_timeout")

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1)
