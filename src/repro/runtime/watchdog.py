"""Step watchdog: straggler detection + hang escalation — plus the
serving accuracy watchdog (ISSUE 6).

At 1000+-node scale the common failure modes are (a) a host silently
slowing down (ECC retries, thermal throttle) and (b) a hung collective.
The watchdog tracks a robust step-time baseline (EMA + MAD) and

* flags *stragglers*: step time > straggler_factor x baseline  -> callback
  (production: report host to the scheduler for drain/requeue);
* raises on *hang*: no step completion within hang_timeout seconds, which
  the failover loop (runtime/failover.py) turns into checkpoint-restart.

``AccuracyWatchdog`` is the estimator-health counterpart for DS-CIM
serving: every ``probe_every`` segments the fault-tolerant scheduler
(runtime/serving.py) compares the serving path's logits against an
exact-mode decode of the same (token, cache) inputs and trips a slot
whose relative RMSE exceeds a threshold derived from the macro's
``ErrorModel`` moments (core/error_model.py) — or whose logits go
NaN/Inf.  A tripped slot is quarantined and its request escalated down
the degradation ladder (dscim2 -> dscim1 -> exact) instead of poisoning
the rest of the batch.
"""
from __future__ import annotations

import threading
import time

import numpy as np

__all__ = ["Watchdog", "StepHang", "AccuracyWatchdog"]


class StepHang(RuntimeError):
    pass


class Watchdog:
    def __init__(self, straggler_factor: float = 3.0,
                 hang_timeout: float = 300.0, on_straggler=None):
        self.factor = straggler_factor
        self.hang_timeout = hang_timeout
        self.on_straggler = on_straggler or (lambda info: None)
        self.ema = None
        self.n_stragglers = 0
        self._last_done = time.monotonic()
        self._armed = threading.Event()
        self._hang = threading.Event()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._monitor, daemon=True)
        self._thread.start()

    # -- hang monitoring (background thread) -----------------------------------
    def _monitor(self):
        while not self._stop.is_set():
            time.sleep(0.1)
            if self._armed.is_set() and \
                    time.monotonic() - self._last_done > self.hang_timeout:
                self._hang.set()
                self._armed.clear()

    # -- per-step API -----------------------------------------------------------
    def step(self):
        """Context manager wrapping one training step."""
        wd = self

        class _Ctx:
            def __enter__(self):
                if wd._hang.is_set():
                    raise StepHang("previous step exceeded hang_timeout")
                wd._armed.set()
                self.t0 = time.monotonic()
                return self

            def __exit__(self, et, ev, tb):
                wd._armed.clear()
                wd._last_done = time.monotonic()
                if et is not None:
                    return False
                dt = time.monotonic() - self.t0
                if wd.ema is None:
                    wd.ema = dt
                elif dt > wd.factor * wd.ema:
                    wd.n_stragglers += 1
                    wd.on_straggler({"step_time": dt, "baseline": wd.ema})
                else:
                    wd.ema = 0.9 * wd.ema + 0.1 * dt
                return False
        return _Ctx()

    def check_hang(self):
        if self._hang.is_set():
            raise StepHang("no step completed within hang_timeout")

    def reset(self):
        """Clear a latched hang so a failover replay can re-arm cleanly
        (without this, the StepHang that triggered the restart would
        re-raise on the replay's first step)."""
        self._hang.clear()
        self._armed.clear()
        self._last_done = time.monotonic()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1)


class AccuracyWatchdog:
    """Sampled exact-vs-stochastic logit drift monitor for DS-CIM serving.

    ``rel_threshold``: maximum healthy per-slot relative logit RMSE
    (``rmse(serving - exact) / rms(exact)``), normally derived from the
    macro's measured error moments via ``from_error_model``; ``None``
    disables drift probes (NaN/Inf detection stays on — the scheduler
    checks per-step logit finiteness every segment regardless).
    ``probe_every``: probe cadence in segments — the monitoring cost is
    one extra exact-mode decode step per ``probe_every`` segments, which
    ``tools/bench_regression.py`` bounds on the fault-free path."""

    def __init__(self, rel_threshold: float | None, probe_every: int = 8):
        if probe_every < 1:
            raise ValueError(f"probe_every must be >= 1, got {probe_every}")
        self.rel_threshold = rel_threshold
        self.probe_every = probe_every
        self.n_probes = 0
        self.n_trips = 0
        self.history: list = []    # (segment, per-slot rel rmse) tuples

    @classmethod
    def from_error_model(cls, em, margin: float = 3.0,
                         probe_every: int = 8,
                         rows: int = 128) -> "AccuracyWatchdog":
        """Threshold = margin x the macro's moment-derived relative psum
        error bound (core/error_model.py ``relative_moment_bound``).  The
        margin absorbs layer-to-logit error propagation (partial
        cancellation both ways); healthy logit drift sits ~2x the bound,
        a hard macro fault ~an order of magnitude above it, so margin 3
        separates cleanly (tests/test_serving_ft.py pins it
        empirically)."""
        return cls(margin * em.relative_moment_bound(rows),
                   probe_every=probe_every)

    def should_probe(self, segment: int) -> bool:
        return self.rel_threshold is not None \
            and segment % self.probe_every == 0

    def check(self, serving_logits, exact_logits, live):
        """Per-slot drift verdicts for one probe.

        serving_logits/exact_logits: (B, V) arrays of the *same* (token,
        cache) decode inputs; live: (B,) bool mask of slots with an active
        request.  Returns (trip (B,) bool, rel (B,) float64) — a slot
        trips when its relative RMSE exceeds the threshold or is not
        finite (NaN/Inf logits)."""
        s = np.asarray(serving_logits, np.float64)
        e = np.asarray(exact_logits, np.float64)
        live = np.asarray(live, bool)
        rms = np.sqrt(np.mean(e * e, axis=-1))
        rel = np.sqrt(np.mean((s - e) ** 2, axis=-1)) / np.maximum(rms, 1e-9)
        trip = live & (~np.isfinite(rel) | (rel > self.rel_threshold))
        self.n_probes += 1
        self.n_trips += int(trip.sum())
        self.history.append(rel)
        return trip, rel
