"""Graceful degradation when ``hypothesis`` is not installed.

``hypothesis`` is declared in pyproject.toml's test extra, but the suite
must still *collect* without it (a bare ``pip install -e .`` environment).
``pytest.importorskip("hypothesis")`` at module top would skip entire test
modules — including their many non-property tests — so instead we export
drop-in ``given``/``settings``/``st`` stand-ins that turn only the
property-based tests into skips.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement (NOT functools.wraps: pytest must not see
            # the strategy parameters, it would resolve them as fixtures)
            def run():
                pytest.skip("hypothesis not installed "
                            "(pip install -e .[test])")
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Placeholder strategy factory: every attribute is a no-op."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
