"""DS-CIM macro: Eq.3/4 identities, backend bit-exactness, Table-I RMSE
bands, truncation-correction behavior."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.macro import DSCIMMacro, dscim1, dscim2
from repro.core.seed_search import calibrated_config, rmse_numpy
from repro.core.remap import build_count_lut
from repro.core import prng

int8s = st.integers(-128, 127)


@settings(max_examples=200, deadline=None)
@given(int8s, int8s)
def test_eq3_signed_unsigned_identity(x, w):
    """x*w == x'w' - 128x - 128w' with x'=x+128, w'=w+128 (paper Eq. 3)."""
    xp, wp = x + 128, w + 128
    assert x * w == xp * wp - 128 * x - 128 * wp


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([64, 128, 256]),
       st.sampled_from([2, 3]))
def test_backends_bit_exact(seed, L, k):
    """lut == bitmatmul == cycle-accurate hardware oracle (bit-exact)."""
    cfg = (dscim1 if k == 2 else dscim2)(L, points="lfsr", seed_u=3,
                                         seed_v=91)
    mac = DSCIMMacro(cfg)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (2, mac.cfg.rows)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (mac.cfg.rows, 3)), jnp.int32)
    c_lut = np.asarray(mac.counts_lut(x, w))
    c_bm = np.asarray(mac.counts_bitmatmul(x, w))
    c_cy = mac.counts_cycle(x, w)
    np.testing.assert_array_equal(c_lut, c_cy)
    np.testing.assert_array_equal(c_bm, c_cy)


PAPER_TABLE1 = {  # (variant, L) -> paper RMSE% (unsigned-fullscale conv.)
    ("dscim1", 64): 3.57, ("dscim1", 128): 2.03, ("dscim1", 256): 0.74,
    ("dscim2", 64): 3.81, ("dscim2", 128): 2.63, ("dscim2", 256): 0.84,
}


@pytest.mark.parametrize("variant,L", list(PAPER_TABLE1))
def test_table1_rmse_bands_paper_points(variant, L):
    """Seed-searched classic-PRNG configs must land at or below ~1.5x the
    paper's Table-I RMSE (we match or beat 5/6 cells; DS-CIM2/256 is within
    1.5x — see EXPERIMENTS.md §Paper-validation)."""
    cfg = calibrated_config(variant, L, "paper")
    mac = DSCIMMacro(cfg)
    r = mac.rmse(n_cols=192, n_vec=32)["unsigned_fullscale"]
    assert r <= PAPER_TABLE1[(variant, L)] * 1.5, (variant, L, r)


@pytest.mark.parametrize("variant,L", [("dscim1", 256), ("dscim2", 64)])
def test_opt_points_beat_paper_points(variant, L):
    """Beyond-paper low-discrepancy + midpoint correction beats the classic
    config at the two headline operating points."""
    r_paper = DSCIMMacro(calibrated_config(variant, L, "paper")).rmse(
        n_cols=192, n_vec=32)["unsigned_fullscale"]
    r_opt = DSCIMMacro(calibrated_config(variant, L, "opt")).rmse(
        n_cols=192, n_vec=32)["unsigned_fullscale"]
    assert r_opt < r_paper


def test_rmse_scales_down_with_length():
    vals = [DSCIMMacro(calibrated_config("dscim1", L, "paper")).rmse(
        n_cols=128, n_vec=16)["unsigned_fullscale"] for L in (64, 128, 256)]
    assert vals[0] > vals[1] > vals[2]


def test_estimator_unbiased_enough():
    """Center-corrected sobol estimator: |bias| well below the RMS error."""
    mac = DSCIMMacro(dscim1(256, points="sobol", seed_u=0, seed_v=60,
                            trunc="center"))
    r = mac.rmse(n_cols=256, n_vec=32)
    assert abs(r["bias"]) < 0.5 * r["rms_abs"]


def test_sparsity_robustness():
    """Paper claim: DS-CIM is robust across product sparsity (Fig. 6c) —
    RMSE under sparse activations stays within 3x of the dense case."""
    mac = DSCIMMacro(calibrated_config("dscim1", 256, "paper"))
    dense = mac.rmse(n_cols=128, n_vec=16, dist="uniform")["unsigned_fullscale"]
    sparse = mac.rmse(n_cols=128, n_vec=16, dist="sparse")["unsigned_fullscale"]
    assert sparse < 3 * dense


def test_rmse_numpy_matches_macro():
    cfg = calibrated_config("dscim2", 64, "paper")
    u, v = prng.make_points(cfg.points, cfg.length, cfg.seed_u, cfg.seed_v,
                            cfg.param_u, cfg.param_v)
    lut = build_count_lut(u, v, cfg.k)
    ru, _, _ = rmse_numpy(lut, cfg.k, cfg.length, n_vec=32, n_cols=192,
                          trunc=cfg.trunc)
    rm = DSCIMMacro(cfg).rmse(n_cols=192, n_vec=32)["unsigned_fullscale"]
    assert abs(ru - rm) / rm < 0.35  # different random draws, same regime
