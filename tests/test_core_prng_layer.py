"""PRNG sequence properties + DSCIMLinear behavior + error model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import prng
from repro.core.dscim_layer import DSCIMLinear, make_linear
from repro.core.error_model import ErrorModel
from repro.core.macro import DSCIMMacro, dscim1, dscim2


# ---------------- PRNG properties ----------------

@pytest.mark.parametrize("kind", ["lfsr", "galois", "lcg", "weyl",
                                  "xorshift", "vdc", "sobol", "r2"])
def test_point_ranges_and_determinism(kind):
    u1, v1 = prng.make_points(kind, 128, 3, 91)
    u2, v2 = prng.make_points(kind, 128, 3, 91)
    np.testing.assert_array_equal(u1, u2)
    assert u1.dtype == np.uint8 and v1.dtype == np.uint8
    assert u1.shape == (128,)


def test_lfsr_period_255():
    seq = prng.lfsr8(255, seed=1)
    assert len(set(seq.tolist())) == 255  # maximal period, 0 excluded
    assert 0 not in set(seq.tolist())


def test_lcg_full_period():
    seq = prng.lcg8(256, seed=7)
    assert len(set(seq.tolist())) == 256


def test_vdc_is_permutation():
    seq = prng.vdc8(256)
    assert sorted(seq.tolist()) == list(range(256))


def test_sobol_2d_stratification():
    """(0,2)-sequence: each aligned 16x16 cell of the 256-point set holds
    exactly one point — the property that makes the per-block counts tight."""
    u, v = prng.sobol2d_8(256, 0, 0)
    cells = set((int(a) // 16, int(b) // 16) for a, b in zip(u, v))
    assert len(cells) == 256


def test_weyl_lattice_equidistribution():
    u = prng.weyl8(256, 0, alpha=159)
    counts = np.bincount(u // 32, minlength=8)
    assert counts.std() == 0  # perfectly equidistributed at coarse scale


# ---------------- DSCIMLinear ----------------

def test_exact_mode_matches_float_within_quant_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (4, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.05, (256, 32)), jnp.float32)
    lin = make_linear("dscim1", 256, "exact")
    rel = float(jnp.linalg.norm(lin(x, w) - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.03


def test_lut_mode_is_deterministic():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (2, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (128, 8)), jnp.float32)
    lin = make_linear("dscim2", 64, "lut")
    np.testing.assert_array_equal(np.asarray(lin(x, w)),
                                  np.asarray(lin(x, w)))


def test_windowed_quant_matches_single_window_when_k_small():
    """K == group_k: windowed path must equal the single-window path."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (3, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (128, 8)), jnp.float32)
    a = DSCIMLinear(dscim1(256, points="sobol"), "exact", group_k=128)
    b = DSCIMLinear(dscim1(256, points="sobol"), "exact", group_k=None)
    np.testing.assert_allclose(np.asarray(a(x, w)), np.asarray(b(x, w)),
                               rtol=1e-5, atol=1e-5)


def test_statistical_mode_moments_match_lut():
    """Gaussian injection tracks the exact process' error scale (2x band)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (16, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (256, 64)), jnp.float32)
    exact = make_linear("dscim1", 256, "exact")(x, w)
    e_lut = np.asarray(make_linear("dscim1", 256, "lut")(x, w)) - exact
    e_sta = np.asarray(make_linear("dscim1", 256, "statistical")(
        x, w, key=jax.random.PRNGKey(0))) - exact
    r = e_sta.std() / e_lut.std()
    assert 0.4 < r < 2.5, r


def test_error_model_scaling_with_k():
    mac = DSCIMMacro(dscim2(64, points="lfsr", seed_u=233, seed_v=199))
    em = ErrorModel.from_macro(mac, n_samples=50_000)
    z = jnp.zeros((4, 8))
    k1 = em.inject(z, jax.random.PRNGKey(0), 128)
    k4 = em.inject(z, jax.random.PRNGKey(0), 512)
    assert float(jnp.std(k4)) > 1.5 * float(jnp.std(k1))


def test_fig6c_naive_or_saturates_dscim_does_not():
    """The headline qualitative claim: conventional independent-PRNG OR-MAC
    saturates at low sparsity; remapped DS-CIM does not."""
    from repro.core.ormac import naive_or_count
    rng = np.random.default_rng(0)
    # dense (low sparsity) unsigned inputs -> many 1s -> OR collisions
    a = rng.integers(150, 256, 64).astype(np.int64)
    w = rng.integers(150, 256, 64).astype(np.int64)
    or_count, sum_count = naive_or_count(a, w, L=128, group=16, seed=1)
    saturation_loss = 1 - or_count / max(sum_count, 1)
    assert saturation_loss > 0.3   # severe saturation for the baseline
    # DS-CIM: remapped OR == exact sum (zero saturation) by construction
    mac = DSCIMMacro(dscim1(128, points="lfsr", seed_u=3, seed_v=91))
    k = mac.cfg.k
    a_s = ((a[:64]) >> k).astype(np.int64)
    w_s = ((w[:64]) >> k).astype(np.int64)
    from repro.core.ormac import dscim_bitstreams, check_disjoint
    ab, wb = dscim_bitstreams(a_s, w_s, mac.u, mac.v, k)
    assert check_disjoint(ab & wb, k)


def test_kernel_mode_matches_lut():
    """DSCIMLinear 'kernel' backend (fused single-launch Pallas) == 'lut'."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(0, 1, (4, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (256, 16)), jnp.float32)
    a = make_linear("dscim1", 256, "lut")(x, w)
    b = make_linear("dscim1", 256, "kernel")(x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
