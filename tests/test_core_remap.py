"""Properties of the sample-region remapping — the paper's central claim:
after remapping, a shared sampling point activates at most one row per OR
group, for ALL data (Sec. IV-B)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import ormac, prng
from repro.core.remap import (build_count_lut, fires, fold, group_size,
                              point_block, row_block, shifted_bits)


@pytest.mark.parametrize("k", [1, 2, 3])
def test_fold_is_partition(k):
    """fold() maps every u in [0,256) to exactly one (code, local) cell and
    covers each (code, local) exactly once -> regions tile the map."""
    u = np.arange(256)
    code, loc = fold(u, k)
    S = shifted_bits(k)
    assert code.min() == 0 and code.max() == (1 << k) - 1
    assert loc.min() == 0 and loc.max() == S - 1
    pairs = set(zip(code.tolist(), loc.tolist()))
    assert len(pairs) == 256  # bijection onto (code, local)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 3), st.integers(0, 2 ** 31 - 1), st.data())
def test_disjointness_property(k, seed, data):
    """Hypothesis: for arbitrary int8 data and any point, at most one row of
    an OR group fires per cycle (collision-free OR accumulation)."""
    G = group_size(k)
    S = shifted_bits(k)
    rng = np.random.default_rng(seed)
    a = rng.integers(0, S, G)
    w = rng.integers(0, S, G)
    u = np.uint8(data.draw(st.integers(0, 255)))
    v = np.uint8(data.draw(st.integers(0, 255)))
    g = np.arange(G)
    f = fires(np.full(G, u), np.full(G, v), a, w, g, k)
    assert f.sum() <= 1


@pytest.mark.parametrize("kind", ["lfsr", "sobol", "weyl"])
@pytest.mark.parametrize("k,L", [(2, 256), (3, 64)])
def test_cycle_oracle_disjoint(kind, k, L):
    u, v = prng.make_points(kind, L, 3, 91)
    rng = np.random.default_rng(0)
    S = shifted_bits(k)
    a = rng.integers(0, S, 128)
    w = rng.integers(0, S, 128)
    count, per_cycle = ormac.dscim_group_count(a, w, u, v, k,
                                               assert_disjoint=True)
    # adder width claim: per-cycle sum bounded by #groups (8 for DS-CIM1,
    # 2 for DS-CIM2 at H=128)
    assert per_cycle.max() <= 128 // group_size(k)


@pytest.mark.parametrize("k,L", [(1, 64), (2, 128), (3, 64)])
def test_lut_matches_bruteforce(k, L):
    """LUT[g,a,w] == direct point-in-region counting."""
    u, v = prng.make_points("lcg", L, 5, 17)
    lut = build_count_lut(u, v, k)
    S = shifted_bits(k)
    rng = np.random.default_rng(1)
    for _ in range(50):
        g = rng.integers(0, group_size(k))
        a = rng.integers(0, S)
        w = rng.integers(0, S)
        direct = int(fires(u.astype(np.int32), v.astype(np.int32),
                           a, w, g, k).sum())
        assert lut[g, a, w] == direct


def test_row_block_wiring():
    bc, br = row_block(np.arange(16), 2)
    assert sorted(zip(bc.tolist(), br.tolist())) == [
        (i, j) for i in range(4) for j in range(4)]


@pytest.mark.parametrize("k", [1, 2, 3])
def test_point_block_inverts_row_block(k):
    """point_block is the inverse pairing of row_block: a point whose folded
    codes equal row g's block lands back on row g."""
    g = np.arange(group_size(k))
    bc, br = row_block(g, k)
    np.testing.assert_array_equal(point_block(bc, br, k), g)


@pytest.mark.parametrize("variant,L", [("dscim1", 256), ("dscim2", 64)])
def test_kernels_agree_on_wiring(variant, L):
    """The baseline (row->(bc,br) compare) and blocked-points (point->row
    table) kernels derive their wiring from the same remap helpers — their
    count matrices must agree exactly."""
    import jax.numpy as jnp

    from repro.core.seed_search import calibrated_config
    from repro.kernels.dscim_mvm import dscim_counts_pallas
    from repro.kernels.dscim_mvm_blocked import dscim_counts_blocked
    from repro.kernels.ops import fold_constants

    cfg = calibrated_config(variant, L, "paper")
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(-128, 128, (16, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (128, 16)), jnp.int8)
    cu, lu, cv, lv = fold_constants(cfg)
    base = np.asarray(dscim_counts_pallas(
        x, w, cu, lu, cv, lv, k=cfg.k, length=cfg.length,
        bm=16, bn=16, bk=8, bl=min(cfg.length, 64)))
    blocked = np.asarray(dscim_counts_blocked(x, w, cfg, bm=16, bn=16,
                                              bk=16))
    np.testing.assert_array_equal(base, blocked)
