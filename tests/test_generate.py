"""Device-resident scanned generation (launch/steps.py make_generate_fn):
bit-identical tokens + logits vs the legacy host loop for every DS-CIM
mode under f32 compute, exactly one decode scan in the traced HLO (one
host dispatch per request), cache-donation no-copy behavior, and the
logit-trace-off-the-hot-path default."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.serve import serve_batch
from repro.launch.steps import (make_decode_step, make_generate_fn,
                                make_prefill_step, prepare_serving_params)
from repro.models import get_model


def _setup(dscim="off", arch="qwen3-0.6b"):
    cfg = get_arch(arch).reduced()
    if dscim != "off":
        cfg = dataclasses.replace(cfg, dscim=dscim)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8),
                                                dtype=np.int32)
    return cfg, params, prompts


# every DSCIMLinear backend, the fused Pallas kernel path, and the '+attn'
# opt-in — the scanned loop must replay the host loop bit for bit (f32
# compute; the noise backends' fallback keys fold shape+salt only, so the
# per-step draws match across drivers too)
MODES = ["off", "exact:dscim2:64", "lut:dscim2:64", "bitmatmul:dscim2:64",
         "kernel:dscim2:64", "kernel+attn:dscim2:64",
         "statistical:dscim2:64", "paper_inject:dscim2:64"]


@pytest.mark.parametrize("dscim", MODES)
def test_scanned_matches_host_loop_bitwise(dscim):
    cfg, params, prompts = _setup(dscim)
    t_host, l_host = serve_batch(cfg, params, prompts, 5, scan=False)
    t_scan, l_scan = serve_batch(cfg, params, prompts, 5, scan=True)
    np.testing.assert_array_equal(t_host, t_scan)
    np.testing.assert_array_equal(np.asarray(l_host[0]),
                                  np.asarray(l_scan[0]))


def _count_scans(jaxpr, length) -> int:
    """Scan primitives of the given trip count, recursing into sub-jaxprs."""
    def subs(v):
        if hasattr(v, "jaxpr"):                      # ClosedJaxpr
            return [v.jaxpr]
        if hasattr(v, "eqns"):                       # Jaxpr
            return [v]
        if isinstance(v, (list, tuple)):
            return [j for x in v for j in subs(x)]
        return []

    n = sum(1 for e in jaxpr.eqns
            if e.primitive.name == "scan" and e.params.get("length") == length)
    for e in jaxpr.eqns:
        for v in e.params.values():
            n += sum(_count_scans(j, length) for j in subs(v))
    return n


def test_generate_is_single_dispatch_single_scan():
    """The whole decode loop is one lax.scan inside one jit: the traced
    generate contains exactly one scan of length n_tokens-1 (the layer
    scans have length n_layers and don't collide for this n_tokens)."""
    cfg, params, prompts = _setup("exact:dscim2:64")
    pp = prepare_serving_params(cfg, params)
    batch = {"tokens": jnp.asarray(prompts)}
    n = 6
    assert n - 1 != cfg.n_layers
    gen = make_generate_fn(cfg, None, n, jit=False)
    jaxpr = jax.make_jaxpr(gen)(pp, batch)
    assert _count_scans(jaxpr.jaxpr, n - 1) == 1


def test_scanned_cache_no_copy_and_host_loop_donation():
    """No-copy cache handling in both drivers.  Scanned: the KV cache lives
    in the scan carry, so compiled temp memory grows only with the cache
    *capacity*, never with one-copy-per-token (8x the tokens must stay far
    under host-loop-copy scaling).  Host loop: donate_argnums actually
    aliases — the donated cache buffer is deleted after the decode call."""
    cfg, params, prompts = _setup("exact:dscim2:64")
    pp = prepare_serving_params(cfg, params)
    batch = {"tokens": jnp.asarray(prompts)}
    B, S = prompts.shape
    # bytes per cache position: k+v planes over layers/batch/kv-heads (f32)
    slot = 2 * cfg.n_layers * B * cfg.n_kv * cfg.head_dim * 4
    m4 = make_generate_fn(cfg, None, 4).lower(pp, batch) \
        .compile().memory_analysis()
    m32 = make_generate_fn(cfg, None, 32).lower(pp, batch) \
        .compile().memory_analysis()
    growth = m32.temp_size_in_bytes - m4.temp_size_in_bytes
    # capacity grows by 28 slots; a per-token cache copy would add
    # ~31 * (S+32) * slot bytes — require well under that, allowing a few
    # capacity-proportional working buffers
    assert growth < 6 * 28 * slot, (growth, slot)

    prefill = jax.jit(make_prefill_step(cfg, None, capacity=S + 4))
    decode = jax.jit(make_decode_step(cfg, None), donate_argnums=(2,))
    logits, cache = prefill(pp, batch)
    kbuf = cache["k"]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    _, cache2 = decode(pp, {"token": tok}, cache)
    assert kbuf.is_deleted()      # donated in place, not copied
    assert not cache2["k"].is_deleted()


def test_logit_trace_off_hot_path_by_default():
    """Default serve returns only the prefill logits; trace_logits=True
    materializes the full on-device per-step stack, consistent with the
    default's tokens."""
    cfg, params, prompts = _setup()
    toks, lite = serve_batch(cfg, params, prompts, 5)
    assert len(lite) == 1 and lite[0].shape == (2, cfg.vocab_padded)
    toks_t, trace = serve_batch(cfg, params, prompts, 5, trace_logits=True)
    assert len(trace) == 5
    np.testing.assert_array_equal(toks, toks_t)
    np.testing.assert_array_equal(np.asarray(lite[0]), np.asarray(trace[0]))
    # greedy argmax of the traced logits reproduces the returned tokens
    np.testing.assert_array_equal(
        np.stack([np.argmax(lg, -1) for lg in trace], axis=1), toks_t)
    # the host loop returns the same full per-step trace (driver A/B)
    _, trace_h = serve_batch(cfg, params, prompts, 5, scan=False,
                             trace_logits=True)
    assert len(trace_h) == 5
    for a, b in zip(trace, trace_h):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_fn_builder_is_cached():
    cfg, _, _ = _setup()
    assert make_generate_fn(cfg, None, 7) is make_generate_fn(cfg, None, 7)
    assert make_generate_fn(cfg, None, 7) is not make_generate_fn(cfg, None, 8)
    assert make_generate_fn(cfg, None, 7) is not \
        make_generate_fn(cfg, None, 7, eos_id=3)


# ---------------------------------------------------------------------------
# ISSUE 4: EOS early-exit while_loop, in-scan sampling, int8 paged KV
# ---------------------------------------------------------------------------

def _assert_prefix_parity(t_full, t_ee, eos, pad=0):
    """Early-exit rows must replay the fixed scan bit for bit up to and
    including each row's first EOS, and pin everything after to pad."""
    n = t_full.shape[1]
    for b in range(t_full.shape[0]):
        hits = np.nonzero(t_full[b] == eos)[0]
        end = hits[0] + 1 if len(hits) else n
        np.testing.assert_array_equal(t_ee[b, :end], t_full[b, :end])
        assert (t_ee[b, end:] == pad).all(), (b, t_ee[b], t_full[b])


@pytest.mark.parametrize("dscim", MODES)
def test_early_exit_matches_fixed_scan(dscim):
    """The lax.while_loop variant (per-slot done-masked ragged completion)
    produces bit-identical tokens up to each sequence's EOS vs the fixed-
    length scan, for every DS-CIM backend incl. kernel and '+attn'."""
    cfg, params, prompts = _setup(dscim)
    n = 5
    t_full, _ = serve_batch(cfg, params, prompts, n)
    # an EOS that row 0 emits early and the other row may never emit
    eos = int(t_full[0, 1])
    t_ee, _ = serve_batch(cfg, params, prompts, n, eos_id=eos)
    _assert_prefix_parity(t_full, t_ee, eos)


def test_early_exit_per_slot_budgets():
    """batch['max_new'] budgets finish slots raggedly (counted including
    the prefill-sampled token); the surviving prefix replays the fixed
    scan; an unreachable EOS alone runs the full length."""
    cfg, params, prompts = _setup("exact:dscim2:64")
    n = 6
    t_full, _ = serve_batch(cfg, params, prompts, n)
    t_b, _ = serve_batch(cfg, params, prompts, n, eos_id=-1, max_new=[2, 4])
    np.testing.assert_array_equal(t_b[0, :2], t_full[0, :2])
    np.testing.assert_array_equal(t_b[1, :4], t_full[1, :4])
    assert (t_b[0, 2:] == 0).all() and (t_b[1, 4:] == 0).all()
    t_noeos, _ = serve_batch(cfg, params, prompts, n, eos_id=-1)
    np.testing.assert_array_equal(t_noeos, t_full)


def test_sampling_in_scan():
    """temp/top-k decode rules draw inside the jitted loop: reproducible
    per seed, seed-sensitive, top-1 == greedy, and the while_loop variant
    draws the identical sequence (one key split per step)."""
    cfg, params, prompts = _setup()
    n = 6
    tg, _ = serve_batch(cfg, params, prompts, n)
    t1, _ = serve_batch(cfg, params, prompts, n, sample="topk:1")
    np.testing.assert_array_equal(t1, tg)     # top-1 == greedy argmax
    a, _ = serve_batch(cfg, params, prompts, n, sample="temp:0.8",
                       rng_seed=3)
    b, _ = serve_batch(cfg, params, prompts, n, sample="temp:0.8",
                       rng_seed=3)
    np.testing.assert_array_equal(a, b)       # deterministic per seed
    c, _ = serve_batch(cfg, params, prompts, n, sample="temp:0.8",
                       rng_seed=4)
    assert (a != c).any()                     # and seed-sensitive
    d, _ = serve_batch(cfg, params, prompts, n, sample="topk:8:0.8",
                       rng_seed=3)
    eos = int(d[0, 1])
    d_ee, _ = serve_batch(cfg, params, prompts, n, sample="topk:8:0.8",
                          rng_seed=3, eos_id=eos)
    _assert_prefix_parity(d, d_ee, eos)


def test_topp_sampling_in_scan():
    """ISSUE 5: nucleus sampling inside the scan — drawn from the same
    carried PRNG key as temp/top-k, so 'topp:1.0:<t>' (nothing truncated)
    reproduces 'temp:<t>' draw for draw; a vanishing p keeps only the top
    token (== greedy); deterministic per seed and identical in the EOS
    while_loop variant (one key split per step in both drivers)."""
    cfg, params, prompts = _setup()
    n = 6
    t_temp, _ = serve_batch(cfg, params, prompts, n, sample="temp:0.8",
                            rng_seed=3)
    t_p1, _ = serve_batch(cfg, params, prompts, n, sample="topp:1.0:0.8",
                          rng_seed=3)
    np.testing.assert_array_equal(t_p1, t_temp)   # p=1.0 == pure temp
    tg, _ = serve_batch(cfg, params, prompts, n)
    t_tiny, _ = serve_batch(cfg, params, prompts, n, sample="topp:1e-9:0.7",
                            rng_seed=3)
    np.testing.assert_array_equal(t_tiny, tg)     # nucleus of 1 == greedy
    a, _ = serve_batch(cfg, params, prompts, n, sample="topp:0.9:0.8",
                       rng_seed=3)
    b, _ = serve_batch(cfg, params, prompts, n, sample="topp:0.9:0.8",
                       rng_seed=3)
    np.testing.assert_array_equal(a, b)           # deterministic per seed
    eos = int(a[0, 1])
    a_ee, _ = serve_batch(cfg, params, prompts, n, sample="topp:0.9:0.8",
                          rng_seed=3, eos_id=eos)
    _assert_prefix_parity(a, a_ee, eos)


def test_bad_sample_spec_rejected():
    cfg, params, prompts = _setup()
    for spec in ("nucleus:0.9", "temp:0", "topk:4:0:1", "topp:0",
                 "topp:1.5", "topp:0.9:0"):
        with pytest.raises(ValueError):
            serve_batch(cfg, params, prompts, 4, sample=spec)


@pytest.mark.parametrize("dscim", ["off", "kernel:dscim2:64"])
def test_paged_int8_kv_close_to_float_kv(dscim):
    """int8 paged KV serves within tolerance of the dense float cache:
    logit drift on the teacher-matched prefix (steps before the first
    token divergence per row — beyond it the drivers feed different
    tokens back and the comparison stops measuring quantization) stays
    under 1e-2 RMSE on the float compute path (the ISSUE 4 acceptance
    metric; 3e-2 through the lowest-accuracy DS-CIM2/L64 macro, whose
    approximate MVMs amplify the cache perturbation), and the early-exit
    variant composes with paging."""
    tol = 1e-2 if dscim == "off" else 3e-2
    cfg, params, prompts = _setup(dscim)
    n = 8
    tf, lf = serve_batch(cfg, params, prompts, n, trace_logits=True)
    tq, lq = serve_batch(cfg, params, prompts, n, trace_logits=True,
                         kv="int8", page_size=4)
    # tokens come off the same prefill, so column 0 always agrees and the
    # matched prefix holds at least one same-input decode step per row
    np.testing.assert_array_equal(tf[:, 0], tq[:, 0])
    from repro.launch.serve import logit_drift_rmse
    rmse = logit_drift_rmse(tf, tq, lf, lq)
    assert rmse <= tol, rmse
    # prefill logits identical (paging only changes the decode path)
    np.testing.assert_array_equal(np.asarray(lf[0]), np.asarray(lq[0]))
    # early-exit + paged: pads pinned after the paged run's own EOS
    t_full, _ = serve_batch(cfg, params, prompts, n, kv="int8", page_size=4)
    eos = int(t_full[0, 1])
    t_ee, _ = serve_batch(cfg, params, prompts, n, kv="int8", page_size=4,
                          eos_id=eos)
    _assert_prefix_parity(t_full, t_ee, eos)


def test_host_loop_rejects_live_work_options():
    cfg, params, prompts = _setup()
    for kw in ({"eos_id": 3}, {"sample": "temp:0.7"}, {"kv": "int8"}):
        with pytest.raises(ValueError):
            serve_batch(cfg, params, prompts, 4, scan=False, **kw)
    with pytest.raises(ValueError):   # budgets need the early-exit variant
        serve_batch(cfg, params, prompts, 4, max_new=[2, 2])
    with pytest.raises(ValueError):   # trace rides the fixed scan only
        serve_batch(cfg, params, prompts, 4, eos_id=3, trace_logits=True)
