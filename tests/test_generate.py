"""Device-resident scanned generation (launch/steps.py make_generate_fn):
bit-identical tokens + logits vs the legacy host loop for every DS-CIM
mode under f32 compute, exactly one decode scan in the traced HLO (one
host dispatch per request), cache-donation no-copy behavior, and the
logit-trace-off-the-hot-path default."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.serve import serve_batch
from repro.launch.steps import (make_decode_step, make_generate_fn,
                                make_prefill_step, prepare_serving_params)
from repro.models import get_model


def _setup(dscim="off", arch="qwen3-0.6b"):
    cfg = get_arch(arch).reduced()
    if dscim != "off":
        cfg = dataclasses.replace(cfg, dscim=dscim)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8),
                                                dtype=np.int32)
    return cfg, params, prompts


# every DSCIMLinear backend, the fused Pallas kernel path, and the '+attn'
# opt-in — the scanned loop must replay the host loop bit for bit (f32
# compute; the noise backends' fallback keys fold shape+salt only, so the
# per-step draws match across drivers too)
MODES = ["off", "exact:dscim2:64", "lut:dscim2:64", "bitmatmul:dscim2:64",
         "kernel:dscim2:64", "kernel+attn:dscim2:64",
         "statistical:dscim2:64", "paper_inject:dscim2:64"]


@pytest.mark.parametrize("dscim", MODES)
def test_scanned_matches_host_loop_bitwise(dscim):
    cfg, params, prompts = _setup(dscim)
    t_host, l_host = serve_batch(cfg, params, prompts, 5, scan=False)
    t_scan, l_scan = serve_batch(cfg, params, prompts, 5, scan=True)
    np.testing.assert_array_equal(t_host, t_scan)
    np.testing.assert_array_equal(np.asarray(l_host[0]),
                                  np.asarray(l_scan[0]))


def _count_scans(jaxpr, length) -> int:
    """Scan primitives of the given trip count, recursing into sub-jaxprs."""
    def subs(v):
        if hasattr(v, "jaxpr"):                      # ClosedJaxpr
            return [v.jaxpr]
        if hasattr(v, "eqns"):                       # Jaxpr
            return [v]
        if isinstance(v, (list, tuple)):
            return [j for x in v for j in subs(x)]
        return []

    n = sum(1 for e in jaxpr.eqns
            if e.primitive.name == "scan" and e.params.get("length") == length)
    for e in jaxpr.eqns:
        for v in e.params.values():
            n += sum(_count_scans(j, length) for j in subs(v))
    return n


def test_generate_is_single_dispatch_single_scan():
    """The whole decode loop is one lax.scan inside one jit: the traced
    generate contains exactly one scan of length n_tokens-1 (the layer
    scans have length n_layers and don't collide for this n_tokens)."""
    cfg, params, prompts = _setup("exact:dscim2:64")
    pp = prepare_serving_params(cfg, params)
    batch = {"tokens": jnp.asarray(prompts)}
    n = 6
    assert n - 1 != cfg.n_layers
    gen = make_generate_fn(cfg, None, n, jit=False)
    jaxpr = jax.make_jaxpr(gen)(pp, batch)
    assert _count_scans(jaxpr.jaxpr, n - 1) == 1


def test_scanned_cache_no_copy_and_host_loop_donation():
    """No-copy cache handling in both drivers.  Scanned: the KV cache lives
    in the scan carry, so compiled temp memory grows only with the cache
    *capacity*, never with one-copy-per-token (8x the tokens must stay far
    under host-loop-copy scaling).  Host loop: donate_argnums actually
    aliases — the donated cache buffer is deleted after the decode call."""
    cfg, params, prompts = _setup("exact:dscim2:64")
    pp = prepare_serving_params(cfg, params)
    batch = {"tokens": jnp.asarray(prompts)}
    B, S = prompts.shape
    # bytes per cache position: k+v planes over layers/batch/kv-heads (f32)
    slot = 2 * cfg.n_layers * B * cfg.n_kv * cfg.head_dim * 4
    m4 = make_generate_fn(cfg, None, 4).lower(pp, batch) \
        .compile().memory_analysis()
    m32 = make_generate_fn(cfg, None, 32).lower(pp, batch) \
        .compile().memory_analysis()
    growth = m32.temp_size_in_bytes - m4.temp_size_in_bytes
    # capacity grows by 28 slots; a per-token cache copy would add
    # ~31 * (S+32) * slot bytes — require well under that, allowing a few
    # capacity-proportional working buffers
    assert growth < 6 * 28 * slot, (growth, slot)

    prefill = jax.jit(make_prefill_step(cfg, None, capacity=S + 4))
    decode = jax.jit(make_decode_step(cfg, None), donate_argnums=(2,))
    logits, cache = prefill(pp, batch)
    kbuf = cache["k"]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    _, cache2 = decode(pp, {"token": tok}, cache)
    assert kbuf.is_deleted()      # donated in place, not copied
    assert not cache2["k"].is_deleted()


def test_logit_trace_off_hot_path_by_default():
    """Default serve returns only the prefill logits; trace_logits=True
    materializes the full on-device per-step stack, consistent with the
    default's tokens."""
    cfg, params, prompts = _setup()
    toks, lite = serve_batch(cfg, params, prompts, 5)
    assert len(lite) == 1 and lite[0].shape == (2, cfg.vocab_padded)
    toks_t, trace = serve_batch(cfg, params, prompts, 5, trace_logits=True)
    assert len(trace) == 5
    np.testing.assert_array_equal(toks, toks_t)
    np.testing.assert_array_equal(np.asarray(lite[0]), np.asarray(trace[0]))
    # greedy argmax of the traced logits reproduces the returned tokens
    np.testing.assert_array_equal(
        np.stack([np.argmax(lg, -1) for lg in trace], axis=1), toks_t)
    # the host loop returns the same full per-step trace (driver A/B)
    _, trace_h = serve_batch(cfg, params, prompts, 5, scan=False,
                             trace_logits=True)
    assert len(trace_h) == 5
    for a, b in zip(trace, trace_h):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_fn_builder_is_cached():
    cfg, _, _ = _setup()
    assert make_generate_fn(cfg, None, 7) is make_generate_fn(cfg, None, 7)
    assert make_generate_fn(cfg, None, 7) is not make_generate_fn(cfg, None, 8)
