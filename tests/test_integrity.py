"""Deterministic integrity layer (ISSUE 9, runtime/integrity.py): digest
algebra for KV pages and prepared weight planes, flip_bits mask
discipline, golden-copy repair, the fault-free bitwise-parity contract
of ``integrity='verify'``, and the end-to-end acceptance drill."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.kvcache import page_checksums
from repro.core.qweights import (iter_qweight_planes, weight_plane_digests,
                                 weight_plane_index)
from repro.launch.serve import serve_continuous
from repro.launch.steps import prepare_serving_params
from repro.models import get_model
from repro.runtime.failover import FailureInjector, flip_bits
from repro.runtime.integrity import IntegrityEngine, parse_integrity
from repro.runtime.serving import integrity_drill


def test_parse_integrity():
    assert parse_integrity(None) == 0
    assert parse_integrity("off") == 0
    assert parse_integrity("verify") == 1
    assert parse_integrity("scrub:4") == 4
    for bad in ("scrub:0", "scrub:-2", "scrub:x", "sometimes"):
        with pytest.raises(ValueError, match="integrity spec"):
            parse_integrity(bad)


def test_flip_bits_mask_width_guard():
    """ISSUE 9 satellite: a mask wider than the element (or empty) is an
    injector configuration bug, not a silent truncation."""
    q = jnp.zeros((3,), jnp.int8)
    for mask in (0x100, 0, -1):
        with pytest.raises(ValueError, match="mask"):
            flip_bits(q, (0,), mask)
    s = jnp.ones((2,), jnp.float32)
    with pytest.raises(ValueError, match="mask"):
        flip_bits(s, (0,), 1 << 32)
    t = jnp.ones((2,), jnp.bfloat16)
    with pytest.raises(ValueError, match="mask"):
        flip_bits(t, (0,), 1 << 16)


def test_flip_bits_f32_scale_plane_involution():
    """An exponent upset on an f32 scale plane flips exactly the
    addressed element's bits and XORs back to the original pattern —
    checked on the uint32 views, so a NaN-producing flip still
    round-trips bitwise."""
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.normal(1, .1, (2, 4, 3)), jnp.float32)
    hit = flip_bits(s, (1, 2, 0), 0x7f000000)
    b0 = np.asarray(s).view(np.uint32)
    b1 = np.asarray(hit).view(np.uint32)
    assert np.argwhere(b0 != b1).tolist() == [[1, 2, 0]]
    assert b1[1, 2, 0] == b0[1, 2, 0] ^ 0x7f000000
    back = flip_bits(hit, (1, 2, 0), 0x7f000000)
    np.testing.assert_array_equal(np.asarray(back).view(np.uint32), b0)


def test_page_checksums_detect_single_bit_flips():
    """Any single-bit upset in any of a page's four planes (int8 k/v,
    f32 k/v scales) moves exactly that (layer, page) digest — the
    position-weighted sum's odd weights are invertible mod 2**32."""
    rng = np.random.default_rng(1)
    L, P, ps, KV, HD = 2, 5, 4, 2, 8
    kp = jnp.asarray(rng.integers(-127, 128, (L, P, ps, KV, HD)), jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, (L, P, ps, KV, HD)), jnp.int8)
    ks = jnp.asarray(rng.normal(1, .1, (L, P, KV)), jnp.float32)
    vs = jnp.asarray(rng.normal(1, .1, (L, P, KV)), jnp.float32)
    ref = np.asarray(page_checksums(kp, vp, ks, vs))
    cases = [
        ("k_pages", dict(kp=flip_bits(kp, (1, 3, 0, 1, 7), 0x01)), (1, 3)),
        ("v_pages", dict(vp=flip_bits(vp, (0, 4, 2, 0, 0), 0x80)), (0, 4)),
        ("k_scale", dict(ks=flip_bits(ks, (1, 0, 1), 1 << 31)), (1, 0)),
        ("v_scale", dict(vs=flip_bits(vs, (0, 2, 0), 1 << 23)), (0, 2)),
    ]
    for name, sub, coord in cases:
        cur = np.asarray(page_checksums(sub.get("kp", kp), sub.get("vp", vp),
                                        sub.get("ks", ks), sub.get("vs", vs)))
        assert np.argwhere(cur != ref).tolist() == [list(coord)], name


def _prepared():
    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              dscim="kernel:dscim1:256")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prepared, golden = prepare_serving_params(cfg, params, golden=True)
    return cfg, params, prepared, golden


def test_weight_digest_detects_and_golden_repairs():
    """Engine sweep attributes injected plane flips to the exact
    (path, 'q'|'scale') coordinates; repair re-installs golden bytes
    bit-exactly and re-verifies clean."""
    cfg, _, prepared, golden = _prepared()
    index = weight_plane_index(prepared)
    assert len(index) > 0 and golden["index"] == index
    np.testing.assert_array_equal(
        np.asarray(weight_plane_digests(prepared)), golden["digests"])

    eng = IntegrityEngine(golden, period=2)
    assert eng.due(0) and not eng.due(1) and eng.due(2)
    assert eng.check_weights(prepared) == []

    qpath = next(p for p, w in index if w == "q")
    spath = next(p for p, w in index if w == "scale")
    inj = FailureInjector(weight_flips={
        0: ((qpath, "q", 1234, 0x20), (spath, "scale", 7, 1 << 22))})
    bad, hits = inj.corrupt_weights(0, prepared)
    assert sorted(hits) == sorted([(qpath, "q"), (spath, "scale")])
    found = eng.check_weights(bad)
    assert sorted(found) == sorted(hits)
    fixed = eng.repair_weights(bad, found)
    assert eng.check_weights(fixed) == []
    planes = {(p, w): x for p, w, x in iter_qweight_planes(fixed)}
    np.testing.assert_array_equal(np.asarray(planes[(qpath, "q")]),
                                  golden["planes"][(qpath, "q")])
    np.testing.assert_array_equal(np.asarray(planes[(spath, "scale")]),
                                  golden["planes"][(spath, "scale")])
    assert eng.counters["weight_mismatches"] == 2
    assert eng.counters["weight_repairs"] == 2
    assert eng.counters["checks"] == 0          # weight sweeps don't count
    assert eng.detections[0]["kind"] == "weight"


def test_integrity_verify_fault_free_bitwise():
    """The 'off is today's behavior / verify is free of side effects'
    contract: with no faults injected, integrity='verify' serves every
    request bitwise-identical to integrity='off' and records zero
    mismatches."""
    cfg, _, _, _ = _prepared()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(3).integers(0, cfg.vocab, (4, 8),
                                                dtype=np.int32)
    budgets = np.array([2, 4, 3, 5], np.int32)
    knobs = dict(slots=2, seg_len=2, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=4)
    out_off, st_off = serve_continuous(cfg, params, prompts, 5, **knobs)
    out_v, st_v = serve_continuous(cfg, params, prompts, 5, **knobs,
                                   integrity="verify")
    for r in range(4):
        np.testing.assert_array_equal(out_v[r], out_off[r], err_msg=str(r))
    gi = st_v["integrity"]
    assert gi["checks"] > 0 and gi["pages_verified"] > 0
    assert gi["page_mismatches"] == 0 and gi["weight_mismatches"] == 0
    assert gi["replays"] == 0
    assert st_off.get("integrity") is None


def test_integrity_drill():
    """The full ISSUE 9 acceptance scenario (page + weight flips under
    scrub:2): exact-coordinate detection, surgical repair, zero ladder
    escalations, bitwise-identical outputs — every assertion lives
    inside integrity_drill itself."""
    report = integrity_drill(log=lambda *a: None)
    leg1, leg2 = report["leg1"], report["leg2"]
    assert leg1["page_repairs"] == 2 and leg1["weight_repairs"] == 1
    assert leg1["replays"] == 0
    assert leg2["weight_repairs"] == 1 and leg2["replays"] == 1
