"""Pallas kernels vs pure-jnp oracles (interpret mode): bit-exact sweeps
over shapes, dtypes, variants — closing the chain
kernel == ref == LUT == cycle-accurate OR-MAC."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.macro import DSCIMMacro
from repro.core.seed_search import calibrated_config
from repro.kernels import ops, ref


@pytest.mark.parametrize("variant,L", [("dscim1", 256), ("dscim1", 64),
                                       ("dscim2", 64), ("dscim2", 128)])
@pytest.mark.parametrize("shape", [(4, 128, 8), (3, 100, 17), (16, 256, 32)])
def test_dscim_kernel_vs_lut(variant, L, shape):
    M, K, N = shape
    cfg = calibrated_config(variant, L, "paper")
    mac = DSCIMMacro(cfg)
    rng = np.random.default_rng(hash((variant, L, shape)) % 2 ** 31)
    x = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int32)
    want = np.asarray(mac.mvm(x, w, backend="lut"))
    got = np.asarray(ops.dscim_mvm(x.astype(jnp.int8), w.astype(jnp.int8),
                                   cfg, bm=8, bn=8, bk=4))
    np.testing.assert_allclose(got, want, atol=0.5)


def test_dscim_kernel_vs_ref_center():
    """Center-corrected variant through the kernel wrapper == ref.py."""
    cfg = calibrated_config("dscim1", 256, "opt")
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-128, 128, (5, 130)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (130, 9)), jnp.int32)
    # compare against the macro path (already cycle-validated)
    mac = DSCIMMacro(cfg)
    want = np.asarray(mac.mvm(x, w, backend="lut"))
    got = np.asarray(ops.dscim_mvm(x.astype(jnp.int8), w.astype(jnp.int8),
                                   cfg, bm=8, bn=8, bk=8))
    np.testing.assert_allclose(got, want, atol=0.5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 40), st.integers(1, 300), st.integers(1, 40),
       st.integers(0, 2 ** 31 - 1))
def test_int8_matmul_kernel_property(M, K, N, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int8)
    got = np.asarray(ops.int8_matmul(x, w, bm=16, bn=16, bk=32))
    want = np.asarray(ref.int8_matmul_ref(x, w))
    np.testing.assert_array_equal(got, want)


def test_ref_counts_vs_cycle_oracle():
    """ref.py's count formulation equals the cycle-accurate OR-MAC."""
    cfg = calibrated_config("dscim2", 64, "paper")
    mac = DSCIMMacro(cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-128, 128, (2, 128)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (128, 4)), jnp.int32)
    got = np.asarray(ref.dscim_counts_ref(
        x, w, jnp.asarray(mac.u.astype(np.int32)),
        jnp.asarray(mac.v.astype(np.int32)), cfg.k))
    want = mac.counts_cycle(x, w)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("variant,L,calib", [
    ("dscim1", 256, "paper"), ("dscim1", 256, "opt"),
    ("dscim2", 64, "paper"), ("dscim2", 128, "opt")])
def test_blocked_kernel_bit_exact(variant, L, calib):
    """Beyond-paper blocked-points kernel == LUT backend (the disjointness
    theorem says out-of-block points can never fire; §Perf cell C)."""
    from repro.kernels.dscim_mvm_blocked import dscim_counts_blocked
    cfg = calibrated_config(variant, L, calib)
    mac = DSCIMMacro(cfg)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(-128, 128, (16, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (128, 16)), jnp.int8)
    want = np.asarray(mac.counts_lut(x.astype(jnp.int32),
                                     w.astype(jnp.int32)))
    got = np.asarray(dscim_counts_blocked(x, w, cfg, bm=16, bn=16, bk=16))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", [(4, 64, 32, 16, 16), (2, 128, 64, 32, 64),
                                   (1, 96, 16, 32, 32)])
def test_flash_attention_kernel(shape):
    """Pallas causal flash attention == plain softmax oracle."""
    from repro.kernels.flash_attention import flash_attention_pallas
    BH, S, d, bq, bk = shape
    rng = np.random.default_rng(sum(shape))
    q = jnp.asarray(rng.normal(0, 1, (BH, S, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (BH, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (BH, S, d)), jnp.float32)
    got = flash_attention_pallas(q, k, v, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)
