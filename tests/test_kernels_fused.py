"""Fused batched DS-CIM MVM kernel (kernels/dscim_fused.py) vs the ``lut``
oracle: batched inputs, all quantization granularities, odd/unpadded shapes,
both calibrated macro variants, center truncation — plus the staged
vmap-per-window baseline it replaces and the tile autotuner."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dscim_layer import DSCIMLinear
from repro.core.macro import dscim1
from repro.core.seed_search import calibrated_config
from repro.kernels.dscim_fused import dscim_fused_mvm, dscim_windowed_vmap_mvm


def _assert_matches(got, want):
    """Identical estimator up to f32 summation-order rounding."""
    scale = max(float(np.abs(want).max()), 1.0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5 * scale)


def _operands(rng, shape, K, N):
    x = jnp.asarray(rng.normal(0, 1, (*shape, K)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (K, N)), jnp.float32)
    return x, w


@pytest.mark.parametrize("group_k", [None, 64, 128])
@pytest.mark.parametrize("variant,L,calib", [("dscim1", 256, "paper"),
                                             ("dscim2", 64, "paper")])
def test_fused_vs_lut_group_granularities(group_k, variant, L, calib):
    cfg = calibrated_config(variant, L, calib)
    rng = np.random.default_rng(L + cfg.k * 1000 + (group_k or 0))
    x, w = _operands(rng, (6,), 200, 24)
    want = np.asarray(DSCIMLinear(cfg, mode="lut", group_k=group_k)(x, w))
    got = np.asarray(dscim_fused_mvm(x, w, cfg, group_k=group_k))
    _assert_matches(got, want)


@pytest.mark.parametrize("shape", [(3, 100, 17), (5, 130, 9), (1, 64, 1)])
def test_fused_odd_unpadded_shapes(shape):
    M, K, N = shape
    cfg = calibrated_config("dscim1", 256, "paper")
    rng = np.random.default_rng(sum(shape))
    x, w = _operands(rng, (M,), K, N)
    want = np.asarray(DSCIMLinear(cfg, mode="lut", group_k=128)(x, w))
    got = np.asarray(dscim_fused_mvm(x, w, cfg, group_k=128))
    _assert_matches(got, want)


@pytest.mark.parametrize("lead", [(2, 3), (2, 2, 4)])
def test_fused_batched_native(lead):
    """Leading batch dims ride the batch grid axis — output matches the
    flattened lut path exactly."""
    cfg = calibrated_config("dscim2", 64, "paper")
    rng = np.random.default_rng(len(lead))
    x, w = _operands(rng, lead, 150, 20)
    want = np.asarray(DSCIMLinear(cfg, mode="lut", group_k=64)(x, w))
    got = np.asarray(dscim_fused_mvm(x, w, cfg, group_k=64))
    assert got.shape == (*lead, 20)
    _assert_matches(got, want)


def test_fused_center_truncation():
    cfg = dscim1(256, points="sobol", seed_u=0, seed_v=60, trunc="center")
    rng = np.random.default_rng(9)
    x, w = _operands(rng, (4,), 130, 11)
    want = np.asarray(DSCIMLinear(cfg, mode="lut", group_k=64)(x, w))
    got = np.asarray(dscim_fused_mvm(x, w, cfg, group_k=64))
    _assert_matches(got, want)


def test_fused_bf16_equals_f32_bits():
    """{0,1} operands are exact in bf16; f32 accumulation keeps counts exact
    — the two bit-dtype paths must agree bit-for-bit."""
    cfg = calibrated_config("dscim1", 256, "paper")
    rng = np.random.default_rng(13)
    x, w = _operands(rng, (4,), 140, 12)
    bf = np.asarray(dscim_fused_mvm(x, w, cfg, bits="bfloat16"))
    f32 = np.asarray(dscim_fused_mvm(x, w, cfg, bits="float32"))
    np.testing.assert_array_equal(bf, f32)


def test_staged_vmap_baseline_matches_lut():
    """The kept perf A/B baseline (pre-fusion staged path) stays bit-exact
    vs the lut oracle."""
    cfg = calibrated_config("dscim1", 256, "paper")
    rng = np.random.default_rng(17)
    x, w = _operands(rng, (5,), 200, 16)
    want = np.asarray(DSCIMLinear(cfg, mode="lut", group_k=128)(x, w))
    got = np.asarray(dscim_windowed_vmap_mvm(x, w, cfg, group_k=128))
    _assert_matches(got, want)


def test_kernel_mode_routes_to_fused():
    """DSCIMLinear.mode='kernel' is the fused path (same numbers)."""
    cfg = calibrated_config("dscim2", 64, "paper")
    rng = np.random.default_rng(21)
    x, w = _operands(rng, (2, 3), 100, 10)
    via_layer = np.asarray(DSCIMLinear(cfg, mode="kernel", group_k=128)(x, w))
    direct = np.asarray(dscim_fused_mvm(x, w, cfg, group_k=128))
    np.testing.assert_array_equal(via_layer, direct)


def test_autotuner_caches_and_matches():
    from repro.kernels import autotune

    autotune.clear()
    cfg = calibrated_config("dscim1", 256, "paper")
    rng = np.random.default_rng(23)
    x, w = _operands(rng, (8,), 64, 8)
    want = np.asarray(DSCIMLinear(cfg, mode="lut", group_k=64)(x, w))
    got = np.asarray(dscim_fused_mvm(x, w, cfg, group_k=64, tune=True))
    _assert_matches(got, want)
    assert len(autotune._CACHE) == 1
    # second call hits the cache (same key, no new entries)
    dscim_fused_mvm(x, w, cfg, group_k=64, tune=True)
    assert len(autotune._CACHE) == 1
