"""Int8 block-paged KV cache (core/kvcache.py) + continuous-batching
scheduler (launch/serve.py serve_continuous): page quantization round
trips, dense->paged conversion, pool byte accounting (the >=3.5x ISSUE 4
claim at the bench shape), page allocator recycling, and end-to-end
scheduler parity — every request served through staggered admission into
recycled slots must reproduce the one-shot early-exit driver bit for bit
(decode math is row-independent, so slot composition must not matter)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.kvcache import (CHECKSUM_KEY, PageAllocator,
                                admission_pages, dense_cache_bytes,
                                dequantize_page, extract_slot_pages,
                                init_paged_cache, insert_slot_pages,
                                kv_cache_bytes, n_pages_for,
                                page_checksums, paged_cache_specs,
                                paged_from_dense, quantize_page)
from repro.launch.serve import serve_batch, serve_continuous
from repro.models import get_model


def _setup(dscim="off", arch="qwen3-0.6b"):
    cfg = get_arch(arch).reduced()
    if dscim != "off":
        cfg = dataclasses.replace(cfg, dscim=dscim)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, model, params


def test_page_quant_roundtrip_error_bound():
    """Symmetric per-(page, kv-head) int8: |dequant - x| <= scale/2, and
    per-head scales isolate an outlier head from the others."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 4, 16))
    x = x.at[:, :, 2].mul(50.0)              # outlier kv head
    q, s = quantize_page(x)
    assert q.dtype == jnp.int8 and s.shape == (3, 4)
    dq = dequantize_page(q, s)
    err = np.abs(np.asarray(dq - x))
    bound = np.asarray(s)[:, None, :, None] / 2 + 1e-6
    assert (err <= bound).all()
    # the outlier head's scale is ~50x the others'; quiet heads keep
    # their resolution
    s = np.asarray(s)
    assert (s[:, 2] > 10 * s[:, [0, 1, 3]].max(1)).all()


def test_paged_from_dense_reconstructs():
    """Full pages land quantized in the pool at the page table's physical
    indices; the S % ps remainder stays in the (unquantized) tail."""
    L, B, S, KV, HD, ps = 2, 3, 11, 2, 8, 4
    ks = jax.random.normal(jax.random.PRNGKey(1), (L, B, S, KV, HD))
    vs = jax.random.normal(jax.random.PRNGKey(2), (L, B, S, KV, HD))
    cache = paged_from_dense(ks, vs, ps)
    assert np.asarray(cache["pos"]).tolist() == [S] * B
    mp = n_pages_for(S, ps)
    assert cache["page_table"].shape == (B, mp)
    nf, rem = divmod(S, ps)
    for b in range(B):
        for j in range(nf):
            phys = int(cache["page_table"][b, j])
            dq = dequantize_page(cache["k_pages"][:, phys],
                                 cache["k_scale"][:, phys])
            ref = ks[:, b, j * ps:(j + 1) * ps]
            sc = np.asarray(cache["k_scale"][:, phys])
            assert (np.abs(np.asarray(dq - ref))
                    <= sc[:, None, :, None] / 2 + 1e-6).all()
        np.testing.assert_allclose(
            np.asarray(cache["v_tail"][:, b, :rem], np.float32),
            np.asarray(vs[:, b, nf * ps:]), atol=0.05)  # bf16 tail


def test_kv_bytes_ratio_at_bench_shape():
    """The resident-bytes claim behind the ISSUE 4 acceptance row: at the
    bench shape (capacity 128, page_size 4) the paged int8 cache is
    >= 3.5x smaller than the dense float cache, and page-count capacity
    is decoupled from slots x max_len (a smaller pool allocates fine)."""
    cfg = get_arch("qwen3-0.6b").reduced()
    B, cap, ps = 4, 128, 4
    dense = dense_cache_bytes(cfg, B, cap)
    paged = kv_cache_bytes(paged_cache_specs(cfg, B, cap, ps))
    assert dense / paged >= 3.5, (dense, paged)
    half = kv_cache_bytes(paged_cache_specs(cfg, B, cap, ps,
                                            n_pages=B * n_pages_for(cap, ps)
                                            // 2))
    assert half < paged


def test_page_allocator_recycles():
    a = PageAllocator(8)
    p1 = a.alloc(3)
    p2 = a.alloc(4)
    assert len(set(p1) | set(p2)) == 7 and a.free_pages == 1
    assert a.alloc(2) is None and a.free_pages == 1   # refusal, no leak
    a.free(p1)
    p3 = a.alloc(4)   # the freed pages + the one never handed out
    assert set(p3) == set(range(8)) - set(p2)
    assert a.free_pages == 0


BUDGETS = np.array([2, 5, 3, 4, 6, 1], np.int32)


@pytest.mark.parametrize("kv", ["float", "int8"])
def test_continuous_matches_oneshot_per_request(kv):
    """End-to-end scheduler correctness: 6 requests through 3 recycled
    slots (staggered admission between 2-step segments) reproduce, per
    request, the one-shot early-exit driver run at the same slot count —
    bit for bit, because decode math is row-independent and the carries
    (cache, per-slot positions, done mask) persist across segments."""
    cfg, model, params = _setup()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (6, 8),
                                                dtype=np.int32)
    outs, stats = serve_continuous(cfg, params, prompts, 6, slots=3,
                                   seg_len=2, max_new=BUDGETS, eos_id=-1,
                                   kv=kv, page_size=4)
    assert [len(o) for o in outs] == BUDGETS.tolist()
    for r in range(6):
        ref, _ = serve_batch(cfg, params, np.tile(prompts[r:r + 1], (3, 1)),
                             6, eos_id=-1, max_new=[int(BUDGETS[r])] * 3,
                             kv=kv, page_size=4)
        np.testing.assert_array_equal(outs[r], ref[0, :BUDGETS[r]], err_msg=str(r))
    # occupancy accounting: 21 useful tokens, 6 of them prefill-sampled,
    # so 15 live decode slot-steps over however many segments ran
    assert stats["useful_tokens"] == int(BUDGETS.sum())
    assert stats["live_slot_steps"] == int(BUDGETS.sum()) - 6
    assert 0 < stats["occupancy"] < 1
    assert stats["slot_steps"] == stats["segments"] * 2 * 3


def test_continuous_eos_completion():
    """EOS-driven completion (not just budgets): requests stop at their
    first EOS and release the slot for the next admission."""
    cfg, model, params = _setup()
    prompts = np.random.default_rng(1).integers(0, cfg.vocab, (4, 8),
                                                dtype=np.int32)
    n = 6
    # pick an EOS some one-shot row emits mid-stream
    ref, _ = serve_batch(cfg, params, np.tile(prompts[0:1], (2, 1)), n)
    eos = int(ref[0, 2])
    stop0 = int(np.nonzero(ref[0] == eos)[0][0])   # first occurrence
    outs, _ = serve_continuous(cfg, params, prompts, n, slots=2, seg_len=2,
                               eos_id=eos)
    assert len(outs[0]) == stop0 + 1 and outs[0][-1] == eos
    for o in outs:
        hits = np.nonzero(o == eos)[0]
        if len(hits):
            assert hits[0] == len(o) - 1      # stops right at first EOS
        else:
            assert len(o) == n                # or runs out its budget


def test_page_allocator_exhaustion_backpressure_reuse():
    """ISSUE 5 edge cases: exhaustion refuses without leaking, repeated
    refusals are stable (backpressure can poll), and an admit after a
    recycle hands out exactly the freed pages — ids cross slots freely."""
    a = PageAllocator(6)
    g1, g2 = a.alloc(2), a.alloc(4)
    assert a.free_pages == 0
    for _ in range(3):                       # polling while full is safe
        assert a.alloc(1) is None
    assert a.free_pages == 0
    a.free(g2)
    g3 = a.alloc(4)                          # admit-after-recycle
    assert set(g3) == set(g2)                # reuses exactly the freed ids
    a.free(g1)
    a.free(g3)
    assert a.free_pages == 6
    assert set(a.alloc(6)) == set(range(6))  # nothing leaked or duplicated
    assert a.alloc(1) is None


@pytest.mark.parametrize("path", ["jnp", "kernel"])
def test_done_slot_flush_never_writes_recycled_page(path, monkeypatch):
    """A done slot at a would-flush position (pos+1 page boundary) must not
    scatter its stale tail into a pool page — the allocator may already
    have granted that physical page to a newly admitted request.  Checked
    on both read paths (the flush is shared jnp code, but the regression
    would corrupt whichever path serves next)."""
    import jax.numpy as jnp

    from repro.layers.attention import decode_attention_paged, init_attention

    monkeypatch.setenv("REPRO_PAGED_ATTN", path)
    cfg = get_arch("qwen3-0.6b").reduced()
    B, ps, MP = 2, 4, 2
    KV, HD = cfg.n_kv, cfg.head_dim
    rng = np.random.default_rng(0)
    P = B * MP
    view = {
        "k_pages": jnp.asarray(rng.integers(-127, 128, (P, ps, KV, HD)),
                               jnp.int8),
        "v_pages": jnp.asarray(rng.integers(-127, 128, (P, ps, KV, HD)),
                               jnp.int8),
        "k_scale": jnp.ones((P, KV), jnp.float32),
        "v_scale": jnp.ones((P, KV), jnp.float32),
        "k_tail": jnp.asarray(rng.normal(0, 1, (B, ps, KV, HD)),
                              jnp.bfloat16),
        "v_tail": jnp.asarray(rng.normal(0, 1, (B, ps, KV, HD)),
                              jnp.bfloat16),
        # slot 0 (done) still *references* page 1; the scheduler has
        # recycled it to slot 1, which maps it as its own second page
        "page_table": jnp.asarray([[0, 1], [2, 1]], jnp.int32),
        "pos": jnp.asarray([2 * ps - 1, ps + 1], jnp.int32),
    }
    params = init_attention(jax.random.PRNGKey(0), cfg.d_model, cfg.n_heads,
                            KV, HD, cfg.qk_norm)
    x = jnp.asarray(rng.normal(0, 1, (B, 1, cfg.d_model)), jnp.float32)
    done = jnp.asarray([True, False])
    _, planes = decode_attention_paged(params, x, view, cfg, done=done,
                                       par=None)
    k_pages_new = planes[0]
    # slot 0 sits at pos 2*ps-1: live, it would flush its tail into
    # physical page 1 this step — done, it must not touch it
    np.testing.assert_array_equal(np.asarray(k_pages_new[1]),
                                  np.asarray(view["k_pages"][1]))
    # the live slot's state is untouched by the dead slot's masking: its
    # pages did not flush either (pos ps+1 is mid-page)
    np.testing.assert_array_equal(np.asarray(k_pages_new),
                                  np.asarray(view["k_pages"]))
    # control: the same state with slot 0 live *does* flush page 1
    _, planes_live = decode_attention_paged(params, x, view, cfg,
                                            done=jnp.asarray([False, False]),
                                            par=None)
    assert (np.asarray(planes_live[0][1])
            != np.asarray(view["k_pages"][1])).any()


def test_continuous_small_page_pool_backpressure():
    """An undersized page pool delays admission instead of corrupting
    state: with pages for only ~2 concurrent sequences, 4 requests still
    complete correctly through 3 slots (slots idle while the pool is
    full), and an impossible pool raises."""
    cfg, model, params = _setup()
    prompts = np.random.default_rng(2).integers(0, cfg.vocab, (4, 8),
                                                dtype=np.int32)
    budgets = np.array([3, 4, 2, 3], np.int32)
    mp = n_pages_for(8 + 4, 4)
    outs, stats = serve_continuous(cfg, params, prompts, 4, slots=3,
                                   seg_len=2, max_new=budgets, eos_id=-1,
                                   kv="int8", page_size=4, n_pages=2 * mp)
    assert [len(o) for o in outs] == budgets.tolist()
    ref_outs, _ = serve_continuous(cfg, params, prompts, 4, slots=3,
                                   seg_len=2, max_new=budgets, eos_id=-1,
                                   kv="int8", page_size=4)
    for a, b in zip(outs, ref_outs):
        np.testing.assert_array_equal(a, b)
    with pytest.raises(RuntimeError):
        serve_continuous(cfg, params, prompts, 4, slots=3, seg_len=2,
                         max_new=budgets, eos_id=-1, kv="int8",
                         page_size=4, n_pages=mp - 1)


def test_allocator_and_admission_guards():
    """ISSUE 9 satellite: zero/negative grants and nonsense admission
    parameters raise at the call site instead of corrupting the pool
    three segments later."""
    a = PageAllocator(4)
    for n in (0, -2):
        with pytest.raises(ValueError, match="positive"):
            a.alloc(n)
    assert a.free_pages == 4                  # guard left the pool intact
    assert a.alloc(4) is not None
    for ps in (0, -4):
        with pytest.raises(ValueError, match="page_size"):
            admission_pages(8, 4, ps)
    for budget in (0, -1):
        with pytest.raises(ValueError, match="budget"):
            admission_pages(8, budget, 4)
    with pytest.raises(ValueError, match="prompt_len/headroom"):
        admission_pages(-1, 4, 4)
    with pytest.raises(ValueError, match="prompt_len/headroom"):
        admission_pages(8, 4, 4, headroom=-1)
    assert admission_pages(7, 4, 4, headroom=2) == n_pages_for(13, 4)


@pytest.mark.parametrize("ps", [4, 8, 16])
def test_slot_page_roundtrip_property(ps):
    """ISSUE 9 satellite: extract -> insert -> extract is the identity on
    a slot's blob across page sizes, ragged positions, and permuted
    page-table layouts — bitwise, including the digest plane's warranty
    on the re-granted pages."""
    L, B, KV, HD, mp = 2, 3, 2, 4, 4
    P = B * mp
    rng = np.random.default_rng(ps)

    def scrambled_cache(perm):
        cache = init_paged_cache(L, B, P, ps, mp, KV, HD, integrity=True)
        grants, poses, off = [], [], 0
        for b in range(B):
            g = int(rng.integers(1, mp + 1))
            grants.append([int(i) for i in perm[off:off + g]])
            off += g
            # ragged: anywhere from 1 token to every granted page flushed
            poses.append(int(rng.integers(1, g * ps + 1)))
        rows = [ids + [ids[-1]] * (mp - len(ids)) for ids in grants]
        cache = dict(
            cache,
            k_pages=jnp.asarray(rng.integers(-127, 128, (L, P, ps, KV, HD)),
                                jnp.int8),
            v_pages=jnp.asarray(rng.integers(-127, 128, (L, P, ps, KV, HD)),
                                jnp.int8),
            k_scale=jnp.asarray(rng.normal(1, .1, (L, P, KV)), jnp.float32),
            v_scale=jnp.asarray(rng.normal(1, .1, (L, P, KV)), jnp.float32),
            k_tail=jnp.asarray(rng.normal(0, 1, (L, B, ps, KV, HD)),
                               jnp.bfloat16),
            v_tail=jnp.asarray(rng.normal(0, 1, (L, B, ps, KV, HD)),
                               jnp.bfloat16),
            page_table=jnp.asarray(rows, jnp.int32),
            pos=jnp.asarray(poses, jnp.int32))
        cache = dict(cache, **{CHECKSUM_KEY: page_checksums(
            cache["k_pages"], cache["v_pages"],
            cache["k_scale"], cache["v_scale"])})
        return cache, grants

    src, src_grants = scrambled_cache(rng.permutation(P))
    dst, _ = scrambled_cache(rng.permutation(P))
    new_perm = rng.permutation(P)
    off = 0
    for b in range(B):
        blob = extract_slot_pages(src, b, src_grants[b])
        b2 = (b + 1) % B                      # different slot on insert
        ids2 = [int(i) for i in new_perm[off:off + blob["page_count"]]]
        off += blob["page_count"]
        dst = insert_slot_pages(dst, b2, ids2, blob)
        blob2 = extract_slot_pages(dst, b2, ids2)
        assert blob2["page_count"] == blob["page_count"]
        assert blob2["pos"] == blob["pos"]
        for key in ("k_pages", "v_pages", "k_scale", "v_scale",
                    "k_tail", "v_tail"):
            np.testing.assert_array_equal(blob2[key], blob[key],
                                          err_msg=f"{key} slot {b}")
        # the digest plane follows the insert: stored sums on the
        # re-granted pages match a fresh recompute (warranty holds)
        fresh = np.asarray(page_checksums(
            dst["k_pages"], dst["v_pages"], dst["k_scale"], dst["v_scale"]))
        np.testing.assert_array_equal(
            np.asarray(dst[CHECKSUM_KEY])[:, ids2], fresh[:, ids2])
