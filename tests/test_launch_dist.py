"""Distribution machinery tests on a small fake-device mesh (subprocess —
the main test process must keep 1 CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_mesh_and_sharding_rules():
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh, make_parallel_ctx
        from repro.launch.sharding import param_specs, cache_partition
        from repro.configs import ARCHS
        from repro.models import get_model
        from jax.sharding import PartitionSpec as P
        mesh = make_debug_mesh(2, 2, pod=2)
        par = make_parallel_ctx(mesh)
        assert par.dp_axes == ("pod", "data")
        cfg = ARCHS["qwen3-0.6b"]
        mod = get_model(cfg)
        ps = jax.eval_shape(lambda k: mod.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = param_specs(cfg, par, ps)
        assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
        assert specs["layers"]["attn"]["wo"] == P(None, "model", "data")
        assert specs["embed"] == P("model", "data")
        cs = mod.cache_specs(cfg, 8, 64)
        cp = cache_partition(cfg, par, cs)
        assert cp["k"][1] == ("pod", "data") and cp["k"][3] == "model"
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_tiny_distributed_train_step_compiles_and_runs():
    """A real (executed, not just lowered) distributed train step on a 2x2
    mesh with FSDP+TP shardings — validates the whole pjit path numerically
    against the single-device step."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.launch.mesh import make_debug_mesh, make_parallel_ctx
        from repro.launch.sharding import (param_specs, opt_state_specs,
                                           batch_specs, to_shardings)
        from repro.launch.steps import make_train_step
        from repro.models import get_model
        from repro.optim.adamw import AdamW
        cfg = ARCHS["olmo-1b"].reduced()
        mod = get_model(cfg)
        opt = AdamW(lr=1e-3)
        params = mod.init_params(cfg, jax.random.PRNGKey(0))
        ostate = opt.init(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                              0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16),
                                              0, cfg.vocab)}
        # single-device reference
        ref_step = jax.jit(make_train_step(cfg, None, opt))
        _, _, m_ref = ref_step(params, ostate, batch)
        # distributed
        mesh = make_debug_mesh(2, 2)
        par = make_parallel_ctx(mesh)
        specs = param_specs(cfg, par, params)
        psh = to_shardings(mesh, specs)
        osh = to_shardings(mesh, opt_state_specs(specs))
        bsh = to_shardings(mesh, batch_specs(cfg, par, batch))
        pd = jax.device_put(params, psh)
        od = jax.device_put(ostate, osh)
        bd = jax.device_put(batch, bsh)
        dist_step = jax.jit(make_train_step(cfg, par, opt),
                            in_shardings=(psh, osh, bsh),
                            out_shardings=(psh, osh, None))
        _, _, m_dist = dist_step(pd, od, bd)
        np.testing.assert_allclose(float(m_ref["loss"]),
                                   float(m_dist["loss"]), rtol=2e-3)
        print("OK", float(m_ref["loss"]), float(m_dist["loss"]))
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_moe_ep_shard_map_numerics():
    """shard_map EP MoE == local MoE on the same inputs (2-way EP)."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.layers.moe import init_moe, moe, moe_local
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(2, 2)
        key = jax.random.PRNGKey(0)
        p = init_moe(key, 16, 32, n_experts=4, top_k=2, n_shared=1)
        x = jax.random.normal(key, (4, 8, 16))
        ref, _ = moe_local(p, x, top_k=2, capacity_factor=8.0,
                           has_shared=True)
        def inner(p_, x_):
            out, aux = moe(p_, x_, top_k=2, capacity_factor=8.0,
                           ep_axis="model", has_shared=True)
            return out
        from repro.parallel import shard_map
        f = shard_map(inner, mesh=mesh,
            in_specs=({"router": P(None, None),
                       "experts": {"w_gate": P("model", None, None),
                                   "w_up": P("model", None, None),
                                   "w_down": P("model", None, None)},
                       "shared": {"w_gate": P(None, None),
                                  "w_up": P(None, None),
                                  "w_down": P(None, None)}},
                      P("data", None, None)),
            out_specs=P("data", None, None))
        got = f(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_hlo_cost_analyzer_loop_exactness():
    """Loop-aware analyzer reproduces analytic dot flops through a scan."""
    r = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch.hlo_cost import analyze_hlo
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(2, 2)
        D, F, L, B = 64, 128, 5, 16
        def f(w1, w2, x):
            def body(h, ws):
                a, b = ws
                return jax.nn.gelu(h @ a) @ b, None
            h, _ = jax.lax.scan(body, x, (w1, w2))
            return h.sum()
        import jax.numpy as jnp
        w1 = jax.ShapeDtypeStruct((L, D, F), jnp.float32)
        w2 = jax.ShapeDtypeStruct((L, F, D), jnp.float32)
        x = jax.ShapeDtypeStruct((B, D), jnp.float32)
        sh = (jax.NamedSharding(mesh, P(None, "data", "model")),
              jax.NamedSharding(mesh, P(None, "model", "data")),
              jax.NamedSharding(mesh, P("data", None)))
        c = jax.jit(f, in_shardings=sh).lower(w1, w2, x).compile()
        cost = analyze_hlo(c.as_text())
        analytic = 2 * (2.0 * B * D * F) * L / 4   # fwd only, per device
        assert abs(cost.flops / analytic - 1) < 0.05, (cost.flops, analytic)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_pipeline_parallel_compiles():
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.pipeline import pp_dryrun
        rec = pp_dryrun(d_model=256, d_ff=512, layers_per_stage=2,
                        microbatches=4, mb_size=1, seq=64)
        assert rec["ok"] and rec["collective_permutes"] > 0
        print("OK", rec)
    """, devices=512, timeout=560)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_dscim_nsharded_prepared_mvm_matches_single_device():
    """ROADMAP sharding item: the prepared weight's output columns tile over
    the 'model' axis (x broadcasts, windows stay local on K) — the sharded
    fused MVM must be bit-identical to the single-device prepared path."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.qweights import prepare_linear_weight
        from repro.core.seed_search import calibrated_config
        from repro.kernels.dscim_fused import (dscim_fused_mvm_prepared,
                                               dscim_fused_mvm_sharded)
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(2, 4)
        cfg = calibrated_config("dscim2", 64, "paper")
        rng = np.random.default_rng(0)
        for shape, gk in (((3, 130), 64), ((2, 5, 100), 128)):
            x = jnp.asarray(rng.normal(0, 1, (*shape,)), jnp.float32)
            w = jnp.asarray(rng.normal(0, 1, (shape[-1], 32)), jnp.float32)
            qw = prepare_linear_weight(w, gk)
            ref = dscim_fused_mvm_prepared(x, qw, cfg)
            got = dscim_fused_mvm_sharded(x, qw, cfg, mesh, axis="model")
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
            # batch additionally sharded over DP (or replicated when the
            # leading dim doesn't divide) — still bitwise
            got_b = dscim_fused_mvm_sharded(x, qw, cfg, mesh, axis="model",
                                            batch_axes=("data",))
            np.testing.assert_array_equal(np.asarray(got_b), np.asarray(ref))
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_param_specs_quantized_subtree():
    """Prepared params get the N-over-'model' rule: q (L, nw, g, N) and
    scale (L, nw, N) both shard their trailing dim; window dims stay local;
    to_shardings descends the QuantizedLinearWeight spec subtree."""
    r = _run("""
        import dataclasses
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import ARCHS
        from repro.core.qweights import QuantizedLinearWeight
        from repro.launch.mesh import make_debug_mesh, make_parallel_ctx
        from repro.launch.sharding import param_specs, to_shardings
        from repro.launch.steps import prepare_serving_params
        from repro.models import get_model
        cfg = dataclasses.replace(ARCHS["qwen3-0.6b"].reduced(),
                                  dscim="kernel:dscim1:256")
        mod = get_model(cfg)
        params = mod.init_params(cfg, jax.random.PRNGKey(0))
        pp = prepare_serving_params(cfg, params)
        par = make_parallel_ctx(make_debug_mesh(2, 2))
        specs = param_specs(cfg, par, pp)
        up = specs["layers"]["mlp"]["w_up"]
        assert isinstance(up, QuantizedLinearWeight), type(up)
        assert up.q == P(None, None, None, "model"), up.q
        assert up.scale == P(None, None, "model"), up.scale
        head = specs["lm_head"]
        assert head.q == P(None, None, "model") and \
            head.scale == P(None, "model"), (head.q, head.scale)
        # float params keep their rules
        assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
        sh = to_shardings(par.mesh, specs)
        assert sh["layers"]["mlp"]["w_up"].q.spec == up.q
        jax.device_put(pp, sh)  # placement actually works
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_mesh_serve_scanned_parity():
    """ISSUE 3 acceptance: serve_batch under a 'model' mesh with prepared
    N-sharded qweights, whole scanned generation loop inside one jit —
    greedy tokens bit-identical to single-device serving and prefill logits
    equal to float tolerance (the DS-CIM MVMs themselves are bitwise — see
    test_dscim_nsharded_prepared_mvm_matches_single_device — but XLA's CPU
    dot blocking differs per shard width for the float attention matmuls,
    so full-stack logits land within reduction-order noise)."""
    r = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch
        from repro.launch.mesh import parallel_ctx_from_spec
        from repro.launch.serve import serve_batch
        from repro.models import get_model
        cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                                  dscim="kernel:dscim1:256")
        model = get_model(cfg)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab, (4, 8), dtype=np.int32)
        ref_t, ref_l = serve_batch(cfg, params, prompts, 6)
        par = parallel_ctx_from_spec("model=4")
        got_t, got_l = serve_batch(cfg, params, prompts, 6, par=par)
        np.testing.assert_array_equal(ref_t, got_t)
        np.testing.assert_allclose(np.asarray(ref_l[0]),
                                   np.asarray(got_l[0]), atol=1e-5)
        # data x model mesh too (batch shards over 'data')
        par2 = parallel_ctx_from_spec("data=2,model=4")
        got2_t, got2_l = serve_batch(cfg, params, prompts, 6, par=par2)
        np.testing.assert_array_equal(ref_t, got2_t)
        np.testing.assert_allclose(np.asarray(ref_l[0]),
                                   np.asarray(got2_l[0]), atol=1e-5)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_moe_prepared_shared_expert_under_mesh():
    """Closes the ROADMAP guard note in models/lm.py: a prepared (resident
    int8) MoE shared expert now serves under a mesh — its planes replicate
    (launch/sharding.py) and the shard_map MoE body computes it locally via
    the DS-CIM linear, matching single-device serving."""
    r = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.core.qweights import QuantizedLinearWeight
        from repro.launch.mesh import parallel_ctx_from_spec
        from repro.launch.serve import serve_batch
        from repro.launch.sharding import param_specs
        from repro.launch.steps import prepare_serving_params
        from repro.models import get_model
        cfg = dataclasses.replace(get_arch("deepseek-moe-16b").reduced(),
                                  dscim="exact:dscim2:64")
        model = get_model(cfg)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        par = parallel_ctx_from_spec("data=2,model=4")
        pp = prepare_serving_params(cfg, params, par)
        sh = pp["layers"]["moe"]["shared"]["w_gate"]
        assert isinstance(sh, QuantizedLinearWeight), type(sh)
        # the prepared shared expert replicates; routed experts keep EP/FSDP
        specs = param_specs(cfg, par, pp)
        sspec = specs["layers"]["moe"]["shared"]["w_gate"]
        assert sspec.q == P(None, None, None, None), sspec.q
        assert specs["layers"]["moe"]["experts"]["w_gate"] == \\
            P(None, "model", None, "data")
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab, (4, 8), dtype=np.int32)
        ref_t, ref_l = serve_batch(cfg, params, prompts, 5)
        got_t, got_l = serve_batch(cfg, params, prompts, 5, par=par)
        np.testing.assert_array_equal(ref_t, got_t)
        np.testing.assert_allclose(np.asarray(ref_l[0]),
                                   np.asarray(got_l[0]), atol=1e-5)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_paged_kv_serve_under_mesh():
    """ISSUE 4: the int8 block-paged KV cache serves under a mesh — the
    page pool / scales / tails / page table get DP-aligned specs from
    cache_partition (the pool shards over the DP axes like the request
    batch; slot-major allocation keeps a slot's pages on its own shard),
    and serve_batch(kv='int8') under model=4 and data=2,model=4 meshes
    reproduces single-device paged serving token for token (prefill
    logits to float tolerance, as in the dense mesh parity test)."""
    r = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.core.kvcache import paged_cache_specs
        from repro.launch.mesh import parallel_ctx_from_spec
        from repro.launch.serve import serve_batch
        from repro.launch.sharding import cache_partition
        from repro.models import get_model
        cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                                  dscim="kernel:dscim1:256")
        par2 = parallel_ctx_from_spec("data=2,model=4")
        cs = paged_cache_specs(cfg, 4, 32, 4)
        cp = cache_partition(cfg, par2, cs)
        assert cp["k_pages"][1] == ("data",) and \\
            cp["k_pages"][2:] == (None, None, None), cp
        assert cp["k_scale"][1] == ("data",), cp
        assert cp["v_tail"][1] == ("data",), cp
        assert cp["page_table"][0] == ("data",), cp
        assert cp["pos"][0] == ("data",), cp
        model = get_model(cfg)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab, (4, 8), dtype=np.int32)
        ref_t, ref_l = serve_batch(cfg, params, prompts, 6, kv="int8",
                                   page_size=4)
        for spec in ("model=4", "data=2,model=4"):
            par = parallel_ctx_from_spec(spec)
            got_t, got_l = serve_batch(cfg, params, prompts, 6, kv="int8",
                                       page_size=4, par=par)
            np.testing.assert_array_equal(ref_t, got_t)
            np.testing.assert_allclose(np.asarray(ref_l[0]),
                                       np.asarray(got_l[0]), atol=1e-5)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_paged_attention_kernel_under_mesh():
    """ISSUE 5 acceptance: the Pallas paged-attention read path serves
    under an 8-fake-device --mesh model=4 (shard_map placement,
    kernels/paged_attention.py ``paged_attention_decode_sharded``) with
    tokens bitwise-equal and the full logit trace within 1e-5 of the jnp
    gather reference under the same mesh; vs the single-device kernel
    path, tokens are bitwise-equal and prefill logits within 1e-5 (the
    full-trace cross-placement comparison is looser for the same reason
    as the dense mesh parity test — XLA CPU dot blocking differs per
    shard width in float attention, and fed-back steps accumulate it).
    The data=2,model=4 mesh additionally exercises the DP-sharded batch +
    gathered-pool in_specs."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch
        from repro.launch.mesh import parallel_ctx_from_spec
        from repro.launch.serve import serve_batch
        from repro.models import get_model
        cfg = get_arch("qwen3-0.6b").reduced()
        model = get_model(cfg)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab, (4, 8), dtype=np.int32)

        def run(path, par):
            return serve_batch(cfg, params, prompts, 6, kv="int8",
                               page_size=4, trace_logits=True,
                               prepare=False, par=par, paged_attn=path)

        ref_t, ref_l = run("kernel", None)
        for spec in ("model=4", "data=2,model=4"):
            par = parallel_ctx_from_spec(spec)
            kt, kl = run("kernel", par)
            jt, jl = run("jnp", par)
            np.testing.assert_array_equal(kt, ref_t)
            np.testing.assert_array_equal(kt, jt)
            np.testing.assert_allclose(np.stack(kl), np.stack(jl),
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(kl[0]),
                                       np.asarray(ref_l[0]), atol=1e-5)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_elastic_mesh_from_env():
    r = _run("""
        import os
        os.environ["REPRO_MESH"] = "d2x4"
        from repro.runtime.elastic import mesh_from_env
        m = mesh_from_env()
        assert m.shape == {"data": 2, "model": 4}, m.shape
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]


def test_elastic_pod_spec_degrades_on_small_host():
    """REPRO_MESH=pod16x16 on an 8-device CI host must warn and fall back
    to the largest supported debug mesh instead of raising (ISSUE 6)."""
    r = _run("""
        import os, warnings
        os.environ["REPRO_MESH"] = "pod16x16"
        from repro.runtime.elastic import mesh_from_env
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            m = mesh_from_env()
        assert m.shape == {"data": 1, "model": 8}, m.shape
        msgs = [str(x.message) for x in w
                if issubclass(x.category, RuntimeWarning)]
        assert any("pod16x16" in s and "degrading" in s for s in msgs), msgs

        # the pod default (no env var) degrades the same way
        del os.environ["REPRO_MESH"]
        with warnings.catch_warnings(record=True) as w2:
            warnings.simplefilter("always")
            m2 = mesh_from_env()
        assert m2.shape == {"data": 1, "model": 8}, m2.shape

        # explicit debug specs still raise when oversubscribed
        os.environ["REPRO_MESH"] = "d4x4"
        try:
            mesh_from_env()
        except Exception:
            pass
        else:
            raise AssertionError("d4x4 on 8 devices should raise")
        print("OK")
    """)
    assert "OK" in r.stdout, r.stderr[-3000:]
