"""Per-arch smoke tests (reduced configs) + decode==prefill consistency +
MoE invariants + DS-CIM serving path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import get_model
from repro.models.lm import lm_loss

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    if cfg.stub_frontend:
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.float32),
                "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward(name):
    """One forward step on the reduced config: shapes + finiteness."""
    cfg = ARCHS[name].reduced()
    mod = get_model(cfg)
    params = mod.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = mod.forward(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(lm_loss(logits, batch["labels"])))


@pytest.mark.parametrize("name", ["olmo-1b", "deepseek-moe-16b", "rwkv6-7b",
                                  "zamba2-7b"])
def test_arch_smoke_grad(name):
    """Representative per-family gradient check (finite, nonzero)."""
    cfg = ARCHS[name].reduced()
    mod = get_model(cfg)
    params = mod.init_params(cfg, KEY)
    batch = _batch(cfg)

    def loss_fn(p):
        lg, aux = mod.forward(p, cfg, batch)
        return lm_loss(lg, batch["labels"]) + 0.01 * aux

    g = jax.grad(loss_fn)(params)
    gnorm = float(jnp.sqrt(sum(jnp.vdot(x, x)
                               for x in jax.tree.leaves(g)).real))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ["qwen3-0.6b", "rwkv6-7b", "zamba2-7b",
                                  "granite-moe-1b-a400m"])
def test_decode_matches_prefill(name):
    """Token-by-token decode logits == full-sequence forward logits —
    KV-cache / recurrent-state correctness across all families."""
    cfg = dataclasses.replace(ARCHS[name].reduced(), remat=False)
    mod = get_model(cfg)
    params = mod.init_params(cfg, KEY)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full_logits, _ = mod.forward(params, cfg, {"tokens": toks})
    # prefill on the first token (cache capacity S), then decode stepwise
    lg, cache = mod.prefill(params, cfg, {"tokens": toks[:, :1]},
                            capacity=S)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, 0]),
                               atol=2e-2, rtol=1e-2)
    for t in range(1, S):
        lg, cache = mod.decode(params, cfg, {"token": toks[:, t]}, cache)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, t]),
            atol=2e-2, rtol=1e-2)


def test_moe_routing_invariants():
    from repro.layers.moe import init_moe, moe_local, _route
    p = init_moe(KEY, 32, 64, n_experts=8, top_k=2, n_shared=1)
    x = jax.random.normal(KEY, (2, 8, 32))
    out, aux = moe_local(p, x, top_k=2, has_shared=True)
    assert out.shape == x.shape and np.isfinite(np.asarray(out)).all()
    ids, weights, _ = _route(x.reshape(-1, 32), p["router"], 2)
    w = np.asarray(weights)
    assert (w >= 0).all()
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_bounded():
    """With capacity_factor >= E/topk (full capacity), output must equal the
    dense gather reference; with tiny capacity, output is damped not NaN."""
    from repro.layers.moe import init_moe, moe
    p = init_moe(KEY, 16, 32, n_experts=4, top_k=1, n_shared=0)
    x = jax.random.normal(KEY, (1, 8, 16))
    full, _ = moe(p, x, top_k=1, capacity_factor=8.0, ep_axis=None)
    tiny, _ = moe(p, x, top_k=1, capacity_factor=0.25, ep_axis=None)
    assert np.isfinite(np.asarray(tiny)).all()
    assert float(jnp.abs(tiny).sum()) <= float(jnp.abs(full).sum()) + 1e-4


def test_dscim_serving_path_runs():
    cfg = dataclasses.replace(ARCHS["qwen3-0.6b"].reduced(),
                              dscim="paper_inject:dscim1:256")
    mod = get_model(cfg)
    params = mod.init_params(cfg, KEY)
    logits, _ = mod.forward(params, cfg, _batch(cfg))
    assert np.isfinite(np.asarray(logits)).all()


def test_tied_embeddings():
    cfg = ARCHS["qwen3-0.6b"].reduced()
    mod = get_model(cfg)
    params = mod.init_params(cfg, KEY)
    assert "lm_head" not in params  # tied
