"""Fused Pallas paged-attention decode kernel (kernels/paged_attention.py)
vs the jnp gather reference (layers/attention.py ``_paged_read_jnp``):
kernel-level parity across page sizes {4, 8, 16}, GQA and MHA geometries,
ragged positions and arbitrary page-table permutations; model-level logit
parity through ``decode_attention_paged`` (pinned via the cache-keyed
``paged_attn`` serve option; the dscim-mode default-on selection and its
``REPRO_PAGED_ATTN`` env override are covered separately); done-masked
ragged serving equality; and the autotune plumbing (checked-in winners
for the serving shapes, candidate validity)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.kernels.paged_attention import (paged_attention_decode,
                                           use_paged_kernel)
from repro.layers.attention import _paged_read_jnp
from repro.models import get_model


def _rand_paged(rng, B, KV, R, HD, ps, MP, extra_pages=2):
    """Random pool + permuted table + ragged positions, pool larger than
    the table needs (untouched pages must stay untouched)."""
    P = B * MP + extra_pages
    view = {
        "k_pages": jnp.asarray(rng.integers(-127, 128, (P, ps, KV, HD)),
                               jnp.int8),
        "v_pages": jnp.asarray(rng.integers(-127, 128, (P, ps, KV, HD)),
                               jnp.int8),
        "k_scale": jnp.asarray(rng.uniform(0.005, 0.02, (P, KV)),
                               jnp.float32),
        "v_scale": jnp.asarray(rng.uniform(0.005, 0.02, (P, KV)),
                               jnp.float32),
        "page_table": jnp.asarray(
            rng.permutation(P)[:B * MP].reshape(B, MP), jnp.int32),
        "pos": jnp.asarray(rng.integers(0, MP * ps, (B,)), jnp.int32),
    }
    kt = jnp.asarray(rng.normal(0, 1, (B, ps, KV, HD)), jnp.bfloat16)
    vt = jnp.asarray(rng.normal(0, 1, (B, ps, KV, HD)), jnp.bfloat16)
    q = jnp.asarray(rng.normal(0, 1, (B, KV, R, HD)), jnp.float32)
    return q, view, kt, vt


@pytest.mark.parametrize("ps", [4, 8, 16])
@pytest.mark.parametrize("KV,R,HD", [(2, 2, 16),   # GQA (the serve config)
                                     (4, 1, 8)])   # MHA (n_rep = 1)
def test_kernel_matches_jnp_reference(ps, KV, R, HD):
    """Every (page size, geometry, cell tiling) combination agrees with
    the jnp reference scan to float-accumulation tolerance on random
    pools with permuted page tables and ragged per-slot positions."""
    rng = np.random.default_rng(ps * 100 + KV)
    B, MP = 3, 3
    q, view, kt, vt = _rand_paged(rng, B, KV, R, HD, ps, MP)
    ref = _paged_read_jnp(q, view, kt, vt)
    for gh in [g for g in (1, 2, 4) if KV % g == 0]:
        for qp in sorted({R, 8}):
            out = paged_attention_decode(
                q, view["k_pages"], view["v_pages"], view["k_scale"],
                view["v_scale"], kt, vt, view["page_table"], view["pos"],
                gh=gh, qp=qp, interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=1e-6, err_msg=f"gh={gh} qp={qp}")


def test_kernel_edge_positions():
    """pos pinned to the page boundaries the masking must get right:
    0 (only the tail's first token), ps-1 (exactly one full logical page
    worth in the tail), ps (first token of page 1), MP*ps-1 (last valid)."""
    KV, R, HD, ps, MP = 2, 2, 16, 4, 3
    rng = np.random.default_rng(7)
    q, view, kt, vt = _rand_paged(rng, 4, KV, R, HD, ps, MP)
    view["pos"] = jnp.asarray([0, ps - 1, ps, MP * ps - 1], jnp.int32)
    ref = _paged_read_jnp(q, view, kt, vt)
    out = paged_attention_decode(
        q, view["k_pages"], view["v_pages"], view["k_scale"],
        view["v_scale"], kt, vt, view["page_table"], view["pos"],
        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def _serve_logits(cfg, params, prompts, n_tokens, path, **kw):
    """serve_batch trace under a pinned read path — ``paged_attn`` is part
    of the jitted builder's cache key, so back-to-back A/Bs are safe."""
    from repro.launch.serve import serve_batch
    return serve_batch(cfg, params, prompts, n_tokens, trace_logits=True,
                       prepare=False, kv="int8", paged_attn=path, **kw)


@pytest.mark.parametrize("ps", [4, 8, 16])
def test_serve_logits_parity_across_page_sizes(ps):
    """Model-level acceptance: the kernel read path reproduces the jnp
    path's full per-step logit trace to <= 1e-5 through
    decode_attention_paged (tail writes, flushes and the layer scan
    included), at every supported page size."""
    cfg = get_arch("qwen3-0.6b").reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (3, 8),
                                                dtype=np.int32)
    tk, lk = _serve_logits(cfg, params, prompts, 8, "kernel", page_size=ps)
    tj, lj = _serve_logits(cfg, params, prompts, 8, "jnp", page_size=ps)
    np.testing.assert_array_equal(tk, tj)
    np.testing.assert_allclose(np.stack(lk), np.stack(lj), atol=1e-5)


def test_serve_logits_parity_mha():
    """MHA geometry (n_kv == n_heads, n_rep == 1) through the model."""
    cfg = get_arch("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, n_kv=cfg.n_heads)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    prompts = np.random.default_rng(1).integers(0, cfg.vocab, (2, 8),
                                                dtype=np.int32)
    tk, lk = _serve_logits(cfg, params, prompts, 6, "kernel", page_size=4)
    tj, lj = _serve_logits(cfg, params, prompts, 6, "jnp", page_size=4)
    np.testing.assert_array_equal(tk, tj)
    np.testing.assert_allclose(np.stack(lk), np.stack(lj), atol=1e-5)


def test_serve_ragged_done_masked_parity():
    """Ragged/done-masked serving (EOS early-exit with skewed per-slot
    budgets): the kernel path's tokens match the jnp path's bit for bit —
    frozen positions on finished slots mask identically in-kernel."""
    from repro.launch.serve import serve_batch
    cfg = get_arch("qwen3-0.6b").reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(2).integers(0, cfg.vocab, (4, 8),
                                                dtype=np.int32)
    out = {path: serve_batch(cfg, params, prompts, 8, kv="int8",
                             page_size=4, eos_id=-1, max_new=[2, 8, 5, 3],
                             paged_attn=path)[0]
           for path in ("kernel", "jnp")}
    np.testing.assert_array_equal(out["kernel"], out["jnp"])


def test_continuous_paged_attn_paths_agree():
    """The continuous-batching scheduler threads paged_attn through
    make_segment_fn: both read paths produce identical per-request
    outputs through staggered admission and slot recycling."""
    from repro.launch.serve import serve_continuous
    cfg = get_arch("qwen3-0.6b").reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(3).integers(0, cfg.vocab, (4, 8),
                                                dtype=np.int32)
    budgets = np.asarray([2, 5, 3, 4], np.int32)
    outs = {}
    for path in ("kernel", "jnp"):
        outs[path], _ = serve_continuous(cfg, params, prompts, 5, slots=2,
                                         seg_len=2, max_new=budgets,
                                         eos_id=-1, kv="int8", page_size=4,
                                         paged_attn=path)
    for a, b in zip(outs["kernel"], outs["jnp"]):
        np.testing.assert_array_equal(a, b)


def test_dscim_kernel_mode_selects_kernel_path(monkeypatch):
    """Selection policy: default-on exactly for 'kernel' dscim modes; the
    env knob forces either path regardless of mode."""
    assert use_paged_kernel("kernel:dscim1:256")
    assert use_paged_kernel("kernel+attn:dscim1:256")
    assert not use_paged_kernel("off")
    assert not use_paged_kernel("lut:dscim1:256")
    monkeypatch.setenv("REPRO_PAGED_ATTN", "kernel")
    assert use_paged_kernel("off")
    monkeypatch.setenv("REPRO_PAGED_ATTN", "jnp")
    assert not use_paged_kernel("kernel:dscim1:256")


def test_autotune_serving_shapes_are_cache_hits():
    """The checked-in cache ships paged-attention winners for the decode
    serving geometry at every supported page size — cold-start tuning is
    a lookup (no sweep), and the winner is a valid (gh, qp) cell."""
    import json

    from repro.kernels import autotune
    with open(autotune.DEFAULT_CACHE) as f:
        disk = json.load(f)
    for ps in (4, 8, 16):
        key = f"paged_attn/B4/kv2r2hd16/ps{ps}/cpu"
        assert key in disk, f"missing checked-in winner {key}"
        gh, qp = autotune.paged_attn_tiles((4, 2, 2, 16), ps,
                                           interpret=True)
        assert (gh, qp) == tuple(disk[key])
        assert 2 % gh == 0 and qp >= 2


def test_tuned_cell_matches_reference():
    """The autotuned (gh, qp) winner computes the same attention as the
    defaults (tiling is numerics-free)."""
    rng = np.random.default_rng(3)
    q, view, kt, vt = _rand_paged(rng, 4, 2, 2, 16, 4, 3)
    args = (q, view["k_pages"], view["v_pages"], view["k_scale"],
            view["v_scale"], kt, vt, view["page_table"], view["pos"])
    base = paged_attention_decode(*args, interpret=True)
    tuned = paged_attention_decode(*args, tune=True, interpret=True)
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(base),
                               atol=1e-6)


def test_kernel_rejects_bad_cells():
    rng = np.random.default_rng(4)
    q, view, kt, vt = _rand_paged(rng, 2, 2, 2, 16, 4, 2)
    args = (q, view["k_pages"], view["v_pages"], view["k_scale"],
            view["v_scale"], kt, vt, view["page_table"], view["pos"])
    with pytest.raises(ValueError, match="must divide"):
        paged_attention_decode(*args, gh=3, interpret=True)
    with pytest.raises(ValueError, match="n_rep"):
        paged_attention_decode(*args, qp=1, interpret=True)
