"""Prefix caching with refcounted copy-on-write pages (ISSUE 10,
core/kvcache.py + runtime/serving.py + runtime/router.py): rolling
page-chunk hashing, the allocator's share/retain/reclaim lifecycle,
COW forking, a property-style random refcount schedule ending with a
leak-free drain, and the bitwise hit-vs-cold contract — greedy tokens
AND per-chunk logit traces — across both paged-attention read paths."""
import asyncio

import jax
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.configs import get_arch
from repro.core.kvcache import (PageAllocator, PrefixCache, admission_pages,
                                cow_fork, init_paged_cache, n_pages_for,
                                prefix_chunk_keys)
from repro.launch.serve import serve_continuous
from repro.launch.steps import init_serve_state, make_extend_fn
from repro.models import get_model

V = 151


def _setup():
    cfg = get_arch("qwen3-0.6b").reduced()
    model = get_model(cfg)
    return cfg, model.init_params(cfg, jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# rolling chunk keys
# --------------------------------------------------------------------------

def test_chunk_keys_roll_over_full_pages():
    """One key per FULL page; key j digests the whole prefix, so a
    divergence at page j changes every key from j on while keys before
    j are untouched — the longest-shared-prefix scan property."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, V, 13, dtype=np.int32)
    b = a.copy()
    b[5] ^= 1                                   # diverge inside page 1
    ka, kb = prefix_chunk_keys(a, 4), prefix_chunk_keys(b, 4)
    assert len(ka) == 3                         # 13 // 4: partial page dropped
    assert ka[0] == kb[0]
    assert ka[1] != kb[1] and ka[2] != kb[2]
    assert prefix_chunk_keys(a[:3], 4) == []    # no full page, no keys


def test_chunk_keys_zero_token_not_absorbed():
    """token 0 must perturb the hash (h*m + 0 == h*m would make a page
    of zeros collide with its own prefix)."""
    z = prefix_chunk_keys(np.zeros(8, np.int32), 4)
    assert z[0] != z[1]


# --------------------------------------------------------------------------
# allocator refcount lifecycle
# --------------------------------------------------------------------------

def test_share_and_free_refcounts():
    a = PageAllocator(4)
    ids = a.alloc(2)
    assert [a.refcount(i) for i in ids] == [1, 1]
    a.share(ids)
    assert [a.refcount(i) for i in ids] == [2, 2]
    assert a.stats()["shared_pages"] == 2
    a.free(ids)                     # first sharer releases: still live
    assert [a.refcount(i) for i in ids] == [1, 1]
    assert a.stats()["live_pages"] == 2
    a.free(ids)                     # last sharer: unretained -> free list
    assert a.stats()["live_pages"] == 0 and a.free_pages == 4
    assert a.stats()["retained_pages"] == 0


def test_free_page_cannot_be_shared():
    a = PageAllocator(2)
    ids = a.alloc(1)
    a.free(ids)
    with pytest.raises(ValueError, match="neither live nor retained"):
        a.share(ids)


def test_retained_revive_and_lru_reclaim():
    """Retainable pages park at refcount 0 with bytes intact; ``share``
    revives them; reclaim runs oldest-first only when an alloc would
    otherwise refuse, firing the drop hooks the index listens on."""
    a = PageAllocator(3)
    ids = a.alloc(3)
    for i in ids:
        a.set_retainable(i)
    a.free([ids[0]])
    a.free([ids[1]])
    a.free([ids[2]])
    assert a.stats() == dict(a.stats(), live_pages=0, retained_pages=3)
    a.share([ids[1]])               # revive out of LRU order
    assert a.refcount(ids[1]) == 1 and a.stats()["retained_pages"] == 2
    dropped = []
    a.on_reclaim(dropped.append)
    got = a.alloc(2)                # forces reclaim: oldest (0) then 2
    assert got is not None and dropped == [ids[0], ids[2]]
    assert a.stats()["reclaimed"] == 2 and a.stats()["retained_pages"] == 0
    a.free(got + [ids[1]])


def test_unmark_retainable_releases_parked_page():
    a = PageAllocator(2)
    (pid,) = a.alloc(1)
    a.set_retainable(pid)
    a.free([pid])
    assert a.stats()["retained_pages"] == 1
    a.set_retainable(pid, False)
    assert a.stats()["retained_pages"] == 0 and a.free_pages == 2


def test_allocator_snapshot_carries_sharing_state():
    a = PageAllocator(4)
    ids = a.alloc(3)
    a.share(ids[:2])
    a.set_retainable(ids[2])
    a.free([ids[2]])                # park one retained
    b = PageAllocator.from_snapshot(a.snapshot())
    assert b.stats() == a.stats()
    assert [b.refcount(i) for i in ids] == [a.refcount(i) for i in ids]
    b.share([ids[2]])               # revive survives the roundtrip
    assert b.refcount(ids[2]) == 1
    # pre-ISSUE-10 blob (no refs/retained keys): every live page singly
    # owned — the backward-compat default
    legacy = {k: v for k, v in PageAllocator(2).snapshot().items()
              if k in ("n_pages", "free", "live", "high_water", "refusals")}
    c = PageAllocator.from_snapshot(legacy)
    assert c.stats()["live_pages"] == 0 and c.free_pages == 2


# --------------------------------------------------------------------------
# prefix index
# --------------------------------------------------------------------------

def test_index_longest_prefix_and_reclaim_purge():
    a = PageAllocator(8)
    pc = PrefixCache(a, 4)
    toks = np.arange(12, dtype=np.int32)
    ids = a.alloc(3)
    assert pc.register(toks, ids) == 3
    n, got = pc.acquire(toks, max_chunks=2)     # capped below full match
    assert (n, got) == (8, ids[:2])
    assert [a.refcount(i) for i in ids] == [2, 2, 1]
    a.free(got)
    # divergence at page 1 matches only page 0
    other = toks.copy()
    other[4] ^= 1
    n, got = pc.acquire(other, max_chunks=2)
    assert (n, got) == (4, ids[:1])
    a.free(got)
    # release the donor: indexed pages park retained, then a pool-draining
    # alloc reclaims them and the index purges — the next lookup misses
    # instead of aliasing a reallocated page
    a.free(ids)
    assert a.stats()["retained_pages"] == 3
    big = a.alloc(8)
    assert len(pc) == 0
    assert pc.acquire(toks, max_chunks=2) == (0, [])
    a.free(big)


def test_register_first_writer_wins():
    a = PageAllocator(8)
    pc = PrefixCache(a, 4)
    toks = np.arange(8, dtype=np.int32)
    first = a.alloc(2)
    dup = a.alloc(2)
    assert pc.register(toks, first) == 2
    assert pc.register(toks, dup) == 0          # keys taken: no new entries
    _, got = pc.acquire(toks, max_chunks=1)
    assert got == first[:1]
    a.free(got)
    a.free(first + dup)


# --------------------------------------------------------------------------
# copy-on-write fork
# --------------------------------------------------------------------------

def test_cow_fork_copies_shared_pages():
    """A shared page inside the writable range is forked onto a fresh
    private page — int8 planes, scales, and digest plane byte-equal —
    and the donor keeps its copy; private pages pass through."""
    a = PageAllocator(6)
    cache = init_paged_cache(1, 1, 6, 4, 4, 1, 4, integrity=True)
    rng = np.random.default_rng(0)
    cache = dict(cache,
                 k_pages=jax.numpy.asarray(
                     rng.integers(-127, 128, cache["k_pages"].shape),
                     cache["k_pages"].dtype))
    ids = a.alloc(3)
    a.share(ids[:1])                            # page 0 shared, rest private
    c2, ids2, nf = cow_fork(cache, a, ids, start_idx=0)
    assert nf == 1 and ids2[1:] == ids[1:] and ids2[0] != ids[0]
    np.testing.assert_array_equal(np.asarray(c2["k_pages"])[:, ids2[0]],
                                  np.asarray(cache["k_pages"])[:, ids[0]])
    np.testing.assert_array_equal(np.asarray(c2["page_sum"])[:, ids2[0]],
                                  np.asarray(cache["page_sum"])[:, ids[0]])
    assert a.refcount(ids[0]) == 1 and a.refcount(ids2[0]) == 1
    # start_idx excludes the shared prefix: nothing left to fork
    a.share(ids2[:1])
    c3, ids3, nf = cow_fork(c2, a, ids2, start_idx=1)
    assert nf == 0 and ids3 == ids2 and c3 is c2
    a.free(ids2[:1])
    a.free(ids2 + [ids[0]])


def test_cow_fork_pool_exhausted_raises():
    a = PageAllocator(2)
    cache = init_paged_cache(1, 1, 2, 4, 2, 1, 4)
    ids = a.alloc(2)
    a.share(ids)
    with pytest.raises(RuntimeError, match="exhausted while forking"):
        cow_fork(cache, a, ids, start_idx=0)
    a.free(ids)
    a.free(ids)


# --------------------------------------------------------------------------
# property: random refcount schedule drains leak-free
# --------------------------------------------------------------------------

def _run_schedule(seed: int) -> None:
    """Random admit (with/without a shared prefix), COW fork, cancel/evict
    (free in arbitrary order), reclaim pressure — after draining every
    request: zero live pages, zero refcounts, and the allocator's books
    (free + retained + live == pool) balance at every step."""
    rng = np.random.default_rng(seed)
    ps, pool = 4, 24
    a = PageAllocator(pool)
    pc = PrefixCache(a, ps)
    live: list = []                              # (ids, tokens) per request
    mirror: dict = {}                            # pid -> expected refcount
    vocab = 7                                    # tiny: collisions -> hits

    def check():
        st_ = a.stats()
        assert a.free_pages + st_["retained_pages"] + st_["live_pages"] \
            == pool
        for pid in range(pool):
            assert a.refcount(pid) == mirror.get(pid, 0), (seed, pid)

    for _ in range(120):
        op = rng.integers(0, 3)
        if op == 0 and len(live) < 5:            # admit
            S = int(rng.integers(ps, 4 * ps + 1))
            toks = rng.integers(0, vocab, S).astype(np.int32)
            need = admission_pages(S, 2, ps, ps - 1)
            _n, shared = pc.acquire(toks, (S - 1) // ps)
            fresh = a.alloc(need - len(shared))
            if fresh is None:
                if shared:
                    a.free(shared)
                    for p in shared:
                        mirror[p] -= 1
                        if mirror[p] == 0:
                            del mirror[p]
                continue
            for p in shared + fresh:
                mirror[p] = mirror.get(p, 0) + 1
            live.append((shared + fresh, toks))
        elif op == 1 and live:                   # cancel/evict, random victim
            ids, toks = live.pop(int(rng.integers(len(live))))
            if rng.integers(2):                  # some finishers register
                pc.register(toks, ids[:len(toks) // ps])
            a.free(ids)
            for p in ids:
                mirror[p] -= 1
                if mirror[p] == 0:
                    del mirror[p]
        elif op == 2 and live:                   # COW write into a request
            i = int(rng.integers(len(live)))
            ids, toks = live[i]
            if a.available_pages < len(ids):     # fork targets must exist
                continue
            cache = init_paged_cache(1, 1, pool, ps, len(ids), 1, 2)
            _, ids2, _ = cow_fork(cache, a, ids, start_idx=0)
            for old, new in zip(ids, ids2):
                if old == new:
                    continue
                mirror[old] -= 1
                if mirror[old] == 0:
                    del mirror[old]
                mirror[new] = 1
            live[i] = (ids2, toks)
        check()

    for ids, _ in live:                          # drain
        a.free(ids)
        for p in ids:
            mirror[p] -= 1
            if mirror[p] == 0:
                del mirror[p]
    live.clear()
    check()
    assert a.stats()["live_pages"] == 0 and not mirror
    # retained pages are reclaimable, never leaked: a full-pool alloc
    # succeeds and returns every page to the free list
    every = a.alloc(pool)
    assert every is not None and len(pc) == 0
    a.free(every)
    assert a.free_pages == pool


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_refcount_schedule_property(seed):
    _run_schedule(seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS, reason="hypothesis drives the sweep")
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_refcount_schedule_fallback(seed):
    _run_schedule(seed)


# --------------------------------------------------------------------------
# bitwise hit-vs-cold: tokens and logit traces, both read paths
# --------------------------------------------------------------------------

@pytest.mark.parametrize("paged_attn", ["jnp", "kernel"])
def test_extend_logit_trace_parity_hit_vs_cold(paged_attn):
    """The acceptance criterion at its sharpest: a prefix-hit admission's
    post-divergence chunk logits are bitwise the cold admission's —
    shared pages hold exactly the bytes the donor's identical chunk
    programs wrote, so the trace cannot tell a hit from a miss."""
    cfg, params = _setup()
    ps, S, budget = 4, 12, 3
    rng = np.random.default_rng(0)
    donor = rng.integers(1, V, S).astype(np.int32)
    hitter = donor.copy()
    hitter[8:] = rng.integers(1, V, S - 8)       # diverge at page 2
    extend = make_extend_fn(cfg, None, ps, eos_id=-1, sample="greedy",
                            paged_attn=paged_attn, trace_logits=True)
    need = admission_pages(S, budget, ps, ps - 1)

    def admit_chunked(state, alloc, pfx, b, toks, use_prefix):
        d = 0
        shared = []
        if use_prefix:
            _n, shared = pfx.acquire(toks, (S - 1) // ps)
            d = len(shared)
        ids = shared + alloc.alloc(need - d)
        cache, ids, _ = cow_fork(state["cache"], alloc, ids, start_idx=d)
        mp = cache["page_table"].shape[1]
        row = jax.numpy.asarray(ids + [ids[-1]] * (mp - len(ids)),
                                jax.numpy.int32)
        cache = dict(cache, page_table=cache["page_table"].at[b].set(row),
                     pos=cache["pos"].at[b].set(d * ps))
        state = dict(state, cache=cache,
                     done=state["done"].at[b].set(True))
        traces = []
        fed = d * ps
        while fed < S:
            part = toks[fed:fed + ps]
            state, tok0, lg = extend(
                params, state, jax.numpy.asarray(part[None]),
                jax.numpy.int32(b), jax.numpy.int32(len(part)),
                jax.numpy.bool_(fed + len(part) >= S),
                jax.numpy.int32(budget))
            traces.append(np.asarray(lg))
            fed += len(part)
        pfx.register(toks, ids[:S // ps])
        return state, ids, int(tok0), traces

    def leg(use_prefix):
        alloc = PageAllocator(4 * need)
        pfx = PrefixCache(alloc, ps)
        state = init_serve_state(cfg, 2, S + budget + ps - 1, kv="int8",
                                 page_size=ps, n_pages=4 * need)
        state, _, _, _ = admit_chunked(state, alloc, pfx, 0, donor, False)
        state, ids, tok0, traces = admit_chunked(state, alloc, pfx, 1,
                                                 hitter, use_prefix)
        return tok0, traces, pfx.stats()

    tok_c, tr_c, st_c = leg(False)
    tok_w, tr_w, st_w = leg(True)
    assert st_c["hits"] == 0 and st_w["hits"] == 1
    assert st_w["pages_deduped"] == 2            # pages 0 and 1 shared
    assert tok_w == tok_c
    assert len(tr_c) == 3 and len(tr_w) == 1     # hit skipped 2 chunks
    np.testing.assert_array_equal(tr_w[0], tr_c[2])


@pytest.mark.parametrize("paged_attn", ["jnp", "kernel"])
def test_serving_prefix_bitwise_vs_cold(paged_attn):
    """End-to-end through the continuous scheduler: warm serving with
    prefix hits emits bitwise the cold leg's tokens on both paged-attn
    read paths, while visibly deduping pages."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    R, S, n = 4, 8, 4
    prompts = rng.integers(0, cfg.vocab, (R, S), dtype=np.int32)
    prompts[1:, :4] = prompts[0, :4]             # 1-page shared prefix
    budgets = np.asarray([4, 3, 4, 2], np.int32)
    knobs = dict(slots=2, seg_len=2, max_new=budgets, eos_id=-1, kv="int8",
                 page_size=4, paged_attn=paged_attn, log=lambda *a: None)
    cold, st_c = serve_continuous(cfg, params, prompts, n, **knobs,
                                  prefix_cache="cold")
    warm, st_w = serve_continuous(cfg, params, prompts, n, **knobs,
                                  prefix_cache="on")
    for r in range(R):
        np.testing.assert_array_equal(warm[r], cold[r], err_msg=f"req {r}")
    assert st_c["prefix"]["hits"] == 0
    assert st_w["prefix"]["hits"] == 3 and st_w["prefix"]["hit_tokens"] == 12
    assert st_w["pages"]["live_pages"] == 0


def test_prefix_requires_int8_kv():
    cfg, params = _setup()
    prompts = np.zeros((1, 8), np.int32)
    with pytest.raises(ValueError, match="int8"):
        serve_continuous(cfg, params, prompts, 2, slots=1, kv="float",
                         prefix_cache=True, eos_id=-1,
                         max_new=np.asarray([2], np.int32))


def test_router_prefix_hits_match_cold_and_snapshot_carries_index():
    """Router admissions through the prefix path match the non-prefix
    chunked router bitwise (same chunk_len), /stats exposes the prefix
    ledger, and a failover snapshot round-trips the index."""
    from repro.runtime.router import Router
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    shared = rng.integers(1, V, 8).astype(np.int32)
    prompts = [np.concatenate([shared, rng.integers(1, V, 4).astype(np.int32)])
               for _ in range(4)]
    budgets = [4, 3, 4, 2]
    kn = dict(seg_len=2, kv="int8", page_size=4, buckets=(16,), chunk_len=4,
              max_prompt=24, max_new_cap=8, slots=2, log=lambda *a: None)

    async def run(prefix):
        r = Router(cfg, params, prefix_cache=prefix, **kn)
        await r.start()
        res = []
        for p, b in zip(prompts, budgets):      # staggered submissions
            res.append(await r.submit(p, b).result())
        st = r.stats()
        snap = r._take_snapshot()
        await r.close()
        assert r.stats()["pages"]["live_pages"] == 0
        return res, st, snap

    warm, st, snap = asyncio.run(run(True))
    cold, st_c, _ = asyncio.run(run(False))
    assert st_c["prefix"] is None
    for i, (w, c) in enumerate(zip(warm, cold)):
        assert (w.status, w.tokens) == (c.status, c.tokens), i
    assert st["prefix"]["hits"] == 3 and st["prefix"]["pages_deduped"] == 6
    assert st["prefix"]["prefill_positions_computed"] \
        < st["prefix"]["prefill_positions_total"]
    pc = PrefixCache.from_snapshot(snap["prefix"],
                                   PageAllocator.from_snapshot(snap["alloc"]))
    assert len(pc) > 0 and pc.hits == st["prefix"]["hits"]
