"""Quantization properties + hardware-model calibration checks."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.hwmodel import (DSCIM1_HW, DSCIM2_HW, HWModel,
                                MacroGeometry)
from repro.core.quant import (dequantize_int8, fp8_cast, fp8_to_int8_aligned,
                              quantize_int8)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.01, 100.0))
def test_int8_quant_roundtrip_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, (4, 32)), jnp.float32)
    qt = quantize_int8(x, axis=-1)
    err = np.abs(np.asarray(dequantize_int8(qt)) - np.asarray(x))
    bound = np.asarray(qt.scale) * 0.5 + 1e-6
    assert (err <= bound + 1e-7 * scale).all()


def test_fp8_cast_is_idempotent():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, 64), jnp.float32)
    once = fp8_cast(x)
    twice = fp8_cast(once)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


def test_fp8_to_int8_group_alignment():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 300)),
                    jnp.float32)
    q, scale, pad = fp8_to_int8_aligned(x, group=128)
    assert q.shape == (2, 3, 128) and pad == 84
    assert q.dtype == jnp.int8
    recon = (q.astype(jnp.float32) * scale).reshape(2, -1)[:, :300]
    rel = float(jnp.sqrt(jnp.mean((recon - fp8_cast(x)) ** 2))
                / jnp.sqrt(jnp.mean(fp8_cast(x) ** 2)))
    assert rel < 0.05  # int8-on-fp8 alignment keeps values within ~1%


# ---------------- hardware model vs Table III ----------------

PAPER = {  # (model, signed): TOPS/W, TOPS/mm2
    "dscim1_256": (669.7, 117.1), "dscim2_64": (3566.1, 363.7),
    "dscim1_64": (2677.2, 468.4), "dscim2_256": (891.5, 90.9),
}


@pytest.mark.parametrize("name,hw", [
    ("dscim1_256", DSCIM1_HW(256)), ("dscim2_64", DSCIM2_HW(64)),
    ("dscim1_64", DSCIM1_HW(64)), ("dscim2_256", DSCIM2_HW(256))])
def test_hwmodel_matches_table3(name, hw):
    tw, tm = PAPER[name]
    s = hw.summary(signed=True)
    assert abs(s["tops_per_watt"] / tw - 1) < 0.10, (name, s["tops_per_watt"])
    assert abs(s["tops_per_mm2"] / tm - 1) < 0.10, (name, s["tops_per_mm2"])


def test_hwmodel_areas_match_paper():
    assert abs(DSCIM1_HW().summary()["area_mm2"] - 0.78) < 0.05
    assert abs(DSCIM2_HW().summary()["area_mm2"] - 0.72) < 0.05


def test_cmr_scaling_fig4():
    """Fig. 4: raising CMR 1 -> 64 multiplies throughput ~64x with ~2x area."""
    lo = DSCIM2_HW(64, cmr=1)
    hi = DSCIM2_HW(64, cmr=64)
    assert hi.tops_1b() / lo.tops_1b() == pytest.approx(64, rel=1e-6)
    assert hi.area_mm2() / lo.area_mm2() < 2.5


def test_latch_cached_accumulator_saving():
    """Paper: latch caching cuts macro power ~21.8%; model within a band."""
    no_latch = HWModel(MacroGeometry(group=64, length=64, latch_cached=False,
                                     freq_ghz=0.4995))
    with_latch = DSCIM2_HW(64)
    e0 = 1 / no_latch.tops_per_watt()
    e1 = 1 / with_latch.tops_per_watt()
    assert 0.15 < 1 - e1 / e0 < 0.35


def test_signed_mode_costs_more():
    hw = DSCIM1_HW(256)
    assert hw.tops_per_watt(signed=True) < hw.tops_per_watt(signed=False)
