"""Prepared (quantize-once) DS-CIM weights: bit-exactness vs the on-the-fly
path across granularities and odd K, pad-metadata round-trip, param-tree
preparation, absence of weight quantization from the traced serving step,
and the noise-key call-site salting fix."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dscim_layer import DSCIMLinear
from repro.core.qweights import (QuantizedLinearWeight,
                                 dequantize_linear_weight,
                                 prepare_dscim_params, prepare_linear_weight)
from repro.core.seed_search import calibrated_config
from repro.kernels.dscim_fused import (dscim_fused_mvm,
                                       dscim_fused_mvm_prepared)

CFG = calibrated_config("dscim2", 64, "paper")


def _operands(rng, M, K, N):
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (K, N)), jnp.float32)
    return x, w


@pytest.mark.parametrize("group_k", [None, 64, 128])
@pytest.mark.parametrize("K", [128, 200, 100])
def test_prepared_fused_bit_identical(group_k, K):
    """The acceptance bar: prepared == on-the-fly, bitwise, for every
    granularity and odd (padded) K."""
    rng = np.random.default_rng(K + (group_k or 0))
    x, w = _operands(rng, 5, K, 24)
    qw = prepare_linear_weight(w, group_k)
    a = np.asarray(dscim_fused_mvm(x, w, CFG, group_k=group_k))
    b = np.asarray(dscim_fused_mvm_prepared(x, qw, CFG))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("mode", ["exact", "lut", "bitmatmul"])
def test_prepared_all_backends_bit_identical(mode):
    rng = np.random.default_rng(7)
    x, w = _operands(rng, 4, 150, 12)
    lin = DSCIMLinear(CFG, mode=mode, group_k=64)
    qw = prepare_linear_weight(w, 64)
    np.testing.assert_array_equal(np.asarray(lin(x, w)),
                                  np.asarray(lin(x, qw)))


@pytest.mark.parametrize("K,group_k", [(100, 64), (130, 128), (64, None)])
def test_pad_metadata_round_trip(K, group_k):
    """Odd K: dequantize strips the zero pad rows exactly and the values
    stay within one quantization step of the original."""
    rng = np.random.default_rng(K)
    w = jnp.asarray(rng.normal(0, 1, (K, 16)), jnp.float32)
    qw = prepare_linear_weight(w, group_k)
    assert qw.k_orig == K and qw.shape == (K, 16)
    g = group_k or K
    assert qw.g == g and qw.nw == -(-K // g)
    wd = np.asarray(dequantize_linear_weight(qw))
    assert wd.shape == (K, 16)
    # one int8 step per window is the worst-case round error
    step = np.asarray(qw.scale).max()
    assert np.abs(wd - np.asarray(w)).max() <= 0.5 * step + 1e-7


def test_prepared_weight_is_pytree_and_sliceable():
    """Stacked (scan-layout) prepared weights slice into per-layer prepared
    weights under tree ops — the property lax.scan relies on."""
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(0, 1, (3, 128, 8)), jnp.float32)  # 3 layers
    qw = prepare_linear_weight(w, 64)
    assert qw.stack == (3,)
    leaves, treedef = jax.tree_util.tree_flatten(qw)
    assert len(leaves) == 2
    qw2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert qw2.k_orig == 128 and qw2.group_k == 64
    sl = jax.tree.map(lambda a: a[1], qw)
    np.testing.assert_array_equal(np.asarray(sl.q), np.asarray(qw.q[1]))
    one = prepare_linear_weight(w[1], 64)
    np.testing.assert_array_equal(np.asarray(sl.q), np.asarray(one.q))
    np.testing.assert_array_equal(np.asarray(sl.scale), np.asarray(one.scale))


def test_prepare_dscim_params_tree_walk():
    from repro.configs import get_arch
    from repro.models import get_model

    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              dscim="exact:dscim1:256")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    pp = prepare_dscim_params(params, cfg)
    mlp = pp["layers"]["mlp"]
    assert isinstance(mlp["w_up"], QuantizedLinearWeight)
    assert isinstance(mlp["w_gate"], QuantizedLinearWeight)
    assert isinstance(mlp["w_down"], QuantizedLinearWeight)
    assert mlp["w_up"].stack == (cfg.n_layers,)
    # attention stays float (default scope), embed stays float (lookup),
    # tied-embedding head is materialized as a prepared matrix
    assert not isinstance(pp["layers"]["attn"]["wq"], QuantizedLinearWeight)
    assert not isinstance(pp["embed"], QuantizedLinearWeight)
    assert isinstance(pp["lm_head"], QuantizedLinearWeight)
    assert pp["lm_head"].shape == (cfg.d_model, cfg.vocab_padded)
    # off/float specs are no-ops
    assert prepare_dscim_params(params, dataclasses.replace(
        cfg, dscim="off")) is params
    # '+attn' opt-in prepares the projections too
    pa = prepare_dscim_params(params, dataclasses.replace(
        cfg, dscim="exact+attn:dscim1:256"))
    assert isinstance(pa["layers"]["attn"]["wq"], QuantizedLinearWeight)


def _count_rounds(jaxpr) -> int:
    """Total quantization ``round`` primitives, recursing into scan/pjit
    sub-jaxprs (the pretty-printer shares repeated lambdas, so string
    counting under-reports)."""
    def subs(v):
        if hasattr(v, "jaxpr"):                      # ClosedJaxpr
            return [v.jaxpr]
        if hasattr(v, "eqns"):                       # Jaxpr
            return [v]
        if isinstance(v, (list, tuple)):
            return [j for x in v for j in subs(x)]
        return []

    n = sum(1 for e in jaxpr.eqns if e.primitive.name == "round")
    for e in jaxpr.eqns:
        for v in e.params.values():
            n += sum(_count_rounds(j) for j in subs(v))
    return n


def test_weight_quantization_absent_from_prepared_trace():
    """The jitted prepared linear quantizes activations only: exactly one
    round op in the jaxpr (the float path has a second one for w)."""
    rng = np.random.default_rng(11)
    x, w = _operands(rng, 2, 128, 8)
    qw = prepare_linear_weight(w, 128)
    lin = DSCIMLinear(CFG, mode="exact", group_k=128)
    n_float = _count_rounds(jax.make_jaxpr(lambda a, b: lin(a, b))(x, w).jaxpr)
    n_prep = _count_rounds(jax.make_jaxpr(lambda a, b: lin(a, b))(x, qw).jaxpr)
    assert n_float == 2 and n_prep == 1


def test_decode_step_prepared_bit_identical_and_quantize_free():
    """Full serve stack: prepared params give bit-identical logits, and the
    traced decode step contains half the quantizations (activations only)."""
    from repro.configs import get_arch
    from repro.launch.serve import serve_batch
    from repro.launch.steps import make_decode_step, prepare_serving_params
    from repro.models import get_model

    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              dscim="exact:dscim1:256")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    pp = prepare_serving_params(cfg, params)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 8), dtype=np.int32)
    t1, l1 = serve_batch(cfg, params, prompts, 4, prepare=False)
    t2, l2 = serve_batch(cfg, params, prompts, 4, prepare=True)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(np.asarray(l1[0]), np.asarray(l2[0]))

    decode = make_decode_step(cfg, None)
    cache = {"k": jnp.zeros((cfg.n_layers, 2, 12, cfg.n_kv, cfg.head_dim)),
             "v": jnp.zeros((cfg.n_layers, 2, 12, cfg.n_kv, cfg.head_dim)),
             "pos": jnp.int32(8)}
    batch = {"token": jnp.zeros((2,), jnp.int32)}
    n_float = _count_rounds(jax.make_jaxpr(decode)(params, batch, cache).jaxpr)
    n_prep = _count_rounds(jax.make_jaxpr(decode)(pp, batch, cache).jaxpr)
    # 4 DS-CIM matmul sites per decode (gate/up/down in the scan body,
    # traced once, + head): the float trace quantizes x and w at each site,
    # the prepared trace only x
    assert n_float == 8, n_float
    assert n_prep == 4, n_prep


def test_prepared_group_mismatch_raises():
    rng = np.random.default_rng(5)
    x, w = _operands(rng, 2, 128, 8)
    qw = prepare_linear_weight(w, 64)
    lin = DSCIMLinear(CFG, mode="exact", group_k=128)
    with pytest.raises(ValueError, match="granularity"):
        lin(x, qw)
    with pytest.raises(TypeError):
        DSCIMLinear(CFG, mode="float")(x, qw)


# ---------------- noise-key call-site salting (satellite fix) ----------------

def test_statistical_salt_decorrelates_call_sites():
    rng = np.random.default_rng(17)
    x, w = _operands(rng, 8, 128, 16)
    lin = DSCIMLinear(calibrated_config("dscim1", 256, "paper"),
                      mode="statistical")
    a = np.asarray(lin(x, w, salt=0))
    b = np.asarray(lin(x, w, salt=1))
    assert not np.array_equal(a, b)          # distinct sites, distinct noise
    np.testing.assert_array_equal(a, np.asarray(lin(x, w, salt=0)))
    # explicit key still wins, salt still decorrelates under a shared key
    k = jax.random.PRNGKey(42)
    ka = np.asarray(lin(x, w, key=k, salt=0))
    kb = np.asarray(lin(x, w, key=k, salt=1))
    assert not np.array_equal(ka, kb)
    # the fallback key also folds in the operand shape
    w2 = jnp.asarray(np.random.default_rng(18).normal(0, 1, (128, 16)),
                     jnp.float32)
    assert not np.array_equal(np.asarray(lin(x, w)) - np.asarray(
        DSCIMLinear(lin.cfg, mode="exact")(x, w)),
        np.asarray(lin(x, w2)) - np.asarray(
        DSCIMLinear(lin.cfg, mode="exact")(x, w2)))


def test_paper_inject_layers_draw_distinct_noise():
    """Through the LM stack, paper_inject noise now differs across layers
    (the PRNGKey(0)-everywhere bug): with identical per-layer weights and
    identical inputs, layer outputs would previously correlate exactly."""
    lin = DSCIMLinear(calibrated_config("dscim2", 64, "paper"),
                      mode="paper_inject")
    rng = np.random.default_rng(23)
    x, w = _operands(rng, 4, 128, 8)
    exact = np.asarray(DSCIMLinear(lin.cfg, mode="exact")(x, w))
    n0 = np.asarray(lin(x, w, salt=0)) - exact
    n8 = np.asarray(lin(x, w, salt=8)) - exact
    assert not np.array_equal(n0, n8)


def test_attn_linear_spec_parsing_and_smoke():
    from repro.models.lm import _attn_linear_for, _linear_for

    assert _attn_linear_for("exact:dscim1:256") is None
    lin = _attn_linear_for("exact+attn:dscim2:64")
    assert lin is not None and lin.mode == "exact"
    assert _linear_for("exact+attn:dscim2:64").mode == "exact"

    # attention with DS-CIM projections (prepared or float) runs and stays
    # close to the exact projections on benign inputs
    from types import SimpleNamespace

    from repro.layers.attention import attention, init_attention

    cfg = SimpleNamespace(n_heads=4, n_kv=2, head_dim=16, rope_theta=1e4,
                          qk_norm=False)
    key = jax.random.PRNGKey(0)
    p = init_attention(key, 64, 4, 2, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64)) * 0.1
    ref, _ = attention(p, x, cfg, q_chunk=8, kv_chunk=8)
    got, _ = attention(p, x, cfg, q_chunk=8, kv_chunk=8, linear=lin, salt=0)
    assert got.shape == ref.shape
    assert float(jnp.abs(got - ref).max()) < 0.1
    pq = prepare_dscim_params({"attn": p}, None, group_k=128,
                              include_attn=True)
    got2, _ = attention(pq["attn"], x, cfg, q_chunk=8, kv_chunk=8,
                        linear=lin, salt=0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))
