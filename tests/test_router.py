"""Async serving router (ISSUE 8, runtime/router.py): bucketed one-shot
admission bitwise-equal to the continuous scheduler, chunked prefill
invariant under chunk size (sequential-decode equivalence), typed
admission refusals, mid-stream cancellation, submission-anchored wall
deadlines, failover replay invisibility, quarantine -> degraded streams,
snapshot-drain -> resume completion, and the zero-page-leak drain
invariant — plus the loadtest helpers' trace shape."""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.serve import serve_continuous
from repro.models import get_model
from repro.runtime.failover import FailureInjector
from repro.runtime.router import Refused, Router
from repro.runtime.watchdog import AccuracyWatchdog

V = 151                    # > any token the tests draw


def _setup(dscim="off"):
    cfg = get_arch("qwen3-0.6b").reduced()
    if dscim != "off":
        cfg = dataclasses.replace(cfg, dscim=dscim)
    model = get_model(cfg)
    return cfg, model.init_params(cfg, jax.random.PRNGKey(0))


# shared knobs: identical (cfg, knob) tuples hit the lru-cached jitted
# builders across Router instances, so the file compiles each program once
KN = dict(seg_len=2, kv="int8", page_size=4, buckets=(4, 8), chunk_len=4,
          max_prompt=24, max_new_cap=8, log=lambda *a: None)


def _router(cfg, params, **kw):
    return Router(cfg, params, **{**KN, **kw})


async def _drained(router):
    await router.close()
    assert router.stats()["pages"]["live_pages"] == 0, router.stats()


def test_bucket_admission_bitwise_vs_serve_continuous():
    """One-shot (bucket-length) admissions through the router emit
    bitwise the tokens serve_continuous gives the same prompts — greedy
    deterministic serving is schedule-independent, and the router reuses
    the scheduler's jitted admit/segment programs."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, V, s).astype(np.int32)
               for s in (4, 8, 4, 8, 4)]
    budgets = [5, 3, 6, 4, 2]

    async def run():
        r = _router(cfg, params, slots=3)
        await r.start()
        res = await asyncio.gather(*[r.submit(p, b).result()
                                     for p, b in zip(prompts, budgets)])
        await _drained(r)
        return res

    res = asyncio.run(run())
    assert [x.status for x in res] == ["ok"] * 5
    for length in (4, 8):
        rows = [i for i, p in enumerate(prompts) if len(p) == length]
        outs, _ = serve_continuous(
            cfg, params, np.stack([prompts[i] for i in rows]),
            max(budgets[i] for i in rows), slots=2, seg_len=2, kv="int8",
            page_size=4, max_new=[budgets[i] for i in rows], eos_id=-1,
            log=lambda *a: None)
        for j, i in enumerate(rows):
            assert res[i].tokens == outs[j].tolist(), (i, length)


def test_chunked_prefill_chunk_size_invariance():
    """Chunked prefill is sequential-decode equivalent: chunk_len=1 IS
    sequential decode (one prompt token per decode_multi call), so every
    other chunking — including a padded, rolled-back final chunk — must
    produce bitwise the same stream."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, V, s).astype(np.int32) for s in (11, 6)]

    async def run(chunk_len):
        r = _router(cfg, params, slots=2, buckets=(64,),
                    chunk_len=chunk_len)
        await r.start()
        res = await asyncio.gather(*[r.submit(p, 5).result()
                                     for p in prompts])
        await _drained(r)
        return [x.tokens for x in res]

    ref = asyncio.run(run(1))
    assert all(len(t) == 5 for t in ref)
    for chunk_len in (3, 4, 6):
        assert asyncio.run(run(chunk_len)) == ref, chunk_len


def test_refusals_typed():
    """submit() backpressure is typed, not a hang: too_large is permanent
    (could never fit), queue is transient with a retry hint, draining is
    the shutdown surface.  None of them create a request."""
    cfg, params = _setup()
    rng = np.random.default_rng(2)

    async def run():
        r = _router(cfg, params, slots=1, max_queue=2)
        await r.start()
        with pytest.raises(Refused) as e:
            r.submit(rng.integers(1, V, 30), 4)      # > max_prompt
        assert e.value.reason == "too_large"
        with pytest.raises(Refused) as e:
            r.submit(rng.integers(1, V, 4), 99)      # > max_new_cap
        assert e.value.reason == "too_large"
        hs = [r.submit(rng.integers(1, V, 4), 4) for _ in range(2)]
        with pytest.raises(Refused) as e:
            r.submit(rng.integers(1, V, 4), 4)       # queue full
        assert e.value.reason == "queue"
        assert e.value.retry_after is not None and e.value.retry_after > 0
        res = await asyncio.gather(*[h.result() for h in hs])
        assert [x.status for x in res] == ["ok", "ok"]
        await _drained(r)
        with pytest.raises(Refused) as e:
            r.submit(rng.integers(1, V, 4), 4)
        assert e.value.reason == "draining"
        st = r.stats()
        assert st["refusals"] == {"queue": 1, "too_large": 2,
                                  "draining": 1}

    asyncio.run(run())


def test_cancel_mid_stream_recycles_pages():
    """handle.cancel() (the client-disconnect path) ends the stream with
    'cancelled' at the next round, frees the slot, and returns its pages
    to the pool while other requests keep streaming."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)

    async def run():
        r = _router(cfg, params, slots=2)
        await r.start()
        h_other = r.submit(rng.integers(1, V, 4), 8)
        h = r.submit(rng.integers(1, V, 4), 8)
        got = []
        async for kind, val in h.events():
            if kind == "token":
                got.append(val)
                if len(got) == 2:
                    h.cancel()
            else:
                status = val
        assert status == "cancelled"
        assert len(got) < 8             # genuinely cut short
        other = await h_other.result()
        assert other.status == "ok" and len(other.tokens) == 8
        assert r.stats()["counters"]["cancelled"] == 1
        await _drained(r)

    asyncio.run(run())


def test_deadline_s_anchored_at_submission():
    """Router wall deadlines are end-to-end SLOs: the clock starts at
    submit(), so a request stuck behind a long stream can expire while
    still queued (0 tokens) — and an admitted request past its budget
    ends 'deadline' with its partial tokens intact."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)

    async def run():
        r = _router(cfg, params, slots=1)
        await r.start()
        h0 = r.submit(rng.integers(1, V, 4), 8)          # hog the slot
        hq = r.submit(rng.integers(1, V, 4), 2, deadline_s=1e-3)
        res0, resq = await asyncio.gather(h0.result(), hq.result())
        assert res0.status == "ok"
        assert resq.status == "deadline" and resq.tokens == []
        h1 = r.submit(rng.integers(1, V, 4), 8, deadline_steps=2)
        res1 = await h1.result()
        assert res1.status == "deadline"
        assert 1 <= len(res1.tokens) < 8                 # partial kept
        await _drained(r)

    asyncio.run(run())


def test_failover_replay_is_invisible():
    """An injected device loss mid-serve restores the latest snapshot and
    replays; streams see no duplicate or missing tokens and the final
    outputs are bitwise the unfaulted run's."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, V, 4).astype(np.int32) for _ in range(3)]

    async def run(injector):
        r = _router(cfg, params, slots=2, injector=injector,
                    snapshot_every=1)
        await r.start()
        res = await asyncio.gather(*[r.submit(p, 6).result()
                                     for p in prompts])
        stats = r.stats()
        await _drained(r)
        return res, stats

    faulted, st = asyncio.run(run(FailureInjector(fail_at=(2,))))
    clean, _ = asyncio.run(run(None))
    assert st["replays"] == 1
    for a, b in zip(faulted, clean):
        assert a.status == b.status == "ok"
        assert a.tokens == b.tokens


class _InfScaleInjector(FailureInjector):
    """Deterministic NaN source (see tests/test_serving_ft.py): one live
    dequant scale set to +inf at segment 1."""

    def corrupt_cache(self, segment, cache, slot_pages):
        key = ("inf", 1)
        if segment != 1 or key in self.fired or slot_pages[0] is None:
            return cache, []
        self.fired.add(key)
        pid = int(slot_pages[0][0])
        return dict(cache, v_scale=cache["v_scale"].at[0, pid, 0]
                    .set(np.inf)), [0]


def test_quarantine_streams_restart_and_degraded():
    """A NaN-quarantined request is re-served down the degradation ladder
    immediately; the client sees an explicit ('restart', None) voiding
    the streamed prefix, the full re-served output, and a terminal
    'degraded' — never silently-poisoned tokens."""
    cfg, params = _setup("kernel:dscim2:64")
    rng = np.random.default_rng(6)

    async def run():
        r = _router(cfg, params, slots=2, injector=_InfScaleInjector(),
                    monitor=AccuracyWatchdog(None), snapshot_every=1)
        await r.start()
        h = r.submit(rng.integers(1, V, 4), 6)
        events = []
        async for ev in h.events():
            events.append(ev)
        stats = r.stats()
        await _drained(r)
        return events, stats

    events, stats = asyncio.run(run())
    kinds = [k for k, _ in events]
    assert events[-1] == ("end", "degraded")
    assert "restart" in kinds
    tail = kinds[kinds.index("restart") + 1:]
    assert tail.count("token") == 6       # the full re-served output
    assert stats["counters"]["quarantined"] == 1
    assert stats["counters"]["degraded"] == 1


def test_drain_snapshot_resume_completes():
    """close('snapshot') parks live + queued requests in a blob (streams
    end 'cancelled', pages freed); Router(resume=blob) revives them and
    serves to completion with outputs bitwise an uninterrupted run's."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, V, 4).astype(np.int32) for _ in range(3)]

    async def interrupted():
        r = _router(cfg, params, slots=2)
        await r.start()
        hs = [r.submit(p, 6) for p in prompts]
        await asyncio.sleep(0)                   # let a round or two run
        blob = await r.close("snapshot")
        assert r.stats()["pages"]["live_pages"] == 0
        res = await asyncio.gather(*[h.result() for h in hs])
        assert {x.status for x in res} == {"cancelled"}
        assert blob is not None and blob["requests"]
        r2 = _router(cfg, params, slots=2, resume=blob)
        await r2.start()
        handles = r2.resume_handles()
        assert set(handles) == {d["rid"] for d in blob["requests"]}
        out = {rid: await h.result() for rid, h in handles.items()}
        await _drained(r2)
        return out

    async def uninterrupted():
        r = _router(cfg, params, slots=2)
        await r.start()
        res = await asyncio.gather(*[r.submit(p, 6).result()
                                     for p in prompts])
        await _drained(r)
        return res

    out = asyncio.run(interrupted())
    ref = asyncio.run(uninterrupted())
    for rid, got in out.items():
        assert got.status == "ok"
        assert got.tokens == ref[rid].tokens, rid


def test_loadtest_trace_shape():
    """The synthetic trace keeps its promises: arrival times are
    monotone, lengths/budgets respect the caps, both admission paths and
    at least one deadline/disconnect appear at realistic sizes."""
    from benchmarks.loadtest import make_trace
    trace = make_trace(0, 400, buckets=(4, 8), max_prompt=12,
                       max_new_cap=8)
    assert len(trace) == 400
    ts = [r["t"] for r in trace]
    assert ts == sorted(ts)
    lens = {len(r["prompt"]) for r in trace}
    assert lens & {4, 8}                        # bucketed one-shot path
    assert lens - {4, 8}                        # chunked path
    assert all(2 <= len(r["prompt"]) <= 12 for r in trace)
    assert all(1 <= r["max_new"] <= 8 for r in trace)
    assert any(r["deadline_steps"] is not None for r in trace)
    assert any(r["deadline_s"] is not None for r in trace)
    assert any(r["disconnect_after"] is not None for r in trace)
    # same seed, same trace — the reproducibility contract
    again = make_trace(0, 400, buckets=(4, 8), max_prompt=12,
                       max_new_cap=8)
    assert all(np.array_equal(a["prompt"], b["prompt"])
               and a["t"] == b["t"] and a["max_new"] == b["max_new"]
               for a, b in zip(trace, again))
