"""Fault-tolerant serving runtime (ISSUE 6, runtime/serving.py): serve-
state snapshot/restore + failover replay (bitwise across KV layouts and
paged-attention read paths), preemptive priority eviction with mid-stream
re-admission parity, deadline cancellation with partial outputs, the
accuracy watchdog + degradation ladder (drift *and* NaN trips), the page-
allocator hardening, the sampler degenerate-row guard, and the end-to-end
chaos drill that pins the whole acceptance contract at once."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.kvcache import (PageAllocator, extract_slot_pages,
                                insert_slot_pages, n_pages_for)
from repro.launch.serve import serve_continuous
from repro.models import get_model
from repro.runtime.failover import FailureInjector, flip_bits
from repro.runtime.serving import (STATUS_DEADLINE, STATUS_OK,
                                   chaos_drill, exact_probe_spec,
                                   next_ladder_spec, watchdog_for_spec)
from repro.runtime.watchdog import AccuracyWatchdog


def _setup(dscim="off", arch="qwen3-0.6b"):
    cfg = get_arch(arch).reduced()
    if dscim != "off":
        cfg = dataclasses.replace(cfg, dscim=dscim)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, model, params


BUDGETS = np.array([2, 5, 3, 4, 6, 1], np.int32)


# --------------------------------------------------------------------------
# page-allocator hardening (satellite a) + blob round trip
# --------------------------------------------------------------------------

def test_page_allocator_free_validation():
    """free() rejects double frees, never-allocated ids and out-of-range
    ids instead of silently corrupting the free list — the classic way a
    scheduler bug turns into cross-request page aliasing."""
    a = PageAllocator(8)
    g1 = a.alloc(3)
    a.free(g1)
    with pytest.raises(ValueError, match="double free"):
        a.free(g1)                         # already back in the pool
    g2 = a.alloc(2)
    with pytest.raises(ValueError, match="double free"):
        a.free(g2 + [g2[0]])               # duplicate inside one call
    with pytest.raises(ValueError, match="never allocated|double free"):
        a.free([7])                        # never handed out
    with pytest.raises(ValueError, match="out of range"):
        a.free([8])
    with pytest.raises(ValueError, match="out of range"):
        a.free([-1])
    # a rejected call must not have committed anything: g2 still live
    assert a.free_pages == 6
    a.free(g2)
    assert a.free_pages == 8


def test_page_allocator_snapshot_roundtrip():
    a = PageAllocator(6)
    g1 = a.alloc(2)
    a.alloc(3)
    a.free(g1)
    snap = a.snapshot()
    b = PageAllocator.from_snapshot(snap)
    assert b.free_pages == a.free_pages == 3
    # identical allocation behaviour from the restored free list
    assert a.alloc(3) == b.alloc(3)
    assert a.alloc(1) is None and b.alloc(1) is None
    # the snapshot is a value, not a view
    snap2 = a.snapshot()
    a.free(g1)
    assert PageAllocator.from_snapshot(snap2).free_pages == 0


def test_page_allocator_random_schedule_properties():
    """Property-style hammer (ISSUE 8 satellite): random grant / free /
    snapshot-restore schedules checked against a reference model after
    every op.  Invariants: a grant never overlaps live pages (the
    double-grant corruption), free-page accounting is exact, high-water
    is the monotone peak of concurrent live pages, refusals are counted
    (not silently retried), a snapshot round-trip is behaviour-preserving
    mid-schedule, and a full drain leaks nothing — the whole pool
    re-allocates."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 33))
        a = PageAllocator(n)
        grants, live = [], set()      # the reference model
        peak = refusals = 0
        for _ in range(300):
            op = rng.random()
            if op < 0.5:
                k = int(rng.integers(1, max(2, n // 2)))
                ids = a.alloc(k)
                if k > n - len(live):
                    assert ids is None
                    refusals += 1
                else:
                    assert ids is not None and len(set(ids)) == k
                    assert all(0 <= i < n for i in ids)
                    assert not set(ids) & live, "double grant"
                    live.update(ids)
                    grants.append(ids)
                    peak = max(peak, len(live))
            elif op < 0.85 and grants:
                g = grants.pop(int(rng.integers(len(grants))))
                a.free(g)
                live.difference_update(g)
            else:
                a = PageAllocator.from_snapshot(a.snapshot())
            assert a.free_pages == n - len(live)
            st = a.stats()
            assert st["live_pages"] == len(live)
            assert st["high_water"] == peak
            assert st["refusals"] == refusals
        for g in grants:
            a.free(g)
        assert a.free_pages == n and a.stats()["live_pages"] == 0
        assert sorted(a.alloc(n)) == list(range(n))


def test_slot_page_blob_roundtrip():
    """extract_slot_pages -> insert_slot_pages restores a slot's share of
    the pool (pages, scales, tail, page-table row, position) bit-exactly
    into different physical pages — the eviction/re-admission primitive."""
    from repro.core.kvcache import init_paged_cache
    L, B, P, ps, KV, HD, mp = 2, 2, 8, 4, 2, 8, 3
    rng = np.random.default_rng(0)
    cache = init_paged_cache(L, B, P, ps, mp, KV, HD)
    cache = {
        **cache,
        "k_pages": jnp.asarray(rng.integers(-127, 128, (L, P, ps, KV, HD)),
                               jnp.int8),
        "v_pages": jnp.asarray(rng.integers(-127, 128, (L, P, ps, KV, HD)),
                               jnp.int8),
        "k_scale": jnp.asarray(rng.normal(1, .1, (L, P, KV)), jnp.float32),
        "v_scale": jnp.asarray(rng.normal(1, .1, (L, P, KV)), jnp.float32),
        "k_tail": jnp.asarray(rng.normal(0, 1, (L, B, ps, KV, HD)),
                              jnp.bfloat16),
        "v_tail": jnp.asarray(rng.normal(0, 1, (L, B, ps, KV, HD)),
                              jnp.bfloat16),
        "page_table": jnp.asarray([[0, 1, 1], [2, 3, 3]], jnp.int32),
        "pos": jnp.asarray([7, 6], jnp.int32),
    }
    blob = extract_slot_pages(cache, 0, [0, 1])
    assert blob["page_count"] == 2 and blob["pos"] == 7
    restored = insert_slot_pages(cache, 0, [5, 6], blob)  # new physical ids
    np.testing.assert_array_equal(np.asarray(restored["k_pages"][:, 5]),
                                  np.asarray(cache["k_pages"][:, 0]))
    np.testing.assert_array_equal(np.asarray(restored["v_pages"][:, 6]),
                                  np.asarray(cache["v_pages"][:, 1]))
    np.testing.assert_array_equal(np.asarray(restored["k_scale"][:, 5]),
                                  np.asarray(cache["k_scale"][:, 0]))
    np.testing.assert_array_equal(
        np.asarray(restored["k_tail"][:, 0], np.float32),
        np.asarray(cache["k_tail"][:, 0], np.float32))
    assert np.asarray(restored["page_table"][0]).tolist() == [5, 6, 6]
    assert int(restored["pos"][0]) == 7
    # the other slot's state is untouched
    np.testing.assert_array_equal(np.asarray(restored["page_table"][1]),
                                  np.asarray(cache["page_table"][1]))
    with pytest.raises(ValueError, match="parked but"):
        insert_slot_pages(cache, 0, [5], blob)


# --------------------------------------------------------------------------
# sampler degenerate-row guard (satellite b)
# --------------------------------------------------------------------------

def test_sampler_degenerate_row_guard():
    """top-k/top-p rows that mask everything (or go NaN upstream) fall
    back to per-row greedy instead of sampling garbage from a uniform-
    over-everything distribution; healthy rows keep drawing."""
    from repro.launch.steps import _make_sampler
    draw = _make_sampler("topk:2:1.0")
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([
        [0.0, 3.0, 1.0, 2.0],                        # healthy
        [-jnp.inf, -jnp.inf, -jnp.inf, -jnp.inf],    # fully masked
        [jnp.nan, 0.5, jnp.nan, 0.2],                # NaN poisoned
        [1.0, jnp.inf, 0.0, 0.0],                    # +inf spike
    ])
    toks = np.asarray(draw(key, logits))
    assert toks[0] in (1, 3)          # top-2 of the healthy row
    assert toks[1] == 0               # all -inf: greedy argmax fallback
    assert toks[2] == 1               # NaN masked out of the argmax
    assert toks[3] == 1               # inf row: the spike is the argmax
    # the guard must not perturb healthy-row draws: all-healthy batch
    # draws the same token for row 0 under the same key
    healthy = jnp.tile(logits[0:1], (4, 1))
    assert np.asarray(draw(key, healthy))[0] == toks[0]


def test_sampler_degenerate_topp():
    from repro.launch.steps import _make_sampler
    draw = _make_sampler("topp:0.5:1.0")
    key = jax.random.PRNGKey(1)
    logits = jnp.asarray([[0.1, 0.9, 0.2, 0.3],
                          [jnp.nan, jnp.nan, jnp.nan, jnp.nan]])
    toks = np.asarray(draw(key, logits))
    assert toks[1] == 0               # all-NaN row: deterministic fallback


# --------------------------------------------------------------------------
# accuracy watchdog + ladder algebra
# --------------------------------------------------------------------------

def test_accuracy_watchdog_check():
    wd = AccuracyWatchdog(rel_threshold=0.5, probe_every=2)
    assert wd.should_probe(0) and not wd.should_probe(1) \
        and wd.should_probe(2)
    exact = np.ones((3, 8))
    near = exact + 0.01
    far = exact + 10.0
    nan = exact.copy()
    nan[2, 0] = np.nan
    live = np.asarray([True, True, False])
    trip, rel = wd.check(np.stack([near[0], far[1], nan[2]]), exact, live)
    assert not trip[0] and rel[0] < 0.1
    assert trip[1] and rel[1] > 1.0
    assert not trip[2]                # dead slots never trip (NaN or not)
    trip2, _ = wd.check(nan, exact, np.asarray([True, True, True]))
    assert trip2[2]                   # live NaN row trips regardless
    assert wd.n_probes == 2 and wd.n_trips == 2
    with pytest.raises(ValueError, match="probe_every"):
        AccuracyWatchdog(0.5, probe_every=0)


def test_ladder_spec_algebra():
    assert next_ladder_spec("kernel:dscim2:64") == "kernel:dscim1:256"
    assert next_ladder_spec("kernel:dscim1:256") == "exact:dscim1:256"
    assert next_ladder_spec("lut+attn:dscim2:64:opt") \
        == "lut+attn:dscim1:256:opt"
    assert next_ladder_spec("exact:dscim1:256") is None
    assert next_ladder_spec("off") is None
    assert exact_probe_spec("kernel+attn:dscim2:64") \
        == "exact+attn:dscim2:64"
    assert exact_probe_spec("off") == "off"


def test_relative_moment_bound_scales():
    from repro.core.dscim_layer import calibrated_config
    from repro.core.error_model import ErrorModel
    from repro.core.macro import DSCIMMacro
    em1 = ErrorModel.from_macro(DSCIMMacro(calibrated_config("dscim1", 256,
                                                             "paper")))
    em2 = ErrorModel.from_macro(DSCIMMacro(calibrated_config("dscim2", 64,
                                                             "paper")))
    b1, b2 = em1.relative_moment_bound(), em2.relative_moment_bound()
    assert 0 < b1 < b2                # dscim2 is the noisier point
    wd = watchdog_for_spec("kernel:dscim2:64", probe_every=4)
    assert wd.rel_threshold == pytest.approx(3.0 * b2)
    assert wd.probe_every == 4


# --------------------------------------------------------------------------
# snapshot/restore + failover replay: bitwise parity (satellite d)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kv,paged_attn", [("float", "auto"),
                                           ("int8", "jnp"),
                                           ("int8", "kernel")])
def test_failover_replay_bitwise(kv, paged_attn):
    """A mid-stream device loss + snapshot restore replays the serve
    bit-identically to the uninterrupted run — across the dense and
    paged KV layouts and both paged-attention read paths."""
    cfg, model, params = _setup()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (6, 8),
                                                dtype=np.int32)
    knobs = dict(slots=3, seg_len=2, max_new=BUDGETS, eos_id=-1, kv=kv,
                 page_size=4, paged_attn=paged_attn)
    ref, _ = serve_continuous(cfg, params, prompts, 6, **knobs)
    outs, stats = serve_continuous(cfg, params, prompts, 6, **knobs,
                                   injector=FailureInjector(fail_at=(2,)),
                                   snapshot_every=1, log=lambda *a: None)
    assert stats["replays"] == 1
    assert stats["status"] == [STATUS_OK] * 6
    for r, (a, b) in enumerate(zip(outs, ref)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {r}")


def test_failover_exhausts_replays():
    """An unrecoverable fault pattern (fresh failure every segment beyond
    the budget) surfaces instead of looping forever."""
    from repro.runtime.failover import SimulatedHardwareFailure
    cfg, model, params = _setup()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8),
                                                dtype=np.int32)
    with pytest.raises(SimulatedHardwareFailure):
        serve_continuous(cfg, params, prompts, 4, slots=2, seg_len=2,
                         eos_id=-1, max_new=np.asarray([4, 4], np.int32),
                         injector=FailureInjector(fail_at=(0, 1, 2, 3)),
                         snapshot_every=1, max_replays=2,
                         log=lambda *a: None)


# --------------------------------------------------------------------------
# preemptive eviction + re-admission (tentpole) and deadlines
# --------------------------------------------------------------------------

def test_eviction_readmission_bitwise_parity():
    """A high-priority admission preempts the youngest lower-priority
    slot; the evictee's pages round-trip host-side and it resumes
    mid-stream — bit-identical (greedy) to a run with a big-enough pool
    that never evicts."""
    cfg, model, params = _setup()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (6, 8),
                                                dtype=np.int32)
    budgets = np.array([6, 8, 8, 6, 6, 6], np.int32)
    prio = np.array([0, 0, 5, 0, 0, 0], np.int64)
    mp = n_pages_for(8 + 8, 4)
    knobs = dict(slots=3, seg_len=2, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=4)
    big, _ = serve_continuous(cfg, params, prompts, 8, **knobs)
    outs, stats = serve_continuous(cfg, params, prompts, 8, **knobs,
                                   n_pages=2 * mp, priority=prio)
    assert stats["evictions"] >= 1 and stats["readmissions"] >= 1
    assert stats["evicted_requests"], stats
    assert stats["status"] == [STATUS_OK] * 6
    for r, (a, b) in enumerate(zip(outs, big)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {r}")


def test_eviction_requires_strictly_higher_priority():
    """Equal priorities never evict (livelock guard): the scheduler falls
    back to the PR-4 wait-for-pages behaviour."""
    cfg, model, params = _setup()
    prompts = np.random.default_rng(2).integers(0, cfg.vocab, (4, 8),
                                                dtype=np.int32)
    budgets = np.array([3, 4, 2, 3], np.int32)
    mp = n_pages_for(8 + 4, 4)
    outs, stats = serve_continuous(cfg, params, prompts, 4, slots=3,
                                   seg_len=2, max_new=budgets, eos_id=-1,
                                   kv="int8", page_size=4, n_pages=2 * mp,
                                   priority=np.zeros(4, np.int64))
    assert stats["evictions"] == 0
    assert [len(o) for o in outs] == budgets.tolist()


def test_deadline_step_cancellation():
    """A step-budget expiry cancels between segments: definite 'deadline'
    status, partial tokens kept, slot + pages recycled for the queue."""
    cfg, model, params = _setup()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (6, 8),
                                                dtype=np.int32)
    dl = np.array([-1, 2, -1, -1, -1, -1], np.int64)
    outs, stats = serve_continuous(cfg, params, prompts, 6, slots=3,
                                   seg_len=2, max_new=BUDGETS, eos_id=-1,
                                   kv="int8", page_size=4,
                                   deadline_steps=dl)
    assert stats["status"][1] == STATUS_DEADLINE
    assert stats["deadline_cancelled"] == 1
    assert 0 < len(outs[1]) < int(BUDGETS[1])     # partial, not empty
    for r in (0, 2, 3, 4, 5):
        assert stats["status"][r] == STATUS_OK
        assert len(outs[r]) == int(BUDGETS[r])


def test_deadline_expired_while_waiting():
    """A queued request whose deadline passes before it ever gets a slot
    is cancelled with empty output, not served late."""
    cfg, model, params = _setup()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 8),
                                                dtype=np.int32)
    budgets = np.array([6, 6, 6, 4], np.int32)
    dl = np.array([-1, -1, -1, 2], np.int64)
    outs, stats = serve_continuous(cfg, params, prompts, 6, slots=2,
                                   seg_len=2, max_new=budgets, eos_id=-1,
                                   deadline_steps=dl)
    assert stats["status"][3] == STATUS_DEADLINE and len(outs[3]) == 0
    assert stats["status"][:3] == [STATUS_OK] * 3


class _FakeClock:
    """Deterministic stand-in for the ``time`` module inside
    runtime/serving.py: every ``perf_counter()`` call advances one fake
    second, so queue time and service time become countable quantities
    instead of scheduler-speed noise."""

    def __init__(self):
        self.t = 0.0

    def perf_counter(self) -> float:
        self.t += 1.0
        return self.t


def test_deadline_s_anchors_at_admission(monkeypatch):
    """Staggered admission under a wall budget (ISSUE 8 satellite): with
    one slot, request 1 queues behind request 0's 16-token stream — over
    8 engine rounds (= 8 fake seconds) of waiting.  Its 6-second wall
    budget must anchor at *admission*: service itself takes ~3 rounds, so
    it completes 'ok'.  Anchoring at serve start (the pre-fix behaviour)
    would have expired it in the queue.  A genuinely tight post-admission
    budget still expires with partial output."""
    import repro.runtime.serving as serving
    cfg, model, params = _setup()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8),
                                                dtype=np.int32)
    budgets = np.array([16, 6], np.int32)

    def run(dl1):
        monkeypatch.setattr(serving, "time", _FakeClock())
        dl = np.array([-1.0, dl1], np.float64)
        return serve_continuous(cfg, params, prompts, 16, slots=1,
                                seg_len=2, max_new=budgets, eos_id=-1,
                                kv="int8", page_size=4, deadline_s=dl)

    outs, stats = run(6.0)
    assert stats["status"] == [STATUS_OK, STATUS_OK], stats["status"]
    assert stats["deadline_cancelled"] == 0
    assert [len(o) for o in outs] == budgets.tolist()

    outs, stats = run(0.5)           # < 1 fake second: expires in service
    assert stats["status"] == [STATUS_OK, STATUS_DEADLINE]
    assert stats["deadline_cancelled"] == 1
    assert 0 < len(outs[1]) < int(budgets[1])     # partial tokens kept


# --------------------------------------------------------------------------
# accuracy watchdog end to end: NaN and drift trips -> ladder
# --------------------------------------------------------------------------

class _InfScaleInjector(FailureInjector):
    """Deterministic NaN source: set one live dequant scale to +inf (a
    single XOR flip cannot guarantee NaN through RMSNorm's squashing,
    so the NaN-path test injects the poisoned value directly)."""

    def corrupt_cache(self, segment, cache, slot_pages):
        key = ("inf", 1)
        if segment != 1 or key in self.fired or slot_pages[0] is None:
            return cache, []
        self.fired.add(key)
        pid = int(slot_pages[0][0])
        return dict(cache, v_scale=cache["v_scale"].at[0, pid, 0]
                    .set(np.inf)), [0]


def test_nonfinite_quarantine_escalates():
    """Inf in the KV pool -> NaN logits -> the slot is quarantined the
    same segment (no probe needed), its poisoned tokens discarded, and
    the request re-served down the ladder to a full, definite output."""
    spec = "kernel:dscim2:64"
    cfg, model, params = _setup(spec)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 8),
                                                dtype=np.int32)
    budgets = np.asarray([8, 6, 8, 5], np.int32)
    mon = AccuracyWatchdog(None)      # NaN-only monitoring: no probes
    outs, stats = serve_continuous(cfg, params, prompts, 8, slots=2,
                                   seg_len=2, max_new=budgets, eos_id=-1,
                                   kv="int8", page_size=4, monitor=mon,
                                   injector=_InfScaleInjector(),
                                   snapshot_every=1, log=lambda *a: None)
    assert stats["quarantined"] == [0]
    assert stats["probes"] == 0
    esc = [e for e in stats["escalations"] if e["request"] == 0]
    assert esc and esc[0]["reason"] == "nonfinite"
    assert esc[0]["to"] == "kernel:dscim1:256" and esc[0]["accepted"]
    assert stats["status"] == [STATUS_OK] * 4
    assert [len(o) for o in outs] == budgets.tolist()


def test_macro_fault_drift_trips_and_escalates():
    """A persistent stuck-at macro fault drifts every live slot past the
    moment-derived threshold; the healthy run never trips (the margin-3
    calibration this pins: healthy ~2x the bound, faulted ~16x)."""
    spec = "kernel:dscim2:64"
    cfg, model, params = _setup(spec)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 8),
                                                dtype=np.int32)
    budgets = np.asarray([6, 5, 6, 5], np.int32)
    knobs = dict(slots=2, seg_len=2, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=4, log=lambda *a: None)
    healthy = watchdog_for_spec(spec, probe_every=1)
    outs_h, stats_h = serve_continuous(cfg, params, prompts, 6, **knobs,
                                       monitor=healthy)
    assert stats_h["probe_trips"] == 0 and not stats_h["quarantined"]
    rels = np.concatenate([h[np.isfinite(h)] for h in healthy.history])
    assert rels.max() < healthy.rel_threshold
    faulted = watchdog_for_spec(spec, probe_every=1)
    inj = FailureInjector(macro_fault_at=0, macro_fault="stuck:3:40.0")
    outs_f, stats_f = serve_continuous(cfg, params, prompts, 6, **knobs,
                                       monitor=faulted, injector=inj)
    assert stats_f["probe_trips"] >= 2
    assert stats_f["quarantined"]
    hops = {(e["frm"], e["to"]) for e in stats_f["escalations"]}
    assert ("kernel:dscim2:64", "kernel:dscim1:256") in hops
    assert stats_f["status"] == [STATUS_OK] * 4
    assert [len(o) for o in outs_f] == budgets.tolist()


def test_monitor_rejects_float_serving():
    cfg, model, params = _setup()          # dscim off: nothing to probe
    prompts = np.zeros((2, 8), np.int32)
    with pytest.raises(ValueError, match="exact-mode twin"):
        serve_continuous(cfg, params, prompts, 4, slots=2, seg_len=2,
                         eos_id=-1, monitor=AccuracyWatchdog(0.5))


# --------------------------------------------------------------------------
# fault model plumbing
# --------------------------------------------------------------------------

def test_flip_bits_float_and_int():
    x = jnp.asarray([1.0, 2.0], jnp.float32)
    y = flip_bits(x, (0,), 1 << 30)
    assert np.isinf(np.asarray(y)[0])      # 1.0 ^ exponent-msb = +inf
    assert np.asarray(y)[1] == 2.0
    q = jnp.asarray([[3, -4]], jnp.int8)
    q2 = flip_bits(q, (0, 1), 0x7f)
    assert np.asarray(q2)[0, 1] == -125   # 0xfc ^ 0x7f = 0x83 as int8
    assert np.asarray(q2)[0, 0] == 3


def test_dscim_fault_spec_wraps_operator():
    """cfg.dscim_fault pins every <stride>-th output column without
    touching params — the exact-mode probe on the same prepared weights
    stays clean (the watchdog's isolation property)."""
    from repro.models.lm import _linear_for, _parse_fault
    assert _parse_fault("stuck:5:24.0") == (5, 24.0)
    with pytest.raises(ValueError, match="dscim_fault"):
        _parse_fault("stuck:5")
    with pytest.raises(ValueError, match="stride"):
        _parse_fault("stuck:0:1.0")
    op = _linear_for("lut:dscim1:256", None, "stuck:4:7.5")
    clean = _linear_for("lut:dscim1:256")
    assert op.group_k == clean.group_k
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 8)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).normal(0, 1, (8, 12)),
                    jnp.float32)
    y = np.asarray(op(x, w))
    assert (y[:, ::4] == 7.5).all()
    np.testing.assert_array_equal(y[:, 1::4],
                                  np.asarray(clean(x, w))[:, 1::4])


# --------------------------------------------------------------------------
# the full acceptance scenario
# --------------------------------------------------------------------------

def test_chaos_drill():
    """The self-verifying end-to-end scenario: device loss + page-pool
    flips + stuck-at macro fault + deadline expiry, every assertion of
    the ISSUE 6 acceptance contract inside chaos_drill itself."""
    report = chaos_drill(log=lambda *a: None)
    assert report["replays"] == 1
    assert report["escalations"] >= 1
    assert report["deadline_cancelled"] == 1
    assert report["clean"]
