"""Self-speculative decoding (ISSUE 7): DS-CIM2 drafts, DS-CIM1 verifies,
one device-resident loop (launch/steps.py ``_make_spec_window``).

The load-bearing contract is *bitwise parity*: greedy spec serving must
reproduce target-only greedy serving exactly — same tokens, same KV cache
evolution — for every kv / paged_attn combination, because the verify
pass replays the single-token op sequence per position and the rollback
(core/kvcache.py ``spec_rollback``) reconstructs the committed paged tail
from the window projections.  The noise backends (statistical /
paper_inject) fold output *shape* into their fallback keys, so a batched
(B, T) verify draws different noise than T single-token calls — they are
excluded from the k>0 bitwise guarantee (and documented as such) but
covered by the k=0 fall-through tests.

Sampled decoding is replay-deterministic (the PRNG key rides the loop
carry); a single-row batch stays bitwise-aligned with the non-spec driver
because the key commits once per emitted position — the rejected-draft
RNG-stream test below pins that.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.kvcache import PageAllocator, spec_rollback
from repro.launch.serve import serve_batch, serve_continuous
from repro.launch.steps import (_draft_cfg, _parse_spec, make_generate_fn,
                                make_segment_fn)
from repro.models import get_model

MODES = ["off", "exact:dscim2:64", "lut:dscim2:64", "bitmatmul:dscim2:64",
         "kernel:dscim2:64", "kernel+attn:dscim2:64",
         "statistical:dscim2:64", "paper_inject:dscim2:64"]
# deterministic estimators: batched verify MVMs are bitwise the
# single-token MVMs, so greedy spec == greedy non-spec exactly
DET_MODES = ["off", "exact:dscim1:256", "lut:dscim1:256",
             "bitmatmul:dscim1:256", "kernel+attn:dscim1:256"]


def _setup(dscim="off", arch="qwen3-0.6b", rows=2):
    cfg = get_arch(arch).reduced()
    if dscim != "off":
        cfg = dataclasses.replace(cfg, dscim=dscim)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (rows, 8),
                                                dtype=np.int32)
    return cfg, params, prompts


# ---------------------------------------------------------------------------
# spec parsing / draft-config algebra
# ---------------------------------------------------------------------------

def test_parse_spec():
    assert _parse_spec(None) is None
    assert _parse_spec("") is None
    assert _parse_spec("dscim2:0") is None          # k=0: plain driver
    assert _parse_spec("dscim2:4") == ("dscim2", 4)
    assert _parse_spec("dscim1:2") == ("dscim1", 2)
    for bad in ["dscim2", "dscim3:4", "dscim2:-1", "dscim2:x", "4"]:
        with pytest.raises(ValueError):
            _parse_spec(bad)


def test_draft_cfg_rewrites_operating_point_only():
    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              dscim="kernel+attn:dscim1:256:minmax")
    d = _draft_cfg(cfg, "dscim2")
    assert d.dscim == "kernel+attn:dscim2:64:minmax"
    assert cfg.dscim == "kernel+attn:dscim1:256:minmax"  # original intact
    # off/float have no estimator to cheapen: degenerate self-draft
    for spec in ["off", "float"]:
        c = dataclasses.replace(cfg, dscim=spec)
        assert _draft_cfg(c, "dscim2").dscim == spec


# ---------------------------------------------------------------------------
# greedy bitwise parity — the tentpole acceptance criterion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv,paged_attn", [("float", "auto"),
                                           ("int8", "jnp"),
                                           ("int8", "kernel")])
def test_spec_greedy_bitwise_kv_combos(kv, paged_attn):
    """kernel:dscim1 verify with dscim2 drafts rejects often enough to
    leave windows page-misaligned — page_size=4 forces draft writes across
    page boundaries, exercising the tail restore + spec_rollback paths."""
    cfg, params, prompts = _setup("kernel:dscim1:256")
    t_ref, _ = serve_batch(cfg, params, prompts, 8, kv=kv, page_size=4,
                           paged_attn=paged_attn)
    t_spec, _, ss = serve_batch(cfg, params, prompts, 8, kv=kv, page_size=4,
                                paged_attn=paged_attn, spec="dscim2:3",
                                spec_stats=True)
    np.testing.assert_array_equal(np.asarray(t_spec), np.asarray(t_ref))
    # every live row emits >= 1 token per verify window
    assert (ss["emitted"] >= ss["windows"]).all()
    assert (ss["emitted"] == 8).all()


@pytest.mark.parametrize("dscim", DET_MODES)
def test_spec_greedy_bitwise_modes(dscim):
    cfg, params, prompts = _setup(dscim)
    t_ref, _ = serve_batch(cfg, params, prompts, 6)
    t_spec, _ = serve_batch(cfg, params, prompts, 6, spec="dscim2:2")
    np.testing.assert_array_equal(np.asarray(t_spec), np.asarray(t_ref))


def test_spec_composes_with_eos_and_budget():
    """EOS early-exit + per-slot budgets under spec: emitted rows stop at
    EOS/budget exactly where the non-spec while_loop stops them."""
    cfg, params, prompts = _setup("kernel:dscim1:256")
    kw = dict(kv="int8", page_size=4, eos_id=14, max_new=[6, 4])
    t_ref, _ = serve_batch(cfg, params, prompts, 8, **kw)
    t_spec, _ = serve_batch(cfg, params, prompts, 8, spec="dscim2:3", **kw)
    np.testing.assert_array_equal(np.asarray(t_spec), np.asarray(t_ref))


# ---------------------------------------------------------------------------
# ISSUE 7 satellite: k=0 falls through to the plain driver, all modes and
# samplers — and rejected-draft RNG streams stay aligned with non-spec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dscim", MODES)
def test_spec_k0_matches_plain_driver(dscim):
    cfg, params, prompts = _setup(dscim)
    for sample in ["greedy", "temp:0.8", "topk:8:0.9", "topp:0.9"]:
        kw = dict(eos_id=7, sample=sample, rng_seed=3)
        t_ref, _ = serve_batch(cfg, params, prompts, 4, **kw)
        t0, _ = serve_batch(cfg, params, prompts, 4, spec="dscim2:0", **kw)
        np.testing.assert_array_equal(np.asarray(t0), np.asarray(t_ref),
                                      err_msg=f"{dscim} {sample}")


def test_spec_rejected_drafts_leave_rng_stream_aligned():
    """Single-row sampled serving: the carried key commits exactly once
    per *emitted* position (rejected draft positions consume nothing), so
    the i-th draw uses the same chain state as the non-spec driver's i-th
    step — greedy dscim2 drafts against temperature sampling reject
    constantly, making this the rejected-draft stream test."""
    cfg, params, prompts = _setup("kernel:dscim1:256", rows=1)
    for kv in ["float", "int8"]:
        kw = dict(kv=kv, page_size=4, sample="temp:0.8", rng_seed=5)
        t_ref, _ = serve_batch(cfg, params, prompts, 8, **kw)
        t_spec, _, ss = serve_batch(cfg, params, prompts, 8, spec="dscim2:3",
                                    spec_stats=True, **kw)
        np.testing.assert_array_equal(np.asarray(t_spec), np.asarray(t_ref),
                                      err_msg=f"kv={kv}")
        # sampling vs greedy drafts must actually have rejected something,
        # or this test pinned nothing
        assert int(ss["windows"][0]) > (8 - 1 + 3) // 4, ss


def test_spec_sampled_replay_deterministic():
    cfg, params, prompts = _setup("kernel:dscim1:256")
    kw = dict(kv="int8", page_size=4, sample="temp:0.8", rng_seed=3,
              spec="dscim2:3")
    a, _ = serve_batch(cfg, params, prompts, 8, **kw)
    b, _ = serve_batch(cfg, params, prompts, 8, **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# the loop stays device-resident: jaxpr shape of the spec drivers
# ---------------------------------------------------------------------------

def _count_scans(jaxpr, length) -> int:
    def subs(v):
        if hasattr(v, "jaxpr"):
            return [v.jaxpr]
        if hasattr(v, "eqns"):
            return [v]
        if isinstance(v, (list, tuple)):
            return [j for x in v for j in subs(x)]
        return []

    n = sum(1 for e in jaxpr.eqns
            if e.primitive.name == "scan" and e.params.get("length") == length)
    for e in jaxpr.eqns:
        for v in e.params.values():
            n += sum(_count_scans(j, length) for j in subs(v))
    return n


def _count_whiles(jaxpr) -> int:
    def subs(v):
        if hasattr(v, "jaxpr"):
            return [v.jaxpr]
        if hasattr(v, "eqns"):
            return [v]
        if isinstance(v, (list, tuple)):
            return [j for x in v for j in subs(x)]
        return []

    n = sum(1 for e in jaxpr.eqns if e.primitive.name == "while")
    for e in jaxpr.eqns:
        for v in e.params.values():
            n += sum(_count_whiles(j) for j in subs(v))
    return n


def test_spec_segment_is_one_scan_accept_in_carry():
    """A spec segment is still ONE top-level lax.scan of seg_len windows
    (one host dispatch per segment); the k-step draft scan and the
    (k+1)-step accept fold live *inside* it — accept/reject never leaves
    the device carry."""
    from repro.launch.steps import init_serve_state, prepare_serving_params
    cfg, params, _ = _setup("kernel:dscim1:256")
    seg_len, k = 3, 4
    assert len({seg_len, k, k + 1, cfg.n_layers}) == 4  # no length clashes
    pp = prepare_serving_params(cfg, params)
    state = init_serve_state(cfg, 2, 16, kv="int8", page_size=4)
    seg = make_segment_fn(cfg, None, seg_len, jit=False,
                          spec=f"dscim2:{k}")
    jaxpr = jax.make_jaxpr(seg)(pp, state)
    assert _count_scans(jaxpr.jaxpr, seg_len) == 1
    assert _count_scans(jaxpr.jaxpr, k) >= 1       # draft loop, inside
    assert _count_scans(jaxpr.jaxpr, k + 1) >= 1   # accept fold, inside


def test_spec_generate_is_one_while_loop():
    from repro.launch.steps import prepare_serving_params
    cfg, params, prompts = _setup("kernel:dscim1:256")
    pp = prepare_serving_params(cfg, params)
    gen = make_generate_fn(cfg, None, 8, jit=False, spec="dscim2:3")
    jaxpr = jax.make_jaxpr(gen)(pp, {"tokens": jnp.asarray(prompts)})
    assert _count_whiles(jaxpr.jaxpr) == 1


def test_spec_rejects_trace_logits_and_host_loop():
    cfg, params, prompts = _setup("kernel:dscim1:256")
    with pytest.raises(ValueError):
        make_generate_fn(cfg, None, 8, trace_logits=True, spec="dscim2:3")
    with pytest.raises(ValueError):
        serve_batch(cfg, params, prompts, 4, scan=False, spec="dscim2:3")


# ---------------------------------------------------------------------------
# ISSUE 7 satellite: builder-cache keying — every serving knob must key a
# fresh executable (stale-cache aliasing here silently serves wrong math)
# ---------------------------------------------------------------------------

def test_builder_cache_keys_every_knob():
    cfg, _, _ = _setup()
    base = dict(n_tokens=7)
    g = make_generate_fn(cfg, None, 7)
    assert g is make_generate_fn(cfg, None, 7)
    for flip in [dict(spec="dscim2:2"), dict(sample="temp:0.5"),
                 dict(paged_attn="jnp"), dict(kv="int8"), dict(eos_id=3)]:
        assert g is not make_generate_fn(cfg, None, 7, **flip), flip
    s = make_segment_fn(cfg, None, 4)
    assert s is make_segment_fn(cfg, None, 4)
    for flip in [dict(spec="dscim2:2"), dict(sample="temp:0.5"),
                 dict(paged_attn="jnp"), dict(eos_id=3)]:
        assert s is not make_segment_fn(cfg, None, 4, **flip), flip
    # distinct spec strings are distinct executables (k is static)
    assert make_generate_fn(cfg, None, 7, spec="dscim2:2") is not \
        make_generate_fn(cfg, None, 7, spec="dscim2:3")


# ---------------------------------------------------------------------------
# paged rollback + allocator accounting
# ---------------------------------------------------------------------------

def test_spec_rollback_rebuilds_committed_tail():
    """After a rejected window crossed a page boundary the committed tail
    page must be rebuilt: offsets >= pos0 from the window projections,
    offsets below it (same page, committed before the window) from the
    pre-window tail.  Pool planes and the page table are untouched —
    rollback never talks to the PageAllocator."""
    rng = np.random.default_rng(0)
    L, B, ps, KV, HD, T = 2, 3, 4, 2, 5, 3        # layer-stacked planes
    pos0 = jnp.asarray([6, 5, 4], jnp.int32)
    new_pos = jnp.asarray([9, 5, 6], jnp.int32)   # cross / reject-all / mid
    tails0 = tuple(jnp.asarray(rng.normal(size=(L, B, ps, KV, HD)),
                               jnp.float32) for _ in range(2))
    win = tuple(jnp.asarray(rng.normal(size=(L, B, T, KV, HD)), jnp.float32)
                for _ in range(2))
    cache = {"k_pages": jnp.zeros((L, 8, ps, KV, HD), jnp.int8),
             "k_tail": jnp.asarray(rng.normal(size=(L, B, ps, KV, HD)),
                                   jnp.float32),
             "v_tail": jnp.asarray(rng.normal(size=(L, B, ps, KV, HD)),
                                   jnp.float32),
             "pos": pos0}
    out = spec_rollback(cache, pos0, new_pos, tails0, win)
    np.testing.assert_array_equal(np.asarray(out["pos"]),
                                  np.asarray(new_pos))
    for plane, t0, w in [("k_tail", tails0[0], win[0]),
                         ("v_tail", tails0[1], win[1])]:
        got = np.asarray(out[plane])
        for b in range(B):
            base = int(new_pos[b]) // ps * ps
            for o in range(ps):
                i = base + o                       # stream index of offset o
                if i >= int(new_pos[b]):
                    continue                       # uncommitted: don't-care
                exp = np.asarray(w)[:, b, i - int(pos0[b])] \
                    if i >= int(pos0[b]) else np.asarray(t0)[:, b, o]
                np.testing.assert_array_equal(got[:, b, o], exp,
                                              err_msg=f"{plane} b={b} o={o}")
    # dense cache: pos truncation only
    d = spec_rollback({"pos": pos0, "k": 1}, pos0, new_pos)
    assert np.asarray(d["pos"]).tolist() == np.asarray(new_pos).tolist()
    assert d["k"] == 1


def test_page_allocator_stats():
    a = PageAllocator(4)
    st0 = a.stats()
    assert {k: st0[k] for k in ("n_pages", "live_pages", "high_water",
                                "refusals")} \
        == {"n_pages": 4, "live_pages": 0, "high_water": 0, "refusals": 0}
    # the ISSUE 10 sharing counters start at zero and stay there on the
    # non-prefix path exercised here
    assert {k: st0[k] for k in ("shared_pages", "retained_pages", "shares",
                                "reclaimed")} \
        == {"shared_pages": 0, "retained_pages": 0, "shares": 0,
            "reclaimed": 0}
    p1 = a.alloc(3)
    assert a.stats()["live_pages"] == 3 and a.stats()["high_water"] == 3
    assert a.alloc(2) is None                     # refused, pool exhausted
    assert a.stats()["refusals"] == 1
    a.free(p1)
    p2 = a.alloc(4)
    st = a.stats()
    assert {k: st[k] for k in ("n_pages", "live_pages", "high_water",
                               "refusals")} \
        == {"n_pages": 4, "live_pages": 4, "high_water": 4, "refusals": 1}
    # counters survive the snapshot/restore failover path
    b = PageAllocator.from_snapshot(a.snapshot())
    assert b.stats() == st
    b.free(p2)
    assert b.stats()["live_pages"] == 0 and b.stats()["high_water"] == 4


# ---------------------------------------------------------------------------
# continuous serving composition: scheduler, deadlines, watchdog
# ---------------------------------------------------------------------------

def test_spec_continuous_bitwise_and_no_page_leak():
    cfg, params, _ = _setup("kernel:dscim1:256")
    prompts = np.random.default_rng(1).integers(0, cfg.vocab, (5, 8),
                                                dtype=np.int32)
    kw = dict(slots=2, seg_len=2, kv="int8", page_size=4, eos_id=14)
    o_ref, _ = serve_continuous(cfg, params, prompts, 8, **kw)
    o_spec, st = serve_continuous(cfg, params, prompts, 8, spec="dscim2:3",
                                  **kw)
    for r, (a, b) in enumerate(zip(o_spec, o_ref)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {r}")
    # every page returned to the allocator; occupancy rows read these
    assert st["pages"]["live_pages"] == 0
    assert st["pages"]["high_water"] >= 1
    assert st["pages"]["n_pages"] >= st["pages"]["high_water"]


def test_spec_deadline_counts_rejected_draft_positions():
    """deadline_steps is a verifier-position ledger: one spec segment
    attempts seg_len * (k+1) positions whether or not drafts are
    accepted, so a budget of exactly that expires the request at the
    first boundary — where the non-spec run takes k+1 x more segments."""
    cfg, params, _ = _setup("kernel:dscim1:256")
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (1, 8),
                                                dtype=np.int32)
    seg_len, k = 2, 3
    kw = dict(slots=1, seg_len=seg_len, kv="int8", page_size=4, eos_id=-1,
              deadline_steps=[seg_len * (k + 1)])
    o_s, st_s = serve_continuous(cfg, params, prompts, 16,
                                 spec=f"dscim2:{k}", **kw)
    o_n, st_n = serve_continuous(cfg, params, prompts, 16, **kw)
    assert st_s["status"] == ["deadline"] and st_n["status"] == ["deadline"]
    assert st_s["segments"] == 1                  # expired after one segment
    assert st_n["segments"] == k + 1              # same ledger, k+1 segments
    # partial tokens kept, and the greedy streams agree on their overlap
    n = min(len(o_s[0]), len(o_n[0]))
    assert n >= 1
    np.testing.assert_array_equal(o_s[0][:n], o_n[0][:n])


def test_spec_watchdog_probes_the_verifier():
    """aux['logits0'] under spec is the first window's verify logits at
    position 0 — verifier estimator, same (token, cache) inputs as the
    exact probe.  A healthy dscim1 run must sit under the dscim1-derived
    threshold; draft (dscim2) logits leaking into the probe would trip
    it."""
    from repro.runtime.serving import watchdog_for_spec
    spec = "kernel:dscim1:256"
    cfg, params, _ = _setup(spec)
    prompts = np.random.default_rng(2).integers(0, cfg.vocab, (2, 8),
                                                dtype=np.int32)
    mon = watchdog_for_spec(spec, probe_every=1)
    _, st = serve_continuous(cfg, params, prompts, 6, slots=2, seg_len=2,
                             kv="int8", page_size=4, eos_id=-1,
                             monitor=mon, spec="dscim2:2")
    assert st["probes"] >= 1
    assert st["probe_trips"] == 0 and st["quarantined"] == []
