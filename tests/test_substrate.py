"""Substrate tests: optimizer, compression, checkpointing, data, runtime."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import SyntheticLM
from repro.optim.adamw import AdamW, cosine_schedule, global_norm
from repro.optim.compression import compress, decompress
from repro.runtime.failover import (FailureInjector, run_with_failover,
                                    SimulatedHardwareFailure)
from repro.runtime.watchdog import StepHang, Watchdog


# ---------------- optimizer ----------------

def test_adamw_matches_numpy_reference():
    opt = AdamW(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                clip_norm=1e9)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.5, 0.5, -1.0])}
    st_ = opt.init(p)
    p1, st1, _ = opt.update(p, g, st_)
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    want = np.asarray(p["w"]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.05, weight_decay=0.0)
    p = {"w": jnp.ones(4) * 5}
    s = opt.init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, s, _ = opt.update(p, g, s)
    assert float(jnp.abs(p["w"]).max()) < 0.3


def test_grad_clipping():
    opt = AdamW(lr=1.0, clip_norm=1.0)
    p = {"w": jnp.zeros(3)}
    s = opt.init(p)
    _, _, gnorm = opt.update(p, {"w": jnp.asarray([3.0, 4.0, 0.0])}, s)
    assert abs(float(gnorm) - 5.0) < 1e-4


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)


# ---------------- compression ----------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_int8_compression_roundtrip_bound(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, 1, 256), jnp.float32)
    q, s = compress(g)
    err = np.abs(np.asarray(decompress(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_drives_mean_error_down():
    """With error feedback, time-averaged compressed gradients converge to
    the true mean (the EF property that keeps training unbiased)."""
    rng = np.random.default_rng(0)
    true = rng.normal(0, 1, 64).astype(np.float32)
    e = np.zeros_like(true)
    acc = np.zeros_like(true)
    n = 400
    for _ in range(n):
        g = true + rng.normal(0, 0.3, 64).astype(np.float32)
        q, s = compress(jnp.asarray(g + e))
        ghat = np.asarray(decompress(q, s))
        e = g + e - ghat
        acc += ghat
    np.testing.assert_allclose(acc / n, true, atol=0.06)


# ---------------- checkpointing ----------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.int32)}}
    ck.save(5, tree, extras={"loss": 1.5}, blocking=True)
    assert ck.latest_step() == 5
    restored, extras = ck.restore(5, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert extras["loss"] == 1.5


def test_checkpoint_keep_n_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, t, blocking=True)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_000000003", "step_000000004"]
    assert ck.latest_step() == 4


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros(3)}, blocking=True)
    with pytest.raises(AssertionError):
        ck.restore(1, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


# ---------------- data ----------------

def test_data_deterministic_and_host_sharded():
    src = SyntheticLM(vocab=64, seed=0)
    a = src.batch(4, 16, step=3, host=0, n_hosts=2)
    b = src.batch(4, 16, step=3, host=0, n_hosts=2)
    c = src.batch(4, 16, step=3, host=1, n_hosts=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["labels"].shape == (4, 16)
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_pipeline_prefetch_order():
    src = SyntheticLM(vocab=32, seed=0)
    pipe = DataPipeline(src, global_batch=4, seq=8, start_step=7)
    try:
        b0 = next(pipe)
        b1 = next(pipe)
        assert b0["step"] == 7 and b1["step"] == 8
    finally:
        pipe.close()


def test_data_has_learnable_structure():
    """Bigram chain: conditional entropy << unigram entropy."""
    src = SyntheticLM(vocab=64, seed=0)
    cond_ent = float(-(src.trans * np.log(src.trans + 1e-12)).sum(-1).mean())
    assert cond_ent < 0.7 * src.unigram_entropy()


# ---------------- runtime ----------------

def test_watchdog_straggler_detection():
    events = []
    wd = Watchdog(straggler_factor=2.0, hang_timeout=60,
                  on_straggler=events.append)
    try:
        for _ in range(5):
            with wd.step():
                time.sleep(0.01)
        with wd.step():
            time.sleep(0.08)
        assert len(events) == 1 and events[0]["step_time"] > 0.05
    finally:
        wd.close()


def test_watchdog_hang_raises():
    wd = Watchdog(hang_timeout=0.2)
    try:
        with wd.step():
            time.sleep(0.01)
        wd._armed.set()
        wd._last_done = time.monotonic() - 1.0
        time.sleep(0.3)
        with pytest.raises(StepHang):
            wd.check_hang()
            with wd.step():
                pass
    finally:
        wd.close()


def test_failover_restarts_and_gives_up():
    inj = FailureInjector(fail_at=(0, 1))
    calls = {"n": 0}

    def train(state):
        inj.maybe_fail(calls["n"])
        calls["n"] += 1
        return "done"

    out, restarts = run_with_failover(
        lambda s: (inj.maybe_fail(0), inj.maybe_fail(1), "done")[-1],
        restore_fn=lambda: None, max_restarts=3)
    assert out == "done" and restarts == 2

    inj2 = FailureInjector(fail_at=(0,))

    def always_fail(state):
        raise SimulatedHardwareFailure("boom")

    with pytest.raises(SimulatedHardwareFailure):
        run_with_failover(always_fail, restore_fn=lambda: None,
                          max_restarts=1)
