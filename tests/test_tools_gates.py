"""CI artifact-gate unit tests (ISSUE 6): the serve/chaos_* derived-field
schema in tools/check_artifacts.py — a chaos row that loses its tok_s /
overhead ratio / drill counters must fail the gate, not silently blind
the bench-regression baseline."""
import importlib.util
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _gate():
    spec = importlib.util.spec_from_file_location(
        "check_artifacts",
        os.path.join(REPO, "tools", "check_artifacts.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _bench(tmp_path, rows):
    p = tmp_path / "bench.json"
    p.write_text(json.dumps({"runs": [{
        "rev": "abcdef1", "ts": "2026-08-08T00:00:00", "rows": rows}]}))
    return str(p)


GOOD = [
    {"name": "serve/chaos_plain/x/R4", "us": 10.0,
     "derived": "tok_s=96.2;useful_tokens=12"},
    {"name": "serve/chaos_monitored/x/R4", "us": 11.0,
     "derived": "tok_s=94.0;overhead_vs_plain=1.023;probes=2"},
    {"name": "serve/chaos_drill/x/R6", "us": 12.0,
     "derived": ("requests=6;clean=1;replays=1;probe_trips=2;"
                 "escalations=2;deadline_cancelled=1;corrupted=2")},
    # non-chaos rows carry no typed contract
    {"name": "serve/kv_float/x", "us": 13.0, "derived": "anything"},
]


def test_chaos_rows_pass(tmp_path):
    assert _gate().check_bench(_bench(tmp_path, GOOD)) == []


def test_chaos_plain_requires_tok_s(tmp_path):
    rows = [dict(GOOD[0], derived="useful_tokens=12")]
    errs = _gate().check_bench(_bench(tmp_path, rows))
    assert len(errs) == 1 and "tok_s" in errs[0]


def test_chaos_monitored_requires_overhead_ratio(tmp_path):
    for bad in ("tok_s=94.0",                       # missing
                "tok_s=94.0;overhead_vs_plain=nan",  # non-finite
                "tok_s=94.0;overhead_vs_plain=-1"):  # non-positive
        rows = [dict(GOOD[1], derived=bad)]
        errs = _gate().check_bench(_bench(tmp_path, rows))
        assert len(errs) == 1 and "overhead_vs_plain" in errs[0], (bad, errs)


def test_chaos_drill_requires_counters(tmp_path):
    rows = [dict(GOOD[2], derived="requests=6;replays=oops")]
    errs = _gate().check_bench(_bench(tmp_path, rows))
    missing = ("replays", "probe_trips", "escalations",
               "deadline_cancelled")
    assert len(errs) == len(missing), errs
    for key in missing:
        assert any(key in e for e in errs), (key, errs)


def test_checked_in_trajectory_passes():
    mod = _gate()
    assert mod.check_bench(os.path.join(REPO, "BENCH_kernels.json")) == []
