"""CI artifact-gate unit tests (ISSUE 6): the serve/chaos_* derived-field
schema in tools/check_artifacts.py — a chaos row that loses its tok_s /
overhead ratio / drill counters must fail the gate, not silently blind
the bench-regression baseline.  ISSUE 10 adds the serve/prefix_* schema
and the docs link/anchor gate (tools/check_docs.py)."""
import importlib.util
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(REPO, "tools", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _gate():
    return _load_tool("check_artifacts")


def _bench(tmp_path, rows):
    p = tmp_path / "bench.json"
    p.write_text(json.dumps({"runs": [{
        "rev": "abcdef1", "ts": "2026-08-08T00:00:00", "rows": rows}]}))
    return str(p)


GOOD = [
    {"name": "serve/chaos_plain/x/R4", "us": 10.0,
     "derived": "tok_s=96.2;useful_tokens=12"},
    {"name": "serve/chaos_monitored/x/R4", "us": 11.0,
     "derived": "tok_s=94.0;overhead_vs_plain=1.023;probes=2"},
    {"name": "serve/chaos_drill/x/R6", "us": 12.0,
     "derived": ("requests=6;clean=1;replays=1;probe_trips=2;"
                 "escalations=2;deadline_cancelled=1;corrupted=2")},
    # non-chaos rows carry no typed contract
    {"name": "serve/kv_float/x", "us": 13.0, "derived": "anything"},
]


def test_chaos_rows_pass(tmp_path):
    assert _gate().check_bench(_bench(tmp_path, GOOD)) == []


def test_chaos_plain_requires_tok_s(tmp_path):
    rows = [dict(GOOD[0], derived="useful_tokens=12")]
    errs = _gate().check_bench(_bench(tmp_path, rows))
    assert len(errs) == 1 and "tok_s" in errs[0]


def test_chaos_monitored_requires_overhead_ratio(tmp_path):
    for bad in ("tok_s=94.0",                       # missing
                "tok_s=94.0;overhead_vs_plain=nan",  # non-finite
                "tok_s=94.0;overhead_vs_plain=-1"):  # non-positive
        rows = [dict(GOOD[1], derived=bad)]
        errs = _gate().check_bench(_bench(tmp_path, rows))
        assert len(errs) == 1 and "overhead_vs_plain" in errs[0], (bad, errs)


def test_chaos_drill_requires_counters(tmp_path):
    rows = [dict(GOOD[2], derived="requests=6;replays=oops")]
    errs = _gate().check_bench(_bench(tmp_path, rows))
    missing = ("replays", "probe_trips", "escalations",
               "deadline_cancelled")
    assert len(errs) == len(missing), errs
    for key in missing:
        assert any(key in e for e in errs), (key, errs)


def test_checked_in_trajectory_passes():
    mod = _gate()
    assert mod.check_bench(os.path.join(REPO, "BENCH_kernels.json")) == []


PREFIX_HIT = {
    "name": "serve/prefix_hit90/x/R6", "us": 14.0,
    "derived": ("tok_s=1234.5;hit_rate_target=0.90;hits=4;lookups=6;"
                "hit_tokens=48;pages_deduped=12;prefill_removed_frac=0.500;"
                "admit_us_hit=100.0;admit_us_cold=274.0;"
                "admit_latency_ratio=0.365;speedup_vs_cold=1.20x;"
                "pages_live=0;pages_retained=3;pages_shares=12")}
PREFIX_ROUTER = {
    "name": "serve/prefix_router/x/R24", "us": 15.0,
    "derived": ("p50_ms=5.0;p99_ms=20.0;tok_s=100.0;refusal_rate=0.1;"
                "requests=20;ok=15;deadline=1;refused=4;cancelled=0;"
                "degraded=0;replays=0;quarantined=0;pages_live=0;"
                "pages_high_water=8;pages_refusals=2;hits=11;lookups=20;"
                "hit_tokens=48;pages_deduped=12;prefill_removed_frac=0.369;"
                "pages_retained=6;bitwise_ok=19")}


def test_prefix_rows_pass(tmp_path):
    assert _gate().check_bench(
        _bench(tmp_path, [PREFIX_HIT, PREFIX_ROUTER])) == []


def test_prefix_hit_row_requires_ledger_and_drained_pool(tmp_path):
    for field, needle in (("hits=4", "hits"),
                          ("prefill_removed_frac=0.500",
                           "prefill_removed_frac"),
                          ("admit_latency_ratio=0.365",
                           "admit_latency_ratio"),
                          ("pages_live=0", "pages_live")):
        bad = PREFIX_HIT["derived"].replace(f"{field};", "")\
                                   .replace(f";{field}", "")
        errs = _gate().check_bench(
            _bench(tmp_path, [dict(PREFIX_HIT, derived=bad)]))
        assert errs and any(needle in e for e in errs), (field, errs)
    leak = PREFIX_HIT["derived"].replace("pages_live=0", "pages_live=2")
    errs = _gate().check_bench(
        _bench(tmp_path, [dict(PREFIX_HIT, derived=leak)]))
    assert errs and "page leak" in errs[0]


def test_prefix_router_row_rides_router_schema(tmp_path):
    # drop bitwise_ok -> prefix error; break the status sum -> router error
    bad1 = PREFIX_ROUTER["derived"].replace(";bitwise_ok=19", "")
    bad2 = PREFIX_ROUTER["derived"].replace("ok=15", "ok=14")
    errs1 = _gate().check_bench(
        _bench(tmp_path, [dict(PREFIX_ROUTER, derived=bad1)]))
    errs2 = _gate().check_bench(
        _bench(tmp_path, [dict(PREFIX_ROUTER, derived=bad2)]))
    assert errs1 and "bitwise_ok" in errs1[0]
    assert errs2 and any("sum" in e for e in errs2)


def test_check_docs_catches_broken_links_and_anchors(tmp_path):
    docs = _load_tool("check_docs")
    a = tmp_path / "a.md"
    a.write_text("# Top Title\n\n## Sub `sec`\n\n"
                 "[ok](b.md)\n[ok2](b.md#real-heading)\n"
                 "[self](#sub-sec)\n"
                 "[bad](missing.md)\n[badfrag](b.md#nope)\n"
                 "[ext](https://example.invalid/x#y)\n"
                 "```\n[fence](nope.md)\n```\n"
                 "`[span](nope2.md)`\n")
    (tmp_path / "b.md").write_text("# Real heading\n[up](a.md)\n")
    errs = docs.check_file(str(a), {})
    errs += docs.check_file(str(tmp_path / "b.md"), {})
    assert len(errs) == 2, errs
    assert "missing.md" in errs[0] and "#nope" in errs[1]


def test_check_docs_passes_on_repo_docs():
    docs = _load_tool("check_docs")
    assert docs.main([]) == 0
