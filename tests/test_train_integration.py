"""End-to-end integration: training loss decreases, checkpoint-restart is
bitwise-consistent, failover mid-run recovers, serve decodes."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch.train import TrainLoop


def _loop(tmp, **kw):
    cfg = ARCHS["olmo-1b"].reduced()
    defaults = dict(steps=30, batch=8, seq=32, ckpt_dir=tmp, lr=1e-3,
                    ckpt_every=10, log=lambda *a: None)
    defaults.update(kw)
    return TrainLoop(cfg, **defaults)


def test_loss_decreases(tmp_path):
    loop = _loop(str(tmp_path))
    loop.run()
    losses = [h["loss"] for h in loop.history]
    assert np.mean(losses[-5:]) < 0.6 * np.mean(losses[:5])


def test_failover_resumes_bitwise(tmp_path):
    """A crash at step 25 restarts from the step-20 checkpoint and replays
    steps 20-24 with identical losses (deterministic data + state)."""
    loop = _loop(str(tmp_path), fail_at=(25,))
    loop.run()
    by_step = {}
    replays = []
    for h in loop.history:
        if h["step"] in by_step:
            replays.append(h["step"])
            assert h["loss"] == pytest.approx(by_step[h["step"]], rel=1e-6)
        by_step[h["step"]] = h["loss"]
    assert 20 in replays  # the replay actually happened


def test_resume_from_checkpoint_continues(tmp_path):
    loop1 = _loop(str(tmp_path), steps=20)
    loop1.run()
    loop2 = _loop(str(tmp_path), steps=30)
    state = loop2.restore_or_init()
    assert state["step"] == 20


def test_serve_decode_runs():
    from repro.launch.serve import serve_batch
    from repro.models import get_model
    cfg = dataclasses.replace(ARCHS["olmo-1b"].reduced(), remat=False)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8),
                                                dtype=np.int32)
    toks, _ = serve_batch(cfg, params, prompts, 4)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < cfg.vocab_padded).all()
