"""CI bench-regression smoke: paged-attention kernel vs jnp gather
(ISSUE 5 satellite).

Runs the serve-bench paged-KV smoke serving configuration twice — once
with the fused Pallas paged-attention read path
(kernels/paged_attention.py), once with the jnp gather reference — and
asserts the matched-prefix logit RMSE between the two paths stays below
the checked-in threshold (tools/ci_thresholds.json), plus full token
agreement.  Kernel drift (a masking bug, a softmax-order change, a tile
regression) is caught here, in CI, instead of surfacing later as a
mysteriously-degraded BENCH row.

The comparison metric is launch/serve.py ``logit_drift_rmse`` — the same
teacher-matched-prefix RMSE serve_bench and the acceptance tests use, so
the threshold means the same thing everywhere.  Both paths run the same
f32 page walk in the same order, so the healthy RMSE is float-epsilon
noise (~1e-8 — XLA's einsum layout vs the kernel's dot_general round
differently); the 1e-5 threshold is the acceptance-criterion bound, two
decades above it.

The two paths are selected via ``serve_batch(paged_attn=...)`` — the
read-path pin is part of the jitted builder's cache key, so each run
traces its own executable.

Usage:  PYTHONPATH=src python -m tools.bench_regression [--smoke]
(--smoke shortens the trace; CI passes it.)  Exit 0 on pass, 1 on drift.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
THRESHOLDS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "ci_thresholds.json")


def _serve_both_paths(smoke: bool):
    """(tokens, trace) for the kernel and gather read paths on the
    serve-bench paged-KV smoke shape (float model — the read path is the
    only thing under test)."""
    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.launch.serve import serve_batch
    from repro.models import get_model

    cfg = get_arch("qwen3-0.6b").reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, prompt_len = 4, 16
    n_tokens = 16 if smoke else 48
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (B, prompt_len), dtype=np.int32)

    return {path: serve_batch(cfg, params, prompts, n_tokens,
                              trace_logits=True, prepare=False,
                              kv="int8", page_size=4, paged_attn=path)
            for path in ("kernel", "jnp")}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace (the CI leg)")
    args = ap.parse_args(argv)

    import numpy as np

    from repro.launch.serve import _agreement, logit_drift_rmse

    with open(THRESHOLDS) as f:
        th = json.load(f)
    out = _serve_both_paths(args.smoke)
    tk, lk = out["kernel"]
    tj, lj = out["jnp"]
    rmse = logit_drift_rmse(tj, tk, lj, lk)
    agree = _agreement(np.asarray(tk), np.asarray(tj), None)
    bound = th["paged_kernel_vs_gather_logit_rmse"]
    min_agree = th["paged_kernel_vs_gather_token_agreement"]
    print(f"paged kernel vs jnp gather: matched-prefix logit RMSE "
          f"{rmse:.3e} (threshold {bound:.0e}), token agreement "
          f"{agree:.4f} (threshold {min_agree})")
    ok = rmse <= bound and agree >= min_agree
    if not ok:
        print("BENCH REGRESSION: paged-attention kernel drifted from the "
              "jnp gather reference", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
